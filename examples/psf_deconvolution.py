"""Use case (a): space-variant deconvolution of galaxy survey images.

Simulates a Euclid-like stack (stamps + spatially varying anisotropic
PSFs + noise), runs the distributed Algorithm 1 with both regularisers
through the declarative ``solve()`` entry point (DESIGN.md §14), and
reports recovery quality + convergence — the paper's Figs. 4/7 in
miniature.

The solver runs the optimized configuration by default (DESIGN.md §16):
the paired-FFT convolution engine on the derived fast pad (81-grid for
41-px stamps instead of the historical 96), the fused Condat
elementwise kernels, chunked on-device iteration, and — for the sparse
mode — ``cost_every="chunk"``: the scan body is objective-free and the
cost is a weighted reduction of the carried starlet stack evaluated
once per dispatched chunk, exactly the granularity at which convergence
is checked anyway.  ``--per-iter-cost`` switches the observability grid
back to every iteration.

    PYTHONPATH=src python examples/psf_deconvolution.py [--n 512]

Surviving preemption (DESIGN.md §18).  On a preemptible TPU slice, add
checkpointing + supervised execution and rerun the same command after
an eviction — the trajectory continues exactly where it stopped, and
transient in-run failures (worker loss, NaN divergence, torn
checkpoint writes) are retried / rolled back instead of killing the
run::

    from repro.resilience import ResilienceConfig

    sol = solve(DeconvolutionProblem(cfg), data.Y, data.psfs,
                checkpoint_dir="ckpt/psf", checkpoint_every=24,
                resume=True,                # picks the newest VALID step
                resilience=ResilienceConfig(ring=2, max_retries=3))
    print(sol.recovery)      # retries / rollbacks / restores ledger

``resume=True`` falls back past a corrupt newest checkpoint (torn
write during the eviction) with a warning; rollback uses the in-memory
snapshot ring first and the checkpoint directory once the ring is dry.
Fault plans for drills come from the ``REPRO_CHAOS`` env var, e.g.
``REPRO_CHAOS="dispatch@1;carry_nan@2;seed=7"``.

Populations, not stacks (DESIGN.md §19).  Survey traffic is thousands
of small *independent* stamp groups.  Looping ``solve()`` pays trace +
compile + dispatch overhead per group; ``solve_many`` pad-and-buckets
the population by shape into a few stacked programs, runs every bucket
chunked with per-lane masked early exit, and returns one ``Solution``
per instance with its own trajectory (parity with the single solve at
rtol 1e-4 — bit-exact for this workload)::

    from repro.core.problem import solve_many

    instances = [(Y0, psfs0), (Y1, psfs1), ...]   # mixed shapes OK
    sols = solve_many(DeconvolutionProblem(cfg), instances,
                      max_iter=200, tol=1e-5, chunk=12,
                      checkpoint_dir="ckpt/many",   # per-bucket dirs
                      resilience=ResilienceConfig())
    print([s.log.iters_run for s in sols])  # converged lanes run fewer

``benchmarks/bench_many.py`` gates the ≥3x aggregate instances/sec this
buys on 64 mixed-shape stamps (``BENCH_many.json``).
"""
import argparse

import jax
import jax.numpy as jnp

from repro.core.problem import solve
from repro.imaging import psf as psf_op
from repro.imaging.condat import SolverConfig
from repro.imaging.deconvolve import DeconvolutionProblem
from repro.launch.mesh import smallest_mesh


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=256)
    ap.add_argument("--iters", type=int, default=60)
    ap.add_argument("--chunk", type=int, default=12)
    ap.add_argument("--per-iter-cost", action="store_true",
                    help="evaluate the objective every iteration "
                         "instead of once per chunk")
    args = ap.parse_args()

    data = psf_op.simulate(args.n, jax.random.PRNGKey(42))
    mse = lambda a, b: float(jnp.mean((a - b) ** 2))
    print(f"simulated {args.n} stamps; FFT grid "
          f"{psf_op.pad_for(data.Y.shape[-1])}^2 "
          f"(seed hardcoded 96^2); observation MSE vs truth: "
          f"{mse(data.Y, data.X_true):.3e}")

    mesh = smallest_mesh()
    for mode in ("sparse", "lowrank"):
        cfg = SolverConfig(mode=mode, n_scales=4, lam=0.05, rank=16)
        # the sparse objective off the carried starlet stack is pure
        # reduction -> per-chunk observability is effectively free; the
        # low-rank objective needs an SVD, so it stays on the skipping
        # grid instead
        cost_every = (1 if args.per_iter_cost
                      else "chunk" if mode == "sparse" else args.chunk)
        sol = solve(DeconvolutionProblem(cfg, sigma_noise=data.sigma),
                    data.Y, data.psfs, mesh=mesh,
                    max_iter=args.iters, tol=1e-5, chunk=args.chunk,
                    cost_every=cost_every)
        log = sol.log
        # per-chunk observability seeds the trace with +inf until the
        # first evaluation — report from the first evaluated objective
        c0 = next(c for c in log.costs if jnp.isfinite(c))
        print(f"[{mode:7s}] cost_every={cost_every!r:8} "
              f"cost {c0:.3f} -> {log.costs[-1]:.3f} "
              f"in {len(log.costs)} iters "
              f"({log.total_seconds:.1f}s, "
              f"converged_at={log.converged_at}); "
              f"deconvolved MSE: {mse(jnp.asarray(sol.x), data.X_true):.3e}")


if __name__ == "__main__":
    main()
