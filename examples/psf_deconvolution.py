"""Use case (a): space-variant deconvolution of galaxy survey images.

Simulates a Euclid-like stack (stamps + spatially varying anisotropic
PSFs + noise), runs the distributed Algorithm 1 with both regularisers
through the declarative ``solve()`` entry point (DESIGN.md §14), and
reports recovery quality + convergence — the paper's Figs. 4/7 in
miniature.

    PYTHONPATH=src python examples/psf_deconvolution.py [--n 512]
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.problem import solve
from repro.imaging import psf as psf_op
from repro.imaging.condat import SolverConfig
from repro.imaging.deconvolve import DeconvolutionProblem
from repro.launch.mesh import smallest_mesh


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=256)
    ap.add_argument("--iters", type=int, default=60)
    args = ap.parse_args()

    data = psf_op.simulate(args.n, jax.random.PRNGKey(42))
    mse = lambda a, b: float(jnp.mean((a - b) ** 2))
    print(f"simulated {args.n} stamps; observation MSE vs truth: "
          f"{mse(data.Y, data.X_true):.3e}")

    mesh = smallest_mesh()
    for mode in ("sparse", "lowrank"):
        cfg = SolverConfig(mode=mode, n_scales=4, lam=0.05, rank=16)
        sol = solve(DeconvolutionProblem(cfg, sigma_noise=data.sigma),
                    data.Y, data.psfs, mesh=mesh,
                    max_iter=args.iters, tol=1e-5)
        log = sol.log
        print(f"[{mode:7s}] cost {log.costs[0]:.3f} -> {log.costs[-1]:.3f} "
              f"in {len(log.costs)} iters "
              f"({log.total_seconds:.1f}s, "
              f"converged_at={log.converged_at}); "
              f"deconvolved MSE: {mse(jnp.asarray(sol.x), data.X_true):.3e}")


if __name__ == "__main__":
    main()
