"""Solve-as-a-service quickstart: run the §20 serving stack in-process.

The paper's architecture ultimately *serves* imaging workloads to many
clients at once.  ``repro.serve`` is that frontend: an asyncio core
that admits requests, coalesces compatible ones (same workload, config
and run options; shapes grouped by the §19 planner) into one
``solve_many`` dispatch per micro-batch, plus a stdlib-only
JSON-over-HTTP transport.

This example starts the HTTP server on a loopback port, fires a small
mixed-shape population at it from ``ServeClient``, streams one
request's per-chunk progress, and prints the service metrics —
including batch occupancy, the signal that coalescing actually
happened.

    PYTHONPATH=src python examples/serve_quickstart.py

Resilient requests ride the same wire: pass ``options={"resilience":
{"max_retries": 2}}`` (and, for drills, ``chaos="dispatch@2"`` — chaos
requests always dispatch solo) and the JSON result carries the
RecoveryReport ledger.

Ops runbook (§21) — what to do when serving misbehaves:

- **Is it alive? Is it ready?**  ``GET /v1/healthz`` is liveness: it
  stays ``ok`` while draining and only flips after a crash.  ``GET
  /v1/readyz`` is readiness: 503 with a detail dict while draining,
  crashed, queue-full, or any workload circuit breaker is open — point
  load balancers here, not at healthz.
- **A workload keeps failing.**  ``/v1/metrics`` shows per-workload
  breaker states (``breakers``) and the ``shed`` counter.  An open
  breaker rejects that workload's submits with ``retriable: true``
  (clients should back off and resubmit); after the cooldown one probe
  request decides whether it closes again.  Other workloads are
  unaffected.
- **A whole coalesced batch failed.**  With ``quarantine`` on
  (default) the service re-dispatches each member solo — look for the
  ``quarantined`` counter and the per-request ``recovery`` report in
  the failed request's result: only the genuinely poisoned request
  fails.
- **Requests hang.**  Set ``dispatch_timeout_s``; the watchdog fails
  hung dispatches (``hung`` counter, ``"hung dispatch"`` error) and
  feeds the breaker.
- **The process died.**  Run with ``journal_dir=`` (and, for long
  solves, ``checkpoint_dir=`` + ``checkpoint_every=``).  Start a new
  service over the SAME ``journal_dir``: every admitted-but-unfinished
  request is re-admitted under its original id (``replayed: true`` in
  its status), journaled buckets re-dispatch together and resume from
  their per-bucket checkpoints.  Clients keep polling the same request
  ids — ``restart_and_replay()`` below drills exactly this.
"""
import tempfile

import jax
import numpy as np

from repro.imaging import psf as psf_op
from repro.serve import ServeConfig
from repro.serve.client import ServeClient
from repro.serve.server import serve_http

CFG = dict(mode="sparse", max_iter=12, tol=0.0, n_scales=2)
OPTIONS = dict(chunk=4, cost_every=1)


def main():
    # 0.2 s coalescing window, up to 8 requests per dispatched bucket
    with serve_http(ServeConfig(batch_window_s=0.2, max_batch=8)) as h:
        print(f"serving on {h.url}")
        client = ServeClient(h.url, timeout=600)

        # a mixed population: two stamp shapes -> two coalesced
        # buckets.  Simulate up front: the submits must land within one
        # coalescing window of each other for the scheduler to group
        # them (real clients arrive concurrently; this loop is serial).
        population = [
            psf_op.simulate(n, jax.random.PRNGKey(i), stamp=stamp)
            for i, (n, stamp) in enumerate([(3, 16), (5, 16),
                                            (4, 20), (6, 20)])]
        ids = [client.submit(
            "deconvolve", (np.asarray(d.Y), np.asarray(d.psfs)),
            cfg=CFG, options=OPTIONS) for d in population]
        print(f"submitted {len(ids)} requests")

        # stream one request's chunk-boundary progress while it runs
        for event in client.events(ids[0]):
            if event.get("kind") == "chunk":
                print(f"  [{ids[0]}] iter {event['done']:3d}  "
                      f"cost={event['cost']:.5f}")
            else:
                print(f"  [{ids[0]}] {event['status']}")

        for rid in ids:
            res = client.result(rid, timeout=600)
            print(f"{rid}: {res['status']}  batch={res['batch_size']}  "
                  f"bucket={res['bucket_key']}  "
                  f"final_cost={res['costs'][-1]:.5f}  "
                  f"p99_chunk={res['time_percentiles_s']['p99']:.4f}s")

        m = client.metrics()
        occ = m["batch_occupancy"]
        print(f"served {m['counters']['completed']} requests, "
              f"occupancy mean={occ['mean']:.1f} max={occ['max']}, "
              f"p50 latency={m['latency_s'].get('p50', 0):.2f}s")

        # readiness flips during drain; liveness does not (§21 runbook)
        print(f"readyz before drain: {client.ready()['ready']}")
        client.drain()
        print(f"healthz after drain: ok={client.health()['ok']} "
              f"readyz: {client.ready()['ready']}")


def restart_and_replay():
    """The §21 restart drill, scripted: a journaled service crashes
    with an admitted request it never ran; a second service started
    over the same ``journal_dir`` owes it, replays it, and finishes it
    under the original request id."""
    journal_dir = tempfile.mkdtemp(prefix="serve-journal-")
    d = psf_op.simulate(3, jax.random.PRNGKey(0), stamp=16)
    inputs = (np.asarray(d.Y), np.asarray(d.psfs))

    # --- incident: the service journals the admit, then "crashes"
    # before the scheduler ever sees the request (serve_admit_drop is
    # the §21 chaos point for exactly that window)
    cfg = ServeConfig(batch_window_s=0.1, max_batch=8,
                      journal_dir=journal_dir,
                      chaos_spec="serve_admit_drop@0")
    with serve_http(cfg) as h:
        client = ServeClient(h.url, timeout=600)
        rid = client.submit("deconvolve", inputs, cfg=CFG,
                            options=OPTIONS)
        print(f"[incident] admitted {rid}, then the process dies")
        h.runner.call(h.runner.service.abandon())
        print(f"[incident] healthz now ok="
              f"{client.health()['ok']}")

    # --- recovery: same journal_dir, fresh process — the request is
    # re-admitted under its original id and completes
    with serve_http(ServeConfig(batch_window_s=0.1, max_batch=8,
                                journal_dir=journal_dir)) as h:
        client = ServeClient(h.url, timeout=600)
        res = client.result(rid, timeout=600)
        print(f"[recovery] {rid}: {res['status']} "
              f"(replayed={res['replayed']}) "
              f"final_cost={res['costs'][-1]:.5f}")
        assert res["status"] == "done" and res["replayed"]
        client.drain()


if __name__ == "__main__":
    main()
    restart_and_replay()
