"""Solve-as-a-service quickstart: run the §20 serving stack in-process.

The paper's architecture ultimately *serves* imaging workloads to many
clients at once.  ``repro.serve`` is that frontend: an asyncio core
that admits requests, coalesces compatible ones (same workload, config
and run options; shapes grouped by the §19 planner) into one
``solve_many`` dispatch per micro-batch, plus a stdlib-only
JSON-over-HTTP transport.

This example starts the HTTP server on a loopback port, fires a small
mixed-shape population at it from ``ServeClient``, streams one
request's per-chunk progress, and prints the service metrics —
including batch occupancy, the signal that coalescing actually
happened.

    PYTHONPATH=src python examples/serve_quickstart.py

Resilient requests ride the same wire: pass ``options={"resilience":
{"max_retries": 2}}`` (and, for drills, ``chaos="dispatch@2"`` — chaos
requests always dispatch solo) and the JSON result carries the
RecoveryReport ledger.
"""
import jax
import numpy as np

from repro.imaging import psf as psf_op
from repro.serve import ServeConfig
from repro.serve.client import ServeClient
from repro.serve.server import serve_http

CFG = dict(mode="sparse", max_iter=12, tol=0.0, n_scales=2)
OPTIONS = dict(chunk=4, cost_every=1)


def main():
    # 0.2 s coalescing window, up to 8 requests per dispatched bucket
    with serve_http(ServeConfig(batch_window_s=0.2, max_batch=8)) as h:
        print(f"serving on {h.url}")
        client = ServeClient(h.url, timeout=600)

        # a mixed population: two stamp shapes -> two coalesced
        # buckets.  Simulate up front: the submits must land within one
        # coalescing window of each other for the scheduler to group
        # them (real clients arrive concurrently; this loop is serial).
        population = [
            psf_op.simulate(n, jax.random.PRNGKey(i), stamp=stamp)
            for i, (n, stamp) in enumerate([(3, 16), (5, 16),
                                            (4, 20), (6, 20)])]
        ids = [client.submit(
            "deconvolve", (np.asarray(d.Y), np.asarray(d.psfs)),
            cfg=CFG, options=OPTIONS) for d in population]
        print(f"submitted {len(ids)} requests")

        # stream one request's chunk-boundary progress while it runs
        for event in client.events(ids[0]):
            if event.get("kind") == "chunk":
                print(f"  [{ids[0]}] iter {event['done']:3d}  "
                      f"cost={event['cost']:.5f}")
            else:
                print(f"  [{ids[0]}] {event['status']}")

        for rid in ids:
            res = client.result(rid, timeout=600)
            print(f"{rid}: {res['status']}  batch={res['batch_size']}  "
                  f"bucket={res['bucket_key']}  "
                  f"final_cost={res['costs'][-1]:.5f}  "
                  f"p99_chunk={res['time_percentiles_s']['p99']:.4f}s")

        m = client.metrics()
        occ = m["batch_occupancy"]
        print(f"served {m['counters']['completed']} requests, "
              f"occupancy mean={occ['mean']:.1f} max={occ['max']}, "
              f"p50 latency={m['latency_s'].get('p50', 0):.2f}s")
        client.drain()


if __name__ == "__main__":
    main()
