"""End-to-end LM training driver (deliverable b): train a ~100M-param
qwen3-family model for a few hundred steps on the synthetic token stream,
with checkpointing and restart.

On this CPU container the default is a width-reduced ~10M config so the
run finishes in minutes; pass --dmodel 768 --layers 12 for the true ~100M
class on real hardware (the code path is identical — config only).

    PYTHONPATH=src python examples/train_lm.py --steps 300
"""
import argparse
import dataclasses

import numpy as np

from repro.configs import get_config, reduced
from repro.configs.base import register
from repro.launch.mesh import smallest_mesh
from repro.launch.train import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--dmodel", type=int, default=128)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    base = get_config("qwen3-1.7b")
    cfg = dataclasses.replace(
        reduced(base, n_layers=args.layers, d_model=args.dmodel,
                vocab=2048, d_ff=args.dmodel * 4,
                n_heads=max(4, args.dmodel // 64)),
        name="qwen3-example")
    register(cfg)
    print(f"model: {cfg.n_layers}L d={cfg.d_model} "
          f"~{cfg.param_count()/1e6:.1f}M params")

    _, _, losses = train(
        "qwen3-example", steps=args.steps, batch=args.batch, seq=args.seq,
        use_reduced=False, ckpt_dir=args.ckpt_dir, ckpt_every=100,
        lr=3e-3, mesh=smallest_mesh(), log_every=25)
    tail = float(np.mean(losses[-10:]))
    head = float(np.mean(losses[:10]))
    print(f"loss: {head:.3f} -> {tail:.3f} "
          f"(improved {head - tail:.3f} nats)")
    # a few hundred steps drops well over 0.3 nats; scale the bar for
    # shorter smoke runs
    bar = 0.3 if args.steps >= 200 else 0.02
    assert tail < head - bar, "model failed to learn"


if __name__ == "__main__":
    main()
