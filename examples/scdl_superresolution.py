"""Use case (b): super-resolution via sparse coupled dictionary training.

Trains coupled HR/LR dictionaries with the distributed Algorithm 2
through the declarative ``solve()`` entry point (DESIGN.md §14), then
super-resolves held-out LR patches: sparse-code them against X_l and
reconstruct with X_h — the paper's remote-sensing pipeline end to end.

    PYTHONPATH=src python examples/scdl_superresolution.py [--gs]
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.problem import solve
from repro.data.synthetic import coupled_patches
from repro.imaging.scdl import SCDLConfig
from repro.launch.mesh import smallest_mesh


def sparse_code(S_l, X_l, lam=0.05, iters=100):
    """ISTA on the LR dictionary (inference-time sparse coding)."""
    L = float(jnp.linalg.norm(X_l, 2) ** 2) * 1.05
    W = jnp.zeros((X_l.shape[1], S_l.shape[1]))

    def body(W, _):
        G = X_l.T @ (X_l @ W - S_l)
        W = W - G / L
        W = jnp.sign(W) * jnp.maximum(jnp.abs(W) - lam / L, 0)
        return W, None

    W, _ = jax.lax.scan(body, W, None, length=iters)
    return W


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--gs", action="store_true",
                    help="grayscale shape (P=289,M=81) instead of HS")
    ap.add_argument("--atoms", type=int, default=128)
    ap.add_argument("--iters", type=int, default=40)
    ap.add_argument("--cost-every", type=int, default=4,
                    help="evaluate the NRMSE objective every k-th "
                         "iteration only (the iterates are unaffected; "
                         "the off-grid log carries the last value)")
    ap.add_argument("--patches", type=int, default=8192,
                    help="training patch count (CI smoke uses a small "
                         "value)")
    args = ap.parse_args()

    p_dim, m_dim = (289, 81) if args.gs else (25, 9)
    K = args.patches
    S_h, S_l = coupled_patches(K + 512, p_dim, m_dim, args.atoms, seed=1)
    train_h, test_h = S_h[:, :K], S_h[:, K:]
    train_l, test_l = S_l[:, :K], S_l[:, K:]

    cfg = SCDLConfig(n_atoms=args.atoms, max_iter=args.iters)
    sol = solve("scdl", train_h, train_l, cfg=cfg, mesh=smallest_mesh(),
                cost_every=args.cost_every)
    Xh, Xl = sol.x
    log = sol.log
    print(f"trained {'GS' if args.gs else 'HS'} dictionaries "
          f"(A={args.atoms}): NRMSE {log.costs[0]:.3f} -> "
          f"{log.costs[-1]:.3f} over {len(log.costs)} iters "
          f"({log.total_seconds:.1f}s, objective every "
          f"{args.cost_every} iters)")

    # super-resolve: code LR patches, decode with the HR dictionary
    W = sparse_code(test_l, jnp.asarray(Xl))
    sr = jnp.asarray(Xh) @ W
    base = jnp.sqrt(jnp.mean(test_h ** 2))
    nrmse = float(jnp.sqrt(jnp.mean((sr - test_h) ** 2)) / base)
    print(f"held-out super-resolution NRMSE: {nrmse:.3f} "
          f"(vs {1.0:.1f} for zero prediction)")
    assert nrmse < 0.9


if __name__ == "__main__":
    main()
