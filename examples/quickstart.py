"""Quickstart: the paper's bundled distributed learning in ~40 lines.

Builds a bundle of co-partitioned arrays, runs an iterative map/reduce
learning loop (ridge regression via distributed gradient descent), and
shows the three core pieces: Bundle.create / bundle_map / map-reduce via
the IterativeDriver.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.bundle import Bundle, gather
from repro.core.driver import IterativeDriver
from repro.launch.mesh import smallest_mesh


def main():
    key = jax.random.PRNGKey(0)
    n, d = 4096, 32
    w_true = jax.random.normal(jax.random.fold_in(key, 1), (d,))
    X = jax.random.normal(jax.random.fold_in(key, 2), (n, d))
    y = X @ w_true + 0.01 * jax.random.normal(jax.random.fold_in(key, 3),
                                              (n,))

    # 1. bundle the co-partitioned dataset (the paper's RDD Bundle);
    #    the model w rides in the replicated side (broadcast variable)
    bundle = Bundle.create(
        {"X": X, "y": y},
        replicated={"w": jnp.zeros((d,)), "lr": jnp.float32(0.05)},
        mesh=smallest_mesh())
    print(f"bundle: {bundle.n_records} records, "
          f"{bundle.n_partitions} partition(s)")

    # 2. one learning iteration = map (local residuals/gradients)
    #    + reduce (psum) — Algorithm-1-shaped
    def step(data, rep, axes):
        r = data["X"] @ rep["w"] - data["y"]
        grad = data["X"].T @ r
        cost = 0.5 * jnp.sum(r ** 2)
        if axes:
            grad = jax.lax.psum(grad, axes)
            cost = jax.lax.psum(cost, axes)
        new_w = rep["w"] - rep["lr"] * grad / data["X"].shape[0]
        # broadcast state rides in the reduced output; data unchanged
        return data, {"cost": cost, "w": new_w}

    # 3. drive to convergence: the broadcast state (w) is folded back
    #    into the replicated carry each iteration, on-device — 8
    #    iterations run per dispatch (chunk=8), the host syncs once per
    #    chunk (checkpointing/straggler hooks omitted)
    driver = IterativeDriver(
        step, bundle, max_iter=200, tol=1e-6, chunk=8,
        update_replicated=lambda rep, out: dict(rep, w=out["w"]))
    out = driver.run()
    w_fit = out.replicated["w"]
    err = float(jnp.linalg.norm(w_fit - w_true) /
                jnp.linalg.norm(w_true))
    print(f"converged at iter {driver.log.converged_at}; "
          f"cost {driver.log.costs[0]:.1f} -> {driver.log.costs[-1]:.4f}; "
          f"relative weight error {err:.2e}")
    assert err < 0.05


if __name__ == "__main__":
    main()
