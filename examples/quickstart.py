"""Quickstart: declare a workload once, solve() it — in ~40 lines.

The paper's driver program (configure -> parallelize -> iterate) is
generic; a workload is ONE `Problem` declaration (DESIGN.md §14): how to
build the co-partitioned bundle, and what one map/reduce learning
iteration does.  Everything else — chunked on-device scans, broadcast
carries, convergence tracking, checkpoint hooks — is derived by
`solve()`.  Here: ridge regression by distributed gradient descent.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.core.bundle import Bundle
from repro.core.problem import Problem, solve
from repro.launch.mesh import smallest_mesh


class RidgeProblem(Problem):
    """The whole workload declaration — this is the paper's claim that
    new analysis tasks are cheap to express on the shared engine."""

    replicated_in_carry = True      # the model w advances every iteration

    def __init__(self, lr: float = 0.05):
        self.lr = lr

    def init_bundle(self, inputs, mesh) -> Bundle:
        X, y = inputs               # co-partitioned over samples
        return Bundle.create(
            {"X": X, "y": y}, mesh=mesh,
            replicated={"w": jnp.zeros(X.shape[1], X.dtype)})

    def full_step(self, d, rep, axes):
        r = d["X"] @ rep["w"] - d["y"]
        grad = d["X"].T @ r
        cost = 0.5 * jnp.sum(r ** 2)
        n = jnp.float32(d["X"].shape[0])
        if axes:                    # map -> psum reduce, no driver trip
            grad = jax.lax.psum(grad, axes)
            cost = jax.lax.psum(cost, axes)
            n = jax.lax.psum(n, axes)   # global row count, so the step
        w_new = rep["w"] - self.lr * grad / n   # size is mesh-invariant
        return d, {"cost": cost, "w": w_new}

    def refresh_replicated(self, rep, out):
        return dict(rep, w=out["w"])

    def finalize(self, bundle, log):
        return jax.device_get(bundle.replicated["w"]), {}


def main():
    key = jax.random.PRNGKey(0)
    n, d = 4096, 32
    w_true = jax.random.normal(jax.random.fold_in(key, 1), (d,))
    X = jax.random.normal(jax.random.fold_in(key, 2), (n, d))
    y = X @ w_true + 0.01 * jax.random.normal(jax.random.fold_in(key, 3),
                                              (n,))

    sol = solve(RidgeProblem(lr=0.05), X, y, mesh=smallest_mesh(),
                max_iter=200, tol=1e-6, chunk=8)
    err = float(jnp.linalg.norm(sol.x - w_true) /
                jnp.linalg.norm(w_true))
    print(f"bundle: {sol.bundle.n_records} records, "
          f"{sol.bundle.n_partitions} partition(s)")
    print(f"converged at iter {sol.log.converged_at}; "
          f"cost {sol.costs[0]:.1f} -> {sol.costs[-1]:.4f}; "
          f"relative weight error {err:.2e}")
    assert err < 0.05


if __name__ == "__main__":
    main()
