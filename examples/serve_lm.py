"""Batched serving example: prefill + greedy decode with a KV cache —
the serve_step the decode dry-run cells lower at production scale.

    PYTHONPATH=src python examples/serve_lm.py --arch glm4-9b
"""
import argparse

from repro.launch.mesh import smallest_mesh
from repro.launch.serve import serve


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    args = ap.parse_args()
    out, stats = serve(args.arch, batch=args.batch,
                       prompt_len=args.prompt_len, gen=args.gen,
                       use_reduced=True, mesh=smallest_mesh())
    print(f"generated {out.shape[1]} tokens for {out.shape[0]} sequences; "
          f"{stats['tok_per_s']:.0f} tok/s on this host")


if __name__ == "__main__":
    main()
