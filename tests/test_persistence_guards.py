"""Checkpoint restore guards, both directions (DESIGN.md §15/§17).

``tests/test_problem_api.py`` covers the scdl-written side (scdl
checkpoint refused by deconvolve resume; scdl config change refused).
This module closes the matrix: deconvolve-written checkpoints refuse an
scdl resume, config-fingerprint changes are caught for *both*
workloads in both drift directions, and run-control fields
(``max_iter``/``tol``) stay out of the fingerprint for both.
"""
import jax
import numpy as np
import pytest

from repro.core.problem import solve
from repro.data.synthetic import coupled_patches
from repro.imaging import psf as psf_op
from repro.imaging.condat import SolverConfig
from repro.imaging.scdl import SCDLConfig


@pytest.fixture(scope="module")
def psf_data():
    return psf_op.simulate(8, jax.random.PRNGKey(11))


@pytest.fixture(scope="module")
def scdl_data():
    return coupled_patches(256, 25, 9, 16, seed=13)


def _write_deconv_ckpt(tmp_path, psf_data, name):
    d = tmp_path / name
    solve("deconvolve", psf_data.Y, psf_data.psfs,
          cfg=SolverConfig(mode="sparse", n_scales=3, max_iter=4),
          chunk=4, tol=0, checkpoint_dir=d, checkpoint_every=4)
    return d


def test_deconvolve_checkpoint_refuses_scdl_resume(tmp_path, psf_data,
                                                   scdl_data):
    """Reverse of the existing scdl->deconvolve guard test: a
    deconvolve checkpoint must refuse to restore into an scdl run."""
    d = _write_deconv_ckpt(tmp_path, psf_data, "ckpt_rev_workload")
    S_h, S_l = scdl_data
    with pytest.raises(ValueError, match="meta"):
        solve("scdl", S_h, S_l, cfg=SCDLConfig(n_atoms=16, max_iter=6),
              chunk=4, tol=0, checkpoint_dir=d, resume=True)


def test_deconvolve_config_change_refused_on_resume(tmp_path, psf_data):
    """Config drift guard for the deconvolve workload (the existing
    test only exercises scdl): resuming with a changed lam must fail."""
    d = _write_deconv_ckpt(tmp_path, psf_data, "ckpt_deconv_cfg")
    with pytest.raises(ValueError, match="meta"):
        solve("deconvolve", psf_data.Y, psf_data.psfs,
              cfg=SolverConfig(mode="sparse", n_scales=3, max_iter=8,
                               lam=0.5),
              chunk=4, tol=0, checkpoint_dir=d, resume=True)


def test_deconvolve_run_control_change_accepted_on_resume(tmp_path,
                                                          psf_data):
    """max_iter/tol are run control, not step math: changing them on a
    deconvolve resume is the continue-a-finished-run workflow and must
    restore cleanly."""
    d = _write_deconv_ckpt(tmp_path, psf_data, "ckpt_deconv_extend")
    rest = solve("deconvolve", psf_data.Y, psf_data.psfs,
                 cfg=SolverConfig(mode="sparse", n_scales=3, max_iter=8,
                                  tol=1e-9),
                 chunk=4, tol=0, checkpoint_dir=d, resume=True)
    assert len(rest.log.costs) == 4        # iterations 4..8 only


def test_scdl_config_change_refused_both_directions(tmp_path, scdl_data):
    """The fingerprint must catch drift in either direction: a run
    with the default lam refuses a lam=0.5 checkpoint just as a lam=0.5
    run refuses a default-lam checkpoint (the existing test only checks
    default -> changed)."""
    S_h, S_l = scdl_data
    d = tmp_path / "ckpt_scdl_rev"
    solve("scdl", S_h, S_l,
          cfg=SCDLConfig(n_atoms=16, max_iter=4, lam_h=0.5),
          chunk=4, tol=0, checkpoint_dir=d, checkpoint_every=4)
    with pytest.raises(ValueError, match="meta"):
        solve("scdl", S_h, S_l, cfg=SCDLConfig(n_atoms=16, max_iter=8),
              chunk=4, tol=0, checkpoint_dir=d, resume=True)


def test_resumed_trajectory_continues_exactly(tmp_path, psf_data):
    """Guard semantics end-to-end: an accepted resume continues the
    exact cost trajectory of an uninterrupted run."""
    cfg = SolverConfig(mode="sparse", n_scales=3, max_iter=8)
    full = solve("deconvolve", psf_data.Y, psf_data.psfs, cfg=cfg,
                 chunk=4, tol=0)
    d = _write_deconv_ckpt(tmp_path, psf_data, "ckpt_traj")
    rest = solve("deconvolve", psf_data.Y, psf_data.psfs, cfg=cfg,
                 chunk=4, tol=0, checkpoint_dir=d, resume=True)
    np.testing.assert_allclose(np.asarray(rest.log.costs),
                               np.asarray(full.costs[4:]),
                               rtol=1e-6, atol=0)
