"""Use-case tests: starlet/PSF operator properties (hypothesis) and the
distributed == sequential equivalences of Algorithms 1 & 2."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.bundle import Bundle
from repro.imaging import lowrank as lr
from repro.imaging import psf as psf_op
from repro.imaging import starlet
from repro.imaging.condat import SolverConfig, solve
from repro.imaging.deconvolve import deconvolve
from repro.imaging.scdl import SCDLConfig, train
from repro.data.synthetic import coupled_patches

settings.register_profile("ci", max_examples=10, deadline=None)
settings.load_profile("ci")

KEY = jax.random.PRNGKey(11)


# ------------------------------------------------------------- starlet
@given(n_scales=st.integers(1, 5), seed=st.integers(0, 100))
def test_starlet_perfect_reconstruction(n_scales, seed):
    x = jax.random.normal(jax.random.PRNGKey(seed), (41, 41))
    co = starlet.decompose(x, n_scales)
    np.testing.assert_allclose(np.asarray(starlet.recompose(co)),
                               np.asarray(x), rtol=1e-4, atol=1e-5)


@given(n_scales=st.integers(1, 4), seed=st.integers(0, 100))
def test_starlet_adjoint_dot_product(n_scales, seed):
    """<Phi x, u> == <x, Phi^T u> to fp32 precision."""
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    x = jax.random.normal(k1, (32, 32))
    u = jax.random.normal(k2, (n_scales, 32, 32))
    lhs = float(jnp.sum(starlet.forward(x, n_scales) * u))
    rhs = float(jnp.sum(x * starlet.adjoint(u, n_scales)))
    assert abs(lhs - rhs) <= 1e-4 * max(abs(lhs), 1.0)


# ------------------------------------------------------------------ H
@given(seed=st.integers(0, 50))
def test_psf_operator_adjoint(seed):
    data = psf_op.simulate(4, jax.random.PRNGKey(seed))
    y = jax.random.normal(jax.random.fold_in(jax.random.PRNGKey(seed), 1),
                          data.Y.shape)
    lhs = float(jnp.sum(psf_op.H(data.X_true, data.psfs) * y))
    rhs = float(jnp.sum(data.X_true * psf_op.Ht(y, data.psfs)))
    assert abs(lhs - rhs) <= 1e-4 * max(abs(lhs), 1.0)


def test_psf_convolve_matches_direct():
    """FFT convolution == direct convolution on a small case."""
    from scipy.signal import convolve2d
    x = np.asarray(jax.random.normal(KEY, (9, 9)), np.float64)
    k = np.zeros((9, 9)); k[3:6, 3:6] = np.random.RandomState(0).rand(3, 3)
    out = np.asarray(psf_op.convolve(jnp.array(x)[None],
                                     jnp.array(k, jnp.float32)[None]))[0]
    ref = convolve2d(x, k, mode="same")
    np.testing.assert_allclose(out, ref, rtol=1e-4, atol=1e-4)


# ------------------------------------------------- paired-FFT engine
def test_fast_pad_rule():
    """Derived grid: smallest 5-smooth size >= 2S - 1 (DESIGN.md §16)."""
    assert psf_op.fast_size(81) == 81          # 3^4
    assert psf_op.fast_size(82) == 90          # 2 * 3^2 * 5
    assert psf_op.pad_for(41) == 81            # the seed hardcoded 96
    assert psf_op.pad_for(64) == 128
    assert psf_op.pad_for(21) == 45
    for s in (9, 21, 33, 41, 57, 64):
        pad = psf_op.pad_for(s)
        assert pad >= 2 * s - 1
        assert psf_op.grid_of(psf_op.psf_fft_pair(
            jnp.ones((2, s, s)))) == pad


@pytest.mark.parametrize("stamp", [21, 64])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_conv_pair_adjoint_property(stamp, dtype):
    """<H(x), y> == <x, Ht(y)> through conv_pair_f's two halves, at
    non-default stamp sizes on the derived pad, fp32 and bf16."""
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(stamp), 3)
    x = jax.random.normal(k1, (3, stamp, stamp), dtype)
    y = jax.random.normal(k2, (3, stamp, stamp), dtype)
    psfs = jax.random.normal(k3, (3, stamp, stamp), dtype)
    kf_pair = psf_op.psf_fft_pair(psfs)
    Hx, Hty = psf_op.conv_pair_f(x, y, kf_pair)
    lhs = float(jnp.sum(Hx.astype(jnp.float32) * y.astype(jnp.float32)))
    rhs = float(jnp.sum(x.astype(jnp.float32) * Hty.astype(jnp.float32)))
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-4
    assert abs(lhs - rhs) <= tol * max(abs(lhs), 1.0)


@pytest.mark.parametrize("stamp", [21, 41, 64])
def test_conv_pair_matches_single_calls(stamp):
    """The batched pair == separate H_f / Ht_f calls == the one-shot
    convolve API (kernel FFT recomputed per call)."""
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(stamp + 1), 3)
    x = jax.random.normal(k1, (4, stamp, stamp))
    y = jax.random.normal(k2, (4, stamp, stamp))
    psfs = jax.random.normal(k3, (4, stamp, stamp))
    kf_pair = psf_op.psf_fft_pair(psfs)
    Hx, Hty = psf_op.conv_pair_f(x, y, kf_pair)
    np.testing.assert_allclose(np.asarray(Hx),
                               np.asarray(psf_op.H_fp(x, kf_pair)),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(Hty),
                               np.asarray(psf_op.Ht_fp(y, kf_pair)),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(Hx),
                               np.asarray(psf_op.H(x, psfs)),
                               rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(Hty),
                               np.asarray(psf_op.Ht(y, psfs)),
                               rtol=1e-4, atol=1e-5)


def test_derived_pad_matches_oversized_grid():
    """The fast pad (81 for S = 41) computes the identical 'same'
    convolution as a generously padded grid — the crop window is
    alias-free at 2S - 1 (DESIGN.md §16)."""
    data = psf_op.simulate(4, jax.random.PRNGKey(5))
    for pad in (96, 128):
        kf = psf_op.psf_fft(data.psfs, pad=pad)
        np.testing.assert_allclose(
            np.asarray(psf_op.H(data.X_true, data.psfs)),
            np.asarray(psf_op.H_f(data.X_true, kf)),
            rtol=1e-4, atol=1e-6)


def test_sparse_dual_overrelax_linearity():
    """Phi(2 X_new - X) == 2 Phi(X_new) - Phi(X): the identity that
    lets the solver carry Phi(X) and run one starlet forward per
    iteration (DESIGN.md §16)."""
    from repro.kernels.starlet2d import ops as starlet_batch
    k1, k2 = jax.random.split(KEY)
    X = jax.random.normal(k1, (6, 41, 41))
    Xn = jax.random.normal(k2, (6, 41, 41))
    direct = starlet_batch.forward(2 * Xn - X, 3)
    linear = 2 * starlet_batch.forward(Xn, 3) - starlet_batch.forward(X, 3)
    np.testing.assert_allclose(np.asarray(linear), np.asarray(direct),
                               rtol=1e-4, atol=1e-5)


# -------------------------------------------------- Algorithm 1 (PSF)
@pytest.fixture(scope="module")
def psf_data():
    return psf_op.simulate(8, jax.random.PRNGKey(2))


def test_sparse_deconvolution_improves_mse(psf_data):
    cfg = SolverConfig(mode="sparse", n_scales=3)
    X, costs = solve(psf_data.Y, psf_data.psfs, cfg,
                     sigma_noise=psf_data.sigma, n_iter=40)
    mse_obs = float(jnp.mean((psf_data.Y - psf_data.X_true) ** 2))
    mse_dec = float(jnp.mean((X - psf_data.X_true) ** 2))
    assert mse_dec < 0.2 * mse_obs
    assert float(costs[-1]) < float(costs[0])


def test_distributed_sparse_equals_sequential(psf_data):
    cfg = SolverConfig(mode="sparse", n_scales=3)
    _, costs = solve(psf_data.Y, psf_data.psfs, cfg,
                     sigma_noise=psf_data.sigma, n_iter=15)
    _, log = deconvolve(psf_data.Y, psf_data.psfs, cfg, mesh=None,
                        sigma_noise=psf_data.sigma, max_iter=15, tol=0)
    np.testing.assert_allclose(np.asarray(costs), np.asarray(log.costs),
                               rtol=1e-4)


def test_distributed_lowrank_converges(psf_data):
    """Primal-dual cost is not monotone; require recovery quality and a
    bounded, non-diverging trajectory instead."""
    cfg = SolverConfig(mode="lowrank", lam=0.05, rank=8)
    Xd, log = deconvolve(psf_data.Y, psf_data.psfs, cfg, mesh=None,
                         max_iter=25, tol=0)
    assert np.isfinite(log.costs).all()
    assert max(log.costs[5:]) <= log.costs[0] * 1.1
    mse_obs = float(jnp.mean((psf_data.Y - psf_data.X_true) ** 2))
    mse_dec = float(np.mean((Xd - np.asarray(psf_data.X_true)) ** 2))
    assert mse_dec < mse_obs


def test_randomized_svt_matches_exact():
    """Distributed randomized SVT == exact SVT on a low-rank matrix."""
    k1, k2 = jax.random.split(KEY)
    U = jax.random.normal(k1, (64, 5))
    V = jax.random.normal(k2, (5, 30))
    A = U @ V
    omega = lr.make_test_matrix(30, rank=8, key=KEY)
    exact = lr.svt(A, 0.5)
    approx = lr.randomized_svt_local(A, omega, 0.5, axes=None)
    np.testing.assert_allclose(np.asarray(approx), np.asarray(exact),
                               rtol=5e-3, atol=5e-3)


# -------------------------------------------------- Algorithm 2 (SCDL)
def _seed_scdl_reference(S_h, S_l, cfg, iters):
    """The pre-overhaul SCDL math, verbatim: per-iteration Gram rebuild +
    LU solves, separate outer einsums, unfused dual updates.  The parity
    oracle for the factor-once Cholesky/Woodbury rebuild."""
    from repro.imaging.scdl import init_dicts
    Xh, Xl = init_dicts(S_h, S_l, cfg)
    c1, c2, c3 = cfg.c1, cfg.c2, cfg.c3
    A = cfg.n_atoms
    K = S_h.shape[1]
    eye = jnp.eye(A)
    Sh, Sl = S_h.T, S_l.T
    Wh = Wl = P = Q = Y1 = Y2 = Y3 = jnp.zeros((K, A))
    soft = lambda x, t: jnp.sign(x) * jnp.maximum(jnp.abs(x) - t, 0.0)
    costs = []
    for _ in range(iters):
        Gh = 2.0 * Xh.T @ Xh + (c1 + c3) * eye
        Gl = 2.0 * Xl.T @ Xl + (c2 + c3) * eye
        rhs_h = 2.0 * Sh @ Xh + c1 * P + Y1 - Y3 + c3 * Wl
        Wh = jnp.linalg.solve(Gh, rhs_h.T).T
        rhs_l = 2.0 * Sl @ Xl + c2 * Q + Y2 + Y3 + c3 * Wh
        Wl = jnp.linalg.solve(Gl, rhs_l.T).T
        P = soft(Wh - Y1 / c1, cfg.lam_h / c1)
        Q = soft(Wl - Y2 / c2, cfg.lam_l / c2)
        Y1 = Y1 + c1 * (P - Wh)
        Y2 = Y2 + c2 * (Q - Wl)
        Y3 = Y3 + c3 * (Wh - Wl)
        phi_h, phi_l = Wh.T @ Wh, Wl.T @ Wl
        Xh = jnp.linalg.solve(phi_h + cfg.delta * eye, (Sh.T @ Wh).T).T
        Xl = jnp.linalg.solve(phi_l + cfg.delta * eye, (Sl.T @ Wl).T).T
        clip = lambda X: X / jnp.maximum(
            jnp.linalg.norm(X, axis=0, keepdims=True), 1.0)
        Xh, Xl = clip(Xh), clip(Xl)
        nrmse_h = jnp.sqrt(jnp.sum((Sh - Wh @ Xh.T) ** 2)
                           / (jnp.sum(Sh ** 2) + 1e-12))
        nrmse_l = jnp.sqrt(jnp.sum((Sl - Wl @ Xl.T) ** 2)
                           / (jnp.sum(Sl ** 2) + 1e-12))
        costs.append(float(0.5 * (nrmse_h + nrmse_l)))
    return np.asarray(Xh), np.asarray(Xl), np.asarray(costs)


def _clustered_patches(K, p_dim, m_dim, n_proto=4, seed=9):
    """Samples drawn from a few prototypes + tiny jitter: the random-
    column dictionary init then holds many near-duplicate atoms, so
    X^T X is nearly rank-``n_proto`` — the ill-conditioned regime the
    ridge Grams must survive."""
    rng = np.random.RandomState(seed)
    proto_h = rng.randn(p_dim, n_proto)
    proto_l = rng.randn(m_dim, n_proto)
    idx = rng.randint(0, n_proto, size=K)
    amp = rng.rand(K) + 0.5
    S_h = proto_h[:, idx] * amp + 1e-3 * rng.randn(p_dim, K)
    S_l = proto_l[:, idx] * amp + 1e-3 * rng.randn(m_dim, K)
    return (jnp.asarray(S_h, jnp.float32), jnp.asarray(S_l, jnp.float32))


def test_scdl_matches_seed_lu_math():
    """Factor-once Cholesky/Woodbury solves == the seed's per-iteration
    LU math within rtol 1e-4 (trajectory AND dictionaries, including the
    delta-damped dictionary update) on well-posed data."""
    S_h, S_l = coupled_patches(256, 25, 9, 16, seed=5)
    cfg = SCDLConfig(n_atoms=16, max_iter=10)
    Xh_ref, Xl_ref, costs_ref = _seed_scdl_reference(S_h, S_l, cfg, 10)
    Xh, Xl, log = train(S_h, S_l, cfg, chunk=4)
    np.testing.assert_allclose(np.asarray(log.costs), costs_ref,
                               rtol=1e-4)
    np.testing.assert_allclose(Xh, Xh_ref, rtol=1e-3, atol=1e-4)
    np.testing.assert_allclose(Xl, Xl_ref, rtol=1e-3, atol=1e-4)


def test_scdl_cholesky_path_matches_seed_lu_on_ill_conditioned():
    """Near-duplicate atoms: X^T X is nearly singular, only the ridge
    keeps the W systems solvable.  The trajectories must agree tightly
    until the NRMSE reaches the data's 1e-3 jitter floor, where the
    problem is degenerate (near-duplicate atoms make the dictionary
    non-unique) and fp32 roundoff dominates BOTH implementations — there
    we require agreement at the floor scale, and that both actually
    solved the problem."""
    S_h, S_l = _clustered_patches(256, 25, 9)
    cfg = SCDLConfig(n_atoms=16, max_iter=10)
    _, _, costs_ref = _seed_scdl_reference(S_h, S_l, cfg, 10)
    Xh, Xl, log = train(S_h, S_l, cfg, chunk=4)
    costs = np.asarray(log.costs)
    np.testing.assert_allclose(costs, costs_ref, rtol=2e-3, atol=2e-3)
    # the well-posed head of the trajectory matches tightly
    np.testing.assert_allclose(costs[:4], costs_ref[:4], rtol=1e-3)
    assert costs[-1] < 0.01 and costs_ref[-1] < 0.01
    norms = np.linalg.norm(Xh, axis=0)
    assert (norms <= 1.0 + 1e-4).all()


def test_scdl_solve_factor_branches_match_lu():
    """All three factor-once regimes (thin Woodbury apply, dense inverse
    via Woodbury build, dense direct) equal a dense LU solve, on an
    ill-conditioned dictionary (near-duplicate atoms + ridge)."""
    from repro.imaging.scdl import _ridge_solve, _solve_factor
    key = jax.random.PRNGKey(3)
    for P, A in [(81, 512), (289, 512), (25, 16)]:
        base = jax.random.normal(key, (P, max(A // 8, 2)))
        X = jnp.repeat(base, 8, axis=1)[:, :A]
        X = X + 1e-3 * jax.random.normal(jax.random.fold_in(key, 1),
                                         (P, A))
        X = X / jnp.maximum(jnp.linalg.norm(X, axis=0, keepdims=True),
                            1e-8)
        S = jax.random.normal(jax.random.fold_in(key, 2), (128, P))
        Z = jax.random.normal(jax.random.fold_in(key, 3), (128, A))
        c = 1.2
        W = _ridge_solve(S, Z, X, _solve_factor(X, c), c)
        G = 2.0 * X.T @ X + c * jnp.eye(A)
        W_ref = jnp.linalg.solve(G, (2.0 * S @ X + Z).T).T
        np.testing.assert_allclose(np.asarray(W), np.asarray(W_ref),
                                   rtol=2e-4, atol=2e-4)


def test_scdl_converges_and_reconstructs():
    S_h, S_l = coupled_patches(512, 25, 9, 32, seed=4)
    cfg = SCDLConfig(n_atoms=32, max_iter=15)
    Xh, Xl, log = train(S_h, S_l, cfg)
    assert log.costs[-1] < 0.25 * log.costs[0]
    assert Xh.shape == (25, 32) and Xl.shape == (9, 32)
    norms = np.linalg.norm(Xh, axis=0)
    assert (norms <= 1.0 + 1e-4).all()


def test_scdl_cost_monotone_tail():
    S_h, S_l = coupled_patches(256, 25, 9, 16, seed=5)
    cfg = SCDLConfig(n_atoms=16, max_iter=12)
    _, _, log = train(S_h, S_l, cfg)
    # NRMSE after the burn-in should never regress by more than 5%
    tail = log.costs[3:]
    assert all(b <= a * 1.05 for a, b in zip(tail, tail[1:]))
