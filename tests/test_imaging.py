"""Use-case tests: starlet/PSF operator properties (hypothesis) and the
distributed == sequential equivalences of Algorithms 1 & 2."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.bundle import Bundle
from repro.imaging import lowrank as lr
from repro.imaging import psf as psf_op
from repro.imaging import starlet
from repro.imaging.condat import SolverConfig, solve
from repro.imaging.deconvolve import deconvolve
from repro.imaging.scdl import SCDLConfig, train
from repro.data.synthetic import coupled_patches

settings.register_profile("ci", max_examples=10, deadline=None)
settings.load_profile("ci")

KEY = jax.random.PRNGKey(11)


# ------------------------------------------------------------- starlet
@given(n_scales=st.integers(1, 5), seed=st.integers(0, 100))
def test_starlet_perfect_reconstruction(n_scales, seed):
    x = jax.random.normal(jax.random.PRNGKey(seed), (41, 41))
    co = starlet.decompose(x, n_scales)
    np.testing.assert_allclose(np.asarray(starlet.recompose(co)),
                               np.asarray(x), rtol=1e-4, atol=1e-5)


@given(n_scales=st.integers(1, 4), seed=st.integers(0, 100))
def test_starlet_adjoint_dot_product(n_scales, seed):
    """<Phi x, u> == <x, Phi^T u> to fp32 precision."""
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    x = jax.random.normal(k1, (32, 32))
    u = jax.random.normal(k2, (n_scales, 32, 32))
    lhs = float(jnp.sum(starlet.forward(x, n_scales) * u))
    rhs = float(jnp.sum(x * starlet.adjoint(u, n_scales)))
    assert abs(lhs - rhs) <= 1e-4 * max(abs(lhs), 1.0)


# ------------------------------------------------------------------ H
@given(seed=st.integers(0, 50))
def test_psf_operator_adjoint(seed):
    data = psf_op.simulate(4, jax.random.PRNGKey(seed))
    y = jax.random.normal(jax.random.fold_in(jax.random.PRNGKey(seed), 1),
                          data.Y.shape)
    lhs = float(jnp.sum(psf_op.H(data.X_true, data.psfs) * y))
    rhs = float(jnp.sum(data.X_true * psf_op.Ht(y, data.psfs)))
    assert abs(lhs - rhs) <= 1e-4 * max(abs(lhs), 1.0)


def test_psf_convolve_matches_direct():
    """FFT convolution == direct convolution on a small case."""
    from scipy.signal import convolve2d
    x = np.asarray(jax.random.normal(KEY, (9, 9)), np.float64)
    k = np.zeros((9, 9)); k[3:6, 3:6] = np.random.RandomState(0).rand(3, 3)
    out = np.asarray(psf_op.convolve(jnp.array(x)[None],
                                     jnp.array(k, jnp.float32)[None]))[0]
    ref = convolve2d(x, k, mode="same")
    np.testing.assert_allclose(out, ref, rtol=1e-4, atol=1e-4)


# -------------------------------------------------- Algorithm 1 (PSF)
@pytest.fixture(scope="module")
def psf_data():
    return psf_op.simulate(8, jax.random.PRNGKey(2))


def test_sparse_deconvolution_improves_mse(psf_data):
    cfg = SolverConfig(mode="sparse", n_scales=3)
    X, costs = solve(psf_data.Y, psf_data.psfs, cfg,
                     sigma_noise=psf_data.sigma, n_iter=40)
    mse_obs = float(jnp.mean((psf_data.Y - psf_data.X_true) ** 2))
    mse_dec = float(jnp.mean((X - psf_data.X_true) ** 2))
    assert mse_dec < 0.2 * mse_obs
    assert float(costs[-1]) < float(costs[0])


def test_distributed_sparse_equals_sequential(psf_data):
    cfg = SolverConfig(mode="sparse", n_scales=3)
    _, costs = solve(psf_data.Y, psf_data.psfs, cfg,
                     sigma_noise=psf_data.sigma, n_iter=15)
    _, log = deconvolve(psf_data.Y, psf_data.psfs, cfg, mesh=None,
                        sigma_noise=psf_data.sigma, max_iter=15, tol=0)
    np.testing.assert_allclose(np.asarray(costs), np.asarray(log.costs),
                               rtol=1e-4)


def test_distributed_lowrank_converges(psf_data):
    """Primal-dual cost is not monotone; require recovery quality and a
    bounded, non-diverging trajectory instead."""
    cfg = SolverConfig(mode="lowrank", lam=0.05, rank=8)
    Xd, log = deconvolve(psf_data.Y, psf_data.psfs, cfg, mesh=None,
                         max_iter=25, tol=0)
    assert np.isfinite(log.costs).all()
    assert max(log.costs[5:]) <= log.costs[0] * 1.1
    mse_obs = float(jnp.mean((psf_data.Y - psf_data.X_true) ** 2))
    mse_dec = float(np.mean((Xd - np.asarray(psf_data.X_true)) ** 2))
    assert mse_dec < mse_obs


def test_randomized_svt_matches_exact():
    """Distributed randomized SVT == exact SVT on a low-rank matrix."""
    k1, k2 = jax.random.split(KEY)
    U = jax.random.normal(k1, (64, 5))
    V = jax.random.normal(k2, (5, 30))
    A = U @ V
    omega = lr.make_test_matrix(30, rank=8, key=KEY)
    exact = lr.svt(A, 0.5)
    approx = lr.randomized_svt_local(A, omega, 0.5, axes=None)
    np.testing.assert_allclose(np.asarray(approx), np.asarray(exact),
                               rtol=5e-3, atol=5e-3)


# -------------------------------------------------- Algorithm 2 (SCDL)
def test_scdl_converges_and_reconstructs():
    S_h, S_l = coupled_patches(512, 25, 9, 32, seed=4)
    cfg = SCDLConfig(n_atoms=32, max_iter=15)
    Xh, Xl, log = train(S_h, S_l, cfg)
    assert log.costs[-1] < 0.25 * log.costs[0]
    assert Xh.shape == (25, 32) and Xl.shape == (9, 32)
    norms = np.linalg.norm(Xh, axis=0)
    assert (norms <= 1.0 + 1e-4).all()


def test_scdl_cost_monotone_tail():
    S_h, S_l = coupled_patches(256, 25, 9, 16, seed=5)
    cfg = SCDLConfig(n_atoms=16, max_iter=12)
    _, _, log = train(S_h, S_l, cfg)
    # NRMSE after the burn-in should never regress by more than 5%
    tail = log.costs[3:]
    assert all(b <= a * 1.05 for a, b in zip(tail, tail[1:]))
