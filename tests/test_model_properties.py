"""Model-level invariants (hypothesis where applicable)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import get_config, reduced
from repro.models import model as M
from repro.parallel.sharding import MeshRules

settings.register_profile("ci", max_examples=8, deadline=None)
settings.load_profile("ci")

RULES = MeshRules(mesh=None)


@pytest.mark.parametrize("arch", ["qwen3-1.7b", "gemma3-27b",
                                  "falcon-mamba-7b", "hymba-1.5b"])
def test_causality(arch):
    """Hidden state at position i must not depend on tokens > i —
    for attention (causal mask), sliding windows, AND the mamba scan."""
    cfg = reduced(get_config(arch))
    params = M.init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    key = jax.random.PRNGKey(1)
    S, cut = 16, 9
    t1 = jax.random.randint(key, (1, S), 0, cfg.vocab_size)
    t2 = t1.at[:, cut:].set((t1[:, cut:] + 7) % cfg.vocab_size)
    h1, _, _ = M.forward(params, {"tokens": t1}, cfg, RULES, remat=False,
                         q_chunk=0)
    h2, _, _ = M.forward(params, {"tokens": t2}, cfg, RULES, remat=False,
                         q_chunk=0)
    np.testing.assert_allclose(np.asarray(h1[:, :cut]),
                               np.asarray(h2[:, :cut]),
                               rtol=1e-5, atol=1e-5)
    assert not np.allclose(np.asarray(h1[:, cut:]),
                           np.asarray(h2[:, cut:]), atol=1e-4)


@given(seed=st.integers(0, 30))
def test_q_chunking_invariance(seed):
    """Lazy-flash query chunking must not change the forward values."""
    cfg = reduced(get_config("glm4-9b"))
    params = M.init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    tokens = jax.random.randint(jax.random.PRNGKey(seed), (2, 16), 0,
                                cfg.vocab_size)
    h_full, _, _ = M.forward(params, {"tokens": tokens}, cfg, RULES,
                             remat=False, q_chunk=0)
    h_chunk, _, _ = M.forward(params, {"tokens": tokens}, cfg, RULES,
                              remat=False, q_chunk=4)
    np.testing.assert_allclose(np.asarray(h_full), np.asarray(h_chunk),
                               rtol=2e-5, atol=2e-5)


def test_remat_invariance():
    """MEMORY_ONLY persistence (remat) must not change loss or grads."""
    cfg = reduced(get_config("qwen3-1.7b"))
    params = M.init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    batch = {"tokens": jnp.ones((2, 16), jnp.int32),
             "labels": jnp.zeros((2, 16), jnp.int32)}
    f = lambda remat: jax.value_and_grad(
        lambda p: M.loss_fn(p, batch, cfg, RULES, remat=remat,
                            q_chunk=0)[0])(params)
    l1, g1 = f(True)
    l2, g2 = f(False)
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-6)
    for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-6)


@pytest.mark.parametrize("arch", ["gemma3-27b", "glm4-9b", "hymba-1.5b"])
def test_int8_kv_decode_matches_bf16(arch):
    """§Perf/F: int8-quantized KV decode must track the exact decode
    closely (small logit error, identical greedy tokens)."""
    cfg = reduced(get_config(arch))
    params = M.init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    S = 12
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, S), 0,
                              cfg.vocab_size)
    hidden, _, _ = M.forward(params, {"tokens": toks}, cfg, RULES,
                             remat=False, q_chunk=0)
    ref_logits = M._head_logits(params, hidden, cfg, RULES)[:, -1:]
    _, cache = M.prefill(params, {"tokens": toks[:, :S - 1]}, cfg, RULES,
                         q_chunk=0)
    for k in ("k", "v"):
        if k in cache:
            pad = jnp.zeros(cache[k].shape[:2] + (1,) + cache[k].shape[3:],
                            cache[k].dtype)
            cache[k] = jnp.concatenate([cache[k], pad], axis=2)
    qcache = M.quantize_cache(cache)
    dec = {"tokens": toks[:, S - 1:S],
           "pos": jnp.full((2,), S - 1, jnp.int32)}
    logits_q, new_cache = M.decode_step(params, qcache, dec, cfg, RULES)
    assert float(jnp.max(jnp.abs(logits_q - ref_logits))) < 0.15
    np.testing.assert_array_equal(np.asarray(jnp.argmax(logits_q, -1)),
                                  np.asarray(jnp.argmax(ref_logits, -1)))
    if "k" in new_cache:
        assert new_cache["k"].dtype == jnp.int8


def test_sliding_window_layers_ignore_far_context():
    """gemma3-family local layers: far-past perturbations must not leak
    through a window-limited all-local model."""
    base = reduced(get_config("gemma3-27b"))
    # all-local variant, window 4
    cfg = dataclasses.replace(base, local_global_ratio=0, sliding_window=4,
                              global_layers=(),
                              rope_theta_local=base.rope_theta)
    cfg = dataclasses.replace(
        cfg, global_layers=())
    object.__setattr__  # noqa — frozen dataclass handled via replace
    # force every layer local by making the pattern never emit global
    cfg = dataclasses.replace(cfg, local_global_ratio=10**6)
    params = M.init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    key = jax.random.PRNGKey(2)
    S = 24
    t1 = jax.random.randint(key, (1, S), 0, cfg.vocab_size)
    t2 = t1.at[:, 0].set((t1[:, 0] + 3) % cfg.vocab_size)
    h1, _, _ = M.forward(params, {"tokens": t1}, cfg, RULES, remat=False,
                         q_chunk=0)
    h2, _, _ = M.forward(params, {"tokens": t2}, cfg, RULES, remat=False,
                         q_chunk=0)
    # with window 4 and 2 layers, influence reaches <= ~8 positions;
    # the tail must be identical
    np.testing.assert_allclose(np.asarray(h1[:, -4:]),
                               np.asarray(h2[:, -4:]),
                               rtol=1e-5, atol=1e-5)
