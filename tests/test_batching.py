"""Pad-and-bucket planner properties (DESIGN.md §19).

The planner is pure bookkeeping — no jax — so its contracts are tested
as properties over randomized instance populations: exact partition
(every instance lands in exactly one bucket), bounded padding waste,
deterministic keys (stable across orderings and processes), and the
end-to-end guarantee the waste bound exists to protect: a padded
instance's trajectory is bit-identical to its unpadded single solve.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given
from hypothesis import strategies as st

from repro.core.batching import (BatchAxes, OpenBucketPlanner, bucket_key,
                                 instance_records, pad_tree_records,
                                 plan_buckets, stack_trees,
                                 static_signature)

AX = BatchAxes(record_axes=(0, 0))


def _population(n, seed, shapes=((16, 16), (20, 20))):
    """n two-array instances with mixed trailing shapes + record counts."""
    rng = np.random.default_rng(seed)
    out = []
    for _ in range(n):
        S = shapes[int(rng.integers(len(shapes)))]
        rec = int(rng.integers(1, 7))
        out.append((np.zeros((rec,) + S, np.float32),
                    np.zeros((rec,) + S, np.float32)))
    return out


# ---------------------------------------------------------------------
# Partition / waste / determinism properties
# ---------------------------------------------------------------------

@given(n=st.integers(1, 24), seed=st.integers(0, 3))
def test_every_instance_in_exactly_one_bucket(n, seed):
    insts = _population(n, seed)
    buckets = plan_buckets(insts, AX)
    covered = [i for b in buckets for i in b.indices]
    assert sorted(covered) == list(range(n))        # exact partition


@given(n=st.integers(1, 24), seed=st.integers(0, 3))
def test_padding_within_waste_budget(n, seed):
    insts = _population(n, seed)
    for budget in (0.0, 0.25, 0.5):
        buckets = plan_buckets(insts, AX, waste_budget=budget)
        for b in buckets:
            slack = sum(b.capacity - r for r in b.records)
            assert b.capacity == max(b.records)
            assert slack <= budget * b.capacity * len(b.indices)
            # members agree on the static signature by construction
            sigs = {static_signature(insts[i], AX) for i in b.indices}
            assert len(sigs) == 1


@given(n=st.integers(2, 16), seed=st.integers(0, 2))
def test_bucket_keys_deterministic_and_order_free(n, seed):
    insts = _population(n, seed)
    a = plan_buckets(insts, AX, salt="s")
    b = plan_buckets(list(insts), AX, salt="s")
    assert [x.key for x in a] == [x.key for x in b]
    # the key binds the salt (problem + config fingerprint)
    c = plan_buckets(insts, AX, salt="other")
    assert {x.key for x in a}.isdisjoint({x.key for x in c})
    # keys are content-addressed, reproducible from the parts
    for x in a:
        members = list(zip(x.indices, x.records))
        assert all(instance_records(insts[i], AX) == r
                   for i, r in members)
        assert x.key == bucket_key("s", x.signature, x.capacity, members)


def test_zero_waste_budget_buckets_by_exact_records():
    insts = _population(12, 0)
    for b in plan_buckets(insts, AX, waste_budget=0.0):
        assert len(set(b.records)) == 1              # no padding at all


def test_no_pad_records_mode_never_mixes_record_counts():
    ax = BatchAxes(record_axes=(1, 1), pad_records=False)
    rng = np.random.default_rng(1)
    insts = [(np.zeros((5, int(k)), np.float32),
              np.zeros((3, int(k)), np.float32))
             for k in rng.integers(4, 8, size=10)]
    for b in plan_buckets(insts, ax):
        assert len(set(b.records)) == 1
        assert b.capacity == b.records[0]


def test_waste_budget_validation():
    insts = _population(2, 0)
    with pytest.raises(ValueError, match="waste_budget"):
        plan_buckets(insts, AX, waste_budget=1.0)
    with pytest.raises(ValueError, match="waste_budget"):
        plan_buckets(insts, AX, waste_budget=-0.1)


def test_pad_tree_records_contract():
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(3, 2)}
    padded = pad_tree_records(tree, 5)
    assert padded["a"].shape == (5, 2)
    np.testing.assert_array_equal(np.asarray(padded["a"][3:]), 0.0)
    np.testing.assert_array_equal(np.asarray(padded["a"][:3]),
                                  np.asarray(tree["a"]))
    with pytest.raises(ValueError):
        pad_tree_records(tree, 2)
    stacked = stack_trees([padded, padded])
    assert stacked["a"].shape == (2, 5, 2)


def _inst(rec, S=16):
    return (np.zeros((rec, S, S), np.float32),
            np.zeros((rec, S, S), np.float32))


def test_bucket_key_stable_under_member_permutation():
    """The key pins *membership*, not arrival order: any permutation of
    the (index, records) list hashes identically, and any change to the
    membership, capacity or salt does not."""
    members = [(0, 5), (1, 3), (2, 5), (3, 1)]
    sig = static_signature(_inst(5), AX)
    want = bucket_key("s", sig, 5, members)
    for perm in ([members[i] for i in (2, 0, 3, 1)],
                 list(reversed(members)),
                 [members[i] for i in (1, 3, 0, 2)]):
        assert bucket_key("s", sig, 5, perm) == want
    assert bucket_key("s", sig, 5, members[:-1]) != want
    assert bucket_key("s", sig, 6, members) != want
    assert bucket_key("t", sig, 5, members) != want


def test_waste_budget_exact_boundary():
    """The admission rule is ``pad <= budget * cap * n`` — exactly at
    the budget admits, one record over splits.  budget=0.1, cap 10:
    records {10, 8} pad 2 == 0.1*10*2 -> one bucket; {10, 7} pad 3 ->
    two."""
    at = plan_buckets([_inst(10), _inst(8)], AX, waste_budget=0.1)
    assert len(at) == 1 and at[0].capacity == 10
    over = plan_buckets([_inst(10), _inst(7)], AX, waste_budget=0.1)
    assert len(over) == 2
    assert sorted(b.capacity for b in over) == [7, 10]


# ---------------------------------------------------------------------
# Incremental (open-bucket) planning — the serving scheduler's half
# ---------------------------------------------------------------------

def test_open_bucket_waste_boundary_matches_offline():
    """Arrival-order admission enforces the identical boundary: small
    then large grows the capacity and re-checks the rule."""
    p = OpenBucketPlanner(AX, waste_budget=0.1)
    b1 = p.offer("a", _inst(8))
    assert p.offer("b", _inst(10)) is b1      # pad 2 == 0.1*10*2
    assert b1.capacity == 10                  # grew to largest member
    p2 = OpenBucketPlanner(AX, waste_budget=0.1)
    b2 = p2.offer("a", _inst(7))
    assert p2.offer("b", _inst(10)) is not b2  # pad 3 > 2: new bucket
    assert len(p2.open_buckets) == 2


def test_open_bucket_planner_keys_match_offline_planner():
    """A closed open-bucket's key is the one ``plan_buckets`` emits for
    the same membership — checkpoints written by a served batch resume
    under the offline planner and vice versa."""
    insts = [_inst(5), _inst(5), _inst(4)]
    offline = plan_buckets(insts, AX, waste_budget=0.25, salt="s")
    assert len(offline) == 1
    p = OpenBucketPlanner(AX, waste_budget=0.25, salt="s")
    buckets = {id(p.offer(i, inst)) for i, inst in enumerate(insts)}
    assert len(buckets) == 1
    closed = p.drain()
    assert [b.key for b in closed] == [offline[0].key]
    # ... and the key is arrival-order independent
    p2 = OpenBucketPlanner(AX, waste_budget=0.25, salt="s")
    for i in (2, 0, 1):
        p2.offer(i, insts[i])
    assert p2.drain()[0].key == offline[0].key


def test_open_bucket_signature_grouping_and_max_members():
    p = OpenBucketPlanner(AX, waste_budget=0.5, max_members=2)
    b16 = p.offer(0, _inst(3, S=16))
    assert p.offer(1, _inst(3, S=20)) is not b16   # shape never mixes
    assert p.offer(2, _inst(3, S=16)) is b16
    assert p.offer(3, _inst(3, S=16)) is not b16   # occupancy cap hit
    assert len(p.open_buckets) == 3


def test_open_bucket_discard_shrinks_capacity():
    p = OpenBucketPlanner(AX, waste_budget=0.5)
    b = p.offer(0, _inst(3))
    p.offer(1, _inst(6))
    assert b.capacity == 6
    p.discard(b, 1)
    assert b.capacity == 3                    # back to largest remaining
    p.discard(b, 0)
    assert len(p.open_buckets) == 0           # emptied bucket closes
    with pytest.raises(ValueError, match="waste_budget"):
        OpenBucketPlanner(AX, waste_budget=1.0)


# ---------------------------------------------------------------------
# The end-to-end property the planner exists to protect
# ---------------------------------------------------------------------

def test_padded_solve_matches_unpadded_bitforbit():
    """A padded instance's valid region reproduces its unpadded single
    solve bit-for-bit: zero records are trajectory-inert and the
    replicated derived state is built pre-padding."""
    from repro.core.problem import solve, solve_many
    from repro.imaging import psf as psf_op
    from repro.imaging.condat import SolverConfig

    cfg = SolverConfig(mode="sparse", max_iter=6, tol=0.0, n_scales=2)
    d3 = psf_op.simulate(3, jax.random.PRNGKey(0), stamp=16)
    d5 = psf_op.simulate(5, jax.random.PRNGKey(1), stamp=16)
    insts = [(d3.Y, d3.psfs), (d5.Y, d5.psfs)]   # one bucket, cap 5
    sols = solve_many("deconvolve", insts, cfg=cfg, chunk=3)
    assert len({b.key for b in plan_buckets(
        insts, BatchAxes(record_axes=(0, 0)))}) == 1
    for inst, sol in zip(insts, sols):
        ref = solve("deconvolve", *inst, cfg=cfg, chunk=3)
        assert sol.x.shape == ref.x.shape
        np.testing.assert_array_equal(np.asarray(sol.x),
                                      np.asarray(ref.x))
