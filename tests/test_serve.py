"""repro.serve: async batched solve-as-a-service (DESIGN.md §20).

The contracts under test, each against the real solver stack on tiny
deconvolution instances:

- a served request reproduces its direct ``solve()`` trajectory
  (rtol 1e-4), solo and coalesced into a mixed-shape batch;
- admission control rejects with the *retriable* status on a full
  queue and while draining, and non-retriable on malformed input;
- queued requests cancel; dispatched ones don't;
- graceful drain: in-flight batches finish ``done``, queued requests
  are rejected retriable;
- progress events stream per chunk (long-poll primitive and the HTTP
  ndjson endpoint agree);
- per-request ``resilience=``/chaos pass-through recovers injected
  faults inside the serving path, dispatched solo;
- the HTTP transport round-trips all of the above over a real socket;
- concurrent serving threads agree on the memoized operator-norm
  setup (starlet + PSF spectral norms).

No pytest-asyncio in the container: each async scenario runs under its
own ``asyncio.run``.
"""
import asyncio
import threading

import jax
import numpy as np
import pytest

from repro.core.problem import solve
from repro.serve import (AsyncSolveService, RequestRejected, ServeConfig,
                         SolveRequest)

ITERS, CHUNK = 6, 2


@pytest.fixture(scope="module")
def instances():
    from repro.imaging import psf as psf_op
    out = []
    for (n, S, seed) in [(3, 16, 0), (5, 16, 1), (3, 20, 2), (4, 20, 3)]:
        d = psf_op.simulate(n, jax.random.PRNGKey(seed), stamp=S)
        out.append((d.Y, d.psfs))
    return out


def _cfg(**kw):
    from repro.imaging.condat import SolverConfig
    base = dict(mode="sparse", max_iter=ITERS, tol=0.0, n_scales=2)
    base.update(kw)
    return SolverConfig(**base)


OPTIONS = dict(chunk=CHUNK, cost_every=1)


def _req(inputs, **kw):
    kw.setdefault("options", dict(OPTIONS))
    return SolveRequest("deconvolve", inputs, cfg=_cfg(), **kw)


def _assert_parity(rec, ref, rtol=1e-4):
    assert rec.status == "done"
    np.testing.assert_allclose(np.asarray(rec.solution.log.costs),
                               np.asarray(ref.log.costs), rtol=rtol)
    for a, b in zip(jax.tree.leaves(rec.solution.x),
                    jax.tree.leaves(ref.x)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=rtol, atol=1e-6)


def _direct(inputs, **kw):
    opts = dict(OPTIONS)
    opts.update(kw)
    return solve("deconvolve", *inputs, cfg=_cfg(), **opts)


# =====================================================================
# Core service: parity, coalescing, admission, cancel, drain
# =====================================================================

def test_single_submit_parity(instances):
    ref = _direct(instances[0])

    async def run():
        async with AsyncSolveService(ServeConfig()) as svc:
            rec = await svc.submit(_req(instances[0]))
            return await svc.result(rec.id, timeout=300)

    rec = asyncio.run(run())
    _assert_parity(rec, ref)
    assert rec.batch_size == 1
    assert rec.latency_s is not None and rec.latency_s > 0
    # chunk-boundary progress arrived for a solo dispatch too
    assert len(rec.events) == ITERS // CHUNK
    assert rec.events[-1]["done"] == ITERS


def test_mixed_shape_coalescing_parity(instances):
    refs = [_direct(i) for i in instances]

    async def run():
        cfg = ServeConfig(batch_window_s=1.0, max_batch=8)
        async with AsyncSolveService(cfg) as svc:
            recs = [await svc.submit(_req(i)) for i in instances]
            return [await svc.result(r.id, timeout=600) for r in recs]

    recs = asyncio.run(run())
    for rec, ref in zip(recs, refs):
        _assert_parity(rec, ref)
    # two stamp shapes -> two buckets of two: coalescing actually
    # happened (occupancy > 1) and shapes never mixed in a bucket
    assert [r.batch_size for r in recs] == [2, 2, 2, 2]
    keys = {r.bucket_key for r in recs}
    assert len(keys) == 2
    assert recs[0].bucket_key == recs[1].bucket_key   # both stamp-16
    assert recs[2].bucket_key == recs[3].bucket_key   # both stamp-20


def test_queue_full_rejects_retriable(instances):
    async def run():
        cfg = ServeConfig(max_queue=1, batch_window_s=30.0, max_batch=8)
        async with AsyncSolveService(cfg) as svc:
            first = await svc.submit(_req(instances[0]))
            with pytest.raises(RequestRejected) as ei:
                await svc.submit(_req(instances[1]))
            assert ei.value.retriable
            assert ei.value.record.status == "rejected"
            assert await svc.cancel(first.id)
            # rejection left a queryable record behind
            assert svc.record(ei.value.record.id).retriable

    asyncio.run(run())


def test_malformed_request_rejects_non_retriable(instances):
    async def run():
        async with AsyncSolveService() as svc:
            with pytest.raises(RequestRejected) as ei:
                await svc.submit(SolveRequest("nonesuch", instances[0]))
            assert not ei.value.retriable

    asyncio.run(run())


def test_cancel_queued_not_running(instances):
    async def run():
        cfg = ServeConfig(batch_window_s=30.0, max_batch=8)
        async with AsyncSolveService(cfg) as svc:
            a = await svc.submit(_req(instances[0]))
            b = await svc.submit(_req(instances[1]))
            assert await svc.cancel(a.id)
            assert a.status == "cancelled"
            assert not await svc.cancel(a.id)      # already terminal
            # b still dispatches alone once its window would expire;
            # flush it now via drain-free path: cancel it too and check
            # the lane emptied cleanly
            assert await svc.cancel(b.id)
            assert svc.metrics.queue_depth == 0
            assert svc.metrics.counter("cancelled") == 2

    asyncio.run(run())


def test_graceful_drain(instances):
    """The §20 drain contract: in-flight batches finish ``done``,
    still-queued requests are rejected with the retriable status, and
    post-drain submits refuse immediately."""

    async def run():
        cfg = ServeConfig(batch_window_s=30.0, max_batch=2)
        async with AsyncSolveService(cfg) as svc:
            # these two hit max_batch -> dispatch immediately
            a = await svc.submit(_req(instances[0]))
            b = await svc.submit(_req(instances[1]))
            # this one sits in a fresh open bucket behind the long window
            c = await svc.submit(_req(instances[2]))
            assert c.status == "queued"
            summary = await svc.drain()
            assert summary["rejected_queued"] == 1
            assert c.status == "rejected" and c.retriable
            assert "drained" in c.error
            assert a.status == "done" and b.status == "done"
            with pytest.raises(RequestRejected) as ei:
                await svc.submit(_req(instances[3]))
            assert ei.value.retriable
            return a, b

    a, b = asyncio.run(run())
    _assert_parity(a, _direct(instances[0]))
    _assert_parity(b, _direct(instances[1]))


def test_progress_long_poll_stream(instances):
    async def run():
        async with AsyncSolveService() as svc:
            rec = await svc.submit(_req(instances[0]))
            events, cursor, terminal = [], 0, False
            while not terminal:
                chunk, terminal, cursor = await svc.wait_events(
                    rec.id, cursor, timeout=0.2)
                events.extend(chunk)
            return rec, events

    rec, events = asyncio.run(run())
    assert rec.status == "done"
    assert [e["done"] for e in events] == \
        list(range(CHUNK, ITERS + 1, CHUNK))
    assert all(np.isfinite(e["cost"]) for e in events)


def test_chaos_resilience_pass_through(instances):
    """A chaos-armed request dispatches solo and its ``resilience=``
    option rides through to the supervisor: the injected dispatch fault
    is retried and the trajectory still matches the clean direct run."""
    from repro.resilience.recovery import ResilienceConfig
    ref = _direct(instances[0])

    async def run():
        cfg = ServeConfig(batch_window_s=5.0, max_batch=8)
        async with AsyncSolveService(cfg) as svc:
            opts = dict(OPTIONS)
            opts["resilience"] = ResilienceConfig(max_retries=2,
                                                  backoff_s=0.0)
            rec = await svc.submit(_req(instances[0], options=opts,
                                        chaos_spec="dispatch@2"))
            return await svc.result(rec.id, timeout=300)

    rec = asyncio.run(run())
    assert rec.batch_size == 1          # chaos never shares a dispatch
    _assert_parity(rec, ref)
    assert rec.solution.recovery is not None
    assert rec.solution.recovery.retries == 1
    assert rec.solution.recovery.faults[0]["point"] == "dispatch"


def test_batch_failure_marks_all_failed(instances):
    """An unsupervised chaos fault fails the request (not the service):
    status ``failed`` with the error string, and the loop keeps serving."""

    async def run():
        async with AsyncSolveService() as svc:
            rec = await svc.submit(_req(instances[0],
                                        chaos_spec="dispatch@0"))
            got = await svc.result(rec.id, timeout=300)
            assert got.status == "failed"
            assert "InjectedFault" in got.error
            assert svc.metrics.counter("failed") == 1
            # service still healthy afterwards
            ok = await svc.submit(_req(instances[0]))
            done = await svc.result(ok.id, timeout=300)
            assert done.status == "done"

    asyncio.run(run())


# =====================================================================
# §21 serving resilience: quarantine, deadlines, cancel/drain in
# flight, breaker shedding, watchdog, journal replay
# =====================================================================

def test_quarantine_isolates_poisoned_lane(instances):
    """A ``serve_bucket_poison`` fault NaNs one lane of a coalesced
    bucket; the bucket fails as a unit and quarantine re-dispatches
    every lane solo: only the poisoned request fails (with a
    per-request recovery report), the sibling reproduces its direct
    trajectory."""
    from repro.resilience.recovery import ResilienceConfig
    res = ResilienceConfig(max_rollbacks=2, backoff_s=0.001, ring=2)

    async def run():
        cfg = ServeConfig(batch_window_s=0.3, max_batch=8,
                          chaos_spec="serve_bucket_poison@0;seed=7")
        async with AsyncSolveService(cfg) as svc:
            opts = dict(OPTIONS)
            opts["resilience"] = res
            a = await svc.submit(_req(instances[0],
                                      options=dict(opts)))
            b = await svc.submit(_req(instances[1],
                                      options=dict(opts)))
            got = [await svc.result(r.id, timeout=300) for r in (a, b)]
            assert svc.metrics.counter("quarantined") == 1
            return got

    out = asyncio.run(run())
    assert {r.bucket_key for r in out} == {out[0].bucket_key}
    assert out[0].batch_size == 2        # they really coalesced
    failed = [r for r in out if r.status == "failed"]
    assert len(failed) == 1
    assert failed[0].quarantined
    assert failed[0].recovery is not None
    assert failed[0].recovery.rollbacks >= 1
    # the per-request report also rides the event stream
    kinds = [e.get("kind") for e in failed[0].events]
    assert "recovery" in kinds
    sibling = next(r for r in out if r.status == "done")
    assert sibling.quarantined
    idx = out.index(sibling)
    _assert_parity(sibling, _direct(instances[idx]))


def test_deadline_expires_at_chunk_boundary(instances):
    """A running request past its ``deadline_s`` is frozen at the next
    chunk boundary — it fails with the deadline error without running
    its full iteration budget."""

    async def run():
        async with AsyncSolveService(ServeConfig(
                batch_window_s=0.05, max_batch=8)) as svc:
            opts = dict(OPTIONS)
            opts["max_iter"] = 600
            rec = await svc.submit(_req(instances[0], options=opts,
                                        deadline_s=0.5))
            got = await svc.result(rec.id, timeout=300)
            assert svc.metrics.counter("expired") == 1
            return got

    got = asyncio.run(run())
    assert got.status == "failed"
    assert "deadline" in got.error
    chunks = [e for e in got.events if e.get("kind") == "chunk"]
    assert max((e["done"] for e in chunks), default=0) < 600


def test_cancel_while_dispatched_freezes_lane(instances):
    """Cancelling a request already in a running coalesced batch
    freezes its lane at the next chunk boundary; the sibling's
    trajectory is untouched."""

    async def run():
        cfg = ServeConfig(batch_window_s=0.2, max_batch=8)
        async with AsyncSolveService(cfg) as svc:
            opts = dict(OPTIONS)
            opts["max_iter"] = 400
            a = await svc.submit(_req(instances[0], options=dict(opts)))
            b = await svc.submit(_req(instances[1], options=dict(opts)))
            # wait for the first progress event: the batch is running
            events, done, _ = await svc.wait_events(a.id, 0,
                                                    timeout=120)
            assert events and not done
            assert await svc.cancel(a.id)          # running -> flagged
            assert not await svc.cancel(a.id)      # only flags once
            got_a = await svc.result(a.id, timeout=300)
            got_b = await svc.result(b.id, timeout=300)
            assert svc.metrics.counter("cancelled") == 1
            return got_a, got_b

    got_a, got_b = asyncio.run(run())
    assert got_a.status == "cancelled"
    assert "chunk boundary" in got_a.error
    # the lane's own log records the freeze point (batched progress
    # events carry the *global* bucket iteration, not the lane's)
    assert got_a.solution is not None
    assert got_a.solution.log.cancelled_at is not None
    _assert_parity(got_b, _direct(instances[1], max_iter=400))


def test_drain_while_dispatched(instances):
    """Draining while a coalesced batch is in flight lets it finish:
    both members come back ``done`` with clean trajectories."""

    async def run():
        cfg = ServeConfig(batch_window_s=0.1, max_batch=8)
        async with AsyncSolveService(cfg) as svc:
            a = await svc.submit(_req(instances[0]))
            b = await svc.submit(_req(instances[1]))
            # in flight once progress starts streaming
            events, done, _ = await svc.wait_events(a.id, 0,
                                                    timeout=120)
            assert events or done
            await svc.drain()
            return (await svc.result(a.id, timeout=300),
                    await svc.result(b.id, timeout=300))

    got_a, got_b = asyncio.run(run())
    _assert_parity(got_a, _direct(instances[0]))
    _assert_parity(got_b, _direct(instances[1]))


def test_breaker_trips_sheds_and_recovers(instances):
    """Repeated dispatch failures trip the workload's circuit breaker:
    further submits shed with the retriable rejection; after the
    cooldown a half-open probe that succeeds closes it again."""

    async def run():
        cfg = ServeConfig(batch_window_s=0.0, max_batch=1,
                          breaker_min_samples=2, breaker_window=4,
                          breaker_error_threshold=0.5,
                          breaker_cooldown_s=0.3)
        async with AsyncSolveService(cfg) as svc:
            for _ in range(2):           # unsupervised injected faults
                rec = await svc.submit(_req(instances[0],
                                            chaos_spec="dispatch@0"))
                got = await svc.result(rec.id, timeout=300)
                assert got.status == "failed"
            assert svc.breaker_states()["deconvolve"]["state"] == "open"
            ok, detail = svc.ready()
            assert not ok and detail["open_breakers"] == ["deconvolve"]
            with pytest.raises(RequestRejected) as ei:
                await svc.submit(_req(instances[0]))
            assert ei.value.retriable
            assert svc.metrics.counter("shed") == 1
            await asyncio.sleep(0.35)    # cooldown -> half-open probe
            rec = await svc.submit(_req(instances[0]))
            got = await svc.result(rec.id, timeout=300)
            assert got.status == "done"
            assert svc.breaker_states()["deconvolve"]["state"] \
                == "closed"
            assert svc.ready()[0]

    asyncio.run(run())


def test_watchdog_reaps_hung_dispatch(instances):
    """A dispatch with no completion after ``dispatch_timeout_s`` is
    reaped: the request fails with the hung-dispatch error and the
    worker's lane is frozen at its next chunk boundary."""

    async def run():
        cfg = ServeConfig(batch_window_s=0.0, max_batch=1,
                          dispatch_timeout_s=0.4)
        async with AsyncSolveService(cfg) as svc:
            opts = dict(OPTIONS)
            opts["max_iter"] = 4000      # far longer than the timeout
            rec = await svc.submit(_req(instances[0], options=opts))
            got = await svc.result(rec.id, timeout=300)
            assert svc.metrics.counter("hung") == 1
            return got

    got = asyncio.run(run())
    assert got.status == "failed"
    assert "hung dispatch" in got.error


def test_journal_replay_recovers_dropped_request(instances):
    """The crash-between-journal-and-schedule drill: an admitted
    request the scheduler never saw (``serve_admit_drop``) survives a
    hard crash via the journal and completes on the restarted
    service."""
    import tempfile
    journal_dir = tempfile.mkdtemp(prefix="serve-journal-")
    ref = _direct(instances[0])

    async def phase1():
        cfg = ServeConfig(batch_window_s=0.05, max_batch=8,
                          journal_dir=journal_dir,
                          chaos_spec="serve_admit_drop@0")
        svc = AsyncSolveService(cfg)
        await svc.start()
        rec = await svc.submit(_req(instances[0]))
        assert rec.status == "queued"    # journaled, never scheduled
        await svc.abandon()
        return rec.id

    rid = asyncio.run(phase1())

    async def phase2():
        cfg = ServeConfig(batch_window_s=0.05, max_batch=8,
                          journal_dir=journal_dir)
        async with AsyncSolveService(cfg) as svc:
            got = await svc.result(rid, timeout=300)
            assert svc.metrics.counter("replayed") == 1
            return got

    got = asyncio.run(phase2())
    assert got.replayed
    _assert_parity(got, ref)


def test_wal_skips_torn_tail(tmp_path):
    """The WAL reader's contract: a torn/corrupt tail line is skipped,
    everything before it is intact."""
    from repro.checkpoint.wal import WriteAheadLog
    path = tmp_path / "j.wal"
    with WriteAheadLog(path) as wal:
        wal.append({"kind": "admit", "id": "a"})
        wal.append({"kind": "done", "id": "a", "status": "done"})
    with open(path, "ab") as f:
        f.write(b"deadbeef {torn")      # crash mid-append
    records, skipped = WriteAheadLog.read(path)
    assert [r["kind"] for r in records] == ["admit", "done"]
    assert skipped == 1


# =====================================================================
# HTTP transport round-trip
# =====================================================================

def test_http_roundtrip(instances):
    from repro.serve.client import ServeClient, ServeError
    from repro.serve.server import serve_http
    ref = _direct(instances[0])
    Y, psfs = (np.asarray(a) for a in instances[0])
    cfg_dict = dict(mode="sparse", max_iter=ITERS, tol=0.0, n_scales=2)

    with serve_http(ServeConfig(batch_window_s=0.2, max_batch=8)) as h:
        c = ServeClient(h.url, timeout=300)
        assert c.health()["ok"]
        rid = c.submit("deconvolve", (Y, psfs), cfg=cfg_dict,
                       options=dict(OPTIONS))
        events = list(c.events(rid))
        assert events[-1]["kind"] == "end"
        assert events[-1]["status"] == "done"
        chunks = [e for e in events if e.get("kind") == "chunk"]
        assert [e["done"] for e in chunks] == \
            list(range(CHUNK, ITERS + 1, CHUNK))
        res = c.result(rid, include_x=True, timeout=300)
        assert res["status"] == "done"
        assert res["iters_run"] == ITERS
        np.testing.assert_allclose(res["costs"],
                                   np.asarray(ref.log.costs), rtol=1e-4)
        np.testing.assert_allclose(np.asarray(res["x"], np.float32),
                                   np.asarray(ref.x), rtol=1e-4,
                                   atol=1e-6)
        assert set(res["time_percentiles_s"]) == {"p50", "p90", "p99"}

        # status view for a finished request
        st = c.status(rid)
        assert st["status"] == "done" and st["batch_size"] == 1

        # error surfaces: unknown id, malformed problem, late cancel
        with pytest.raises(ServeError) as ei:
            c.status("deadbeef")
        assert ei.value.status == 404
        with pytest.raises(ServeError) as ei:
            c.submit("nonesuch", (Y,))
        assert ei.value.status == 400 and not ei.value.retriable
        assert c.cancel(rid) is False          # already terminal

        m = c.metrics()
        assert m["counters"]["completed"] == 1
        assert m["counters"]["rejected"] == 1

        # drain over HTTP: later submits refuse retriable (503)
        c.drain()
        with pytest.raises(ServeError) as ei:
            c.submit("deconvolve", (Y, psfs), cfg=cfg_dict)
        assert ei.value.status == 503 and ei.value.retriable


def test_http_resilient_chaos_request(instances):
    """The CI serve-smoke drill: a chaos-armed request with a
    ``resilience`` dict submitted over the wire recovers and reports
    its RecoveryReport in the JSON result."""
    from repro.serve.client import ServeClient
    from repro.serve.server import serve_http
    Y, psfs = (np.asarray(a) for a in instances[0])

    with serve_http() as h:
        c = ServeClient(h.url, timeout=300)
        rid = c.submit(
            "deconvolve", (Y, psfs),
            cfg=dict(mode="sparse", max_iter=ITERS, tol=0.0, n_scales=2),
            options=dict(chunk=CHUNK, cost_every=1,
                         resilience=dict(max_retries=2, backoff_s=0.0)),
            chaos="dispatch@2")
        res = c.result(rid, timeout=300)
        assert res["status"] == "done"
        assert res["recovery"]["retries"] == 1


# =====================================================================
# Concurrent-setup thread safety (serving workers share process state)
# =====================================================================

def test_concurrent_setup_thread_safety(instances):
    """Concurrent server workers hit the memoized starlet spectral norm
    and the module-level jitted PSF power iteration simultaneously; all
    threads must agree with the single-threaded values."""
    from repro.imaging import psf as psf_op
    from repro.imaging import starlet
    starlet._spectral_norm_default.cache_clear()
    psfs = np.asarray(instances[0][1])
    want_star = starlet.spectral_norm(3, (16, 16))
    want_psf = psf_op.spectral_norm(psfs, iters=20)
    starlet._spectral_norm_default.cache_clear()

    results, errors = [], []
    barrier = threading.Barrier(8)

    def worker():
        try:
            barrier.wait()
            s = starlet.spectral_norm(3, (16, 16))
            p = psf_op.spectral_norm(psfs, iters=20)
            results.append((s, p))
        except Exception as e:             # pragma: no cover
            errors.append(e)

    threads = [threading.Thread(target=worker) for _ in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors
    assert len(results) == 8
    for s, p in results:
        assert s == want_star
        np.testing.assert_allclose(p, want_psf, rtol=1e-6)
    # one cache entry, not eight racing recomputations
    assert starlet._spectral_norm_default.cache_info().currsize == 1
