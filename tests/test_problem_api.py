"""Problem-API tests (DESIGN.md §14): the workload registry, the
``solve()`` entry point and its wiring derivation, the legacy-signature
deprecation shims (bit-identical results), the RunOptions compatibility
path, and the checkpoint/restore round-trip through
``core/persistence.py``."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import problems
from repro.core.bundle import Bundle
from repro.core.driver import IterativeDriver, RunOptions
from repro.core.problem import Problem, derive_options, register, solve
from repro.data.synthetic import coupled_patches
from repro.imaging import psf as psf_op
from repro.imaging.condat import SolverConfig
from repro.imaging.deconvolve import DeconvolutionProblem, deconvolve
from repro.imaging.lowrank import CompletionConfig, LowRankCompletionProblem
from repro.imaging.scdl import SCDLConfig, SCDLProblem, train

KEY = jax.random.PRNGKey(3)


@pytest.fixture(scope="module")
def psf_data():
    return psf_op.simulate(8, jax.random.PRNGKey(2))


@pytest.fixture(scope="module")
def scdl_data():
    return coupled_patches(256, 25, 9, 16, seed=5)


# ------------------------------------------------------------ registry
def test_registry_lists_all_workloads():
    keys = problems.list()
    for k in ("deconvolve", "lowrank", "scdl"):
        assert k in keys
    assert problems.get("deconvolve") is DeconvolutionProblem
    assert problems.get("scdl") is SCDLProblem
    assert problems.get("lowrank") is LowRankCompletionProblem
    for k in keys:
        assert issubclass(problems.get(k), Problem)
        assert problems.get(k).name == k


def test_registry_unknown_key_raises_helpful_error():
    with pytest.raises(KeyError) as exc:
        problems.get("no_such_workload")
    msg = str(exc.value)
    assert "no_such_workload" in msg
    for k in ("deconvolve", "lowrank", "scdl"):
        assert k in msg            # the error names what IS available
    assert "register" in msg       # ...and how to add one


def test_register_validates():
    with pytest.raises(TypeError):
        register("bogus")(object)  # not a Problem subclass

    class Dupe(Problem):
        pass

    with pytest.raises(ValueError, match="already registered"):
        register("scdl")(Dupe)


# ------------------------------------------------- deprecation shims
def test_deconvolve_shim_warns_and_matches_solve(psf_data):
    cfg = SolverConfig(mode="sparse", n_scales=3)
    sol = solve("deconvolve", psf_data.Y, psf_data.psfs, cfg=cfg,
                max_iter=6, tol=0, chunk=4)
    with pytest.deprecated_call():
        X_old, log_old = deconvolve(psf_data.Y, psf_data.psfs, cfg,
                                    max_iter=6, tol=0, chunk=4)
    # the shim routes through solve(): bit-identical, not merely close
    np.testing.assert_array_equal(np.asarray(sol.x), np.asarray(X_old))
    np.testing.assert_array_equal(sol.log.costs, log_old.costs)


def test_train_shim_warns_and_matches_solve(scdl_data):
    S_h, S_l = scdl_data
    cfg = SCDLConfig(n_atoms=16, max_iter=6)
    sol = solve("scdl", S_h, S_l, cfg=cfg, chunk=4)
    with pytest.deprecated_call():
        Xh, Xl, log_old = train(S_h, S_l, cfg, chunk=4)
    np.testing.assert_array_equal(np.asarray(sol.x[0]), Xh)
    np.testing.assert_array_equal(np.asarray(sol.x[1]), Xl)
    np.testing.assert_array_equal(sol.log.costs, log_old.costs)


def _ridge_bundle_and_step():
    X = jax.random.normal(KEY, (64, 4))
    y = X @ jnp.arange(1.0, 5.0)
    bundle = Bundle.create({"X": X, "y": y},
                           replicated={"w": jnp.zeros((4,))})

    def step(d, rep, axes):
        r = d["X"] @ rep["w"] - d["y"]
        grad = d["X"].T @ r / d["X"].shape[0]
        return d, {"cost": 0.5 * jnp.sum(r ** 2),
                   "w": rep["w"] - 0.1 * grad}

    return bundle, step


def test_driver_legacy_kwargs_warn_and_match_options():
    bundle, step = _ridge_bundle_and_step()
    upd = lambda rep, out: {"w": out["w"]}
    with pytest.deprecated_call():
        legacy = IterativeDriver(step, bundle, max_iter=8, tol=0,
                                 chunk=4, update_replicated=upd)
    legacy_out = legacy.run()
    bundle2, step2 = _ridge_bundle_and_step()
    opt = IterativeDriver(step2, bundle2, options=RunOptions(
        max_iter=8, tol=0, chunk=4, update_replicated=upd))
    opt_out = opt.run()
    np.testing.assert_array_equal(legacy.log.costs, opt.log.costs)
    np.testing.assert_array_equal(np.asarray(legacy_out.replicated["w"]),
                                  np.asarray(opt_out.replicated["w"]))


def test_driver_unknown_kwarg_raises():
    bundle, step = _ridge_bundle_and_step()
    with pytest.raises(TypeError, match="step_fm_light"):
        IterativeDriver(step, bundle, step_fm_light=lambda *a: None)


def test_driver_integer_cost_every_rejects_cost_fn():
    """An integer cadence + a step_fn_cost is a wiring contradiction
    (the function would be dead): fail loudly instead of silently
    picking one of the two modes."""
    bundle, step = _ridge_bundle_and_step()
    with pytest.raises(ValueError, match='cost_every="chunk"'):
        IterativeDriver(step, bundle, options=RunOptions(
            max_iter=8, tol=0, chunk=4, cost_every=2,
            step_fn_light=lambda d, r, a: d,
            step_fn_cost=lambda d, r, a: jnp.float32(-1.0)))


def test_cost_every_typo_raises():
    with pytest.raises(ValueError, match="chunk"):
        RunOptions(cost_every="Chunk")


def test_solve_rejects_non_problem_argument(psf_data):
    """Passing the config where the problem goes must fail with a
    guided error, not an opaque AttributeError downstream."""
    with pytest.raises(TypeError, match="workload key"):
        solve(SolverConfig(mode="sparse"), psf_data.Y, psf_data.psfs)


def test_driver_chunk_cost_requires_both_steps():
    """cost_every="chunk" with only one half of the contract must fail
    loudly instead of silently evaluating the objective every
    iteration."""
    bundle, step = _ridge_bundle_and_step()
    with pytest.raises(ValueError, match="step_fn_light"):
        IterativeDriver(step, bundle, options=RunOptions(
            cost_every="chunk", step_fn_cost=lambda d, r, a: 0.0))
    with pytest.raises(ValueError, match="step_fn_cost"):
        IterativeDriver(step, bundle, options=RunOptions(
            cost_every="chunk", step_fn_light=lambda d, r, a: d))


# ------------------------------------------------- wiring derivation
def test_solve_rejects_wiring_kwargs(psf_data):
    with pytest.raises(TypeError, match="derived from the Problem"):
        solve("deconvolve", psf_data.Y, psf_data.psfs,
              cfg=SolverConfig(mode="sparse", n_scales=3),
              step_fn_light=lambda *a: None)


def test_derive_options_enforces_declarations():
    class NoLight(Problem):
        def full_step(self, d, rep, axes):
            return d, jnp.float32(0.0)

    with pytest.raises(ValueError, match="light_step"):
        derive_options(NoLight(), RunOptions(cost_every=4))
    with pytest.raises(ValueError, match="cost"):
        derive_options(NoLight(), RunOptions(cost_every="chunk"))

    class Carry(NoLight):
        replicated_in_carry = True

    with pytest.raises(ValueError, match="refresh_replicated"):
        derive_options(Carry(), RunOptions())

    class BareLightRefresh(NoLight):
        # refresh declared but NOT in-carry: the light step returns
        # bare d', so the chunk-cost scan could never feed the update
        def light_step(self, d, rep, axes):
            return d

        def cost(self, d, rep, axes):
            return jnp.float32(0.0)

        def refresh_replicated(self, rep, out):
            return rep

    with pytest.raises(ValueError, match="replicated_in_carry"):
        derive_options(BareLightRefresh(), RunOptions(cost_every="chunk"))


@pytest.mark.parametrize("mode", ["sparse", "lowrank"])
def test_deconvolve_per_chunk_cost_mode(psf_data, mode):
    """The generalized chunk-granular objective (bare-return light step,
    no broadcast update): chunk-final entries match the every-iteration
    run, earlier slots carry the previous evaluation (+inf first)."""
    cfg = SolverConfig(mode=mode, n_scales=3, lam=0.05, rank=8)
    sol1 = solve("deconvolve", psf_data.Y, psf_data.psfs, cfg=cfg,
                 max_iter=12, tol=0, chunk=5, cost_every=1)
    solc = solve("deconvolve", psf_data.Y, psf_data.psfs, cfg=cfg,
                 max_iter=12, tol=0, chunk=5, cost_every="chunk")
    np.testing.assert_allclose(np.asarray(solc.x), np.asarray(sol1.x),
                               rtol=1e-6, atol=1e-7)
    c1, cc = np.asarray(sol1.log.costs), np.asarray(solc.log.costs)
    assert len(cc) == 12
    for i in (4, 9, 11):           # chunk-final iterations (12 = 5+5+2)
        np.testing.assert_allclose(cc[i], c1[i], rtol=1e-5)
    assert np.isinf(cc[0]) and cc[5] == cc[4]


# --------------------------------------------- third workload: lowrank
def test_lowrank_completion_recovers():
    k1, k2, k3 = jax.random.split(KEY, 3)
    A = jax.random.normal(k1, (64, 4)) @ jax.random.normal(k2, (4, 48))
    M = (jax.random.uniform(k3, A.shape) < 0.6).astype(A.dtype)
    # the range finder must overshoot the target rank comfortably: the
    # masked residual raises the iterate's rank above r between SVTs
    cfg = CompletionConfig(rank=12, oversample=12, lam=0.2, step=0.9,
                           max_iter=300)
    sol = solve("lowrank", A, M, cfg=cfg, tol=0)
    err0 = float(jnp.linalg.norm(M * A - A) / jnp.linalg.norm(A))
    err = float(np.linalg.norm(sol.x - np.asarray(A))
                / np.linalg.norm(np.asarray(A)))
    assert err < 0.1 * err0        # ~0.61 -> ~0.02 at these settings
    assert sol.log.costs[-1] < sol.log.costs[0]


def test_lowrank_completion_chunked_matches_per_step():
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(9), 3)
    A = jax.random.normal(k1, (32, 3)) @ jax.random.normal(k2, (3, 24))
    M = (jax.random.uniform(k3, A.shape) < 0.7).astype(A.dtype)
    cfg = CompletionConfig(rank=6, lam=0.05, max_iter=12)
    sol1 = solve("lowrank", A, M, cfg=cfg, tol=0, chunk=1)
    solk = solve("lowrank", A, M, cfg=cfg, tol=0, chunk=5)
    np.testing.assert_allclose(solk.log.costs, sol1.log.costs, rtol=1e-5)
    np.testing.assert_allclose(solk.x, sol1.x, rtol=1e-4, atol=1e-5)
    # integer skipping and per-chunk objective also wire up (light+cost)
    sol3 = solve("lowrank", A, M, cfg=cfg, tol=0, chunk=4, cost_every=3)
    np.testing.assert_allclose(np.asarray(sol3.log.costs)[::3],
                               np.asarray(sol1.log.costs)[::3], rtol=1e-5)
    solc = solve("lowrank", A, M, cfg=cfg, tol=0, chunk=4,
                 cost_every="chunk")
    np.testing.assert_allclose(np.asarray(solc.log.costs)[3::4],
                               np.asarray(sol1.log.costs)[3::4],
                               rtol=1e-5)


# ------------------------------------------- checkpoint/restore e2e
def test_checkpoint_roundtrip_scdl(tmp_path, scdl_data):
    """solve(checkpoint_every=k) then resume into a fresh solve: the
    cost trajectory continues exactly where the first run left off —
    covers core/persistence.spill_bundle/restore_bundle end-to-end,
    including the broadcast carry (dictionaries + solve factors)."""
    S_h, S_l = scdl_data
    cfg = SCDLConfig(n_atoms=16, max_iter=12)
    full = solve("scdl", S_h, S_l, cfg=cfg, chunk=4, tol=0)
    d = tmp_path / "ckpt_scdl"
    part = solve("scdl", S_h, S_l, cfg=cfg, chunk=4, tol=0, max_iter=8,
                 checkpoint_dir=d, checkpoint_every=4)
    assert len(part.log.costs) == 8
    assert sorted(p.name for p in d.iterdir()) == [
        "step_00000004", "step_00000008"]
    rest = solve("scdl", S_h, S_l, cfg=cfg, chunk=4, tol=0, max_iter=12,
                 checkpoint_dir=d, resume=True)
    np.testing.assert_allclose(rest.log.costs, full.log.costs[8:],
                               rtol=1e-6)
    np.testing.assert_allclose(np.asarray(rest.x[0]),
                               np.asarray(full.x[0]),
                               rtol=1e-5, atol=1e-6)


def test_checkpoint_roundtrip_deconvolve(tmp_path, psf_data):
    """Same round-trip for a workload whose iterate is all data-side
    (no broadcast carry), resuming from an explicit step."""
    cfg = SolverConfig(mode="sparse", n_scales=3)
    d = tmp_path / "ckpt_psf"
    full = solve("deconvolve", psf_data.Y, psf_data.psfs, cfg=cfg,
                 max_iter=12, tol=0, chunk=4)
    solve("deconvolve", psf_data.Y, psf_data.psfs, cfg=cfg,
          max_iter=8, tol=0, chunk=4, checkpoint_dir=d,
          checkpoint_every=8)
    rest = solve("deconvolve", psf_data.Y, psf_data.psfs, cfg=cfg,
                 max_iter=12, tol=0, chunk=4, checkpoint_dir=d,
                 resume=8)
    np.testing.assert_allclose(rest.log.costs, full.log.costs[8:],
                               rtol=1e-6)
    np.testing.assert_allclose(np.asarray(rest.x), np.asarray(full.x),
                               rtol=1e-6, atol=1e-7)


def test_checkpoint_meta_guards_workload(tmp_path, psf_data, scdl_data):
    """A checkpoint written by one workload refuses to restore into
    another (manifest meta check)."""
    S_h, S_l = scdl_data
    d = tmp_path / "ckpt_guard"
    solve("scdl", S_h, S_l, cfg=SCDLConfig(n_atoms=16, max_iter=4),
          chunk=4, tol=0, checkpoint_dir=d, checkpoint_every=4)
    with pytest.raises(ValueError, match="meta"):
        solve("deconvolve", psf_data.Y, psf_data.psfs,
              cfg=SolverConfig(mode="sparse", n_scales=3),
              max_iter=6, tol=0, checkpoint_dir=d, resume=True)


def test_checkpoint_meta_guards_config(tmp_path, scdl_data):
    """Resuming under a *changed* config (same shapes!) must fail
    loudly — the manifest carries a config fingerprint."""
    S_h, S_l = scdl_data
    d = tmp_path / "ckpt_cfg"
    solve("scdl", S_h, S_l, cfg=SCDLConfig(n_atoms=16, max_iter=4),
          chunk=4, tol=0, checkpoint_dir=d, checkpoint_every=4)
    with pytest.raises(ValueError, match="meta"):
        solve("scdl", S_h, S_l,
              cfg=SCDLConfig(n_atoms=16, max_iter=8, lam_h=0.5),
              chunk=4, tol=0, checkpoint_dir=d, resume=True)
    # ...but run-control fields (max_iter/tol) are excluded from the
    # fingerprint: extending the budget on resume is the canonical
    # continue-a-finished-run workflow
    rest = solve("scdl", S_h, S_l,
                 cfg=SCDLConfig(n_atoms=16, max_iter=6),
                 chunk=4, tol=0, checkpoint_dir=d, resume=True)
    assert len(rest.log.costs) == 2  # iterations 4..6


def test_resume_missing_step_raises(tmp_path, scdl_data):
    S_h, S_l = scdl_data
    d = tmp_path / "ckpt_step"
    solve("scdl", S_h, S_l, cfg=SCDLConfig(n_atoms=16, max_iter=4),
          chunk=4, tol=0, checkpoint_dir=d, checkpoint_every=4)
    with pytest.raises(ValueError, match="latest saved step"):
        solve("scdl", S_h, S_l, cfg=SCDLConfig(n_atoms=16, max_iter=8),
              chunk=4, tol=0, checkpoint_dir=d, resume=12)


def test_resume_without_dir_raises(psf_data):
    with pytest.raises(ValueError, match="checkpoint_dir"):
        solve("deconvolve", psf_data.Y, psf_data.psfs,
              cfg=SolverConfig(mode="sparse", n_scales=3), resume=True)


def test_resume_from_empty_dir_raises(tmp_path, psf_data):
    """A mistyped/never-written checkpoint directory must fail loudly,
    not silently recompute from iteration 0."""
    with pytest.raises(ValueError, match="no checkpoints"):
        solve("deconvolve", psf_data.Y, psf_data.psfs,
              cfg=SolverConfig(mode="sparse", n_scales=3),
              checkpoint_dir=tmp_path / "nowhere", resume=True)


def test_checkpoint_every_without_dir_raises(psf_data):
    """checkpoint_every with nowhere to write must fail loudly, not
    silently produce an unrecoverable run."""
    with pytest.raises(ValueError, match="checkpoint_dir"):
        solve("deconvolve", psf_data.Y, psf_data.psfs,
              cfg=SolverConfig(mode="sparse", n_scales=3),
              max_iter=4, checkpoint_every=2)


def test_checkpoint_dir_without_cadence_or_resume_raises(tmp_path,
                                                         psf_data):
    """The converse asymmetry: a checkpoint_dir that would never be
    read or written signals a mistake."""
    with pytest.raises(ValueError, match="checkpoint_every"):
        solve("deconvolve", psf_data.Y, psf_data.psfs,
              cfg=SolverConfig(mode="sparse", n_scales=3),
              max_iter=4, checkpoint_dir=tmp_path / "ckpt")


def test_options_with_wiring_fields_rejected(psf_data):
    with pytest.raises(TypeError, match="step wiring"):
        solve("deconvolve", psf_data.Y, psf_data.psfs,
              cfg=SolverConfig(mode="sparse", n_scales=3),
              options=RunOptions(max_iter=4,
                                 update_replicated=lambda r, o: r))


# ------------------------------------------------- custom problems
def test_custom_problem_through_solve():
    """The quickstart promise: a new workload is one small declaration —
    replicated-carry ridge regression converging through solve()."""

    class Ridge(Problem):
        replicated_in_carry = True

        def init_bundle(self, inputs, mesh):
            X, y = inputs
            return Bundle.create(
                {"X": X, "y": y},
                replicated={"w": jnp.zeros(X.shape[1], X.dtype)})

        def full_step(self, d, rep, axes):
            r = d["X"] @ rep["w"] - d["y"]
            grad = d["X"].T @ r
            n = jnp.float32(d["X"].shape[0])
            if axes:
                grad = jax.lax.psum(grad, axes)
                n = jax.lax.psum(n, axes)
            return d, {"cost": 0.5 * jnp.sum(r ** 2),
                       "w": rep["w"] - 0.3 * grad / n}

        def refresh_replicated(self, rep, out):
            return dict(rep, w=out["w"])

        def finalize(self, bundle, log):
            return np.asarray(jax.device_get(bundle.replicated["w"])), {}

    X = jax.random.normal(KEY, (32, 3))
    y = X @ jnp.ones((3,))
    sol = solve(Ridge(), X, y, max_iter=200, tol=1e-6, chunk=8)
    assert sol.log.converged_at is not None
    np.testing.assert_allclose(sol.x, np.ones(3), rtol=1e-2)
    assert sol.costs == sol.log.costs


# ----------------------------------------------- percentiles / progress

def test_percentiles_helper():
    from repro.core.driver import RunLog, percentiles
    assert percentiles([]) == {}
    vals = list(range(1, 101))                      # 1..100
    p = percentiles(vals, qs=(50, 90, 99))
    assert set(p) == {"p50", "p90", "p99"}
    assert p["p50"] == pytest.approx(50.5)
    assert p["p50"] <= p["p90"] <= p["p99"]
    # non-integer quantiles keep their float label
    assert set(percentiles(vals, qs=(99.9,))) == {"p99.9"}
    log = RunLog(times=[0.1, 0.2, 0.3, 0.4])
    assert log.percentiles() == percentiles(log.times)
    assert RunLog().percentiles() == {}


def test_solution_percentiles_surface(psf_data):
    cfg = SolverConfig(mode="sparse", max_iter=6, tol=0.0, n_scales=2)
    sol = solve("deconvolve", psf_data.Y, psf_data.psfs, cfg=cfg, chunk=3)
    p = sol.percentiles()
    assert set(p) == {"p50", "p90", "p99"}
    assert p == sol.log.percentiles()
    assert all(v >= 0 for v in p.values())
    assert sol.percentiles(qs=(50,)) == {
        "p50": pytest.approx(float(np.percentile(sol.log.times, 50)))}


def test_progress_fn_chunk_events(psf_data):
    """progress_fn fires once per chunk-boundary sync with the running
    iteration count and the newest objective; the per-step path fires
    per iteration; iters_run lands on the log for both."""
    cfg = SolverConfig(mode="sparse", max_iter=7, tol=0.0, n_scales=2)
    events = []
    sol = solve("deconvolve", psf_data.Y, psf_data.psfs, cfg=cfg,
                chunk=3, cost_every=1, progress_fn=events.append)
    assert [e["done"] for e in events] == [3, 6, 7]   # tail chunk of 1
    assert [e["iters"] for e in events] == [3, 3, 1]
    assert all(e["kind"] == "chunk" for e in events)
    assert events[-1]["cost"] == pytest.approx(sol.log.costs[-1])
    assert all(e["dt_s"] > 0 for e in events)
    assert sol.log.iters_run == 7

    per_step = []
    sol1 = solve("deconvolve", psf_data.Y, psf_data.psfs, cfg=cfg,
                 chunk=1, progress_fn=per_step.append)
    assert [e["done"] for e in per_step] == list(range(1, 8))
    assert sol1.log.iters_run == 7


def test_progress_fn_batched_per_instance(psf_data):
    """solve_many relays per-instance progress keyed by original index,
    skipping padding rows."""
    from repro.core.problem import solve_many
    cfg = SolverConfig(mode="sparse", max_iter=6, tol=0.0, n_scales=2)
    d2 = psf_op.simulate(3, jax.random.PRNGKey(7))
    seen = {}
    sols = solve_many(
        "deconvolve", [(psf_data.Y, psf_data.psfs), (d2.Y, d2.psfs)],
        cfg=cfg, chunk=3,
        progress_fn=lambda e: [seen.setdefault(j, []).append(st)
                               for j, st in e["instances"].items()])
    assert sorted(seen) == [0, 1]
    for j, sol in enumerate(sols):
        assert seen[j][-1]["iters_run"] == sol.log.iters_run == 6
        assert seen[j][-1]["cost"] == pytest.approx(sol.log.costs[-1])
