"""Fused-iteration engine tests: `make_scan_step(chunk=K)` trajectories
must match the per-step driver and the sequential reference for every
workload, including chunk lengths that don't divide max_iter (tail
chunks) and cost_every skipping."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.bundle import Bundle
from repro.core.driver import IterativeDriver, RunOptions
from repro.core.engine import make_scan_step
from repro.core.problem import solve as solve_problem
from repro.data.synthetic import coupled_patches
from repro.imaging import psf as psf_op
from repro.imaging.condat import SolverConfig, solve
from repro.imaging.deconvolve import DeconvolutionProblem
from repro.imaging.scdl import SCDLConfig, SCDLProblem


def deconvolve(Y, psfs, cfg, sigma_noise=0.02, **kw):
    """Drive Algorithm 1 through solve() (the shim-free path; the
    deprecated legacy signatures are covered by test_problem_api)."""
    sol = solve_problem(DeconvolutionProblem(cfg, sigma_noise=sigma_noise),
                        Y, psfs, **kw)
    return sol.x, sol.log


def train(S_h, S_l, cfg, **kw):
    """Drive Algorithm 2 through solve()."""
    sol = solve_problem(SCDLProblem(cfg), S_h, S_l, **kw)
    Xh, Xl = sol.x
    return Xh, Xl, sol.log


KEY = jax.random.PRNGKey(2)
N_ITER = 12


@pytest.fixture(scope="module")
def psf_data():
    return psf_op.simulate(8, KEY)


@pytest.mark.parametrize("mode", ["sparse", "lowrank"])
@pytest.mark.parametrize("chunk", [4, 5, 32])
def test_fused_matches_per_step_and_sequential(psf_data, mode, chunk):
    """chunk=5 exercises the tail chunk (12 = 5 + 5 + 2); chunk=32 a
    single chunk longer than the run."""
    cfg = SolverConfig(mode=mode, n_scales=3, lam=0.05, rank=8)
    _, costs_seq = solve(psf_data.Y, psf_data.psfs, cfg,
                         sigma_noise=psf_data.sigma, n_iter=N_ITER)
    _, log_1 = deconvolve(psf_data.Y, psf_data.psfs, cfg,
                          sigma_noise=psf_data.sigma, max_iter=N_ITER,
                          tol=0, chunk=1)
    _, log_k = deconvolve(psf_data.Y, psf_data.psfs, cfg,
                          sigma_noise=psf_data.sigma, max_iter=N_ITER,
                          tol=0, chunk=chunk)
    assert len(log_k.costs) == N_ITER
    # low-rank replaces the reference's exact SVT with the randomized
    # range-finder SVT (DESIGN.md §2) — match the reference loosely and
    # the per-step driver (same math) tightly
    seq_rtol = 1e-5 if mode == "sparse" else 5e-2
    np.testing.assert_allclose(np.asarray(log_1.costs),
                               np.asarray(costs_seq), rtol=seq_rtol)
    np.testing.assert_allclose(np.asarray(log_k.costs),
                               np.asarray(log_1.costs), rtol=1e-5)


def test_fused_cost_every_matches_on_grid(psf_data):
    cfg = SolverConfig(mode="sparse", n_scales=3)
    X1, log_1 = deconvolve(psf_data.Y, psf_data.psfs, cfg,
                           sigma_noise=psf_data.sigma, max_iter=N_ITER,
                           tol=0, chunk=4, cost_every=1)
    X3, log_3 = deconvolve(psf_data.Y, psf_data.psfs, cfg,
                           sigma_noise=psf_data.sigma, max_iter=N_ITER,
                           tol=0, chunk=4, cost_every=3)
    # identical iterates; objective evaluated only on the cost grid
    np.testing.assert_allclose(X3, X1, rtol=1e-6, atol=1e-7)
    c1, c3 = np.asarray(log_1.costs), np.asarray(log_3.costs)
    np.testing.assert_allclose(c3[::3], c1[::3], rtol=1e-5)
    # off-grid entries carry the last evaluated cost forward
    assert c3[1] == c3[0] and c3[2] == c3[0]
    # ...including across a chunk boundary (i=4 starts chunk 2 with
    # 4 % 3 != 0): the carry must survive the dispatch, not reset to 0
    assert c3[4] == c3[3] and c3[5] == c3[3]
    assert (c3 != 0.0).all()


def test_per_step_cost_every_matches_on_grid(psf_data):
    """cost_every must also skip on the chunk=1 (per-step) path."""
    cfg = SolverConfig(mode="sparse", n_scales=3)
    X1, log_1 = deconvolve(psf_data.Y, psf_data.psfs, cfg,
                           sigma_noise=psf_data.sigma, max_iter=6,
                           tol=0, chunk=1, cost_every=1)
    X3, log_3 = deconvolve(psf_data.Y, psf_data.psfs, cfg,
                           sigma_noise=psf_data.sigma, max_iter=6,
                           tol=0, chunk=1, cost_every=3)
    np.testing.assert_allclose(X3, X1, rtol=1e-6, atol=1e-7)
    c1, c3 = np.asarray(log_1.costs), np.asarray(log_3.costs)
    np.testing.assert_allclose(c3[::3], c1[::3], rtol=1e-5)
    assert c3[1] == c3[0] and c3[4] == c3[3]


@pytest.mark.parametrize("chunk", [4, 5])
def test_scdl_fused_matches_per_step(chunk):
    S_h, S_l = coupled_patches(256, 25, 9, 16, seed=5)
    cfg = SCDLConfig(n_atoms=16, max_iter=N_ITER)
    Xh1, Xl1, log_1 = train(S_h, S_l, cfg, chunk=1)
    Xhk, Xlk, log_k = train(S_h, S_l, cfg, chunk=chunk)
    assert len(log_k.costs) == N_ITER
    np.testing.assert_allclose(log_k.costs, log_1.costs, rtol=1e-5)
    # chunk=1 folds the broadcast factors on the host (eager) vs in the
    # scan carry (jitted) — identical algebra, ulp-level fp differences
    np.testing.assert_allclose(Xhk, Xh1, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(Xlk, Xl1, rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("chunk", [1, 4])
def test_scdl_cost_every_matches_on_grid(chunk):
    """SCDL's cost_every (the light step feeds the dictionary broadcast
    every iteration — ``light_updates_replicated``): identical iterates,
    objective only on the grid, on both the fused and per-step paths."""
    S_h, S_l = coupled_patches(256, 25, 9, 16, seed=5)
    cfg = SCDLConfig(n_atoms=16, max_iter=N_ITER)
    Xh1, _, log_1 = train(S_h, S_l, cfg, chunk=chunk, cost_every=1)
    Xh3, _, log_3 = train(S_h, S_l, cfg, chunk=chunk, cost_every=3)
    np.testing.assert_allclose(Xh3, Xh1, rtol=1e-5, atol=1e-7)
    c1, c3 = np.asarray(log_1.costs), np.asarray(log_3.costs)
    np.testing.assert_allclose(c3[::3], c1[::3], rtol=1e-5)
    # off-grid entries carry the last evaluated objective forward,
    # including across the chunk boundary at i=4 (4 % 3 != 0)
    assert c3[1] == c3[0] and c3[2] == c3[0]
    assert c3[4] == c3[3] and c3[5] == c3[3]


def test_scdl_per_chunk_cost_matches(chunk=5):
    """cost_every="chunk" (engine.make_chunk_cost_step): no cond in the
    scan body, one objective evaluation per dispatch on the chunk-final
    state — entries match the full run at chunk-final iterations, the
    rest carry the previous evaluation (+inf before the first)."""
    S_h, S_l = coupled_patches(256, 25, 9, 16, seed=5)
    cfg = SCDLConfig(n_atoms=16, max_iter=N_ITER)
    Xh1, _, log_1 = train(S_h, S_l, cfg, chunk=chunk)
    Xhc, _, log_c = train(S_h, S_l, cfg, chunk=chunk,
                          cost_every="chunk")
    np.testing.assert_allclose(Xhc, Xh1, rtol=1e-5, atol=1e-7)
    c1, cc = np.asarray(log_1.costs), np.asarray(log_c.costs)
    assert len(cc) == N_ITER
    # chunk-final entries: 4, 9, and the tail chunk's 11 (12 = 5+5+2)
    for i in (4, 9, 11):
        np.testing.assert_allclose(cc[i], c1[i], rtol=1e-5)
    assert np.isinf(cc[0]) and cc[5] == cc[4]


def test_make_scan_step_cost_buffer_and_carry():
    """Direct engine-level check: (K,) cost buffer, replicated carried
    through the scan via update_replicated."""
    key = jax.random.PRNGKey(0)
    X = jax.random.normal(key, (64, 4))
    y = X @ jnp.arange(1.0, 5.0)
    bundle = Bundle.create({"X": X, "y": y},
                           replicated={"w": jnp.zeros((4,))})

    def step(d, rep, axes):
        r = d["X"] @ rep["w"] - d["y"]
        grad = d["X"].T @ r / d["X"].shape[0]
        cost = 0.5 * jnp.sum(r ** 2)
        if axes:
            grad = jax.lax.psum(grad, axes)
            cost = jax.lax.psum(cost, axes)
        return d, {"cost": cost, "w": rep["w"] - 0.1 * grad}

    fused = make_scan_step(step, bundle, chunk=6, donate=False,
                           update_replicated=lambda rep, out:
                           {"w": out["w"]})
    data, rep, trace = fused(bundle.data, bundle.replicated, 0)
    assert trace["cost"].shape == (6,)
    # dictionaries/matrix outputs are folded into the carry, not stacked
    assert "w" not in trace
    costs = np.asarray(trace["cost"])
    assert (np.diff(costs) < 0).all()          # GD on a ridge problem

    # the fused trajectory equals six per-step applications
    rep_ref = {"w": jnp.zeros((4,))}
    ref_costs = []
    d_ref = bundle.data
    for _ in range(6):
        d_ref, out = step(d_ref, rep_ref, ())
        ref_costs.append(float(out["cost"]))
        rep_ref = {"w": out["w"]}
    np.testing.assert_allclose(costs, ref_costs, rtol=1e-6)
    np.testing.assert_allclose(np.asarray(rep["w"]),
                               np.asarray(rep_ref["w"]), rtol=1e-6)


def test_driver_chunked_convergence_and_log():
    """Chunked driver stops on the chunk boundary after convergence and
    logs per-iteration times."""
    key = jax.random.PRNGKey(1)
    X = jax.random.normal(key, (32, 3))
    y = X @ jnp.ones((3,))
    bundle = Bundle.create({"X": X, "y": y},
                           replicated={"w": jnp.zeros((3,))})

    def step(d, rep, axes):
        r = d["X"] @ rep["w"] - d["y"]
        grad = d["X"].T @ r / d["X"].shape[0]
        return d, {"cost": 0.5 * jnp.sum(r ** 2),
                   "w": rep["w"] - 0.3 * grad}

    driver = IterativeDriver(
        step, bundle, options=RunOptions(
            max_iter=200, tol=1e-6, chunk=8,
            update_replicated=lambda rep, out: {"w": out["w"]}))
    out = driver.run()
    assert driver.log.converged_at is not None
    assert (driver.log.converged_at + 1) % 8 == 0
    assert len(driver.log.times) == len(driver.log.costs)
    w = np.asarray(out.replicated["w"])
    np.testing.assert_allclose(w, np.ones(3), rtol=1e-2)
