"""MoE routing correctness: the sort-based capacity dispatch must equal
the dense oracle (all experts computed, gate-weighted) when capacity is
ample, and degrade only by dropping when it is not."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs.base import MoEConfig
from repro.models import moe as moe_lib

settings.register_profile("ci", max_examples=15, deadline=None)
settings.load_profile("ci")


def _params(key, E, d, f, nsh=0):
    ks = jax.random.split(key, 8)
    mk = lambda k, shp: jax.random.normal(k, shp) * 0.3
    return moe_lib.MoEParams(
        router=mk(ks[0], (d, E)),
        we1=mk(ks[1], (E, d, f)), we3=mk(ks[2], (E, d, f)),
        we2=mk(ks[3], (E, f, d)),
        ws1=mk(ks[4], (d, nsh * f)) if nsh else None,
        ws3=mk(ks[5], (d, nsh * f)) if nsh else None,
        ws2=mk(ks[6], (nsh * f, d)) if nsh else None,
    )


def _dense_oracle(p, x, moe, n_real):
    """Compute every expert densely; combine with the same gates."""
    weights, ids, _ = moe_lib.route(x, p.router, moe, n_real)
    h = jax.nn.silu(jnp.einsum("td,edf->tef", x, p.we1)) * \
        jnp.einsum("td,edf->tef", x, p.we3)
    y_all = jnp.einsum("tef,efd->ted", h, p.we2)          # (T, E, d)
    gates = jnp.zeros((x.shape[0], p.we1.shape[0]))
    gates = gates.at[jnp.arange(x.shape[0])[:, None], ids].set(weights)
    out = jnp.einsum("te,ted->td", gates, y_all)
    return out + moe_lib.shared_expert_ffn(p, x)


@given(seed=st.integers(0, 50), top_k=st.integers(1, 4))
def test_capacity_dispatch_matches_dense_oracle(seed, top_k):
    key = jax.random.PRNGKey(seed)
    T, d, f, E = 64, 16, 32, 8
    moe = MoEConfig(n_experts=E, top_k=top_k, capacity_factor=8.0)
    p = _params(key, E, d, f)
    x = jax.random.normal(jax.random.fold_in(key, 1), (T, d))
    out, aux = moe_lib.moe_ffn(p, x, moe, tp_size=1, axis_name=None,
                               n_real_experts=E)
    ref = _dense_oracle(p, x, moe, E)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)
    assert float(aux) > 0


def test_shared_experts_included():
    key = jax.random.PRNGKey(3)
    T, d, f, E = 32, 16, 32, 8
    moe = MoEConfig(n_experts=E, top_k=2, n_shared_experts=2,
                    capacity_factor=8.0)
    p = _params(key, E, d, f, nsh=2)
    x = jax.random.normal(jax.random.fold_in(key, 1), (T, d))
    out, _ = moe_lib.moe_ffn(p, x, moe, tp_size=1, axis_name=None,
                             n_real_experts=E)
    ref = _dense_oracle(p, x, moe, E)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


def test_padded_experts_receive_no_tokens():
    """Router-masked pad experts (E=5 padded to 8) never fire."""
    key = jax.random.PRNGKey(4)
    T, d, f = 64, 16, 32
    E_real, E_pad = 5, 8
    moe = MoEConfig(n_experts=E_real, top_k=2, capacity_factor=8.0)
    p = _params(key, E_pad, d, f)
    x = jax.random.normal(jax.random.fold_in(key, 1), (T, d))
    _, ids, _ = moe_lib.route(x, p.router, moe, E_real)
    assert int(jnp.max(ids)) < E_real


def test_capacity_drop_degrades_gracefully():
    """Tiny capacity drops tokens but output stays finite and bounded."""
    key = jax.random.PRNGKey(5)
    T, d, f, E = 128, 16, 32, 4
    moe = MoEConfig(n_experts=E, top_k=2, capacity_factor=0.25)
    p = _params(key, E, d, f)
    x = jax.random.normal(jax.random.fold_in(key, 1), (T, d))
    out, _ = moe_lib.moe_ffn(p, x, moe, tp_size=1, axis_name=None,
                             n_real_experts=E)
    assert np.isfinite(np.asarray(out)).all()
    ref = _dense_oracle(p, x, moe, E)
    # dropped-token rows are zero; the rest match
    norms = jnp.linalg.norm(out, axis=-1)
    assert float(jnp.linalg.norm(out)) <= float(jnp.linalg.norm(ref)) * 1.5
