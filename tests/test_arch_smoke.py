"""Per-architecture smoke tests: reduced config of the same family, one
forward/train step on CPU, asserting shapes + finite outputs. The FULL
configs are exercised only via the dry-run (ShapeDtypeStruct, no alloc)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, SHAPES, get_config, reduced, \
    shape_applicable
from repro.models import model as M
from repro.optim import adamw as A
from repro.parallel.sharding import MeshRules
from repro.training import steps as S

RULES = MeshRules(mesh=None)
B, SL = 2, 16


def _batch(cfg, key):
    if cfg.frontend == "embed":
        return {"embeds": jax.random.normal(key, (B, SL, cfg.d_model),
                                            jnp.float32),
                "labels": jnp.zeros((B, SL), jnp.int32)}
    return {"tokens": jax.random.randint(key, (B, SL), 0, cfg.vocab_size),
            "labels": jnp.zeros((B, SL), jnp.int32)}


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_and_finite(arch, key):
    cfg = reduced(get_config(arch))
    params = M.init_params(cfg, key, dtype=jnp.float32)
    batch = _batch(cfg, key)
    hidden, cache, aux = M.forward(params, batch, cfg, RULES, remat=False,
                                   q_chunk=8, collect_cache=True)
    assert hidden.shape == (B, SL, cfg.d_model)
    assert np.isfinite(np.asarray(hidden)).all()
    if cfg.uses_attention:
        hd = cfg.resolved_head_dim
        assert cache["k"].shape == (cfg.n_layers, B, SL, cfg.n_kv_heads, hd)
    if cfg.uses_ssm:
        dI = cfg.ssm.expand * cfg.d_model
        assert cache["ssm"].shape == (cfg.n_layers, B, dI, cfg.ssm.d_state)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_no_nans(arch, key):
    cfg = reduced(get_config(arch))
    params = M.init_params(cfg, key, dtype=jnp.float32)
    opt = A.adamw_init(params)
    step = jax.jit(S.build_train_step(cfg, RULES, remat=True, q_chunk=0))
    p2, o2, metrics = step(params, opt, _batch(cfg, key))
    loss = float(np.asarray(metrics["loss"]))
    assert np.isfinite(loss) and loss > 0
    assert np.isfinite(float(np.asarray(metrics["grad_norm"])))
    # params actually changed
    delta = jax.tree.reduce(
        lambda a, x: a + float(jnp.sum(jnp.abs(x[0] - x[1]))),
        jax.tree.map(lambda a, b: (a, b), p2, params), 0.0)
    assert delta > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_decode_match_forward(arch, key):
    """Serving path equivalence: prefill(S-1) + decode(1) == forward(S)."""
    cfg = reduced(get_config(arch))
    params = M.init_params(cfg, key, dtype=jnp.float32)
    full = _batch(cfg, key)
    full.pop("labels")
    hidden, _, _ = M.forward(params, full, cfg, RULES, remat=False,
                             q_chunk=0)
    ref_logits = M._head_logits(params, hidden, cfg, RULES)

    pre = {k: v[:, :SL - 1] for k, v in full.items()}
    logits_pre, cache = M.prefill(params, pre, cfg, RULES, q_chunk=0)
    np.testing.assert_allclose(np.asarray(logits_pre),
                               np.asarray(ref_logits[:, SL - 2:SL - 1]),
                               rtol=2e-4, atol=2e-4)

    for name in ("k", "v"):
        if name in cache:
            pad = jnp.zeros(cache[name].shape[:2] + (1,)
                            + cache[name].shape[3:], cache[name].dtype)
            cache[name] = jnp.concatenate([cache[name], pad], axis=2)
    dec_key = "embeds" if cfg.frontend == "embed" else "tokens"
    dec = {dec_key: full[dec_key][:, SL - 1:SL],
           "pos": jnp.full((B,), SL - 1, jnp.int32)}
    logits_dec, _ = M.decode_step(params, cache, dec, cfg, RULES)
    np.testing.assert_allclose(np.asarray(logits_dec),
                               np.asarray(ref_logits[:, -1:]),
                               rtol=2e-3, atol=2e-3)


def test_param_counts_match_analytic(key):
    """init_params leaf sizes sum to the analytic count (padding noted)."""
    for arch in ("qwen3-1.7b", "falcon-mamba-7b", "deepseek-moe-16b"):
        cfg = reduced(get_config(arch))
        params = M.init_params(cfg, key, dtype=jnp.float32)
        total = sum(int(np.prod(x.shape))
                    for x in jax.tree.leaves(params))
        analytic = cfg.param_count()
        # padding (vocab to 256, experts to 16) makes init >= analytic
        assert total >= analytic
        assert total <= analytic * 2.2


def test_long_context_applicability():
    long = SHAPES["long_500k"]
    runs = {a for a in ARCH_IDS
            if shape_applicable(get_config(a), long)}
    assert runs == {"hymba-1.5b", "falcon-mamba-7b", "gemma3-27b"}
