"""Shared fixtures. NOTE: no XLA_FLAGS here — tests see 1 CPU device;
multi-device behaviour is tested via subprocesses (test_distributed.py).

When ``hypothesis`` is unavailable (the TPU container doesn't ship it) a
deterministic stand-in is installed before test modules import it: every
``@given`` test runs over a small fixed sample drawn from each strategy's
bounds instead of being skipped at collection time."""
import sys

import jax
import pytest

try:
    import hypothesis  # noqa: F401
except ImportError:
    import itertools
    import types

    class _Strategy:
        def __init__(self, samples):
            self.samples = list(samples)

    def _integers(lo=0, hi=10):
        mid = (lo + hi) // 2
        vals = sorted({lo, mid, hi})
        return _Strategy(vals)

    def _floats(lo=0.0, hi=1.0, **_kw):
        return _Strategy([lo, (lo + hi) / 2.0, hi])

    def _booleans():
        return _Strategy([False, True])

    def _sampled_from(xs):
        return _Strategy(list(xs))

    def _given(**strategies):
        names = sorted(strategies)

        def deco(fn):
            grids = [strategies[n].samples for n in names]

            def wrapper(*args, **kw):
                for combo in itertools.product(*grids):
                    fn(*args, **dict(zip(names, combo)), **kw)

            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            return wrapper

        return deco

    class _Settings:
        def __init__(self, *a, **kw):
            pass

        def __call__(self, fn):
            return fn

        @staticmethod
        def register_profile(name, **kw):
            pass

        @staticmethod
        def load_profile(name):
            pass

    _mod = types.ModuleType("hypothesis")
    _mod.given = _given
    _mod.settings = _Settings
    _mod.assume = lambda cond: True
    _st = types.ModuleType("hypothesis.strategies")
    _st.integers = _integers
    _st.floats = _floats
    _st.booleans = _booleans
    _st.sampled_from = _sampled_from
    _mod.strategies = _st
    sys.modules["hypothesis"] = _mod
    sys.modules["hypothesis.strategies"] = _st


@pytest.fixture(scope="session")
def key():
    return jax.random.PRNGKey(0)
