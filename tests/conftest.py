"""Shared fixtures. NOTE: no XLA_FLAGS here — tests see 1 CPU device;
multi-device behaviour is tested via subprocesses (test_distributed.py)."""
import jax
import pytest


@pytest.fixture(scope="session")
def key():
    return jax.random.PRNGKey(0)
