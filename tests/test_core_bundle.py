"""Property tests for the paper's core invariants (hypothesis)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.bundle import Bundle, bundle_map, bundle_map_reduce, gather

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")


def _mk_bundle(n, k_arrays, seed=0, mesh=None):
    key = jax.random.PRNGKey(seed)
    data = {f"d{i}": jax.random.normal(jax.random.fold_in(key, i),
                                       (n, 3 + i))
            for i in range(k_arrays)}
    return Bundle.create(data, mesh=mesh)


@given(n=st.integers(2, 64), k=st.integers(1, 5))
def test_bundle_invariant_and_roundtrip(n, k):
    b = _mk_bundle(n, k)
    assert b.n_records == n
    out = gather(b)
    assert set(out) == {f"d{i}" for i in range(k)}
    for i in range(k):
        assert out[f"d{i}"].shape == (n, 3 + i)


@given(n=st.integers(2, 32))
def test_bundle_rejects_misaligned_leading_axis(n):
    key = jax.random.PRNGKey(0)
    data = {"a": jnp.zeros((n, 2)), "b": jnp.zeros((n + 1, 2))}
    with pytest.raises(ValueError):
        Bundle.create(data)


@given(n=st.integers(2, 48), scale=st.floats(-2, 2))
def test_map_commutes_with_local_apply(n, scale):
    """map(f) on the bundle == f applied to the gathered arrays — the
    Bundle/Unbundle re-usability property."""
    b = _mk_bundle(n, 2)
    f = lambda d: {"d0": d["d0"] * scale + 1.0, "d1": d["d1"] ** 2}
    mapped = gather(bundle_map(f, b))
    direct = jax.tree.map(np.asarray, f(b.data))
    for name in mapped:
        np.testing.assert_allclose(mapped[name], direct[name],
                                   rtol=1e-5, atol=1e-5)


@given(n=st.integers(2, 48))
def test_map_reduce_equals_sequential_reduce(n):
    b = _mk_bundle(n, 2)
    part = bundle_map_reduce(
        lambda d: {"s": jnp.sum(d["d0"]), "g": d["d1"].T @ d["d1"]}, b)
    np.testing.assert_allclose(float(part["s"]),
                               float(jnp.sum(b.data["d0"])), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(part["g"]),
                               np.asarray(b.data["d1"].T @ b.data["d1"]),
                               rtol=1e-5, atol=1e-5)


def test_zip_requires_equal_records():
    a, b = _mk_bundle(8, 1), _mk_bundle(12, 1, seed=1)
    with pytest.raises(ValueError):
        a.zip(b)


def test_persistence_policies_equivalent():
    """MEMORY_ONLY (remat) and plain step compute identical results."""
    from repro.core import persistence as P
    b = _mk_bundle(16, 2)

    def step(d, rep, axes):
        return {"d0": d["d0"] * 2, "d1": d["d1"] + 1}, jnp.sum(d["d0"])

    wrapped = P.wrap_step(step, P.Policy.MEMORY_ONLY)
    out1, c1 = step(b.data, None, ())
    out2, c2 = wrapped(b.data, None, ())
    np.testing.assert_allclose(float(c1), float(c2))
    for k in out1:
        np.testing.assert_allclose(np.asarray(out1[k]),
                                   np.asarray(out2[k]))


def test_spill_restore_roundtrip():
    from repro.core import persistence as P
    b = _mk_bundle(16, 3)
    host = P.spill(b)
    b2 = P.restore(b, host)
    for k in b.data:
        np.testing.assert_allclose(np.asarray(b.data[k]),
                                   np.asarray(b2.data[k]))
