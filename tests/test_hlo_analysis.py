"""Unit tests for the HLO analyzer that feeds the roofline."""
import jax
import jax.numpy as jnp
import pytest

from repro.launch.hlo_analysis import analyze, parse_hlo, type_bytes


def test_type_bytes():
    assert type_bytes("f32[16,128]{1,0}") == 16 * 128 * 4
    assert type_bytes("bf16[2,3]{1,0}") == 12
    assert type_bytes("(s32[], f32[4,4]{1,0}, /*index=2*/bf16[8]{0})") == \
        4 + 64 + 16
    assert type_bytes("f32[]") == 4


def _compile(fn, *args):
    return jax.jit(fn).lower(*args).compile()


def test_flops_counts_scanned_matmuls():
    """Trip-count correction: a 10-step scanned matmul counts 10x (XLA's
    cost_analysis counts it once — the bug this module exists to fix)."""
    def f(w, x):
        def body(c, _):
            return c @ w, None
        out, _ = jax.lax.scan(body, x, None, length=10)
        return out.sum()

    w = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    c = _compile(f, w, x)
    stats = analyze(c.as_text())
    one = 2 * 128 ** 3
    assert stats.n_while == 1
    assert stats.trip_counts[0] == 10
    assert 9.5 * one <= stats.flops <= 11 * one


def test_flops_unscanned_matches_cost_analysis():
    def f(a, b):
        return (a @ b).sum()

    a = jax.ShapeDtypeStruct((256, 64), jnp.float32)
    b = jax.ShapeDtypeStruct((64, 512), jnp.float32)
    c = _compile(f, a, b)
    stats = analyze(c.as_text())
    expect = 2 * 256 * 64 * 512
    assert abs(stats.flops - expect) / expect < 0.05


def test_batched_dot_flops():
    def f(a, b):
        return jnp.einsum("bij,bjk->bik", a, b).sum()

    a = jax.ShapeDtypeStruct((4, 32, 64), jnp.float32)
    b = jax.ShapeDtypeStruct((4, 64, 16), jnp.float32)
    c = _compile(f, a, b)
    stats = analyze(c.as_text())
    expect = 2 * 4 * 32 * 64 * 16
    assert abs(stats.flops - expect) / expect < 0.05


def test_traffic_nonzero_and_bounded():
    def f(a):
        return jnp.tanh(a * 2.0 + 1.0).sum()

    a = jax.ShapeDtypeStruct((1024, 1024), jnp.float32)
    c = _compile(f, a)
    stats = analyze(c.as_text())
    nbytes = 1024 * 1024 * 4
    assert nbytes * 0.9 <= stats.traffic_bytes <= nbytes * 6


def test_parse_handles_tuple_types_with_comments():
    comps, entry = parse_hlo(
        "ENTRY %main (p: f32[4]) -> f32[4] {\n"
        "  %p = f32[4]{0} parameter(0)\n"
        "  %t = (f32[4]{0}, /*index=1*/s32[2]{0}) tuple(%p, %p)\n"
        "  ROOT %g = f32[4]{0} get-tuple-element(%t), index=0\n"
        "}\n")
    ops = comps[entry].ops
    assert [o.kind for o in ops] == ["parameter", "tuple",
                                     "get-tuple-element"]
    assert ops[1].result_bytes == 16 + 8
