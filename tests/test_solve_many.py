"""solve_many: pad-and-bucket batched multi-instance solve (§19).

The contract under test: for every builtin workload, each instance of a
batched run reproduces its own single ``solve()`` trajectory (cost curve
to rtol 1e-4, iterate to fp noise), while converged instances are frozen
in place by the active mask (fewer ``iters_run`` than the bucket's
running maximum) and the whole thing composes with checkpointing and
supervised execution.
"""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.driver import RunOptions
from repro.core.problem import Solution, solve, solve_many
from repro.resilience import chaos
from repro.resilience.recovery import ResilienceConfig

ITERS, CHUNK = 10, 4


@pytest.fixture(scope="module")
def psf_instances():
    from repro.imaging import psf as psf_op
    out = []
    for (n, S, seed) in [(3, 16, 0), (5, 16, 1), (4, 16, 2), (3, 20, 3)]:
        d = psf_op.simulate(n, jax.random.PRNGKey(seed), stamp=S)
        out.append((d.Y, d.psfs))
    return out


def _deconv_cfg(**kw):
    from repro.imaging.condat import SolverConfig
    base = dict(mode="sparse", max_iter=ITERS, tol=0.0, n_scales=2)
    base.update(kw)
    return SolverConfig(**base)


def _assert_instance_parity(sol, ref, rtol=1e-4):
    fin = np.isfinite(np.asarray(ref.log.costs))
    np.testing.assert_allclose(np.asarray(sol.log.costs)[fin],
                               np.asarray(ref.log.costs)[fin], rtol=rtol)
    for a, b in zip(jax.tree.leaves(sol.x), jax.tree.leaves(ref.x)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=rtol, atol=1e-6)


# =====================================================================
# Per-instance trajectory parity, all three workloads
# =====================================================================

@pytest.mark.parametrize("cost_every", [1, 3, "chunk"])
def test_deconvolve_parity_all_cadences(psf_instances, cost_every):
    cfg = _deconv_cfg()
    sols = solve_many("deconvolve", psf_instances, cfg=cfg,
                      chunk=CHUNK, cost_every=cost_every)
    assert all(isinstance(s, Solution) for s in sols)
    for inst, sol in zip(psf_instances, sols):
        ref = solve("deconvolve", *inst, cfg=cfg,
                    chunk=CHUNK, cost_every=cost_every)
        assert sol.x.shape == inst[0].shape     # unpadded result
        assert sol.log.iters_run == ITERS
        _assert_instance_parity(sol, ref)


def test_lowrank_parity():
    from repro.imaging.lowrank import CompletionConfig

    def make(n, p, seed):
        r = np.random.default_rng(seed)
        Y = (r.normal(size=(n, 3)) @ r.normal(size=(3, p))).astype(
            np.float32)
        M = (r.random((n, p)) < 0.6).astype(np.float32)
        return jnp.asarray(Y), jnp.asarray(M)

    insts = [make(8, 10, 0), make(6, 10, 1), make(8, 12, 2)]
    cfg = CompletionConfig(rank=4, max_iter=ITERS, tol=0.0)
    sols = solve_many("lowrank", insts, cfg=cfg, chunk=CHUNK)
    for inst, sol in zip(insts, sols):
        _assert_instance_parity(
            sol, solve("lowrank", *inst, cfg=cfg, chunk=CHUNK))


def test_scdl_parity():
    from repro.imaging.scdl import SCDLConfig

    def make(K, seed):
        r = np.random.default_rng(seed)
        return (jnp.asarray(r.normal(size=(25, K)).astype(np.float32)),
                jnp.asarray(r.normal(size=(16, K)).astype(np.float32)))

    insts = [make(20, 0), make(20, 1), make(24, 2)]
    cfg = SCDLConfig(n_atoms=6, max_iter=ITERS, tol=0.0)
    sols = solve_many("scdl", insts, cfg=cfg, chunk=CHUNK)
    for inst, sol in zip(insts, sols):
        _assert_instance_parity(
            sol, solve("scdl", *inst, cfg=cfg, chunk=CHUNK))


# =====================================================================
# Masked early exit
# =====================================================================

def test_masked_early_exit_frees_converged_instance():
    from repro.imaging import psf as psf_op
    d = psf_op.simulate(4, jax.random.PRNGKey(9), stamp=16)
    live = (d.Y, d.psfs)
    settled = (jnp.zeros_like(d.Y), d.psfs)   # converges immediately
    cfg = _deconv_cfg(max_iter=40, tol=1e-6)
    sols = solve_many("deconvolve", [live, settled], cfg=cfg,
                      chunk=CHUNK, cost_every=1)
    assert sols[1].log.iters_run < sols[0].log.iters_run
    assert sols[1].log.converged_at is not None
    assert sols[1].log.converged_at + 1 == sols[1].log.iters_run
    # the frozen lane's iterate is exactly its state at convergence:
    # still the zero image the zero observations fix
    np.testing.assert_array_equal(np.asarray(sols[1].x), 0.0)
    # and the live lane is untouched by sharing a bucket with it
    # (single solve does not track iters_run; its cost log is one entry
    # per iteration actually run)
    ref = solve("deconvolve", *live, cfg=cfg, chunk=CHUNK, cost_every=1)
    assert sols[0].log.iters_run == len(ref.log.costs)
    _assert_instance_parity(sols[0], ref)


# =====================================================================
# Checkpoint / resume / resilience composition
# =====================================================================

def test_bucket_checkpoint_resume_roundtrip(tmp_path, psf_instances):
    cfg = _deconv_cfg()
    ref = solve_many("deconvolve", psf_instances, cfg=cfg,
                     chunk=CHUNK, cost_every=1)
    solve_many("deconvolve", psf_instances, cfg=_deconv_cfg(max_iter=8),
               chunk=CHUNK, cost_every=1,
               checkpoint_dir=str(tmp_path), checkpoint_every=4)
    assert all(d.startswith("bucket_") for d in os.listdir(tmp_path))
    assert len(os.listdir(tmp_path)) >= 2      # mixed shapes: 2+ buckets
    res = solve_many("deconvolve", psf_instances, cfg=cfg,
                     chunk=CHUNK, cost_every=1,
                     checkpoint_dir=str(tmp_path), resume=True)
    for r, s in zip(ref, res):
        np.testing.assert_array_equal(np.asarray(r.x), np.asarray(s.x))
        assert s.log.iters_run == ITERS


def test_resume_requires_true_not_step(tmp_path, psf_instances):
    with pytest.raises(ValueError, match="resume=True"):
        solve_many("deconvolve", psf_instances, cfg=_deconv_cfg(),
                   checkpoint_dir=str(tmp_path), resume=4,
                   checkpoint_every=4)


def test_resume_without_any_bucket_checkpoints(tmp_path, psf_instances):
    with pytest.raises(ValueError, match="no bucket checkpoints"):
        solve_many("deconvolve", psf_instances, cfg=_deconv_cfg(),
                   checkpoint_dir=str(tmp_path), resume=True)


def test_chaos_drill_on_batched_run(tmp_path, psf_instances):
    cfg = _deconv_cfg()
    ref = solve_many("deconvolve", psf_instances, cfg=cfg,
                     chunk=CHUNK, cost_every=1)
    cc = chaos.ChaosConfig.parse("dispatch@1;carry_nan@2;seed=7")
    with chaos.active_chaos(cc) as st:
        sols = solve_many("deconvolve", psf_instances, cfg=cfg,
                          chunk=CHUNK, cost_every=1,
                          checkpoint_dir=str(tmp_path),
                          checkpoint_every=4,
                          resilience=ResilienceConfig(backoff_s=1e-3))
    assert ("dispatch", 1) in st.fired and ("carry_nan", 2) in st.fired
    hit = [s.recovery for s in sols
           if s.recovery.retries or s.recovery.rollbacks]
    assert hit, "injected faults landed on no bucket"
    for r, s in zip(ref, sols):
        _assert_instance_parity(s, r)


# =====================================================================
# Option validation (satellite: RunOptions hardening)
# =====================================================================

@pytest.mark.parametrize("bad", [0, -1, -8])
def test_run_options_rejects_nonpositive_chunk(bad):
    with pytest.raises(ValueError, match="chunk"):
        RunOptions(max_iter=4, chunk=bad)


@pytest.mark.parametrize("bad", [0, -3])
def test_run_options_rejects_nonpositive_cost_every(bad):
    with pytest.raises(ValueError, match="cost_every"):
        RunOptions(max_iter=4, cost_every=bad)


def test_run_options_rejects_unknown_cost_every_string():
    with pytest.raises(ValueError, match="chunk"):
        RunOptions(max_iter=4, cost_every="sometimes")


def test_checkpoint_every_clamped_to_max_iter(tmp_path, psf_instances):
    # checkpoint_every far beyond max_iter still writes the final step,
    # mirroring the chunk clamp
    solve_many("deconvolve", psf_instances[:1], cfg=_deconv_cfg(),
               chunk=CHUNK, checkpoint_dir=str(tmp_path),
               checkpoint_every=10_000)
    from repro.checkpoint import latest_step
    bdirs = os.listdir(tmp_path)
    assert len(bdirs) == 1
    assert latest_step(tmp_path / bdirs[0]) == ITERS


# =====================================================================
# Misc contracts
# =====================================================================

def test_empty_instance_list():
    assert solve_many("deconvolve", [], cfg=_deconv_cfg()) == []


def test_single_instance_bucket(psf_instances):
    cfg = _deconv_cfg()
    [sol] = solve_many("deconvolve", psf_instances[:1], cfg=cfg,
                       chunk=CHUNK)
    _assert_instance_parity(
        sol, solve("deconvolve", *psf_instances[0], cfg=cfg, chunk=CHUNK))
