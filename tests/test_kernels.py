"""Per-kernel shape/dtype sweeps asserting allclose against the pure-jnp
oracles (interpret mode on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

KEY = jax.random.PRNGKey(7)


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else \
        dict(rtol=2e-5, atol=2e-5)


# -------------------------------------------------------------- starlet
from repro.kernels.starlet2d.ops import decompose as k_decompose
from repro.kernels.starlet2d.ops import smooth as k_smooth
from repro.kernels.starlet2d.ref import smooth_ref
from repro.imaging import starlet


@pytest.mark.parametrize("scale", [0, 1, 2, 3])
@pytest.mark.parametrize("shape", [(128, 41, 41), (256, 32, 32)])
def test_starlet_smooth(scale, shape):
    imgs = jax.random.normal(jax.random.fold_in(KEY, 11), shape)
    out = k_smooth(imgs, scale=scale)
    ref = smooth_ref(imgs, scale)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


def test_starlet_kernel_decompose_matches_imaging():
    imgs = jax.random.normal(jax.random.fold_in(KEY, 12), (128, 41, 41))
    co = k_decompose(imgs, 3)
    ref = jax.vmap(lambda im: starlet.decompose(im, 3),
                   in_axes=0, out_axes=1)(imgs)
    np.testing.assert_allclose(np.asarray(co), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("scale", [0, 2])
@pytest.mark.parametrize("shape", [(100, 41, 41), (37, 16, 16),
                                   (130, 41, 41)])
def test_starlet_smooth_non_block_aligned(scale, shape):
    """Batch sizes that don't divide block_n pad up and slice back."""
    imgs = jax.random.normal(jax.random.fold_in(KEY, 13), shape)
    out = k_smooth(imgs, scale=scale)
    ref = smooth_ref(imgs, scale)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)
    # a block size that forces padding must agree too
    out_pad = k_smooth(imgs, scale=scale, block_n=64)
    np.testing.assert_allclose(np.asarray(out_pad), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


def test_starlet_batched_forward_adjoint_match_reference():
    """ops.forward/adjoint (the condat hot path) vs per-stamp vmap of the
    imaging reference, on a non-block-aligned batch."""
    from repro.kernels.starlet2d.ops import adjoint as k_adjoint
    from repro.kernels.starlet2d.ops import forward as k_forward
    imgs = jax.random.normal(jax.random.fold_in(KEY, 14), (100, 32, 32))
    co = k_forward(imgs, 4)
    ref = jax.vmap(lambda im: starlet.forward(im, 4),
                   in_axes=0, out_axes=1)(imgs)
    np.testing.assert_allclose(np.asarray(co), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)
    adj = k_adjoint(co, 4)
    ref_adj = jax.vmap(lambda u: starlet.adjoint(u, 4), in_axes=1)(co)
    np.testing.assert_allclose(np.asarray(adj), np.asarray(ref_adj),
                               rtol=1e-5, atol=1e-5)


# ----------------------------------------------------------- dict outer
from repro.kernels.dict_outer.ops import dict_outer, dict_outer_pair
from repro.kernels.dict_outer.ref import dict_outer_pair_ref, dict_outer_ref

# (1000, ...) and block_k=512 exercise the non-block-aligned zero-pad
DO_CASES = [(2048, 25, 64), (1024, 289, 128), (512, 9, 256),
            (1000, 25, 64)]


def _do_tol(dtype, K):
    return dict(rtol=2e-2, atol=K * 2e-3) if dtype == jnp.bfloat16 else \
        dict(rtol=1e-4, atol=K * 1e-6)


@pytest.mark.parametrize("case", DO_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_dict_outer(case, dtype):
    K, P, A = case
    S = jax.random.normal(jax.random.fold_in(KEY, 13), (K, P), dtype)
    W = jax.random.normal(jax.random.fold_in(KEY, 14), (K, A), dtype)
    sw, ww = dict_outer(S, W, use_kernel=True)
    swr, wwr = dict_outer_ref(S, W)
    tol = _do_tol(dtype, K)
    np.testing.assert_allclose(np.asarray(sw), np.asarray(swr), **tol)
    np.testing.assert_allclose(np.asarray(ww), np.asarray(wwr), **tol)


DOP_CASES = [(2048, 289, 81, 128), (1000, 289, 81, 128),
             (512, 25, 9, 256), (130, 25, 9, 128)]


@pytest.mark.parametrize("case", DOP_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_dict_outer_pair(case, dtype):
    """The coupled-pair fusion: one grid pass over K produces all four
    outer products, including non-block-aligned sample counts."""
    K, P, M, A = case
    Sh = jax.random.normal(jax.random.fold_in(KEY, 15), (K, P), dtype)
    Sl = jax.random.normal(jax.random.fold_in(KEY, 16), (K, M), dtype)
    Wh = jax.random.normal(jax.random.fold_in(KEY, 17), (K, A), dtype)
    Wl = jax.random.normal(jax.random.fold_in(KEY, 18), (K, A), dtype)
    out = dict_outer_pair(Sh, Sl, Wh, Wl, use_kernel=True)
    ref = dict_outer_pair_ref(Sh, Sl, Wh, Wl)
    tol = _do_tol(dtype, K)
    for o, r in zip(out, ref):
        np.testing.assert_allclose(np.asarray(o), np.asarray(r), **tol)


# ---------------------------------------------------------- admm elwise
from repro.kernels.admm_elwise.ops import admm_elwise
from repro.kernels.admm_elwise.ref import admm_elwise_ref

AE_KW = dict(c1=0.4, c2=0.4, c3=0.8, t1=0.025, t2=0.025)
AE_CASES = [(2048, 128), (1000, 256), (130, 128), (512, 512)]


@pytest.mark.parametrize("case", AE_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_admm_elwise(case, dtype):
    """Fused soft-threshold + dual updates over the stacked (K, 5, A)
    multiplier state, kernel vs oracle, non-block-aligned K included."""
    K, A = case
    Wh = jax.random.normal(jax.random.fold_in(KEY, 19), (K, A), dtype)
    Wl = jax.random.normal(jax.random.fold_in(KEY, 20), (K, A), dtype)
    YZ = jax.random.normal(jax.random.fold_in(KEY, 21), (K, 5, A), dtype)
    out = admm_elwise(Wh, Wl, YZ, use_kernel=True, **AE_KW)
    ref = admm_elwise_ref(Wh, Wl, YZ, **AE_KW)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))


def test_admm_elwise_matches_unfused_formulation():
    """The kernel's clip/fold algebra equals the textbook step 8:
    soft-threshold P/Q then three dual ascent updates and the Z
    right-hand-side combinations."""
    K, A = 257, 64
    c1, c2, c3, t1, t2 = (AE_KW[k] for k in ("c1", "c2", "c3", "t1",
                                             "t2"))
    Wh = jax.random.normal(jax.random.fold_in(KEY, 22), (K, A))
    Wl = jax.random.normal(jax.random.fold_in(KEY, 23), (K, A))
    YZ = jax.random.normal(jax.random.fold_in(KEY, 24), (K, 5, A))
    y1, y2, y3 = YZ[:, 0], YZ[:, 1], YZ[:, 2]
    soft = lambda x, t: jnp.sign(x) * jnp.maximum(jnp.abs(x) - t, 0.0)
    P = soft(Wh - y1 / c1, t1)
    Q = soft(Wl - y2 / c2, t2)
    Y1 = y1 + c1 * (P - Wh)
    Y2 = y2 + c2 * (Q - Wl)
    Y3 = y3 + c3 * (Wh - Wl)
    Z1 = c1 * P + Y1 - Y3 + c3 * Wl
    Z2 = c2 * Q + Y2 + Y3
    expect = jnp.stack([Y1, Y2, Y3, Z1, Z2], axis=1)
    got = admm_elwise_ref(Wh, Wl, YZ, **AE_KW)
    np.testing.assert_allclose(np.asarray(got), np.asarray(expect),
                               rtol=1e-5, atol=1e-6)


# --------------------------------------------------------- condat elwise
from repro.kernels.condat_elwise.ops import condat_dual, condat_primal
from repro.kernels.condat_elwise.ref import (condat_dual_ref,
                                             condat_primal_ref)

# (100, ...) / (130, ...) exercise the non-block-aligned zero-pad
CP_CASES = [(100, 41), (130, 21), (16, 41), (256, 33)]


@pytest.mark.parametrize("case", CP_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_condat_primal(case, dtype):
    """Fused gradient step + positivity prox (+ over-relaxation for the
    low-rank path), kernel vs oracle, non-block-aligned stacks."""
    N, S = case
    X = jax.random.normal(jax.random.fold_in(KEY, 30), (N, S, S), dtype)
    Ua = jax.random.normal(jax.random.fold_in(KEY, 31), (N, S, S), dtype)
    g = jax.random.normal(jax.random.fold_in(KEY, 32), (N, S, S), dtype)
    out = condat_primal(X, Ua, g, 0.31, use_kernel=True, interpret=True)
    ref = condat_primal_ref(X, Ua, g, 0.31)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))
    xn, xb = condat_primal(X, Ua, g, 0.31, with_xbar=True,
                           use_kernel=True, interpret=True)
    rn, rb = condat_primal_ref(X, Ua, g, 0.31, with_xbar=True)
    np.testing.assert_allclose(np.asarray(xn, np.float32),
                               np.asarray(rn, np.float32), **_tol(dtype))
    np.testing.assert_allclose(np.asarray(xb, np.float32),
                               np.asarray(rb, np.float32), **_tol(dtype))


@pytest.mark.parametrize("case", [(3, 100, 41), (4, 37, 21), (2, 130, 33)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_condat_dual(case, dtype):
    """Fused over-relaxation + dual clamp over the (J, n, S, S) stack
    with the (J, n, 1, 1) weight column broadcast, kernel vs oracle on
    non-block-aligned flattened sizes."""
    J, N, S = case
    U = jax.random.normal(jax.random.fold_in(KEY, 33), (J, N, S, S), dtype)
    Cn = jax.random.normal(jax.random.fold_in(KEY, 34), (J, N, S, S), dtype)
    Co = jax.random.normal(jax.random.fold_in(KEY, 35), (J, N, S, S), dtype)
    W = jax.random.uniform(jax.random.fold_in(KEY, 36), (J, N, 1, 1),
                           jnp.float32).astype(dtype)
    out = condat_dual(U, Cn, Co, W, 0.47, use_kernel=True, interpret=True)
    ref = condat_dual_ref(U, Cn, Co, W, 0.47)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))


def test_condat_dual_matches_unfused_formulation():
    """The fused pass equals the textbook dual step: V = U + sig
    Phi(X_bar) with Phi(X_bar) = 2 C_new - C_old, then clamp to
    [-W, W]."""
    J, N, S = 3, 64, 41
    sig = 0.8
    U = jax.random.normal(jax.random.fold_in(KEY, 37), (J, N, S, S))
    Cn = jax.random.normal(jax.random.fold_in(KEY, 38), (J, N, S, S))
    Co = jax.random.normal(jax.random.fold_in(KEY, 39), (J, N, S, S))
    W = jax.random.uniform(jax.random.fold_in(KEY, 40), (J, N, 1, 1))
    got = condat_dual(U, Cn, Co, W, sig)
    expect = jnp.clip(U + sig * (2 * Cn - Co), -W, W)
    np.testing.assert_allclose(np.asarray(got), np.asarray(expect),
                               rtol=1e-5, atol=1e-6)
