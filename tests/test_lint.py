"""repro.lint checker suite (DESIGN.md §17).

Each rule gets ≥2 positive fixtures (seeded violations the checker must
catch, with the right rule ID and line) and ≥1 negative fixture (the
idiomatic clean spelling that must NOT be flagged).  Plus: suppression
comments, the CLI exit/report contract, stable rule IDs, and the
acceptance gate that the repo's own tree lints clean.
"""
import json
import textwrap
from pathlib import Path

import pytest

from repro.lint import all_rules, lint_file, lint_paths
from repro.lint.cli import main as lint_main

REPO = Path(__file__).resolve().parents[1]


def _lint(tmp_path, source, name="mod.py"):
    p = tmp_path / name
    p.parent.mkdir(parents=True, exist_ok=True)
    p.write_text(textwrap.dedent(source))
    return lint_file(p)


def _ids(findings):
    return [f.rule.id for f in findings]


def _only(findings, rule_id):
    return [f for f in findings if f.rule.id == rule_id]


# =====================================================================
# RPL101 donated-reuse
# =====================================================================

def test_rpl101_read_after_scan_step_donation(tmp_path):
    found = _lint(tmp_path, """
        from repro.core.engine import make_scan_step

        def run(bundle, fn):
            step = make_scan_step(fn, bundle, chunk=8)
            data, rep = bundle.data, bundle.replicated
            data2, rep2, trace = step(data, rep, 0)
            return data.sum()
    """)
    hits = _only(found, "RPL101")
    assert len(hits) == 1
    assert hits[0].line == 8
    assert "'data'" in hits[0].message


def test_rpl101_carried_output_slot_reused(tmp_path):
    found = _lint(tmp_path, """
        from repro.core.engine import make_chunk_cost_step

        def run(bundle, light, cost, last):
            step = make_chunk_cost_step(light, cost, bundle, chunk=8)
            d, rep = bundle.data, bundle.replicated
            d, rep, new_last, trace = step(d, rep, 0, last)
            print(last)
    """)
    hits = _only(found, "RPL101")
    assert len(hits) == 1 and "'last'" in hits[0].message


def test_rpl101_loop_carried_donation(tmp_path):
    # donating in one loop trip and reading at the top of the next
    found = _lint(tmp_path, """
        from repro.core.engine import make_step

        def run(bundle, fn, data, rep):
            step = make_step(fn, bundle)
            for i in range(10):
                fresh, out = step(data, rep)
    """)
    assert _ids(found) == ["RPL101"]


def test_rpl101_negative_rebinding_and_donate_false(tmp_path):
    found = _lint(tmp_path, """
        from repro.core.engine import make_scan_step, make_step

        def clean(bundle, fn):
            step = make_scan_step(fn, bundle, chunk=8)
            data, rep = bundle.data, bundle.replicated
            for i in range(4):
                data, rep, trace = step(data, rep, i)
            return data

        def bench(bundle, fn, data, rep):
            step = make_step(fn, bundle, donate=False)
            for _ in range(3):
                out = step(data, rep)      # donate=False: reuse is fine
            return out
    """)
    assert _only(found, "RPL101") == []


# =====================================================================
# RPL201 blockspec-grid
# =====================================================================

_PALLAS_HEADER = """
    from jax.experimental import pallas as pl

    def kernel(x_ref, o_ref):
        o_ref[...] = x_ref[...]
"""


def test_rpl201_block_divisor_mismatch(tmp_path):
    found = _lint(tmp_path, _PALLAS_HEADER + """
        def fwd(x, n_full, block_n, block_m, interpret=False):
            return pl.pallas_call(
                kernel,
                grid=(n_full // block_n,),
                in_specs=[pl.BlockSpec((block_m, 4), lambda i: (i, 0))],
                out_specs=[pl.BlockSpec((block_m, 4), lambda i: (i, 0))],
                interpret=interpret,
            )(x)
    """)
    hits = _only(found, "RPL201")
    assert len(hits) == 2          # both specs use the wrong block name
    assert "block_n" in hits[0].message and "block_m" in hits[0].message


def test_rpl201_index_map_arity_mismatch(tmp_path):
    found = _lint(tmp_path, _PALLAS_HEADER + """
        def fwd(x, n_full, m_full, block_n, block_m, interpret=False):
            return pl.pallas_call(
                kernel,
                grid=(n_full // block_n, m_full // block_m),
                in_specs=[pl.BlockSpec((block_n, block_m),
                                       lambda i: (i, 0))],
                interpret=interpret,
            )(x)
    """)
    hits = _only(found, "RPL201")
    assert len(hits) == 1 and "2 axes" in hits[0].message


def test_rpl201_input_block_ignores_grid_index(tmp_path):
    found = _lint(tmp_path, _PALLAS_HEADER + """
        def fwd(x, n_full, block_n, interpret=False):
            return pl.pallas_call(
                kernel,
                grid=(n_full // block_n,),
                in_specs=[pl.BlockSpec((block_n, 4), lambda i: (0, 0))],
                interpret=interpret,
            )(x)
    """)
    hits = _only(found, "RPL201")
    assert len(hits) == 1 and "same input block" in hits[0].message


def test_rpl201_negative_idiomatic_and_accumulator(tmp_path):
    # the repo idiom: matching divisor/block names, plus an accumulator
    # out_spec pinned to one block (legit on TPU's sequential grid)
    found = _lint(tmp_path, _PALLAS_HEADER + """
        def fwd(S, W, k_full, block_k, P, A, interpret=False):
            return pl.pallas_call(
                kernel,
                grid=(k_full // block_k,),
                in_specs=[
                    pl.BlockSpec((block_k, P), lambda i: (i, 0)),
                    pl.BlockSpec((block_k, A), lambda i: (i, 0)),
                ],
                out_specs=[pl.BlockSpec((P, A), lambda i: (0, 0))],
                interpret=interpret,
            )(S, W)
    """)
    assert _only(found, "RPL201") == []


# =====================================================================
# RPL202 missing-interpret
# =====================================================================

def test_rpl202_no_interpret_kwarg(tmp_path):
    found = _lint(tmp_path, _PALLAS_HEADER + """
        def fwd(x, n_full, block_n):
            return pl.pallas_call(
                kernel,
                grid=(n_full // block_n,),
                in_specs=[pl.BlockSpec((block_n, 4), lambda i: (i, 0))],
            )(x)
    """)
    hits = _only(found, "RPL202")
    assert len(hits) == 1 and "fallback" in hits[0].message


def test_rpl202_hardcoded_interpret_mode(tmp_path):
    found = _lint(tmp_path, _PALLAS_HEADER + """
        def fwd(x, n_full, block_n):
            return pl.pallas_call(
                kernel,
                grid=(n_full // block_n,),
                in_specs=[pl.BlockSpec((block_n, 4), lambda i: (i, 0))],
                interpret=True,
            )(x)
    """)
    hits = _only(found, "RPL202")
    assert len(hits) == 1 and "hardcodes" in hits[0].message


def test_rpl202_negative_plumbed_interpret(tmp_path):
    found = _lint(tmp_path, _PALLAS_HEADER + """
        from repro.kernels.common import auto_interpret

        def fwd(x, n_full, block_n, interpret=None):
            if interpret is None:
                interpret = auto_interpret()
            return pl.pallas_call(
                kernel,
                grid=(n_full // block_n,),
                in_specs=[pl.BlockSpec((block_n, 4), lambda i: (i, 0))],
                interpret=interpret,
            )(x)
    """)
    assert _only(found, "RPL202") == []


# =====================================================================
# RPL203 ref-parity (import-and-inspect)
# =====================================================================

def test_rpl203_signature_drift_and_missing_wrapper(tmp_path):
    fam = tmp_path / "kernels" / "fam"
    fam.mkdir(parents=True)
    (fam / "ref.py").write_text(textwrap.dedent("""
        def foo_ref(a, b, gamma):
            return a + b * gamma

        def bar_ref(a):
            return a
    """))
    found = _lint(tmp_path, """
        def foo(a, b, *, use_kernel=True, interpret=None, block_n=128):
            return a + b
    """, name="kernels/fam/ops.py")
    hits = _only(found, "RPL203")
    msgs = " | ".join(h.message for h in hits)
    assert len(hits) == 2
    assert "drifted" in msgs and "gamma" in msgs   # foo lost a param
    assert "bar" in msgs                           # bar_ref has no bar


def test_rpl203_missing_ref_sibling(tmp_path):
    found = _lint(tmp_path, """
        def foo(a, b):
            return a + b
    """, name="kernels/solo/ops.py")
    hits = _only(found, "RPL203")
    assert len(hits) == 1 and "no sibling ref.py" in hits[0].message


def test_rpl203_negative_parity_ok(tmp_path):
    fam = tmp_path / "kernels" / "good"
    fam.mkdir(parents=True)
    (fam / "ref.py").write_text(textwrap.dedent("""
        def foo_ref(a, b, gamma):
            return a + b * gamma
    """))
    found = _lint(tmp_path, """
        def foo(a, b, gamma, *, use_kernel=True, interpret=None,
                block_n=128):
            return a + b * gamma
    """, name="kernels/good/ops.py")
    assert _only(found, "RPL203") == []


# =====================================================================
# RPL301 traced-branch
# =====================================================================

def test_rpl301_if_on_traced_value(tmp_path):
    found = _lint(tmp_path, """
        import jax

        @jax.jit
        def f(x):
            if x > 0:
                return x
            return -x
    """)
    hits = _only(found, "RPL301")
    assert len(hits) == 1 and hits[0].line == 6


def test_rpl301_while_on_traced_reduction(tmp_path):
    found = _lint(tmp_path, """
        import jax
        import jax.numpy as jnp

        @jax.jit
        def g(x):
            while jnp.sum(x) > 1.0:
                x = x * 0.5
            return x
    """)
    assert len(_only(found, "RPL301")) == 1


def test_rpl301_scan_body_branch(tmp_path):
    # reachability through lax.scan, not just @jit
    found = _lint(tmp_path, """
        import jax
        import jax.numpy as jnp

        def run(xs):
            def body(carry, x):
                if carry > 0:
                    carry = carry + x
                return carry, carry
            return jax.lax.scan(body, jnp.float32(0), xs)
    """)
    assert len(_only(found, "RPL301")) == 1


def test_rpl301_negative_static_idioms(tmp_path):
    # the repo's idioms: `if axes:`, defaulted control params, metadata
    # attributes, shape-query helpers — none may be flagged
    found = _lint(tmp_path, """
        import jax
        import jax.numpy as jnp
        from functools import partial

        @partial(jax.jit, static_argnames=("mode",))
        def f(x, axes, mode, use_kernel=None):
            if axes:
                x = jax.lax.psum(x, axes)
            if use_kernel is None:
                use_kernel = True
            if mode == "sparse":
                x = x * 2
            if x.ndim == 2:
                x = x[None]
            n = len(x.shape)
            flat = [v for v in (x, x) if jnp.ndim(v) > 0]
            return jnp.where(x > 0, x, -x), n, flat
    """)
    assert _only(found, "RPL301") == []


# =====================================================================
# RPL302 host-cast
# =====================================================================

def test_rpl302_float_cast(tmp_path):
    found = _lint(tmp_path, """
        import jax
        import jax.numpy as jnp

        @jax.jit
        def f(x):
            return float(jnp.sum(x))
    """)
    hits = _only(found, "RPL302")
    assert len(hits) == 1 and "float()" in hits[0].message


def test_rpl302_item_call(tmp_path):
    found = _lint(tmp_path, """
        import jax

        @jax.jit
        def f(x):
            s = x.sum()
            return s.item()
    """)
    hits = _only(found, "RPL302")
    assert len(hits) == 1 and ".item()" in hits[0].message


def test_rpl302_negative_host_side_cast(tmp_path):
    # not jit-reachable -> host code may cast freely
    found = _lint(tmp_path, """
        import jax.numpy as jnp

        def summarize(x):
            return float(jnp.sum(x))
    """)
    assert _only(found, "RPL302") == []


# =====================================================================
# RPL303 numpy-on-traced
# =====================================================================

def test_rpl303_np_call_on_traced(tmp_path):
    found = _lint(tmp_path, """
        import jax
        import numpy as np

        @jax.jit
        def f(x):
            return np.sum(x)
    """)
    hits = _only(found, "RPL303")
    assert len(hits) == 1 and "np.sum" in hits[0].message


def test_rpl303_np_asarray_in_scan_body(tmp_path):
    found = _lint(tmp_path, """
        import jax
        import numpy as np

        def run(xs, c0):
            def body(c, x):
                return c + np.asarray(x), c
            return jax.lax.scan(body, c0, xs)
    """)
    assert len(_only(found, "RPL303")) == 1


def test_rpl303_negative_np_on_static_metadata(tmp_path):
    found = _lint(tmp_path, """
        import jax
        import numpy as np

        @jax.jit
        def f(x):
            n = np.prod(x.shape)     # static metadata: fine
            return x / n
    """)
    assert _only(found, "RPL303") == []


# =====================================================================
# RPL401 f64-dtype
# =====================================================================

def test_rpl401_jnp_float64_reference(tmp_path):
    found = _lint(tmp_path, """
        import jax.numpy as jnp

        def f(x):
            return x.astype(jnp.float64)
    """)
    assert len(_only(found, "RPL401")) == 1


def test_rpl401_dtype_string_in_jax_call(tmp_path):
    found = _lint(tmp_path, """
        import jax.numpy as jnp

        def f():
            return jnp.zeros((4, 4), dtype="float64")
    """)
    assert len(_only(found, "RPL401")) == 1


def test_rpl401_negative_host_numpy_f64(tmp_path):
    # host-side numpy reference computations are f64 by default — only
    # jax-side wide dtypes are in scope
    found = _lint(tmp_path, """
        import numpy as np

        def reference(x):
            return np.asarray(x, np.float64).sum()
    """)
    assert _only(found, "RPL401") == []


# =====================================================================
# RPL402 bf16-accum
# =====================================================================

def test_rpl402_sum_over_bf16_cast(tmp_path):
    found = _lint(tmp_path, """
        import jax.numpy as jnp

        def f(x):
            return jnp.sum(x.astype(jnp.bfloat16))
    """)
    hits = _only(found, "RPL402")
    assert len(hits) == 1 and "sum" in hits[0].message


def test_rpl402_matmul_operator_on_f16(tmp_path):
    found = _lint(tmp_path, """
        import jax.numpy as jnp

        def f(a, b):
            return a.astype(jnp.float16) @ b
    """)
    hits = _only(found, "RPL402")
    assert len(hits) == 1 and "matmul" in hits[0].message


def test_rpl402_negative_wide_accumulator(tmp_path):
    found = _lint(tmp_path, """
        import jax.numpy as jnp

        def f(x, a, b):
            s = jnp.sum(x.astype(jnp.bfloat16), dtype=jnp.float32)
            m = jnp.matmul(a.astype(jnp.bfloat16), b,
                           preferred_element_type=jnp.float32)
            t = jnp.sum(x.astype(jnp.float32))
            return s, m, t
    """)
    assert _only(found, "RPL402") == []


# =====================================================================
# RPL501 problem-hooks
# =====================================================================

def test_rpl501_missing_full_step(tmp_path):
    found = _lint(tmp_path, """
        from repro.core.problem import Problem, register

        @register("fixture_a")
        class A(Problem):
            def init_bundle(self, inputs, mesh):
                return None
    """)
    hits = _only(found, "RPL501")
    assert len(hits) == 1 and "full_step" in hits[0].message


def test_rpl501_wrong_hook_arity(tmp_path):
    found = _lint(tmp_path, """
        from repro.core.problem import Problem, register

        @register("fixture_b")
        class B(Problem):
            def init_bundle(self, inputs):      # lost the mesh param
                return None

            def full_step(self, d, rep, axes, extra):
                return d, 0.0
    """)
    hits = _only(found, "RPL501")
    assert len(hits) == 2
    assert all("DESIGN.md" in h.message for h in hits)


def test_rpl501_negative_conforming_class(tmp_path):
    found = _lint(tmp_path, """
        from repro.core.problem import Problem, register

        @register("fixture_c")
        class C(Problem):
            replicated_in_carry = True

            def init_bundle(self, inputs, mesh):
                return None

            def full_step(self, d, rep, axes):
                return d, 0.0

            def light_step(self, d, rep, axes):
                return d, 0.0

            def refresh_replicated(self, rep, out):
                return rep

        class NotRegistered:
            def init_bundle(self):      # not @register-ed: out of scope
                pass
    """)
    assert _only(found, "RPL501") == []
    assert _only(found, "RPL502") == []


# =====================================================================
# RPL502 problem-metadata
# =====================================================================

def test_rpl502_replicated_without_refresh(tmp_path):
    found = _lint(tmp_path, """
        from repro.core.problem import Problem, register

        @register("fixture_d")
        class D(Problem):
            replicated_in_carry = True

            def init_bundle(self, inputs, mesh):
                return None

            def full_step(self, d, rep, axes):
                return d, 0.0
    """)
    hits = _only(found, "RPL502")
    assert len(hits) == 2       # needs refresh_replicated AND light_step
    msgs = " | ".join(h.message for h in hits)
    assert "refresh_replicated" in msgs and "light_step" in msgs


def test_rpl502_refresh_without_flag_and_chunk_without_cost(tmp_path):
    found = _lint(tmp_path, """
        from repro.core.problem import Problem, register

        @register("fixture_e")
        class E(Problem):
            default_cost_every = "chunk"

            def init_bundle(self, inputs, mesh):
                return None

            def full_step(self, d, rep, axes):
                return d, 0.0

            def refresh_replicated(self, rep, out):
                return rep
    """)
    hits = _only(found, "RPL502")
    msgs = " | ".join(h.message for h in hits)
    assert "dead wiring" in msgs          # refresh without the flag
    assert "chunk" in msgs                # cost_every="chunk" without cost


# =====================================================================
# RPL601 noncanonical-import
# =====================================================================

def test_rpl601_auto_interpret_via_kernel_reexport(tmp_path):
    found = _lint(tmp_path, """
        from repro.kernels.condat_elwise.kernel import auto_interpret
    """)
    hits = _only(found, "RPL601")
    assert len(hits) == 1 and "repro.kernels.common" in hits[0].message


def test_rpl601_pad_leading_via_ops(tmp_path):
    found = _lint(tmp_path, """
        from repro.kernels.dict_outer.ops import pad_leading
    """)
    assert len(_only(found, "RPL601")) == 1


def test_rpl601_negative_canonical_import(tmp_path):
    found = _lint(tmp_path, """
        from repro.kernels.common import auto_interpret, pad_leading
        from repro.kernels.dict_outer.kernel import dict_outer_fwd
    """)
    assert _only(found, "RPL601") == []


# =====================================================================
# Suppressions
# =====================================================================

_SUPPRESSIBLE = """
    import jax

    @jax.jit
    def f(x):
        if x > 0:{comment}
            return x
        return -x
"""


def test_suppression_by_rule_id(tmp_path):
    src = _SUPPRESSIBLE.format(comment="  # repro-lint: disable=RPL301")
    assert _only(_lint(tmp_path, src), "RPL301") == []


def test_suppression_by_slug(tmp_path):
    src = _SUPPRESSIBLE.format(
        comment="  # repro-lint: disable=traced-branch")
    assert _only(_lint(tmp_path, src), "RPL301") == []


def test_suppression_file_wide(tmp_path):
    src = "# repro-lint: disable-file=RPL301\n" + \
        textwrap.dedent(_SUPPRESSIBLE.format(comment=""))
    p = tmp_path / "mod.py"
    p.write_text(src)
    assert _only(lint_file(p), "RPL301") == []


def test_suppression_only_hides_named_rule(tmp_path):
    src = _SUPPRESSIBLE.format(comment="  # repro-lint: disable=RPL999")
    assert len(_only(_lint(tmp_path, src), "RPL301")) == 1


# =====================================================================
# RPL701 swallowed-exception
# =====================================================================

def test_rpl701_bare_except_pass_in_core(tmp_path):
    found = _lint(tmp_path, """
        def load(path):
            try:
                return open(path).read()
            except:
                pass
    """, name="repro/core/loader.py")
    hits = _only(found, "RPL701")
    assert len(hits) == 1
    assert hits[0].line == 5
    assert "bare except" in hits[0].message


def test_rpl701_broad_except_logged_only_in_checkpoint(tmp_path):
    found = _lint(tmp_path, """
        def write(step, tree):
            try:
                _do_write(step, tree)
            except Exception as e:
                print("checkpoint write failed:", e)
    """, name="repro/checkpoint/writer.py")
    hits = _only(found, "RPL701")
    assert len(hits) == 1 and "except Exception" in hits[0].message


def test_rpl701_broad_tuple_except_in_resilience(tmp_path):
    found = _lint(tmp_path, """
        def step(fn):
            try:
                return fn()
            except (OSError, BaseException):
                return None
    """, name="repro/resilience/loop.py")
    assert len(_only(found, "RPL701")) == 1


def test_rpl701_reraise_and_router_are_clean(tmp_path):
    found = _lint(tmp_path, """
        from repro.resilience.errors import classify

        def dispatch(self, fn):
            try:
                return fn()
            except Exception as e:
                if classify(e) != "transient":
                    raise
                self.retries += 1

        def background(self, fn):
            try:
                fn()
            except BaseException as e:
                self._record_failure(e)
    """, name="repro/core/supervised.py")
    assert _only(found, "RPL701") == []


def test_rpl701_narrow_except_is_clean(tmp_path):
    found = _lint(tmp_path, """
        def parse(text):
            try:
                return int(text)
            except ValueError:
                return None
    """, name="repro/core/parse.py")
    assert _only(found, "RPL701") == []


def test_rpl701_out_of_scope_not_flagged(tmp_path):
    found = _lint(tmp_path, """
        def probe():
            try:
                return _compile()
            except Exception:
                return None
    """, name="repro/kernels/probe.py")
    assert _only(found, "RPL701") == []


# =====================================================================
# RPL801 batch-axes
# =====================================================================

def test_rpl801_undeclared_constructor_state(tmp_path):
    found = _lint(tmp_path, """
        from repro.core.problem import Problem, register

        @register("toy")
        class Toy(Problem):
            def __init__(self, cfg=None, sigma=0.02):
                self.cfg = cfg
                self.sigma = sigma

            def init_bundle(self, inputs, mesh):
                return build(inputs, self.cfg, noise=self.sigma)

            def full_step(self, d, rep, axes):
                return d, {"cost": 0.0}

            def batch_axes(self):
                from repro.core.batching import BatchAxes
                return BatchAxes(record_axes=0)
    """)
    hits = _only(found, "RPL801")
    assert len(hits) == 1
    assert "self.sigma" in hits[0].message
    assert "instance_invariant" in hits[0].message


def test_rpl801_missing_batch_axes_declaration(tmp_path):
    found = _lint(tmp_path, """
        from repro.core.problem import Problem, register

        @register("toy")
        class Toy(Problem):
            def __init__(self, key=None):
                self.key = key

            def init_bundle(self, inputs, mesh):
                return build(inputs, key=self.key, cfg=self.cfg)

            def full_step(self, d, rep, axes):
                return d, {"cost": 0.0}
    """)
    hits = _only(found, "RPL801")
    assert len(hits) == 1
    assert "declares no batch_axes()" in hits[0].message
    assert "key" in hits[0].message


def test_rpl801_declared_state_is_clean(tmp_path):
    found = _lint(tmp_path, """
        from repro.core.batching import BatchAxes
        from repro.core.problem import Problem, register

        @register("toy")
        class Toy(Problem):
            def __init__(self, cfg=None, key=None):
                self.cfg = cfg
                self.key = key
                self._cache = None

            def init_bundle(self, inputs, mesh):
                return build(inputs, self.cfg, key=self.key,
                             helper=self.helper())

            def helper(self):
                return 1

            def full_step(self, d, rep, axes):
                return d, {"cost": 0.0}

            def batch_axes(self):
                return BatchAxes(record_axes=0,
                                 instance_invariant=("key",))
    """)
    assert _only(found, "RPL801") == []


def test_rpl801_unregistered_class_not_flagged(tmp_path):
    found = _lint(tmp_path, """
        class Helper:
            def __init__(self, sigma):
                self.sigma = sigma

            def init_bundle(self, inputs, mesh):
                return build(inputs, self.sigma)
    """)
    assert _only(found, "RPL801") == []


# =====================================================================
# RPL901 untracked-task
# =====================================================================

def test_rpl901_bare_create_task(tmp_path):
    found = _lint(tmp_path, """
        import asyncio

        async def start(self):
            asyncio.create_task(self._watchdog())
    """, name="repro/serve/svc.py")
    hits = _only(found, "RPL901")
    assert len(hits) == 1
    assert "discards the task handle" in hits[0].message


def test_rpl901_assigned_never_used(tmp_path):
    found = _lint(tmp_path, """
        import asyncio

        async def start(self):
            t = asyncio.ensure_future(self._watchdog())
            return self
    """, name="repro/serve/svc.py")
    hits = _only(found, "RPL901")
    assert len(hits) == 1
    assert "'t'" in hits[0].message


def test_rpl901_tracked_handles_clean(tmp_path):
    found = _lint(tmp_path, """
        import asyncio

        async def start(self):
            # stored on the object: cancellable and inspectable
            self._watchdog_task = asyncio.create_task(self._watchdog())
            self._watchdog_task.add_done_callback(self._task_exc)

        async def probe(self, coros):
            # awaited and gathered handles retrieve their exceptions
            t = asyncio.create_task(coros[0])
            await t
            rest = [asyncio.ensure_future(c) for c in coros[1:]]
            return await asyncio.gather(*rest)
    """, name="repro/serve/svc.py")
    assert _only(found, "RPL901") == []


def test_rpl901_out_of_scope_clean(tmp_path):
    found = _lint(tmp_path, """
        import asyncio

        async def fire_and_forget(coro):
            asyncio.create_task(coro)
    """, name="repro/core/loop.py")
    assert _only(found, "RPL901") == []


# =====================================================================
# Registry / CLI / output contracts
# =====================================================================

def test_rule_ids_stable():
    ids = {r.id: r.slug for r in all_rules()}
    assert ids == {
        "RPL101": "donated-reuse",
        "RPL201": "blockspec-grid",
        "RPL202": "missing-interpret",
        "RPL203": "ref-parity",
        "RPL301": "traced-branch",
        "RPL302": "host-cast",
        "RPL303": "numpy-on-traced",
        "RPL401": "f64-dtype",
        "RPL402": "bf16-accum",
        "RPL501": "problem-hooks",
        "RPL502": "problem-metadata",
        "RPL601": "noncanonical-import",
        "RPL701": "swallowed-exception",
        "RPL801": "batch-axes",
        "RPL901": "untracked-task",
    }


def test_finding_format_is_path_line_col(tmp_path):
    found = _lint(tmp_path, """
        from repro.kernels.dict_outer.kernel import auto_interpret
    """)
    line = found[0].format()
    import re
    assert re.match(
        r"^.+mod\.py:\d+:\d+: RPL\d{3}\[[a-z0-9-]+\] .+", line), line


def test_syntax_error_reported_not_raised(tmp_path):
    found = _lint(tmp_path, "def broken(:\n")
    assert [f.rule.id for f in found] == ["RPL000"]


def test_cli_exit_codes_and_report(tmp_path, capsys):
    dirty = tmp_path / "dirty.py"
    dirty.write_text(
        "from repro.kernels.dict_outer.kernel import auto_interpret\n")
    clean = tmp_path / "clean.py"
    clean.write_text("x = 1\n")
    report = tmp_path / "report.json"

    assert lint_main([str(dirty), "--report", str(report)]) == 1
    out = capsys.readouterr().out
    assert "RPL601" in out and "1 finding" in out
    data = json.loads(report.read_text())
    assert data["findings"][0]["rule"] == "RPL601"
    assert {r["id"] for r in data["rules"]} >= {"RPL101", "RPL601"}

    assert lint_main([str(clean)]) == 0
    assert lint_main(["--list-rules"]) == 0
    assert "RPL301" in capsys.readouterr().out


def test_cli_select_filters_rules(tmp_path, capsys):
    dirty = tmp_path / "dirty.py"
    dirty.write_text(
        "from repro.kernels.dict_outer.kernel import auto_interpret\n")
    assert lint_main([str(dirty), "--select", "RPL101"]) == 0
    assert lint_main([str(dirty), "--select", "noncanonical-import"]) == 1


# =====================================================================
# Acceptance: the repo's own tree lints clean
# =====================================================================

def test_repo_tree_lints_clean():
    findings = lint_paths([REPO / "src", REPO / "tests",
                           REPO / "benchmarks"])
    assert findings == [], "\n".join(f.format() for f in findings)
