"""Runtime contract sanitizers (``solve(..., checks=True)`` /
``REPRO_CHECKS=1``, DESIGN.md §17) and the ``REPRO_FORCE_INTERPRET``
kernel-backend override."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.bundle import Bundle
from repro.core.checks import (CheckError, assert_all_finite,
                               assert_costs_finite, checks_enabled)
from repro.core.driver import IterativeDriver, RunOptions
from repro.core.problem import Problem, solve

KEY = jax.random.PRNGKey(7)


class Quad(Problem):
    """Tiny averaging iteration with injectable contract violations."""

    def __init__(self, bad=None):
        self.bad = bad

    def init_bundle(self, inputs, mesh):
        (y,) = inputs
        x0 = jnp.zeros_like(y)
        if self.bad == "init_nan":
            x0 = x0.at[0].set(jnp.nan)
        return Bundle.create({"x": x0, "y": y}, mesh=mesh)

    def full_step(self, d, rep, axes):
        x = 0.5 * (d["x"] + d["y"])
        if self.bad == "nan":
            x = x * jnp.float32(0.0) / jnp.float32(0.0)
        if self.bad == "dtype":
            x = x.astype(jnp.float16)   # carry dtype flip f32 -> f16
        cost = jnp.sum((x - d["y"]) ** 2)
        return dict(d, x=x), cost


@pytest.fixture(scope="module")
def y():
    return jnp.asarray(np.linspace(0.0, 1.0, 32), jnp.float32)


# ------------------------------------------------------------ clean run
def test_checks_clean_run_identical_trajectory(y):
    off = solve(Quad(), y, max_iter=8, chunk=4, tol=0.0)
    on = solve(Quad(), y, max_iter=8, chunk=4, tol=0.0, checks=True)
    np.testing.assert_array_equal(np.asarray(off.costs),
                                  np.asarray(on.costs))


# -------------------------------------------------------- finite guards
def test_checks_catch_injected_nan_chunked(y):
    with pytest.raises(CheckError, match="NaN"):
        solve(Quad("nan"), y, max_iter=8, chunk=4, tol=0.0, checks=True)


def test_checks_catch_injected_nan_per_step(y):
    with pytest.raises(CheckError, match="iteration 0"):
        solve(Quad("nan"), y, max_iter=4, chunk=1, tol=0.0, checks=True)


def test_checks_reject_nonfinite_init_bundle(y):
    with pytest.raises(CheckError, match="initial bundle state"):
        solve(Quad("init_nan"), y, max_iter=4, chunk=4, tol=0.0,
              checks=True)


def test_checks_off_is_silent(y):
    # the exact same poisoned run proceeds when checks are off — that
    # is the failure mode the sanitizer exists for
    sol = solve(Quad("nan"), y, max_iter=4, chunk=2, tol=0.0)
    assert np.isnan(sol.costs).any()


# ------------------------------------------------- carry-contract guard
def test_checks_catch_carry_dtype_flip_chunked(y):
    # caught at trace time (eval_shape pre-flight), before any dispatch
    with pytest.raises(CheckError, match="before any dispatch"):
        solve(Quad("dtype"), y, max_iter=8, chunk=4, tol=0.0,
              checks=True)


def test_checks_catch_carry_dtype_flip_per_step(y):
    with pytest.raises(CheckError, match="dtype float32 -> float16"):
        solve(Quad("dtype"), y, max_iter=4, chunk=1, tol=0.0,
              checks=True)


# ------------------------------------------------------- env force-mode
def test_repro_checks_env_force_enables(y, monkeypatch):
    monkeypatch.setenv("REPRO_CHECKS", "1")
    with pytest.raises(CheckError):
        solve(Quad("nan"), y, max_iter=8, chunk=4, tol=0.0)


def test_repro_checks_env_falsy_values_stay_off(monkeypatch):
    for val in ("", "0", "false", "no"):
        monkeypatch.setenv("REPRO_CHECKS", val)
        assert checks_enabled(False) is False
    monkeypatch.setenv("REPRO_CHECKS", "1")
    assert checks_enabled(False) is True
    monkeypatch.delenv("REPRO_CHECKS")
    assert checks_enabled(True) is True


# --------------------------------------------- hand-wired driver access
def test_checks_available_on_handwired_driver(y):
    # RunOptions.checks is run control, not solve()-only sugar
    prob = Quad("nan")
    bundle = prob.init_bundle((y,), None)
    driver = IterativeDriver(
        prob.full_step, bundle,
        options=RunOptions(max_iter=8, tol=0.0, chunk=4, checks=True))
    with pytest.raises(CheckError):
        driver.run()


# ------------------------------------------------------------ unit level
def test_assert_costs_finite_honors_inf_seed_convention():
    # +inf is the engine's not-yet-evaluated seed: allowed
    assert_costs_finite(np.array([np.inf, 1.0, 0.5]), "t")
    with pytest.raises(CheckError, match="NaN"):
        assert_costs_finite(np.array([1.0, np.nan]), "t")
    with pytest.raises(CheckError):
        assert_costs_finite(np.array([-np.inf]), "t")


def test_assert_all_finite_names_the_leaf():
    tree = {"ok": jnp.ones(3), "bad": {"inner": jnp.array([1.0, np.inf])},
            "ints": jnp.arange(3)}        # int leaves are skipped
    with pytest.raises(CheckError, match="inner"):
        assert_all_finite(tree, "t")
    assert_all_finite({"a": jnp.ones(2)}, "t")


# =====================================================================
# REPRO_FORCE_INTERPRET (kernels/common.auto_interpret override)
# =====================================================================

def test_force_interpret_env_override(monkeypatch):
    from repro.kernels.common import auto_interpret
    backend_default = jax.default_backend() != "tpu"
    monkeypatch.delenv("REPRO_FORCE_INTERPRET", raising=False)
    assert auto_interpret() is backend_default
    monkeypatch.setenv("REPRO_FORCE_INTERPRET", "1")
    assert auto_interpret() is True
    for val in ("0", "false", "no", ""):
        monkeypatch.setenv("REPRO_FORCE_INTERPRET", val)
        assert auto_interpret() is backend_default


def test_force_interpret_kernels_still_correct(monkeypatch):
    # forced interpreter mode must agree with the jnp oracle
    from repro.kernels.dict_outer.ops import dict_outer
    from repro.kernels.dict_outer.ref import dict_outer_ref
    monkeypatch.setenv("REPRO_FORCE_INTERPRET", "1")
    S = jax.random.normal(KEY, (96, 8))
    W = jax.random.normal(jax.random.PRNGKey(8), (96, 6))
    got = dict_outer(S, W, block_k=32)
    want = dict_outer_ref(S, W)
    for g, w in zip(got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                   rtol=1e-5, atol=1e-5)
