"""Multi-device semantics tests.

jax locks the device count at first init, so anything needing >1 device
runs in a subprocess with XLA_FLAGS=--xla_force_host_platform_device_count=8.
Each scenario asserts distributed == single-device math.
"""
import os
import subprocess
import sys
import textwrap
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent


def run_sub(body: str, timeout: int = 600):
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = (
            "--xla_force_host_platform_device_count=8 "
            + os.environ.get("XLA_FLAGS", ""))
        import jax, jax.numpy as jnp, numpy as np
        assert len(jax.devices()) == 8
        from repro.launch.mesh import make_mesh
    """) + textwrap.dedent(body)
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src")
    proc = subprocess.run([sys.executable, "-c", script], env=env,
                          capture_output=True, text=True, timeout=timeout)
    assert proc.returncode == 0, f"STDOUT:\n{proc.stdout}\nERR:\n{proc.stderr}"
    return proc.stdout


def test_bundle_distributed_equals_local():
    run_sub("""
    from repro.core.bundle import Bundle, bundle_map, bundle_map_reduce, gather
    mesh = make_mesh((4, 2), ("data", "model"))
    key = jax.random.PRNGKey(0)
    data = {"a": jax.random.normal(key, (16, 5)),
            "b": jax.random.normal(jax.random.fold_in(key, 1), (16, 3))}
    b_loc = Bundle.create(dict(data))
    b_dist = Bundle.create(dict(data), mesh=mesh, axes=("data",))
    assert b_dist.n_partitions == 4
    f = lambda d: {"a": d["a"] * 2 + 1, "b": jnp.tanh(d["b"])}
    out_l = gather(bundle_map(f, b_loc))
    out_d = gather(bundle_map(f, b_dist))
    for k in out_l:
        np.testing.assert_allclose(out_l[k], out_d[k], rtol=1e-6)
    g = lambda d: {"gram": d["a"].T @ d["a"], "s": jnp.sum(d["b"])}
    r_l = bundle_map_reduce(g, b_loc)
    r_d = bundle_map_reduce(g, b_dist)
    np.testing.assert_allclose(np.asarray(r_l["gram"]),
                               np.asarray(r_d["gram"]), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(float(r_l["s"]), float(r_d["s"]), rtol=1e-5)
    print("bundle ok")
    """)


def test_psf_deconvolution_distributed_equals_sequential():
    run_sub("""
    from repro.imaging import psf as psf_op
    from repro.imaging.condat import SolverConfig, solve
    from repro.imaging.deconvolve import deconvolve
    mesh = make_mesh((8,), ("data",))
    data = psf_op.simulate(16, jax.random.PRNGKey(2))
    cfg = SolverConfig(mode="sparse", n_scales=3)
    _, costs = solve(data.Y, data.psfs, cfg, sigma_noise=data.sigma, n_iter=10)
    X, log = deconvolve(data.Y, data.psfs, cfg, mesh=mesh,
                        sigma_noise=data.sigma, max_iter=10, tol=0)
    np.testing.assert_allclose(np.asarray(costs), np.asarray(log.costs),
                               rtol=1e-3)
    print("psf distributed ok")
    """)


def test_scdl_distributed_equals_sequential():
    run_sub("""
    from repro.data.synthetic import coupled_patches
    from repro.imaging.scdl import SCDLConfig, train
    mesh = make_mesh((8,), ("data",))
    S_h, S_l = coupled_patches(256, 25, 9, 16, seed=5)
    cfg = SCDLConfig(n_atoms=16, max_iter=8)
    Xh_s, Xl_s, log_s = train(S_h, S_l, cfg, mesh=None)
    Xh_d, Xl_d, log_d = train(S_h, S_l, cfg, mesh=mesh)
    np.testing.assert_allclose(log_s.costs, log_d.costs, rtol=5e-3)
    np.testing.assert_allclose(Xh_s, Xh_d, rtol=1e-2, atol=1e-3)
    print("scdl distributed ok")

    # ill-conditioned regime: near-duplicate atoms, the factor-once
    # Cholesky/Woodbury broadcast must still give distributed ==
    # sequential (the psum'd outer products feed identical factors)
    rng = np.random.RandomState(9)
    proto_h, proto_l = rng.randn(25, 4), rng.randn(9, 4)
    idx = rng.randint(0, 4, size=256); amp = rng.rand(256) + 0.5
    S_h = jnp.asarray(proto_h[:, idx] * amp
                      + 1e-3 * rng.randn(25, 256), jnp.float32)
    S_l = jnp.asarray(proto_l[:, idx] * amp
                      + 1e-3 * rng.randn(9, 256), jnp.float32)
    Xh_s, _, log_s = train(S_h, S_l, cfg, mesh=None)
    Xh_d, _, log_d = train(S_h, S_l, cfg, mesh=mesh)
    np.testing.assert_allclose(log_s.costs, log_d.costs,
                               rtol=5e-3, atol=1e-3)
    print("scdl ill-conditioned distributed ok")
    """)


def test_hierarchical_psum_and_compression():
    run_sub("""
    from functools import partial
    from jax.sharding import PartitionSpec as P
    from repro.core.compat import shard_map
    from repro.parallel.collectives import (CompressedReducer,
                                            hierarchical_psum_local)
    mesh = make_mesh((2, 4), ("pod", "data"))
    x = jax.random.normal(jax.random.PRNGKey(0), (16, 8))

    def flat(xl):
        return jax.lax.psum(jax.lax.psum(xl, "data"), "pod")

    def hier(xl):
        return hierarchical_psum_local(xl, pod_axis="pod", data_axis="data")

    sm = partial(shard_map, mesh=mesh, in_specs=(P(("pod", "data")),),
                 out_specs=P(("pod", "data")), check_vma=False)
    np.testing.assert_allclose(np.asarray(sm(flat)(x)),
                               np.asarray(sm(hier)(x)), rtol=1e-5)

    red = CompressedReducer(mesh)
    def comp(xl):
        e = jnp.zeros_like(xl)
        mean, e2 = red.reduce_local({"g": xl}, {"g": e})
        return mean["g"]
    exact = sm(lambda xl: jax.lax.pmean(jax.lax.pmean(xl, "data"), "pod"))(x)
    approx = sm(comp)(x)
    err = float(jnp.max(jnp.abs(exact - approx)))
    scale = float(jnp.max(jnp.abs(exact)))
    assert err <= 0.02 * max(scale, 1e-6) + 1e-4, (err, scale)
    print("collectives ok")
    """)


def test_pipeline_parallel_matches_sequential():
    run_sub("""
    from jax.sharding import PartitionSpec as P
    from repro.parallel.pipeline import make_pipelined_forward
    mesh = make_mesh((4, 2), ("stage", "data"))
    S_, Lp, D = 4, 2, 16          # 4 stages x 2 layers = 8 layers
    key = jax.random.PRNGKey(0)
    Ws = jax.random.normal(key, (S_, Lp, D, D)) * 0.3
    x = jax.random.normal(jax.random.fold_in(key, 1), (8, D))

    def layer_fn(wstack, h):
        def body(h, w):
            return jnp.tanh(h @ w), None
        h, _ = jax.lax.scan(body, h, wstack)
        return h

    # sequential reference over all 8 layers
    ref = x
    for s in range(S_):
        ref = layer_fn(Ws[s], ref)

    fwd = make_pipelined_forward(layer_fn, mesh, n_micro=4,
                                 data_axes=("data",))
    out = fwd(Ws, x)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)
    print("pipeline ok")
    """)


def test_elastic_checkpoint_restore_across_device_counts(tmp_path):
    # save on 8 devices (sharded), restore in THIS 1-device process
    run_sub(f"""
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.checkpoint import save
    mesh = make_mesh((8,), ("data",))
    w = jax.device_put(jnp.arange(64.0).reshape(8, 8),
                       NamedSharding(mesh, P("data")))
    save(r"{tmp_path}", 5, {{"w": w}})
    print("saved")
    """)
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.checkpoint import restore
    out, _ = restore(tmp_path, 5, {"w": jnp.zeros((8, 8))})
    np.testing.assert_array_equal(np.asarray(out["w"]),
                                  np.arange(64.0).reshape(8, 8))
