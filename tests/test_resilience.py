"""Resilient-solve suite (DESIGN.md §18): the chaos matrix.

Every recovery path of ``solve(..., resilience=ResilienceConfig(...))``
is exercised with *deterministic* injected faults
(``repro.resilience.chaos``) and must reproduce the fault-free
trajectory to rtol 1e-4 (in fact bit-exactly: snapshots round-trip
fp32 through host memory unchanged):

- transient dispatch failures -> bounded retry from the snapshot ring;
- NaN-poisoned carries -> divergence rollback (ring, then the newest
  *valid* on-disk checkpoint once the ring is dry);
- corrupted newest checkpoint -> resume falls back to the previous
  retention entry (explicit ``resume=step`` stays loud);
- async checkpoint write failures -> surfaced at the next sync point;
- Pallas kernel failures -> per-family compiled->interpret->ref
  degradation with a recorded warning.
"""
import warnings
from pathlib import Path

import jax
import numpy as np
import pytest

from repro.checkpoint import (Checkpointer, CheckpointCorruptError,
                              CheckpointWriteError, latest_step,
                              latest_valid_step, validate_checkpoint)
from repro.core.problem import solve
from repro.kernels import common as kcommon
from repro.resilience import chaos
from repro.resilience.errors import (DivergenceError, InjectedFault,
                                     ResilienceExhausted, classify)
from repro.resilience.recovery import RecoveryReport, ResilienceConfig

ITERS, CHUNK = 12, 4        # 3 chunk dispatches: first / mid / last


@pytest.fixture(scope="module")
def psf_data():
    from repro.imaging import psf as psf_op
    return psf_op.simulate(8, jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def scdl_data():
    from repro.data.synthetic import coupled_patches
    return coupled_patches(256, 25, 9, 16, seed=0)


@pytest.fixture(scope="module")
def lowrank_data():
    rng = np.random.default_rng(4)
    U = rng.normal(size=(24, 3)).astype(np.float32)
    V = rng.normal(size=(3, 18)).astype(np.float32)
    Y = U @ V + 0.01 * rng.normal(size=(24, 18)).astype(np.float32)
    M = (rng.random((24, 18)) < 0.6).astype(np.float32)
    return Y, M


def _solve(workload, data, **kw):
    opts = dict(max_iter=ITERS, tol=0, chunk=CHUNK)
    opts.update(kw)
    if workload == "deconvolve":
        from repro.imaging.condat import SolverConfig
        return solve("deconvolve", data.Y, data.psfs,
                     cfg=SolverConfig(mode="sparse", n_scales=3), **opts)
    if workload == "lowrank":
        from repro.imaging.lowrank import CompletionConfig
        Y, M = data
        return solve("lowrank", Y, M,
                     cfg=CompletionConfig(rank=4, max_iter=ITERS), **opts)
    from repro.imaging.scdl import SCDLConfig
    S_h, S_l = data
    return solve("scdl", S_h, S_l,
                 cfg=SCDLConfig(n_atoms=16, max_iter=ITERS), **opts)


@pytest.fixture(scope="module")
def ref_trajs(psf_data, scdl_data, lowrank_data):
    """Fault-free reference runs, one per workload."""
    return {"deconvolve": _solve("deconvolve", psf_data),
            "scdl": _solve("scdl", scdl_data),
            "lowrank": _solve("lowrank", lowrank_data)}


def _assert_parity(sol, ref):
    np.testing.assert_allclose(sol.log.costs, ref.log.costs, rtol=1e-4)
    for a, b in zip(jax.tree.leaves(sol.x), jax.tree.leaves(ref.x)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-6)


# =====================================================================
# The chaos matrix: both workloads x both fault kinds x chunk position
# =====================================================================

@pytest.mark.parametrize("pos", [0, 1, 2], ids=["first", "mid", "last"])
@pytest.mark.parametrize("point", ["dispatch", "carry_nan"])
@pytest.mark.parametrize("workload", ["deconvolve", "scdl", "lowrank"])
def test_chaos_matrix_auto_recovers(workload, point, pos, psf_data,
                                    scdl_data, lowrank_data, ref_trajs):
    data = {"deconvolve": psf_data, "scdl": scdl_data,
            "lowrank": lowrank_data}[workload]
    cc = chaos.ChaosConfig.parse(f"{point}@{pos};seed=11")
    with chaos.active_chaos(cc) as st:
        sol = _solve(workload, data, resilience=ResilienceConfig())
    assert (point, pos) in st.fired
    _assert_parity(sol, ref_trajs[workload])
    rec = sol.recovery
    assert isinstance(rec, RecoveryReport)
    if point == "dispatch":
        assert rec.retries == 1 and rec.rollbacks == 0
        assert rec.faults[0]["point"] == "dispatch"
    else:
        assert rec.rollbacks == 1 and rec.retries == 0
        assert rec.checkpoint_restores == 0
        assert rec.faults[0]["point"] == "divergence"
    assert rec.wall_time_lost_s >= 0.0


def test_fault_free_supervised_run_is_clean(psf_data, ref_trajs):
    sol = _solve("deconvolve", psf_data, resilience=ResilienceConfig())
    _assert_parity(sol, ref_trajs["deconvolve"])
    rec = sol.recovery
    assert rec.retries == rec.rollbacks == rec.checkpoint_restores == 0
    assert rec.faults == [] and rec.kernel_fallbacks == []


def test_unsupervised_run_has_no_recovery(ref_trajs):
    assert ref_trajs["deconvolve"].recovery is None


def test_unsupervised_chaos_fault_is_fatal(psf_data):
    cc = chaos.ChaosConfig.parse("dispatch@1")
    with chaos.active_chaos(cc):
        with pytest.raises(InjectedFault):
            _solve("deconvolve", psf_data)


def test_retry_budget_exhaustion_raises(psf_data):
    cc = chaos.ChaosConfig.parse("dispatch@0,1,2,3,4,5")
    with chaos.active_chaos(cc):
        with pytest.raises(ResilienceExhausted):
            _solve("deconvolve", psf_data,
                   resilience=ResilienceConfig(max_retries=2,
                                               backoff_s=1e-3))


# =====================================================================
# Rollback sources: ring first, then the newest valid disk checkpoint
# =====================================================================

def test_repeated_divergence_falls_back_to_disk(tmp_path, psf_data,
                                                ref_trajs):
    from repro.checkpoint import checkpointer as ckpt
    from repro.core import persistence

    def checkpoint_fn(bundle, i):
        # synchronous write: the disk fallback must find step i+1
        ckpt.save(tmp_path, i + 1, persistence.spill_bundle(bundle))

    # chunk at i=4 diverges twice: rollback #1 consumes the only ring
    # entry, rollback #2 finds the re-pushed snapshot already failed and
    # restores the step-4 checkpoint from disk
    cc = chaos.ChaosConfig.parse("carry_nan@1,2;seed=5")
    with chaos.active_chaos(cc):
        sol = _solve("deconvolve", psf_data,
                     checkpoint_every=CHUNK, checkpoint_fn=checkpoint_fn,
                     resilience=ResilienceConfig(
                         ring=1, checkpoint_dir=str(tmp_path)))
    assert sol.recovery.rollbacks == 2
    assert sol.recovery.checkpoint_restores == 1
    _assert_parity(sol, ref_trajs["deconvolve"])


def test_rollback_budget_exhaustion_raises(psf_data):
    # every chunk invocation poisoned: rollback can never get ahead
    cc = chaos.ChaosConfig.parse(
        "carry_nan@" + ",".join(str(i) for i in range(32)))
    with chaos.active_chaos(cc):
        with pytest.raises(ResilienceExhausted):
            _solve("deconvolve", psf_data,
                   resilience=ResilienceConfig(max_rollbacks=3))


# =====================================================================
# Hardened checkpointing: corruption detection + resume fallback
# =====================================================================

def _corrupt_leaf(directory, step):
    leaf = sorted((Path(directory) / f"step_{step:08d}")
                  .glob("leaf_*.npy"))[0]
    data = leaf.read_bytes()
    leaf.write_bytes(data[: len(data) // 2])


def test_resume_falls_back_past_corrupt_newest(tmp_path, psf_data,
                                               ref_trajs):
    _solve("deconvolve", psf_data, max_iter=8,
           checkpoint_dir=str(tmp_path), checkpoint_every=4)
    assert latest_step(tmp_path) == 8
    assert validate_checkpoint(tmp_path, 8) is None
    _corrupt_leaf(tmp_path, 8)
    assert validate_checkpoint(tmp_path, 8) is not None
    assert latest_valid_step(tmp_path) == (4, [8])

    with pytest.warns(RuntimeWarning, match="integrity"):
        sol = _solve("deconvolve", psf_data,
                     checkpoint_dir=str(tmp_path), resume=True)
    # resumed from step 4 -> iterations 4..11 of the reference run
    assert len(sol.log.costs) == ITERS - 4
    np.testing.assert_allclose(
        sol.log.costs, ref_trajs["deconvolve"].log.costs[4:], rtol=1e-4)


def test_resume_explicit_corrupt_step_stays_loud(tmp_path, psf_data):
    _solve("deconvolve", psf_data, max_iter=8,
           checkpoint_dir=str(tmp_path), checkpoint_every=4)
    _corrupt_leaf(tmp_path, 8)
    with pytest.raises(CheckpointCorruptError, match="integrity"):
        _solve("deconvolve", psf_data,
               checkpoint_dir=str(tmp_path), resume=8)


def test_chaos_ckpt_corrupt_injector(tmp_path, psf_data):
    # the second save (step 8) is torn after its checksums are computed
    cc = chaos.ChaosConfig.parse("ckpt_corrupt@1")
    with chaos.active_chaos(cc):
        _solve("deconvolve", psf_data, max_iter=8,
               checkpoint_dir=str(tmp_path), checkpoint_every=4)
    assert latest_step(tmp_path) == 8
    assert validate_checkpoint(tmp_path, 4) is None
    assert validate_checkpoint(tmp_path, 8) is not None
    assert latest_valid_step(tmp_path) == (4, [8])


# =====================================================================
# Async Checkpointer failure surfacing
# =====================================================================

def test_async_write_failure_surfaces_at_wait(tmp_path):
    tree = {"a": np.arange(8, dtype=np.float32)}
    cc = chaos.ChaosConfig.parse("ckpt_write@0")
    with chaos.active_chaos(cc):
        w = Checkpointer(tmp_path)
        w.save_async(1, tree)
        with pytest.raises(CheckpointWriteError) as ei:
            w.wait()
        assert isinstance(ei.value.__cause__, InjectedFault)
        # the failure is consumed: the next save succeeds and validates
        w.save_async(2, tree)
        w.close()
    assert latest_step(tmp_path) == 2
    assert validate_checkpoint(tmp_path, 2) is None


def test_async_write_failure_surfaces_at_next_save(tmp_path):
    tree = {"a": np.zeros(4, dtype=np.float32)}
    cc = chaos.ChaosConfig.parse("ckpt_write@0")
    with chaos.active_chaos(cc):
        w = Checkpointer(tmp_path)
        w.save_async(1, tree)
        with pytest.raises(CheckpointWriteError):
            w.save(2, tree)
        w.close()


def test_async_write_failure_surfaces_at_close(tmp_path):
    tree = {"a": np.zeros(4, dtype=np.float32)}
    cc = chaos.ChaosConfig.parse("ckpt_write@0")
    with chaos.active_chaos(cc):
        w = Checkpointer(tmp_path)
        w.save_async(1, tree)
        with pytest.raises(CheckpointWriteError):
            w.close()


def test_solve_surfaces_async_checkpoint_failure(tmp_path, psf_data):
    cc = chaos.ChaosConfig.parse("ckpt_write@0")
    with chaos.active_chaos(cc):
        with pytest.raises(CheckpointWriteError):
            _solve("deconvolve", psf_data, max_iter=8,
                   checkpoint_dir=str(tmp_path), checkpoint_every=4)


# =====================================================================
# Kernel degradation: compiled -> interpret -> ref, once per family
# =====================================================================

@pytest.fixture
def fresh_kernels():
    kcommon.reset_degradation()
    yield
    kcommon.reset_degradation()


def test_kernel_degradation_parity_and_warning(fresh_kernels):
    from repro.kernels.dict_outer.ops import dict_outer
    from repro.kernels.dict_outer.ref import dict_outer_ref
    rng = np.random.default_rng(0)
    S = np.asarray(rng.normal(size=(64, 16)), np.float32)
    W = np.asarray(rng.normal(size=(64, 16)), np.float32)
    cc = chaos.ChaosConfig.parse("kernel:dict_outer@0;seed=3")
    with chaos.active_chaos(cc):
        with pytest.warns(RuntimeWarning, match="degraded"):
            got = dict_outer(S, W, use_kernel=True)
    want = dict_outer_ref(S, W)
    for g, r in zip(got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(r),
                                   rtol=1e-5, atol=1e-5)
    events = kcommon.kernel_fallbacks()
    assert [e["family"] for e in events] == ["dict_outer"]
    # degradation is per-family and sticky: the next call silently uses
    # the surviving level, no new event, no new warning
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        again = dict_outer(S, W, use_kernel=True)
    np.testing.assert_allclose(np.asarray(again[0]), np.asarray(want[0]),
                               rtol=1e-5, atol=1e-5)
    assert len(kcommon.kernel_fallbacks()) == 1


def test_kernel_degradation_reset(fresh_kernels):
    from repro.kernels.condat_elwise.ops import condat_dual
    from repro.kernels.condat_elwise.ref import condat_dual_ref
    rng = np.random.default_rng(1)
    U = np.asarray(rng.normal(size=(2, 4, 8, 8)), np.float32)
    C = np.asarray(rng.normal(size=(2, 4, 8, 8)), np.float32)
    W = np.asarray(rng.normal(size=(2, 4, 1, 1)), np.float32) ** 2
    cc = chaos.ChaosConfig.parse("kernel:condat_elwise@0")
    with chaos.active_chaos(cc):
        with pytest.warns(RuntimeWarning, match="condat_elwise"):
            got = condat_dual(U, C, 0.9 * C, W, 0.5, use_kernel=True)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(condat_dual_ref(U, C, 0.9 * C, W, 0.5)),
        rtol=1e-5, atol=1e-5)
    kcommon.reset_degradation()
    assert kcommon.kernel_fallbacks() == ()
    # healthy again after reset: no warning on the next call
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        condat_dual(U, C, 0.9 * C, W, 0.5, use_kernel=True)


def test_solve_reports_kernel_fallbacks(fresh_kernels, psf_data,
                                        ref_trajs):
    # the deconvolution step traces the starlet kernels: an injected
    # construction fault degrades the family and lands on the report
    cc = chaos.ChaosConfig.parse("kernel:starlet2d@0")
    with chaos.active_chaos(cc):
        with pytest.warns(RuntimeWarning, match="starlet2d"):
            sol = _solve("deconvolve", psf_data,
                         resilience=ResilienceConfig())
    assert any(e["family"] == "starlet2d"
               for e in sol.recovery.kernel_fallbacks)
    # ref-path parity: the degraded run still reproduces the trajectory
    np.testing.assert_allclose(sol.log.costs,
                               ref_trajs["deconvolve"].log.costs,
                               rtol=1e-4)


# =====================================================================
# Chaos plumbing + error taxonomy
# =====================================================================

def test_chaos_spec_parsing():
    cc = chaos.ChaosConfig.parse("dispatch@1,3;carry_nan;seed=9")
    assert cc.seed == 9
    assert cc.faults == {"dispatch": (1, 3), "carry_nan": (0,)}
    with pytest.raises(ValueError, match="unknown chaos fault point"):
        chaos.ChaosConfig.parse("warp_core@0")


def test_chaos_env_var_path(monkeypatch, psf_data):
    monkeypatch.setenv(chaos.ENV_VAR, "dispatch@1;seed=3")
    assert not chaos.is_active()
    sol = _solve("deconvolve", psf_data, resilience=ResilienceConfig())
    assert sol.recovery.retries == 1
    assert sol.recovery.faults[0]["point"] == "dispatch"
    assert not chaos.is_active()        # deactivated after the run


def test_classify_taxonomy():
    assert classify(InjectedFault("dispatch")) == "transient"
    assert classify(OSError("disk gone")) == "transient"
    assert classify(RuntimeError("UNAVAILABLE: worker lost")) \
        == "transient"
    assert classify(ValueError("bad shape")) == "fatal"
    assert classify(DivergenceError("nan", step=3)) == "fatal"
    assert classify(ResilienceExhausted("done")) == "fatal"
    class Custom(Exception):
        pass
    assert classify(Custom(), (Custom,)) == "transient"


def test_recovery_report_json_schema():
    rep = RecoveryReport()
    rep.retries = 2
    rep.record_fault("dispatch", 8, InjectedFault("dispatch", step=8))
    out = rep.to_json()
    assert set(out) == {"retries", "rollbacks", "checkpoint_restores",
                        "faults", "kernel_fallbacks", "wall_time_lost_s"}
    assert out["retries"] == 2
    assert out["faults"][0]["point"] == "dispatch"
    assert out["faults"][0]["step"] == 8
    assert "retries=2" in str(rep)


def test_resilience_config_requires_ring():
    with pytest.raises(ValueError, match="ring"):
        ResilienceConfig(ring=0)
