"""Substrate tests: optimizer, schedules, checkpointing (atomic/async/
elastic).  The LM trainer/data tests left with the pruned LM surface
(DESIGN.md §15)."""
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.checkpoint import Checkpointer, latest_step, restore, save
from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update
from repro.optim.schedule import warmup_cosine

settings.register_profile("ci", max_examples=20, deadline=None)
settings.load_profile("ci")


# ------------------------------------------------------------ optimizer
def test_adamw_descends_quadratic():
    w = {"w": jnp.array([3.0, -2.0])}
    opt = adamw_init(w)
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0)
    loss = lambda p: jnp.sum(p["w"] ** 2)
    for _ in range(200):
        g = jax.grad(loss)(w)
        w, opt, _ = adamw_update(g, opt, w, cfg)
    assert float(loss(w)) < 1e-3


def test_adamw_grad_clip():
    w = {"w": jnp.ones((4,))}
    opt = adamw_init(w)
    cfg = AdamWConfig(lr=1e-3, grad_clip=1.0)
    g = {"w": jnp.full((4,), 1e6)}
    _, _, metrics = adamw_update(g, opt, w, cfg)
    assert float(metrics["grad_norm"]) == pytest.approx(2e6, rel=1e-3)


@given(step=st.integers(0, 10_000))
def test_warmup_cosine_bounds(step):
    s = float(warmup_cosine(jnp.int32(step), warmup=100, total=10_000))
    assert 0.0 <= s <= 1.0


def test_zero1_specs_shard_largest_dim():
    from jax.sharding import PartitionSpec as P
    from repro.optim.adamw import opt_pspecs
    pspecs = {"w": P(None, "model")}
    shapes = {"w": jax.ShapeDtypeStruct((64, 32), jnp.float32)}
    out = opt_pspecs(pspecs, shapes, dp_axes=("data",), dp_size=16)
    assert out["m"]["w"] == P("data", "model")


# ---------------------------------------------------------- checkpoints
def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6.0).reshape(2, 3),
            "b": {"c": jnp.ones((4,), jnp.int32)}}
    save(tmp_path, 3, tree, meta={"tag": "x"})
    assert latest_step(tmp_path) == 3
    like = jax.tree.map(lambda x: jnp.zeros_like(x), tree)
    out, manifest = restore(tmp_path, 3, like)
    assert manifest["meta"]["tag"] == "x"
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_atomicity_no_partial_dirs(tmp_path):
    tree = {"a": jnp.ones((8, 8))}
    save(tmp_path, 1, tree)
    save(tmp_path, 2, tree)
    names = {p.name for p in Path(tmp_path).iterdir()}
    assert not any(n.endswith(".tmp") for n in names)


def test_checkpoint_shape_mismatch_rejected(tmp_path):
    save(tmp_path, 1, {"a": jnp.ones((4,))})
    with pytest.raises(ValueError):
        restore(tmp_path, 1, {"a": jnp.ones((5,))})


def test_checkpointer_async_and_retention(tmp_path):
    ck = Checkpointer(tmp_path, keep=2)
    for s in (1, 2, 3, 4):
        ck.save_async(s, {"w": jnp.full((4,), float(s))})
    ck.wait()
    steps = sorted(int(p.name.split("_")[1]) for p in tmp_path.iterdir())
    assert steps == [3, 4]
