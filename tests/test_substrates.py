"""Substrate tests: optimizer, schedules, checkpointing (atomic/async/
elastic), data determinism, trainer failure-recovery equivalence."""
import json
import os
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.checkpoint import Checkpointer, latest_step, restore, save
from repro.data.synthetic import lm_batch
from repro.configs import get_config, reduced
from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update, \
    global_norm
from repro.optim.schedule import warmup_cosine

settings.register_profile("ci", max_examples=20, deadline=None)
settings.load_profile("ci")


# ------------------------------------------------------------ optimizer
def test_adamw_descends_quadratic():
    w = {"w": jnp.array([3.0, -2.0])}
    opt = adamw_init(w)
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0)
    loss = lambda p: jnp.sum(p["w"] ** 2)
    for _ in range(200):
        g = jax.grad(loss)(w)
        w, opt, _ = adamw_update(g, opt, w, cfg)
    assert float(loss(w)) < 1e-3


def test_adamw_grad_clip():
    w = {"w": jnp.ones((4,))}
    opt = adamw_init(w)
    cfg = AdamWConfig(lr=1e-3, grad_clip=1.0)
    g = {"w": jnp.full((4,), 1e6)}
    _, _, metrics = adamw_update(g, opt, w, cfg)
    assert float(metrics["grad_norm"]) == pytest.approx(2e6, rel=1e-3)


@given(step=st.integers(0, 10_000))
def test_warmup_cosine_bounds(step):
    s = float(warmup_cosine(jnp.int32(step), warmup=100, total=10_000))
    assert 0.0 <= s <= 1.0


def test_zero1_specs_shard_largest_dim():
    from jax.sharding import PartitionSpec as P
    from repro.optim.adamw import opt_pspecs
    pspecs = {"w": P(None, "model")}
    shapes = {"w": jax.ShapeDtypeStruct((64, 32), jnp.float32)}
    out = opt_pspecs(pspecs, shapes, dp_axes=("data",), dp_size=16)
    assert out["m"]["w"] == P("data", "model")


# ---------------------------------------------------------- checkpoints
def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6.0).reshape(2, 3),
            "b": {"c": jnp.ones((4,), jnp.int32)}}
    save(tmp_path, 3, tree, meta={"tag": "x"})
    assert latest_step(tmp_path) == 3
    like = jax.tree.map(lambda x: jnp.zeros_like(x), tree)
    out, manifest = restore(tmp_path, 3, like)
    assert manifest["meta"]["tag"] == "x"
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_atomicity_no_partial_dirs(tmp_path):
    tree = {"a": jnp.ones((8, 8))}
    save(tmp_path, 1, tree)
    save(tmp_path, 2, tree)
    names = {p.name for p in Path(tmp_path).iterdir()}
    assert not any(n.endswith(".tmp") for n in names)


def test_checkpoint_shape_mismatch_rejected(tmp_path):
    save(tmp_path, 1, {"a": jnp.ones((4,))})
    with pytest.raises(ValueError):
        restore(tmp_path, 1, {"a": jnp.ones((5,))})


def test_checkpointer_async_and_retention(tmp_path):
    ck = Checkpointer(tmp_path, keep=2)
    for s in (1, 2, 3, 4):
        ck.save_async(s, {"w": jnp.full((4,), float(s))})
    ck.wait()
    steps = sorted(int(p.name.split("_")[1]) for p in tmp_path.iterdir())
    assert steps == [3, 4]


# ---------------------------------------------------- data determinism
def test_lm_batch_deterministic_and_step_dependent():
    cfg = reduced(get_config("qwen3-1.7b"))
    b1 = lm_batch(cfg, 4, 32, seed=0, step=7)
    b2 = lm_batch(cfg, 4, 32, seed=0, step=7)
    b3 = lm_batch(cfg, 4, 32, seed=0, step=8)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                  np.asarray(b2["tokens"]))
    assert not np.array_equal(np.asarray(b1["tokens"]),
                              np.asarray(b3["tokens"]))
    assert (np.asarray(b1["tokens"]) < cfg.vocab_size).all()


# ------------------------------------------- failure-recovery replay
def test_trainer_failure_recovery_bit_exact(tmp_path):
    """Crash at step N + restore == uninterrupted run (lineage replay)."""
    from repro.launch.train import SimulatedFailure, train

    kw = dict(steps=12, batch=2, seq=16, use_reduced=True, seed=3,
              lr=1e-3, verbose=False)
    _, _, ref_losses = train("qwen3-1.7b", **kw)

    ckpt = tmp_path / "ck"
    with pytest.raises(SimulatedFailure):
        train("qwen3-1.7b", ckpt_dir=ckpt, ckpt_every=5, fail_at=8, **kw)
    _, _, resumed = train("qwen3-1.7b", ckpt_dir=ckpt, resume=True, **kw)
    # resumed covers steps [5, 12); compare the overlap exactly
    np.testing.assert_allclose(np.asarray(ref_losses[5:]),
                               np.asarray(resumed), rtol=1e-6)
