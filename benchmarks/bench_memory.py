"""Paper Fig. 6 / 11 / 12 — memory-per-worker benchmarks.

Per-device bytes from ``compiled.memory_analysis()`` for the two
use-case steps at N in {3x, 6x} partitions, measured in an 8-device
subprocess (devices are the workers; more partitions => smaller blocks,
the paper's memory/partition trade-off).  derived = per-device bytes.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap
from pathlib import Path

from benchmarks.common import emit

_SCRIPT = """
import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8 "
                            + os.environ.get("XLA_FLAGS", ""))
import json
import jax, jax.numpy as jnp
from repro.launch.mesh import make_mesh
from repro.core.bundle import Bundle
from repro.core.engine import make_step
from repro.imaging import psf as psf_op
from repro.imaging.condat import SolverConfig
from repro.imaging.deconvolve import build_bundle as psf_bundle, \
    make_step_fn as psf_step
from repro.imaging.scdl import SCDLConfig, build_bundle as scdl_bundle, \
    make_step_fn as scdl_step
from repro.data.synthetic import coupled_patches

out = {}
mesh = make_mesh((8,), ("data",))

data = psf_op.simulate(384, jax.random.PRNGKey(1))
cfg = SolverConfig(mode="sparse", n_scales=3)
bundle, _ = psf_bundle(data.Y, data.psfs, cfg, mesh=mesh,
                       sigma_noise=data.sigma)
step = make_step(psf_step(cfg), bundle, donate=False)
c = step.lower(bundle.data, bundle.replicated).compile()
ma = c.memory_analysis()
out["psf_sparse"] = dict(args=ma.argument_size_in_bytes,
                         temp=ma.temp_size_in_bytes)

S_h, S_l = coupled_patches(4096, 289, 81, 128, seed=3)
scfg = SCDLConfig(n_atoms=256)
b2 = scdl_bundle(S_h, S_l, scfg, mesh=mesh)
step2 = make_step(scdl_step(scfg), b2, donate=False)
c2 = step2.lower(b2.data, b2.replicated).compile()
ma2 = c2.memory_analysis()
out["scdl_gs"] = dict(args=ma2.argument_size_in_bytes,
                      temp=ma2.temp_size_in_bytes)
print("JSON" + json.dumps(out))
"""


def run():
    repo = Path(__file__).resolve().parent.parent
    env = dict(os.environ)
    env["PYTHONPATH"] = str(repo / "src")
    proc = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                          capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, proc.stderr[-2000:]
    payload = [l for l in proc.stdout.splitlines()
               if l.startswith("JSON")][0][4:]
    out = json.loads(payload)
    for name, d in out.items():
        emit(f"fig6_11_12/{name}_mem_per_worker", 0.0,
             f"args_bytes={d['args']};temp_bytes={d['temp']}")
