"""Paper Fig. 6 / 11 / 12 — memory-per-worker benchmarks.

Per-device bytes from ``compiled.memory_analysis()`` for the two
use-case steps at N in {3x, 6x} partitions, measured in an 8-device
subprocess (devices are the workers; more partitions => smaller blocks,
the paper's memory/partition trade-off), plus the host-side peak
(``tracemalloc``) of building each bundle — the paper's driver keeps
the full population on the host between dispatches, so host footprint
is part of the per-worker budget.  derived = per-device bytes.

Emits ``BENCH_memory.json`` (uploaded as a CI artifact next to the
other BENCH tables).  ``--smoke`` shrinks both workloads so the whole
subprocess compiles in seconds.

    PYTHONPATH=src python -m benchmarks.bench_memory [--smoke]
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
from pathlib import Path

from benchmarks.common import emit, write_bench_json

_SCRIPT = """
import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8 "
                            + os.environ.get("XLA_FLAGS", ""))
import json
import tracemalloc
import jax, jax.numpy as jnp
from repro.launch.mesh import make_mesh
from repro.core.bundle import Bundle
from repro.core.engine import make_step
from repro.imaging import psf as psf_op
from repro.imaging.condat import SolverConfig
from repro.imaging.deconvolve import build_bundle as psf_bundle, \
    make_step_fn as psf_step
from repro.imaging.scdl import SCDLConfig, build_bundle as scdl_bundle, \
    make_step_fn as scdl_step
from repro.data.synthetic import coupled_patches

SMOKE = {smoke}
out = {{}}
mesh = make_mesh((8,), ("data",))


def measure(name, build, step_fn):
    tracemalloc.start()
    bundle, cfg = build()
    step = make_step(step_fn(cfg), bundle, donate=False)
    c = step.lower(bundle.data, bundle.replicated).compile()
    _, host_peak = tracemalloc.get_traced_memory()
    tracemalloc.stop()
    ma = c.memory_analysis()
    out[name] = dict(args=ma.argument_size_in_bytes,
                     temp=ma.temp_size_in_bytes,
                     output=ma.output_size_in_bytes,
                     host_peak=host_peak)


def build_psf():
    data = psf_op.simulate(48 if SMOKE else 384, jax.random.PRNGKey(1),
                           stamp=16 if SMOKE else 41)
    cfg = SolverConfig(mode="sparse", n_scales=2 if SMOKE else 3)
    bundle, _ = psf_bundle(data.Y, data.psfs, cfg, mesh=mesh,
                           sigma_noise=data.sigma)
    return bundle, cfg


def build_scdl():
    if SMOKE:
        S_h, S_l = coupled_patches(256, 25, 9, 16, seed=3)
        scfg = SCDLConfig(n_atoms=8)
    else:
        S_h, S_l = coupled_patches(4096, 289, 81, 128, seed=3)
        scfg = SCDLConfig(n_atoms=256)
    return scdl_bundle(S_h, S_l, scfg, mesh=mesh), scfg


measure("psf_sparse", build_psf, psf_step)
measure("scdl_gs", build_scdl, scdl_step)
print("JSON" + json.dumps(out))
"""


def run(smoke: bool = False):
    repo = Path(__file__).resolve().parent.parent
    env = dict(os.environ)
    env["PYTHONPATH"] = str(repo / "src")
    proc = subprocess.run(
        [sys.executable, "-c", _SCRIPT.format(smoke=smoke)], env=env,
        capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, proc.stderr[-2000:]
    payload = [l for l in proc.stdout.splitlines()
               if l.startswith("JSON")][0][4:]
    out = json.loads(payload)
    records = []
    for name, d in out.items():
        emit(f"fig6_11_12/{name}_mem_per_worker", 0.0,
             f"args_bytes={d['args']};temp_bytes={d['temp']}")
        records.append({
            "name": f"memory/{name}",
            "device_args_bytes": d["args"],
            "device_temp_bytes": d["temp"],
            "device_output_bytes": d["output"],
            "device_peak_bytes": d["args"] + d["temp"] + d["output"],
            "host_build_peak_bytes": d["host_peak"],
            "devices": 8,
            "smoke": smoke,
        })
    write_bench_json("BENCH_memory.json", records)


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    run(smoke=args.smoke)
