"""Problem-API overhead benchmark: `solve()` vs a hand-wired driver.

The declarative entry point (DESIGN.md §14) must be free: `solve()`
derives the step wiring once per run and then executes the *same*
compiled chunked-scan programs as a hand-assembled ``IterativeDriver``.
This table verifies that claim on the PSF sparse workload:

- ``handwired`` — ``build_bundle`` + ``IterativeDriver(make_step_fn,
  options=RunOptions(...))``, the pre-PR-4 wiring;
- ``solve``     — ``solve(DeconvolutionProblem(cfg), Y, psfs, ...)``.

Both report the steady-state per-iteration time (first chunk of every
run dropped — it contains XLA compilation).  Run order is rotated each
rep so every variant visits every position, and the gated ratio is the
median of *per-rep paired* ratios — host-load drift within a rep hits
both sides of each pair, and a bursty rep is voted out by the median.
The ratio is asserted ≤ 1 + ``tolerance`` on full runs (smoke runs only
record it — micro-timings on shared CI runners flake) and both cost
trajectories are asserted identical, so the API adds no per-dispatch
overhead and no numerical drift.  Records land in ``BENCH_api.json``.

    PYTHONPATH=src python -m benchmarks.bench_api [--smoke]
"""
from __future__ import annotations

import json

import jax
import numpy as np

from benchmarks.common import emit, write_bench_json
from repro.core.driver import IterativeDriver, RunOptions
from repro.core.problem import solve
from repro.imaging import psf as psf_op
from repro.imaging.condat import SolverConfig
from repro.imaging.deconvolve import (DeconvolutionProblem, build_bundle,
                                      make_light_step_fn, make_step_fn)


def _steady_times(log, chunk: int):
    """Per-iteration times with the compile-bearing first chunk dropped
    (keep at least one sample)."""
    times = log.times
    skip = min(max(chunk, 1), max(len(times) - 1, 0))
    return list(times[skip:])


def _run_handwired(data, cfg, iters, chunk):
    bundle, _ = build_bundle(data.Y, data.psfs, cfg,
                             sigma_noise=data.sigma)
    driver = IterativeDriver(
        make_step_fn(cfg), bundle,
        options=RunOptions(max_iter=iters, tol=0, chunk=chunk,
                           step_fn_light=make_light_step_fn(cfg)))
    driver.run()
    return driver.log


def _run_solve(data, cfg, iters, chunk):
    sol = solve(DeconvolutionProblem(cfg, sigma_noise=data.sigma),
                data.Y, data.psfs, max_iter=iters, tol=0, chunk=chunk)
    return sol.log


def _run_solve_checks(data, cfg, iters, chunk):
    sol = solve(DeconvolutionProblem(cfg, sigma_noise=data.sigma),
                data.Y, data.psfs, max_iter=iters, tol=0, chunk=chunk,
                checks=True)
    return sol.log


def _run_solve_resilience(data, cfg, iters, chunk):
    from repro.resilience import ResilienceConfig
    sol = solve(DeconvolutionProblem(cfg, sigma_noise=data.sigma),
                data.Y, data.psfs, max_iter=iters, tol=0, chunk=chunk,
                resilience=ResilienceConfig())
    return sol.log


def run(n: int = 128, iters: int = 96, chunk: int = 8, reps: int = 3,
        tolerance: float = 0.02, smoke: bool = False) -> None:
    if smoke:
        # tiny problem for CI: record the ratio but don't hard-assert it
        # — per-iteration times are tens of microseconds there and a
        # co-tenant noise burst on a shared runner would flake the job;
        # the authoritative gate is the full run's
        n, iters, reps, tolerance = 32, 24, 2, None
    data = psf_op.simulate(n, jax.random.PRNGKey(1))
    cfg = SolverConfig(mode="sparse", n_scales=3)

    # solve_checks (runtime sanitizers on) and solve_resilience
    # (supervised execution, DESIGN.md §18) are recorded but never
    # gated: both pay deliberate per-chunk host work (sync / snapshot
    # spill).  The ≤tolerance gate below runs on the plain solve, which
    # is therefore also the regression guard for "checks=False and
    # resilience=None add zero dispatches".
    runners = {"handwired": _run_handwired, "solve": _run_solve,
               "solve_checks": _run_solve_checks,
               "solve_resilience": _run_solve_resilience}
    # rotate run order each rep so every runner visits every position —
    # a plain reversal would pin the middle runner in place and leave
    # monotone host-load drift uncancelled for it
    labels = tuple(runners)
    orders = [labels[r:] + labels[:r] for r in range(len(labels))]
    samples = {k: [] for k in runners}
    rep_medians = {k: [] for k in runners}
    costs = {}
    for rep in range(reps):
        for label in orders[rep % len(orders)]:
            log = runners[label](data, cfg, iters, chunk)
            t = _steady_times(log, chunk)
            samples[label] += t
            rep_medians[label].append(float(np.median(t)))
            costs[label] = log.costs
    # identical wiring -> identical numbers, not merely close (the
    # sanitizers only observe, so checks=True must not drift either)
    np.testing.assert_array_equal(np.asarray(costs["handwired"]),
                                  np.asarray(costs["solve"]))
    np.testing.assert_array_equal(np.asarray(costs["handwired"]),
                                  np.asarray(costs["solve_checks"]))
    np.testing.assert_array_equal(np.asarray(costs["handwired"]),
                                  np.asarray(costs["solve_resilience"]))

    us = {k: float(np.median(v) * 1e6) for k, v in samples.items()}
    # gate on the median of per-rep paired ratios: each pair ran back to
    # back inside one rep, so slow host drift divides out of every pair
    ratio = float(np.median([s / h for s, h in zip(rep_medians["solve"],
                                                   rep_medians["handwired"])]))
    records = []
    for label in ("handwired", "solve", "solve_checks",
                  "solve_resilience"):
        rec = {"name": f"api_dispatch/sparse_n{n}_chunk{chunk}_{label}",
               "us_per_iter": round(us[label], 1),
               "vs_handwired": round(us[label] / us["handwired"], 4),
               "traj_identical": True}
        records.append(rec)
        print("BENCH " + json.dumps(rec), flush=True)
        emit(f"api/sparse_n{n}_{label}", us[label],
             f"x_handwired={us[label] / us['handwired']:.4f}")
    write_bench_json("BENCH_api.json", records)
    if tolerance is not None:
        assert ratio <= 1.0 + tolerance, (
            f"solve() per-dispatch overhead {100 * (ratio - 1):.1f}% "
            f"exceeds {100 * tolerance:.0f}% vs hand-wired driver")


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    run(smoke=args.smoke)
