"""Paper Fig. 4 / Fig. 5 / Fig. 7 — PSF deconvolution benchmarks.

Fig. 4  speedup & per-loop time vs N partitions (sparse / low-rank, two
        stack sizes).  Partitions are emulated as sequential chunks of the
        bundle (``lax.map`` over N blocks): the measured column exposes the
        partitioning overhead the paper attributes to Spark task/shuffle
        costs; `derived` is the modeled M-worker speedup
        T_seq / (T_seq/M + T_comm) with T_comm from the step's collective
        bytes at ICI bandwidth.
Fig. 5  scalability vs cores: modeled from the same terms (measured
        wall-clock cannot scale on one physical core — stated).
Fig. 7  convergence: cost-vs-iteration trajectories, sequential vs
        distributed math (asserted equal; derived = final cost).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_call
from repro.core.bundle import Bundle
from repro.core.engine import make_step
from repro.imaging import psf as psf_op
from repro.imaging.condat import SolverConfig
from repro.imaging.deconvolve import build_bundle, make_step_fn

X_CORES = 24                     # the paper's cluster: 24 cores
ICI_BW = 50e9


def _chunked_step(step, data, rep, n_parts):
    """Apply the per-partition step over N sequential chunks (the paper's
    N partitions on fixed cores)."""
    def one(chunk):
        new, out = step(chunk, rep, ())
        return new, out["cost"]

    chunks = jax.tree.map(
        lambda x: x.reshape((n_parts, x.shape[0] // n_parts)
                            + x.shape[1:]), data)
    new, costs = jax.lax.map(one, chunks)
    new = jax.tree.map(
        lambda x: x.reshape((-1,) + x.shape[2:]), new)
    return new, jnp.sum(costs)


def fig4_speedup(n_images=(576, 1152), modes=("sparse", "lowrank")):
    # 576/1152 divide by all the paper's partition counts N in
    # {2x,3x,4x,6x} with x=24 cores (the 10k/20k stacks scaled to what a
    # single CPU core benchmarks in minutes)
    for n in n_images:
        data = psf_op.simulate(n, jax.random.PRNGKey(1))
        for mode in modes:
            cfg = SolverConfig(mode=mode, n_scales=3, rank=8)
            bundle, _ = build_bundle(data.Y, data.psfs, cfg,
                                     sigma_noise=data.sigma)
            step = make_step_fn(cfg)
            seq = make_step(step, bundle, donate=False)
            t_seq = time_call(seq, bundle.data, bundle.replicated)
            # communication model: low-rank psums r*r + r*p per iter;
            # sparse reduces one scalar
            if mode == "lowrank":
                r = cfg.rank + 8
                comm_bytes = 4 * (r * r + r * 41 * 41) * 2
            else:
                comm_bytes = 4.0
            for mult in (2, 3, 4, 6):
                n_parts = mult * X_CORES
                if n % n_parts:
                    continue
                fn = jax.jit(lambda d, r_: _chunked_step(step, d, r_,
                                                         n_parts))
                t = time_call(fn, bundle.data, bundle.replicated)
                t_comm_us = comm_bytes / ICI_BW * 1e6 * np.log2(X_CORES)
                derived = t_seq / (t_seq / X_CORES + t_comm_us
                                   + 0.02 * t)   # 2% per-task overhead
                emit(f"fig4/psf_{mode}_n{n}_N{mult}x", t,
                     f"modeled_speedup_24w={derived:.2f}")


def fig5_scaling(n=512):
    data = psf_op.simulate(n, jax.random.PRNGKey(1))
    cfg = SolverConfig(mode="sparse", n_scales=3)
    bundle, _ = build_bundle(data.Y, data.psfs, cfg,
                             sigma_noise=data.sigma)
    seq = make_step(make_step_fn(cfg), bundle, donate=False)
    t_seq = time_call(seq, bundle.data, bundle.replicated)
    for cores in (4, 8, 16, 24, 48):
        derived = t_seq / (t_seq / cores + 50.0)  # fixed 50us sync cost
        emit(f"fig5/psf_scaling_cores{cores}", t_seq,
             f"modeled_speedup={derived:.2f}")


def fig7_convergence(n=256, iters=30):
    data = psf_op.simulate(n, jax.random.PRNGKey(2))
    cfg = SolverConfig(mode="sparse", n_scales=3)
    from repro.core.problem import solve as solve_problem
    from repro.imaging.condat import solve
    from repro.imaging.deconvolve import DeconvolutionProblem
    import time as _t
    t0 = _t.perf_counter()
    _, costs_seq = solve(data.Y, data.psfs, cfg, sigma_noise=data.sigma,
                         n_iter=iters)
    t_seq = _t.perf_counter() - t0
    t0 = _t.perf_counter()
    log = solve_problem(DeconvolutionProblem(cfg, sigma_noise=data.sigma),
                        data.Y, data.psfs, mesh=None, max_iter=iters,
                        tol=0).log
    t_dist = _t.perf_counter() - t0
    match = np.allclose(np.asarray(costs_seq), np.asarray(log.costs),
                        rtol=1e-3)
    emit("fig7/psf_convergence", t_dist / iters * 1e6,
         f"final_cost={log.costs[-1]:.4f};traj_match={match};"
         f"seq_s={t_seq:.2f};dist_s={t_dist:.2f}")
    assert match


def run():
    fig4_speedup()
    fig5_scaling()
    fig7_convergence()
