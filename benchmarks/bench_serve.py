"""Serving throughput: coalescing micro-batch scheduler vs serialized.

The §20 claim under benchmark: when many clients hit the service at
once, coalescing compatible requests into ``solve_many`` buckets beats
dispatching them one at a time — the same §19 amortization
(one stacked program per bucket instead of one dispatch chain per
request), now paid for by a *scheduler* rather than an offline planner.

Methodology: a closed-loop load of concurrent clients (each submits,
awaits its result, submits the next) drives one in-process
:class:`~repro.serve.service.AsyncSolveService` through a mixed
workload — sparse-deconvolution stamps over four shape signatures plus
SCDL and low-rank completion instances, so coalescing must handle
multiple lanes (workload x config) and multiple buckets per lane.  Two
service configurations over the identical request sequence:

- **serialized** — ``max_batch=1`` (coalescing off): every request is
  its own dispatch on the single worker; this is the one-at-a-time
  baseline a naive frontend would run.
- **batched** — ``batch_window_s`` deadline + ``max_batch=32``: the
  scheduler coalesces whatever the closed loop has in flight.

Both runs include compile time (that IS the fixed cost coalescing
amortizes).  Every batched-run request is then checked for trajectory
parity (rtol 1e-4) against a direct ``solve()`` reference, and p50/p99
request latency is reported from the service metrics.

The batched arm runs twice — with and without the §21 crash-safe
request journal — so ``BENCH_serve.json`` records the durability tax
(``journal_overhead_pct``: WAL append per admit/bucket/terminal state).
The acceptance gate applies to the *journaled* run: durability is the
§21 deployment posture, so the speedup must survive it.

Acceptance gate (full run only): batched (journal on) >= 2x
requests/sec over serialized.

    PYTHONPATH=src python -m benchmarks.bench_serve [--smoke]
"""
from __future__ import annotations

import asyncio
import json
import tempfile
import time

import jax
import numpy as np

from benchmarks.common import emit, write_bench_json
from repro.core.problem import solve
from repro.serve import AsyncSolveService, ServeConfig, SolveRequest

CHUNK = 8


def _deconv_cfg(iters):
    from repro.imaging.condat import SolverConfig
    return SolverConfig(mode="sparse", max_iter=iters, tol=0.0,
                        n_scales=2)


def _workload(n_deconv: int, n_scdl: int, n_lowrank: int, iters: int):
    """(problem, inputs, cfg) triples: deconvolution over the four
    §19 shape combos plus two non-imaging lanes."""
    from repro.imaging import psf as psf_op
    from repro.imaging.lowrank import CompletionConfig
    from repro.imaging.scdl import SCDLConfig
    out = []
    combos = [(3, 16), (5, 16), (4, 20), (6, 20)]
    dcfg = _deconv_cfg(iters)
    for i in range(n_deconv):
        n, S = combos[i % len(combos)]
        d = psf_op.simulate(n, jax.random.PRNGKey(i), stamp=S)
        out.append(("deconvolve", (d.Y, d.psfs), dcfg))
    scfg = SCDLConfig(n_atoms=6, max_iter=iters, tol=0.0)
    for i in range(n_scdl):
        r = np.random.default_rng(100 + i)
        out.append(("scdl",
                    (r.normal(size=(25, 20)).astype(np.float32),
                     r.normal(size=(16, 20)).astype(np.float32)), scfg))
    lcfg = CompletionConfig(rank=4, max_iter=iters, tol=0.0)
    for i in range(n_lowrank):
        r = np.random.default_rng(200 + i)
        Y = (r.normal(size=(8, 3)) @ r.normal(size=(3, 10))).astype(
            np.float32)
        M = (r.random((8, 10)) < 0.6).astype(np.float32)
        out.append(("lowrank", (Y, M), lcfg))
    return out


async def _closed_loop(service, work, clients: int):
    """Closed-loop drive: ``clients`` concurrent workers each pull the
    next request off the shared sequence, submit, and block on its
    result.  Returns the finished records in ``work`` order."""
    queue = list(enumerate(work))
    results = [None] * len(work)

    async def client():
        while queue:
            idx, (problem, inputs, cfg) = queue.pop(0)
            rec = await service.submit(SolveRequest(
                problem, inputs, cfg=cfg,
                options=dict(chunk=CHUNK, cost_every=1)))
            results[idx] = await service.result(rec.id, timeout=600)

    await asyncio.gather(*[client() for _ in range(clients)])
    return results


async def _drive(config, work, clients):
    async with AsyncSolveService(config) as svc:
        t0 = time.perf_counter()
        recs = await _closed_loop(svc, work, clients)
        dt = time.perf_counter() - t0
        return recs, dt, svc.metrics.snapshot()


def run(n_deconv: int = 16, n_scdl: int = 4, n_lowrank: int = 4,
        iters: int = 16, clients: int = 8, smoke: bool = False) -> None:
    if smoke:
        n_deconv, n_scdl, n_lowrank, iters, clients = 6, 2, 0, 8, 4
    work = _workload(n_deconv, n_scdl, n_lowrank, iters)
    total = len(work)

    serial_cfg = ServeConfig(max_batch=1, batch_window_s=0.0, workers=1)
    batched_cfg = ServeConfig(max_batch=32, batch_window_s=0.25,
                              workers=1, waste_budget=0.5)
    journal_cfg = ServeConfig(max_batch=32, batch_window_s=0.25,
                              workers=1, waste_budget=0.5,
                              journal_dir=tempfile.mkdtemp(
                                  prefix="bench-serve-journal-"))
    serial_recs, dt_serial, _ = asyncio.run(
        _drive(serial_cfg, work, clients))
    _, dt_nojournal, _ = asyncio.run(
        _drive(batched_cfg, work, clients))
    batched_recs, dt_batched, m = asyncio.run(
        _drive(journal_cfg, work, clients))

    # every batched request reproduces its direct solve() trajectory
    for (problem, inputs, cfg), rec in zip(work, batched_recs):
        assert rec.status == "done", rec.public()
        ref = solve(problem, *inputs, cfg=cfg, chunk=CHUNK, cost_every=1)
        np.testing.assert_allclose(np.asarray(rec.solution.log.costs),
                                   np.asarray(ref.log.costs), rtol=1e-4)

    serial_rps = total / dt_serial
    batched_rps = total / dt_batched
    speedup = batched_rps / serial_rps
    journal_overhead = (dt_batched - dt_nojournal) / dt_nojournal
    occupancy = m["batch_occupancy"]
    records = [{
        "name": f"serve/mixed_x{total}_clients{clients}",
        "requests": total,
        "clients": clients,
        "iters": iters,
        "serial_s": round(dt_serial, 3),
        "batched_s": round(dt_batched, 3),
        "batched_nojournal_s": round(dt_nojournal, 3),
        "journal_overhead_pct": round(100.0 * journal_overhead, 2),
        "serial_rps": round(serial_rps, 3),
        "batched_rps": round(batched_rps, 3),
        "speedup": round(speedup, 3),
        "batch_occupancy_mean": occupancy["mean"],
        "batch_occupancy_max": occupancy["max"],
        "latency_p50_s": m["latency_s"].get("p50"),
        "latency_p99_s": m["latency_s"].get("p99"),
        "traj_match": True,
    }]
    print("BENCH " + json.dumps(records[0]), flush=True)
    emit(f"serve/mixed_x{total}_clients{clients}",
         dt_batched / total * 1e6, f"speedup={speedup:.3f}")
    if not smoke:
        # the acceptance gate: coalescing >= 2x requests/sec, with the
        # request journal enabled (durability must not eat the win)
        assert speedup >= 2.0, records
        assert occupancy["max"] > 1, records
    write_bench_json("BENCH_serve.json", records)


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    run(smoke=args.smoke)
