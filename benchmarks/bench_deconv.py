"""Deconvolution hot-path benchmark: pre-PR sparse path vs the
paired-FFT engine (DESIGN.md §16).

The baseline is the PRE-overhaul implementation frozen verbatim below —
NOT the original seed (that is ``bench_driver``'s baseline): batched
starlet kernel, PSF kernel FFTs cached at the hardcoded 96-grid,
carried forward model, but a conjugation per adjoint, TWO starlet
forwards per cost iteration (X_bar for the dual, X_new for the
objective) and ~6 separately-rooted elementwise passes.  The new path
runs the derived fast pad (81 for S = 41, 29% fewer FFT points), the
carried (kf, conj kf) spectrum pair, ONE starlet forward per iteration
(Phi(X_bar) = 2 Phi(X_new) - Phi(X) off the carried stack, which also
serves the objective) and the fused ``condat_elwise`` tails.

Both variants share the same step sizes and run through the same
chunked driver; trajectories are asserted equal (rtol 1e-4, pure fp32
reassociation apart) on the warm-up round, then timing rounds
interleave the variants (bench_driver methodology).  The acceptance
gate is >= 1.3x per-iteration on the full-size run; the
``cost_every="chunk"`` row additionally shows the fastest observability
mode (its objective is a weighted reduction of the carried stacks — no
transform at all in the cost step).

    PYTHONPATH=src python -m benchmarks.bench_deconv [--smoke]
"""
from __future__ import annotations

import json

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import (ROUND_ITERS, emit, timed_round,
                               write_bench_json)
from repro.core.bundle import Bundle
from repro.core.driver import IterativeDriver, RunOptions
from repro.imaging import psf as psf_op
from repro.imaging.condat import (SolverConfig, solve, step_sizes,
                                  weight_matrix)
from repro.imaging.deconvolve import (build_bundle, make_cost_fn,
                                      make_light_step_fn, make_step_fn)
from repro.kernels.starlet2d import ops as starlet_batch

_PRE_PAD = 96                    # the pre-PR hardcoded FFT grid, frozen


def _pre_fft_kernel(psfs):
    h = psfs.shape[-2]
    padded = jnp.zeros(psfs.shape[:-2] + (_PRE_PAD, _PRE_PAD), psfs.dtype)
    padded = padded.at[..., :h, :h].set(psfs)
    return jnp.fft.rfft2(jnp.roll(padded, (-(h // 2), -(h // 2)),
                                  axis=(-2, -1)))


def _pre_conv_f(x, kf, adjoint=False):
    s = x.shape[-1]
    xf = jnp.fft.rfft2(x, s=(_PRE_PAD, _PRE_PAD))
    if adjoint:
        kf = jnp.conj(kf)                 # conjugation on the hot path
    return jnp.fft.irfft2(xf * kf, s=(_PRE_PAD, _PRE_PAD))[..., :s, :s]


def _pre_sparse_update(d, rep, cfg):
    U = jnp.swapaxes(d["Xd"], 0, 1)
    W = jnp.swapaxes(d["W"], 0, 1)
    U_adj = starlet_batch.adjoint(U, cfg.n_scales)
    grad = _pre_conv_f(d["HX"] - d["Y"], d["psf_f"], adjoint=True)
    X_new = jnp.maximum(d["Xp"] - rep["tau"] * grad
                        - rep["tau"] * U_adj, 0.0)
    X_bar = 2 * X_new - d["Xp"]
    V = U + rep["sig"] * starlet_batch.forward(X_bar, cfg.n_scales)
    U_new = jnp.clip(V, -W, W)
    return dict(d, Xp=X_new, Xd=jnp.swapaxes(U_new, 0, 1),
                HX=_pre_conv_f(X_new, d["psf_f"])), W


def make_pre_step_fn(cfg: SolverConfig):
    """The pre-PR per-iteration math, frozen verbatim: the objective
    re-runs the starlet forward on X_new every evaluated iteration."""
    def step(d, rep, axes):
        d_new, W = _pre_sparse_update(d, rep, cfg)
        cost = 0.5 * jnp.sum((d["Y"] - d_new["HX"]) ** 2) + \
            jnp.sum(jnp.abs(W * starlet_batch.forward(d_new["Xp"],
                                                      cfg.n_scales)))
        if axes:
            cost = jax.lax.psum(cost, axes)
        return d_new, {"cost": cost}

    return step


def make_pre_light_step_fn(cfg: SolverConfig):
    def step(d, rep, axes):
        d_new, _ = _pre_sparse_update(d, rep, cfg)
        return d_new

    return step


def _pre_bundle(data, cfg, tau, sig):
    kf = _pre_fft_kernel(data.psfs)
    X0 = _pre_conv_f(data.Y, kf, adjoint=True)
    W = weight_matrix(data.psfs, data.sigma, cfg.n_scales, cfg.k_sigma)
    d = {"Y": data.Y, "psf_f": kf, "Xp": X0,
         "HX": _pre_conv_f(X0, kf),
         "W": jnp.swapaxes(W, 0, 1),
         "Xd": jnp.zeros((data.Y.shape[0], cfg.n_scales)
                         + data.Y.shape[1:])}
    return Bundle.create(d, replicated={"tau": jnp.float32(tau),
                                        "sig": jnp.float32(sig)})


def run(n: int = 64, iters: int = 96, rounds: int = 6, chunk: int = 8,
        smoke: bool = False) -> None:
    if smoke:
        n, iters, rounds = 32, 32, 3
    data = psf_op.simulate(n, jax.random.PRNGKey(1))
    cfg = SolverConfig(mode="sparse", n_scales=3)
    kf_pair = psf_op.psf_fft_pair(data.psfs)
    tau, sig, _ = step_sizes(data.Y, data.psfs, cfg, data.sigma,
                             kf_pair=kf_pair)
    _, costs_ref = solve(data.Y, data.psfs, cfg, sigma_noise=data.sigma,
                         n_iter=iters)
    costs_ref = np.asarray(costs_ref)

    drivers = {}
    drivers["pre_pr"] = IterativeDriver(
        make_pre_step_fn(cfg), _pre_bundle(data, cfg, tau, sig),
        options=RunOptions(max_iter=iters, tol=0, chunk=chunk,
                           step_fn_light=make_pre_light_step_fn(cfg)))

    def new_driver(**opts):
        bundle, _ = build_bundle(data.Y, data.psfs, cfg,
                                 sigma_noise=data.sigma)
        return IterativeDriver(
            make_step_fn(cfg), bundle,
            options=RunOptions(max_iter=iters, tol=0, chunk=chunk,
                               step_fn_light=make_light_step_fn(cfg),
                               **opts))

    drivers["paired"] = new_driver()
    drivers["paired_costchunk"] = new_driver(
        cost_every="chunk", step_fn_cost=make_cost_fn(cfg))

    # warm-up round compiles every program and checks trajectory parity
    for label, drv in drivers.items():
        drv.bundle = drv.run()
        costs = np.asarray(drv.log.costs)
        if label == "paired_costchunk":
            # per-chunk observability: the objective is only evaluated
            # on chunk boundaries — compare there
            np.testing.assert_allclose(costs[chunk - 1::chunk],
                                       costs_ref[chunk - 1::chunk],
                                       rtol=1e-4)
        else:
            np.testing.assert_allclose(costs, costs_ref, rtol=1e-4)

    for drv in drivers.values():
        drv.max_iter = ROUND_ITERS
    samples = {label: [] for label in drivers}
    for _ in range(rounds):
        for label, drv in drivers.items():
            samples[label].append(timed_round(drv, ROUND_ITERS))

    results = {label: float(np.median(s)) for label, s in samples.items()}
    base = results["pre_pr"]
    records = []
    for label in drivers:
        us = results[label]
        rec = {
            "name": f"deconv/sparse_n{n}_chunk{chunk}_{label}",
            "us_per_iter": round(us, 1),
            "vs_pre_pr": round(us / base, 3),
            "speedup": round(base / us, 3),
            "traj_match": True,
        }
        records.append(rec)
        print("BENCH " + json.dumps(rec), flush=True)
        emit(f"deconv/sparse_n{n}_chunk{chunk}_{label}", us,
             f"speedup={base / us:.3f}")
    if not smoke:
        # the acceptance gate: >= 1.3x per-iteration on the sparse path
        assert base / results["paired"] >= 1.3, results
    write_bench_json("BENCH_deconv.json", records)


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    run(smoke=args.smoke)
