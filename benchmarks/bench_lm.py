"""Framework benchmark: reduced-config LM step timings per architecture
(train + decode), plus kernel-vs-oracle interpret timings.

derived = tokens/s on this host for the reduced config (CPU; correctness
artifact — production numbers come from the §Roofline model).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_call
from repro.configs import ARCH_IDS, get_config, reduced
from repro.models import model as M
from repro.optim import adamw as A
from repro.parallel.sharding import MeshRules
from repro.training import steps as S

RULES = MeshRules(mesh=None)


def run():
    B, SL = 2, 32
    key = jax.random.PRNGKey(0)
    for arch in ARCH_IDS:
        cfg = reduced(get_config(arch))
        params = M.init_params(cfg, key, dtype=jnp.float32)
        opt = A.adamw_init(params)
        if cfg.frontend == "embed":
            batch = {"embeds": jax.random.normal(key, (B, SL, cfg.d_model),
                                                 jnp.float32),
                     "labels": jnp.zeros((B, SL), jnp.int32)}
        else:
            batch = {"tokens": jnp.ones((B, SL), jnp.int32),
                     "labels": jnp.zeros((B, SL), jnp.int32)}
        ts = jax.jit(S.build_train_step(cfg, RULES, remat=True, q_chunk=0))
        t = time_call(ts, params, opt, batch, warmup=1, iters=3)
        emit(f"lm/train_step_{arch}", t,
             f"tok_per_s={B * SL / (t / 1e6):.0f}")

        cache = M.init_cache(cfg, B, SL, dtype=jnp.float32)
        dec_key = "embeds" if cfg.frontend == "embed" else "tokens"
        dec = {dec_key: (batch[dec_key][:, :1]),
               "pos": jnp.zeros((B,), jnp.int32)}
        sv = jax.jit(S.build_serve_step(cfg, RULES))
        t = time_call(sv, params, cache, dec, warmup=1, iters=3)
        emit(f"lm/serve_step_{arch}", t,
             f"tok_per_s={B / (t / 1e6):.0f}")
