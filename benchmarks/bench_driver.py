"""Dispatch-overhead benchmark: per-step driver vs fused chunked scan.

The paper's headline claim is that removing the per-iteration driver
round-trip dominates everything else.  This table measures it directly
on the PSF sparse workload, four ways:

- ``seed_per_step`` — the seed execution model: one dispatch + one host
  sync per iteration AND the seed per-iteration math, frozen verbatim
  below (per-stamp vmap starlet cascades, PSF kernel FFTs recomputed on
  the hardcoded 96-grid inside every H/Ht, H(X) evaluated twice per
  iteration, ~6 unfused elementwise passes).  This is the baseline the
  acceptance ratio is measured against.
- ``per_step`` — same per-iteration dispatch pattern, current math
  (paired-FFT engine on the derived pad, carried Phi(X), fused Condat
  tails — DESIGN.md §16); isolates the math win.
- ``chunk8`` / ``chunk32`` — K iterations fused on-device per dispatch
  via ``core.engine.make_scan_step``; adds the execution-model win.

Methodology (the chunk-32 cliff post-mortem, DESIGN.md §16): every
variant's driver is built ONCE and its compiled programs are warmed by
a full untimed round (a chunk-K program's first dispatch includes XLA
compilation — the seed bench's smoke run had ``iters < 32``, so the
chunk32 row was a single dispatch whose "per-iteration time" was ~95%
compile); the timed rounds then interleave the variants against
host-load drift and report the per-round median.  Cost trajectories are
asserted equal to the sequential reference on the warm-up round (rtol
1e-4 — seed math runs on the 96-grid, current math on the derived
fast grid, identical up to fp32 rounding), with the Condat step sizes
computed once and shared so every variant iterates the same algorithm.
The chunk32 <= chunk8 ordering is gated.

    PYTHONPATH=src python -m benchmarks.bench_driver [--smoke]
"""
from __future__ import annotations

import json
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import (ROUND_ITERS, emit, timed_round,
                               write_bench_json)
from repro.core.bundle import Bundle
from repro.core.driver import IterativeDriver, RunOptions
from repro.imaging import psf as psf_op
from repro.imaging import starlet
from repro.imaging.condat import (SolverConfig, solve, step_sizes,
                                  weight_matrix)
from repro.imaging.deconvolve import (build_bundle, make_light_step_fn,
                                      make_step_fn)

CHUNKS = (1, 8, 32)
_SEED_PAD = 96                   # the seed's hardcoded FFT grid, frozen


def _seed_fft_kernel(psf):
    h = psf.shape[-2]
    padded = jnp.zeros(psf.shape[:-2] + (_SEED_PAD, _SEED_PAD), psf.dtype)
    padded = padded.at[..., :h, :h].set(psf)
    return jnp.fft.rfft2(jnp.roll(padded, (-(h // 2), -(h // 2)),
                                  axis=(-2, -1)))


def _seed_conv(x, psf, adjoint=False):
    s = x.shape[-1]
    kf = _seed_fft_kernel(psf)
    if adjoint:
        kf = jnp.conj(kf)
    xf = jnp.fft.rfft2(x, s=(_SEED_PAD, _SEED_PAD))
    return jnp.fft.irfft2(xf * kf, s=(_SEED_PAD, _SEED_PAD))[..., :s, :s]


def make_seed_step_fn(cfg: SolverConfig):
    """The seed's per-iteration math, kept verbatim as the benchmark
    baseline: vmap-of-rolls starlet transforms, H/Ht with the PSF FFT
    recomputed per call on the hardcoded 96-grid, H(X) evaluated for
    gradient and cost separately, and the primal/dual/objective
    elementwise chain left to generic fusion."""
    fwd = jax.vmap(partial(starlet.forward, n_scales=cfg.n_scales))
    adj = jax.vmap(partial(starlet.adjoint, n_scales=cfg.n_scales),
                   in_axes=1)

    def step(d, rep, axes):
        Y, psfs, Xp = d["Y"], d["psf"], d["Xp"]
        tau, sig = rep["tau"], rep["sig"]
        U = jnp.swapaxes(d["Xd"], 0, 1)
        W = jnp.swapaxes(d["W"], 0, 1)
        grad = _seed_conv(_seed_conv(Xp, psfs) - Y, psfs, adjoint=True)
        X_new = jnp.maximum(Xp - tau * grad - tau * adj(U), 0.0)
        X_bar = 2 * X_new - Xp
        U_new = jnp.clip(U + sig * fwd(X_bar).swapaxes(0, 1), -W, W)
        cost = 0.5 * jnp.sum((Y - _seed_conv(X_new, psfs)) ** 2) + \
            jnp.sum(jnp.abs(W * fwd(X_new).swapaxes(0, 1)))
        if axes:
            cost = jax.lax.psum(cost, axes)
        return dict(d, Xp=X_new, Xd=jnp.swapaxes(U_new, 0, 1)), \
            {"cost": cost}

    return step


def _seed_bundle(data, cfg, tau, sig):
    """The seed's bundle layout (raw PSF stack, no carried spectra /
    forward model / starlet stack), sharing the new path's step sizes so
    every variant runs the identical algorithm."""
    W = weight_matrix(data.psfs, data.sigma, cfg.n_scales, cfg.k_sigma)
    d = {"Y": data.Y, "psf": data.psfs,
         "Xp": psf_op.Ht(data.Y, data.psfs),
         "W": jnp.swapaxes(W, 0, 1),
         "Xd": jnp.zeros((data.Y.shape[0], cfg.n_scales)
                         + data.Y.shape[1:])}
    return Bundle.create(d, replicated={"tau": jnp.float32(tau),
                                        "sig": jnp.float32(sig)})


def _make_driver(data, cfg, iters, chunk, tau, sig, seed_math):
    if seed_math:
        return IterativeDriver(
            make_seed_step_fn(cfg), _seed_bundle(data, cfg, tau, sig),
            options=RunOptions(max_iter=iters, tol=0, chunk=chunk))
    bundle, _ = build_bundle(data.Y, data.psfs, cfg,
                             sigma_noise=data.sigma)
    return IterativeDriver(
        make_step_fn(cfg), bundle,
        options=RunOptions(max_iter=iters, tol=0, chunk=chunk,
                           step_fn_light=make_light_step_fn(cfg)))


def run(n: int = 64, iters: int = 96, rounds: int = 8,
        smoke: bool = False) -> None:
    if smoke:
        n, iters, rounds = 32, 32, 3
    data = psf_op.simulate(n, jax.random.PRNGKey(1))
    cfg = SolverConfig(mode="sparse", n_scales=3)
    kf_pair = psf_op.psf_fft_pair(data.psfs)
    tau, sig, _ = step_sizes(data.Y, data.psfs, cfg, data.sigma,
                             kf_pair=kf_pair)
    _, costs_ref = solve(data.Y, data.psfs, cfg, sigma_noise=data.sigma,
                         n_iter=iters)
    costs_ref = np.asarray(costs_ref)

    variants = [("seed_per_step", 1, True)]
    variants += [("per_step" if c == 1 else f"chunk{c}", c, False)
                 for c in CHUNKS]

    # warm-up round: compiles every program (incl. the tail chunk) and
    # checks the trajectory against the sequential reference
    drivers = {}
    for label, chunk, seed_math in variants:
        drv = _make_driver(data, cfg, iters, chunk, tau, sig, seed_math)
        drv.bundle = drv.run()
        np.testing.assert_allclose(np.asarray(drv.log.costs), costs_ref,
                                   rtol=1e-4)
        drivers[label] = drv

    # timed rounds, interleaved against host-load drift
    for drv in drivers.values():
        drv.max_iter = ROUND_ITERS
    samples = {label: [] for label in drivers}
    for _ in range(rounds):
        for label, drv in drivers.items():
            samples[label].append(timed_round(drv, ROUND_ITERS))

    results = {label: float(np.median(s)) for label, s in samples.items()}
    records = []
    base = results["seed_per_step"]
    for label, _, _ in variants:
        us = results[label]
        rec = {
            "name": f"driver_dispatch/sparse_n{n}_{label}",
            "us_per_iter": round(us, 1),
            "vs_seed_per_step": round(us / base, 3),
            "traj_match": True,
        }
        if label.startswith("chunk"):
            rec["vs_per_step"] = round(us / results["per_step"], 3)
        records.append(rec)
        print("BENCH " + json.dumps(rec), flush=True)
        emit(f"driver/sparse_n{n}_{label}", us,
             f"x_seed={us / base:.3f}")
    if not smoke:
        # the chunk-32 cliff gate: with compile kept out of the samples,
        # larger chunks must not be slower per iteration than chunk 8
        # (smoke skips the assert — a 32-sample median on a shared CI
        # core is within the noise band this gate sits at)
        assert results["chunk32"] <= results["chunk8"] * 1.05, \
            (results["chunk32"], results["chunk8"])
    write_bench_json("BENCH_driver.json", records)


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    run(smoke=args.smoke)
