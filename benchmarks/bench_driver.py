"""Dispatch-overhead benchmark: per-step driver vs fused chunked scan.

The paper's headline claim is that removing the per-iteration driver
round-trip dominates everything else.  This table measures it directly
on the PSF sparse workload, four ways:

- ``seed_per_step`` — the seed execution model: one dispatch + one host
  sync per iteration AND the seed per-iteration math (per-stamp vmap
  starlet cascades, PSF kernel FFTs recomputed inside every H/Ht, H(X)
  evaluated twice per iteration).  This is the baseline the acceptance
  ratio is measured against.
- ``per_step`` — same per-iteration dispatch pattern, current math
  (batched starlet kernel, cached PSF FFTs, carried forward model);
  isolates the math win.
- ``chunk8`` / ``chunk32`` — K iterations fused on-device per dispatch
  via ``core.engine.make_scan_step``; adds the execution-model win.

Cost trajectories of every variant are asserted equal to the sequential
reference (rtol 1e-5), so the speedups are pure implementation, not
algorithm.  Emits one ``BENCH {json}`` line per variant (tracked in the
perf trajectory) plus the common CSV rows.

    PYTHONPATH=src python -m benchmarks.bench_driver [--smoke]
"""
from __future__ import annotations

import json
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, write_bench_json
from repro.core.bundle import Bundle
from repro.core.driver import IterativeDriver, RunOptions
from repro.imaging import psf as psf_op
from repro.imaging import starlet
from repro.imaging.condat import SolverConfig, solve
from repro.imaging.deconvolve import (build_bundle, make_light_step_fn,
                                      make_step_fn)

CHUNKS = (1, 8, 32)


def make_seed_step_fn(cfg: SolverConfig):
    """The seed's per-iteration math, kept verbatim as the benchmark
    baseline: vmap-of-rolls starlet transforms, H/Ht with the PSF FFT
    recomputed per call, and H(X) evaluated for gradient and cost
    separately."""
    fwd = jax.vmap(partial(starlet.forward, n_scales=cfg.n_scales))
    adj = jax.vmap(partial(starlet.adjoint, n_scales=cfg.n_scales),
                   in_axes=1)

    def step(d, rep, axes):
        Y, psfs, Xp = d["Y"], d["psf"], d["Xp"]
        tau, sig = rep["tau"], rep["sig"]
        U = jnp.swapaxes(d["Xd"], 0, 1)
        W = jnp.swapaxes(d["W"], 0, 1)
        grad = psf_op.Ht(psf_op.H(Xp, psfs) - Y, psfs)
        X_new = jnp.maximum(Xp - tau * grad - tau * adj(U), 0.0)
        X_bar = 2 * X_new - Xp
        U_new = jnp.clip(U + sig * fwd(X_bar).swapaxes(0, 1), -W, W)
        cost = 0.5 * jnp.sum((Y - psf_op.H(X_new, psfs)) ** 2) + \
            jnp.sum(jnp.abs(W * fwd(X_new).swapaxes(0, 1)))
        if axes:
            cost = jax.lax.psum(cost, axes)
        return dict(d, Xp=X_new, Xd=jnp.swapaxes(U_new, 0, 1)), \
            {"cost": cost}

    return step


def _drive(data, cfg, iters: int, chunk: int,
           seed_math: bool = False) -> IterativeDriver:
    bundle, _ = build_bundle(data.Y, data.psfs, cfg,
                             sigma_noise=data.sigma)
    if seed_math:
        stripped = {k: v for k, v in bundle.data.items()
                    if k not in ("psf_f", "HX")}
        bundle = Bundle(data=stripped, replicated=bundle.replicated,
                        mesh=bundle.mesh, axes=bundle.axes)
        driver = IterativeDriver(make_seed_step_fn(cfg), bundle,
                                 options=RunOptions(max_iter=iters, tol=0,
                                                    chunk=chunk))
    else:
        driver = IterativeDriver(
            make_step_fn(cfg), bundle,
            options=RunOptions(max_iter=iters, tol=0, chunk=chunk,
                               step_fn_light=make_light_step_fn(cfg)))
    driver.run()
    return driver


def _per_iter_us(driver: IterativeDriver, chunk: int) -> float:
    # the first dispatch of each compiled program includes XLA
    # compilation; drop the first chunk (keeping at least one sample when
    # the whole run fits in a single chunk) and report the median
    times = driver.log.times
    skip = min(max(chunk, 1), max(len(times) - 1, 0))
    return float(np.median(np.asarray(times[skip:])) * 1e6)


def run(n: int = 256, iters: int = 96, smoke: bool = False) -> None:
    if smoke:
        n, iters = 32, 24
    data = psf_op.simulate(n, jax.random.PRNGKey(1))
    cfg = SolverConfig(mode="sparse", n_scales=3)
    _, costs_ref = solve(data.Y, data.psfs, cfg, sigma_noise=data.sigma,
                         n_iter=iters)
    costs_ref = np.asarray(costs_ref)

    variants = [("seed_per_step", 1, True)]
    variants += [("per_step" if c == 1 else f"chunk{c}", c, False)
                 for c in CHUNKS]
    results, records = {}, []
    for label, chunk, seed_math in variants:
        driver = _drive(data, cfg, iters, chunk, seed_math=seed_math)
        np.testing.assert_allclose(np.asarray(driver.log.costs),
                                   costs_ref, rtol=1e-5)
        us = _per_iter_us(driver, chunk)
        results[label] = us
        base = results["seed_per_step"]
        rec = {
            "name": f"driver_dispatch/sparse_n{n}_{label}",
            "us_per_iter": round(us, 1),
            "vs_seed_per_step": round(us / base, 3),
            "traj_match": True,
        }
        if "per_step" in results and label.startswith("chunk"):
            rec["vs_per_step"] = round(us / results["per_step"], 3)
        records.append(rec)
        print("BENCH " + json.dumps(rec), flush=True)
        emit(f"driver/sparse_n{n}_{label}", us,
             f"x_seed={us / base:.3f}")
    write_bench_json("BENCH_driver.json", records)


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    run(smoke=args.smoke)
