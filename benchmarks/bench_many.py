"""Batched multi-instance solve: looped ``solve()`` vs ``solve_many``.

Production traffic for the paper's architecture is a *population* of
small independent instances (galaxy stamps), not one big stack.  The
looped baseline pays the fixed per-instance costs N times over — a full
trace + XLA compile per distinct shape AND per instance (each ``solve``
builds fresh step programs), plus per-chunk dispatch overhead on tiny
kernels.  ``solve_many`` (DESIGN.md §19) pads-and-buckets the population
into a handful of stacked programs: one compile per bucket, every
dispatch advancing K iterations of ALL instances.

Methodology: 64 mixed-shape sparse-deconvolution stamps (S in {16, 20},
3-6 records each over four distinct signatures), tol=0, cost_every=1.  Both paths are timed end to end
(compile included — that IS the fixed cost being amortized); the same
baseline solutions then serve as the per-instance parity reference
(rtol 1e-4).  A second tiny run demonstrates masked early exit: a
zero-observation instance converges once its cost window fills and
reports fewer ``iters_run`` than its bucket's running maximum.

Acceptance gate (full run only): >= 3x aggregate instances/sec.

    PYTHONPATH=src python -m benchmarks.bench_many [--smoke]
"""
from __future__ import annotations

import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, write_bench_json
from repro.core.problem import solve, solve_many
from repro.imaging import psf as psf_op
from repro.imaging.condat import SolverConfig


def _population(count: int):
    """Mixed-shape stamp instances: 16^2 stamps with 3 or 5 records,
    20^2 stamps with 4 or 6 — four distinct signatures, the shape mix a
    survey tile actually produces (a few stamp formats, a few blend
    multiplicities) rather than one shape per instance.  The loop
    baseline pays its per-``solve`` trace+compile regardless of shape
    reuse, so limiting the combo set does not handicap it — it only
    lets both paths hit warm ``init_bundle`` caches."""
    combos = [(3, 16), (5, 16), (4, 20), (6, 20)]
    out = []
    for i in range(count):
        n, S = combos[i % len(combos)]
        d = psf_op.simulate(n, jax.random.PRNGKey(i), stamp=S)
        out.append((d.Y, d.psfs))
    return out


def _parity(sols, refs):
    for s, r in zip(sols, refs):
        np.testing.assert_allclose(np.asarray(s.log.costs),
                                   np.asarray(r.log.costs), rtol=1e-4)
        np.testing.assert_allclose(np.asarray(s.x), np.asarray(r.x),
                                   rtol=1e-4, atol=1e-6)


def _early_exit_demo(cfg_kw, chunk):
    d = psf_op.simulate(4, jax.random.PRNGKey(99), stamp=16)
    insts = [(d.Y, d.psfs), (jnp.zeros_like(d.Y), d.psfs)]
    cfg = SolverConfig(mode="sparse", tol=1e-6, **cfg_kw)
    sols = solve_many("deconvolve", insts, cfg=cfg, chunk=chunk,
                      cost_every=1)
    iters = [s.log.iters_run for s in sols]
    assert iters[1] < iters[0], iters      # masked lane froze early
    return iters


def run(count: int = 64, iters: int = 24, chunk: int = 8,
        smoke: bool = False) -> None:
    if smoke:
        count, iters, chunk = 8, 16, 8     # 2 chunked dispatches
    cfg = SolverConfig(mode="sparse", max_iter=iters, tol=0.0,
                       n_scales=2)
    insts = _population(count)

    t0 = time.perf_counter()
    refs = [solve("deconvolve", *inst, cfg=cfg, chunk=chunk,
                  cost_every=1) for inst in insts]
    dt_loop = time.perf_counter() - t0

    t0 = time.perf_counter()
    # waste_budget=0.5: with two record counts per stamp size, padding
    # the smaller to the larger merges each size into ONE bucket (pad
    # never exceeds half the bucket volume), so the population runs as
    # two stacked programs instead of four
    sols = solve_many("deconvolve", insts, cfg=cfg, chunk=chunk,
                      cost_every=1, waste_budget=0.5)
    dt_many = time.perf_counter() - t0

    _parity(sols, refs)
    early = _early_exit_demo(dict(max_iter=4 * chunk, n_scales=2), chunk)

    speedup = dt_loop / dt_many
    records = [{
        "name": f"many/deconv_sparse_x{count}_chunk{chunk}",
        "instances": count,
        "iters": iters,
        "loop_s": round(dt_loop, 3),
        "solve_many_s": round(dt_many, 3),
        "loop_inst_per_s": round(count / dt_loop, 3),
        "many_inst_per_s": round(count / dt_many, 3),
        "speedup": round(speedup, 3),
        "traj_match": True,
        "early_exit_iters_run": early,
    }]
    print("BENCH " + json.dumps(records[0]), flush=True)
    emit(f"many/deconv_sparse_x{count}_chunk{chunk}",
         dt_many / count * 1e6, f"speedup={speedup:.3f}")
    if not smoke:
        # the acceptance gate: >= 3x aggregate instances/sec
        assert speedup >= 3.0, records
    write_bench_json("BENCH_many.json", records)


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    run(smoke=args.smoke)
