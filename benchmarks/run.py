"""Benchmark harness: one function per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only psf,scdl,memory,driver,api,deconv,many,serve]
                                            [--smoke]

Prints ``name,us_per_call,derived`` CSV (see benchmarks/common.py for the
single-core measurement caveats; the derived column is defined per
table).  ``--smoke`` shrinks the driver table to a tiny problem size for
CI.
"""
from __future__ import annotations

import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only",
                    default="psf,scdl,memory,driver,api,deconv,many,serve")
    ap.add_argument("--smoke", action="store_true")
    args = ap.parse_args()
    wanted = set(args.only.split(","))

    print("name,us_per_call,derived")
    failures = []
    if "psf" in wanted:
        from benchmarks import bench_psf
        _run(bench_psf.run, "psf", failures)
    if "scdl" in wanted:
        from benchmarks import bench_scdl
        _run(lambda: bench_scdl.run(smoke=args.smoke), "scdl", failures)
    if "memory" in wanted:
        from benchmarks import bench_memory
        _run(lambda: bench_memory.run(smoke=args.smoke), "memory",
             failures)
    if "driver" in wanted:
        from benchmarks import bench_driver
        _run(lambda: bench_driver.run(smoke=args.smoke), "driver",
             failures)
    if "api" in wanted:
        from benchmarks import bench_api
        _run(lambda: bench_api.run(smoke=args.smoke), "api", failures)
    if "deconv" in wanted:
        from benchmarks import bench_deconv
        _run(lambda: bench_deconv.run(smoke=args.smoke), "deconv",
             failures)
    if "many" in wanted:
        from benchmarks import bench_many
        _run(lambda: bench_many.run(smoke=args.smoke), "many", failures)
    if "serve" in wanted:
        from benchmarks import bench_serve
        _run(lambda: bench_serve.run(smoke=args.smoke), "serve",
             failures)
    if failures:
        print(f"# FAILED tables: {failures}", file=sys.stderr)
        raise SystemExit(1)


def _run(fn, tag, failures):
    try:
        fn()
    except Exception:
        traceback.print_exc()
        failures.append(tag)


if __name__ == "__main__":
    main()
