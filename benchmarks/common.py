"""Shared benchmark utilities: timing, CSV emission, small problem sizes.

Container constraint (DESIGN.md §9): one physical CPU core — cross-device
wall-clock speedups are not physical here.  Every benchmark therefore
reports (i) measured us_per_call on this host and (ii) a `derived` column
whose meaning is stated per table (modeled speedup from the roofline
communication model, byte counts, cost values, ...).
"""
from __future__ import annotations

import time
from typing import Callable

import jax
import numpy as np


def time_call(fn: Callable, *args, warmup: int = 2, iters: int = 5,
              **kw) -> float:
    """Median wall time per call in microseconds (block_until_ready)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args, **kw))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args, **kw))
        times.append(time.perf_counter() - t0)
    return float(np.median(times) * 1e6)


def emit(name: str, us_per_call: float, derived) -> None:
    print(f"{name},{us_per_call:.1f},{derived}", flush=True)


def write_bench_json(path: str, records) -> None:
    """Persist a benchmark table's BENCH records (list of dicts) as a
    ``BENCH_*.json`` file next to the CSV output — CI uploads these as
    workflow artifacts so the perf trajectory survives the run log."""
    import json
    with open(path, "w") as f:
        json.dump(records, f, indent=2)
    print(f"# wrote {path}", flush=True)


# short timed rounds (one 32-iteration window each) let driver-level
# benchmarks interleave their variants at fine grain against host-load
# drift; 32 divides the warm-up lengths, so every compiled chunk
# program is reused as-is
ROUND_ITERS = 32


def timed_round(driver, iters: int = ROUND_ITERS) -> float:
    """One re-run of a warmed IterativeDriver; returns us/iteration.
    The driver's bundle is rebound to the run's output so donated
    buffers stay valid across rounds."""
    n0 = len(driver.log.times)
    driver.bundle = driver.run()
    return float(np.sum(driver.log.times[n0:]) / iters * 1e6)
