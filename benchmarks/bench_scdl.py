"""Paper Fig. 9 / Fig. 10 / Fig. 13 / Fig. 14 — SCDL benchmarks, plus the
hot-path overhaul table.

Fig. 9   per-iteration time & modeled speedup vs dictionary atoms
         A in {512, 1024, 2056} for HS (P=25, M=9) and GS (P=289, M=81)
         patch shapes, vs N partitions.
Fig. 10  scalability vs cores (modeled; one physical core here).
Fig. 13  persistence policies: MEMORY_ONLY (device-resident, remat) vs
         MEMORY_AND_DISK (host spill each iteration) — this one is a REAL
         measured effect on this host (device<->host copies).
Fig. 14  convergence: NRMSE trajectories sequential vs distributed.

Overhaul per-iteration comparison (DESIGN.md §13): the seed per-iteration
math (per-block Gram rebuild + K-RHS LU solves, four separate
outer-product einsums, unfused dual updates, objective every iteration)
vs the factor-once broadcast math, both driven through the same chunked
driver on the GS patch shape.  NRMSE trajectories are asserted equal
within rtol 1e-4, the timings land in ``BENCH_scdl.json`` (same record
shape as ``bench_driver.py``), and each variant also prints a
``BENCH {json}`` line.

    PYTHONPATH=src python -m benchmarks.bench_scdl [--smoke]
"""
from __future__ import annotations

import json
import time as _t

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_call, write_bench_json
from repro.core.bundle import Bundle
from repro.core.driver import IterativeDriver, RunOptions
from repro.core.engine import make_step
from repro.core import persistence as P
from repro.data.synthetic import coupled_patches
from repro.core.problem import solve
from repro.imaging.scdl import (SCDLConfig, SCDLProblem, build_bundle,
                                make_cost_fn, make_light_step_fn,
                                make_refresh_fn, make_step_fn)

X_CORES = 24
SHAPES = {"HS": (25, 9), "GS": (289, 81)}


# ------------------------------------------------- seed-math baseline
def make_seed_step_fn(cfg: SCDLConfig):
    """The pre-overhaul per-iteration math, kept verbatim as the
    benchmark baseline (and the parity oracle for the factor-once
    rebuild): every partition re-builds the ridge Grams and LU-solves a
    K_loc-RHS system each iteration, the four outer products run as
    separate einsums, the dual updates as an unfused elementwise chain,
    and the NRMSE objective is evaluated every iteration."""

    def seed_code_updates(d, rep):
        Xh, Xl = rep["Xh"], rep["Xl"]
        c1, c2, c3 = cfg.c1, cfg.c2, cfg.c3
        A = Xh.shape[1]
        eye = jnp.eye(A, dtype=Xh.dtype)
        Gh = 2.0 * Xh.T @ Xh + (c1 + c3) * eye
        Gl = 2.0 * Xl.T @ Xl + (c2 + c3) * eye
        rhs_h = (2.0 * d["Sh"] @ Xh + c1 * d["P"] + d["Y1"]
                 - d["Y3"] + c3 * d["Wl"])
        Wh = jnp.linalg.solve(Gh, rhs_h.T).T
        rhs_l = (2.0 * d["Sl"] @ Xl + c2 * d["Q"] + d["Y2"]
                 + d["Y3"] + c3 * Wh)
        Wl = jnp.linalg.solve(Gl, rhs_l.T).T
        soft = lambda x, t: jnp.sign(x) * jnp.maximum(jnp.abs(x) - t, 0.0)
        Pv = soft(Wh - d["Y1"] / c1, cfg.lam_h / c1)
        Q = soft(Wl - d["Y2"] / c2, cfg.lam_l / c2)
        Y1 = d["Y1"] + c1 * (Pv - Wh)
        Y2 = d["Y2"] + c2 * (Q - Wl)
        Y3 = d["Y3"] + c3 * (Wh - Wl)
        return dict(d, Wh=Wh, Wl=Wl, P=Pv, Q=Q, Y1=Y1, Y2=Y2, Y3=Y3)

    def step(d, rep, axes):
        d = seed_code_updates(d, rep)
        parts = {
            "ShWh": d["Sh"].T @ d["Wh"], "SlWl": d["Sl"].T @ d["Wl"],
            "phi_h": d["Wh"].T @ d["Wh"], "phi_l": d["Wl"].T @ d["Wl"],
        }
        if axes:
            parts = jax.tree.map(lambda x: jax.lax.psum(x, axes), parts)
        A = rep["Xh"].shape[1]
        eye = jnp.eye(A, dtype=rep["Xh"].dtype)
        Xh = jnp.linalg.solve(parts["phi_h"] + cfg.delta * eye,
                              parts["ShWh"].T).T
        Xl = jnp.linalg.solve(parts["phi_l"] + cfg.delta * eye,
                              parts["SlWl"].T).T
        clip = lambda X: X / jnp.maximum(
            jnp.linalg.norm(X, axis=0, keepdims=True), 1.0)
        new_dicts = {"Xh": clip(Xh), "Xl": clip(Xl)}
        res = {"res_h": jnp.sum((d["Sh"] - d["Wh"] @ new_dicts["Xh"].T) ** 2),
               "res_l": jnp.sum((d["Sl"] - d["Wl"] @ new_dicts["Xl"].T) ** 2),
               "n_h": jnp.sum(d["Sh"] ** 2), "n_l": jnp.sum(d["Sl"] ** 2)}
        if axes:
            res = jax.tree.map(lambda x: jax.lax.psum(x, axes), res)
        nrmse_h = jnp.sqrt(res["res_h"] / (res["n_h"] + 1e-12))
        nrmse_l = jnp.sqrt(res["res_l"] / (res["n_l"] + 1e-12))
        return d, {"cost": 0.5 * (nrmse_h + nrmse_l), **new_dicts}

    return step


def seed_bundle(S_h, S_l, cfg: SCDLConfig) -> Bundle:
    """The seed bundle layout: splitting variables P/Q as state, only the
    dictionaries broadcast (same initialisation as ``build_bundle``)."""
    from repro.imaging.scdl import init_dicts
    X_h, X_l = init_dicts(S_h, S_l, cfg)
    K, A = S_h.shape[1], cfg.n_atoms
    zeros = lambda: jnp.zeros((K, A), S_h.dtype)
    data = {"Sh": S_h.T, "Sl": S_l.T,
            "Wh": zeros(), "Wl": zeros(), "P": zeros(), "Q": zeros(),
            "Y1": zeros(), "Y2": zeros(), "Y3": zeros()}
    return Bundle.create(data, replicated={"Xh": X_h, "Xl": X_l})


def seed_driver(S_h, S_l, cfg: SCDLConfig, iters: int,
                chunk: int = 8) -> IterativeDriver:
    """Drive the seed math through the current chunked driver."""
    driver = IterativeDriver(
        make_seed_step_fn(cfg), seed_bundle(S_h, S_l, cfg),
        options=RunOptions(
            max_iter=iters, tol=0, chunk=chunk,
            update_replicated=lambda r, out: {"Xh": out["Xh"],
                                              "Xl": out["Xl"]}))
    driver.run()
    return driver


def step_overhaul(K=4096, A=512, iters=32, chunk=8, cost_every=4,
                  reps=6, smoke: bool = False):
    """Seed math vs factor-once math, per iteration, GS patch shape.

    Two phases.  **Parity**: both variants run end-to-end through the
    driver and the NRMSE trajectories are asserted equal (full grid for
    ``cost_every=1``, the evaluation grid for the skipping modes).
    **Timing**: the compiled programs are dispatched *interleaved*
    (seed, new-ce1, new-skip, new-per-chunk, repeat) so host-load drift
    hits every variant equally — sequential whole-run timing on a shared
    host can swing ±25% and swamp the ratio being measured.

    Baselines, following ``bench_driver.py``'s methodology: the primary
    ``vs_seed`` ratio is against ``seed_per_step`` — the seed math under
    its execution model (one dispatch + one host sync per iteration,
    i.e. fig9's published per-iteration step time on main); the
    ``vs_seed_chunk`` column is against the seed math driven through the
    chunked scan, isolating the pure per-iteration-math win.
    """
    if smoke:
        K, A, iters, chunk, cost_every, reps = 512, 128, 4, 2, 2, 2
    p_dim, m_dim = SHAPES["GS"]
    S_h, S_l = coupled_patches(K, p_dim, m_dim, min(A, K // 4), seed=2)
    cfg = SCDLConfig(n_atoms=A, max_iter=iters)

    # ---- parity: trajectories vs the seed math (rtol 1e-4)
    drv_seed = seed_driver(S_h, S_l, cfg, iters, chunk=chunk)
    costs_seed = np.asarray(drv_seed.log.costs)
    log_new = solve(SCDLProblem(cfg), S_h, S_l, chunk=chunk,
                    cost_every=1).log
    np.testing.assert_allclose(np.asarray(log_new.costs), costs_seed,
                               rtol=1e-4)
    log_ce = solve(SCDLProblem(cfg), S_h, S_l, chunk=chunk,
                   cost_every=cost_every).log
    np.testing.assert_allclose(
        np.asarray(log_ce.costs)[::cost_every],
        costs_seed[::cost_every], rtol=1e-4)
    log_cc = solve(SCDLProblem(cfg), S_h, S_l, chunk=chunk,
                   cost_every="chunk").log
    np.testing.assert_allclose(
        np.asarray(log_cc.costs)[chunk - 1::chunk],
        costs_seed[chunk - 1::chunk], rtol=1e-4)
    big = min(4 * chunk, iters)
    log_c32 = solve(SCDLProblem(cfg), S_h, S_l, chunk=big,
                    cost_every="chunk").log
    np.testing.assert_allclose(
        np.asarray(log_c32.costs)[big - 1::big],
        costs_seed[big - 1::big], rtol=1e-4)

    # ---- timing: interleaved dispatch of the compiled programs
    from repro.core.engine import (init_cost_like, init_out_like,
                                   make_chunk_cost_step, make_scan_step)
    sb = seed_bundle(S_h, S_l, cfg)
    nb = build_bundle(S_h, S_l, cfg)
    seed_one = make_step(make_seed_step_fn(cfg), sb, donate=False)
    seed_scan = make_scan_step(
        make_seed_step_fn(cfg), sb, chunk=chunk, donate=False,
        update_replicated=lambda r, o: {"Xh": o["Xh"], "Xl": o["Xl"]})
    new_step = make_scan_step(
        make_step_fn(cfg), nb, chunk=chunk, donate=False,
        update_replicated=make_refresh_fn(cfg))
    ce_step = make_scan_step(
        make_step_fn(cfg), nb, chunk=chunk, donate=False,
        update_replicated=make_refresh_fn(cfg),
        fn_light=make_light_step_fn(cfg), cost_every=cost_every,
        light_updates_replicated=True)
    cc_step = make_chunk_cost_step(
        make_light_step_fn(cfg), make_cost_fn(cfg), nb, chunk=chunk,
        donate=False, update_replicated=make_refresh_fn(cfg))
    cc_big = cc_step if big == chunk else make_chunk_cost_step(
        make_light_step_fn(cfg), make_cost_fn(cfg), nb, chunk=big,
        donate=False, update_replicated=make_refresh_fn(cfg))
    last_out = init_out_like(make_step_fn(cfg), nb)
    last_cost = init_cost_like(make_cost_fn(cfg), nb)

    def seed_dispatch():
        # the seed execution model: host syncs the cost every iteration
        _, out = seed_one(sb.data, sb.replicated)
        jax.block_until_ready(out["cost"])

    calls = {
        "seed_per_step": (1, seed_dispatch),
        "seed_chunk%d" % chunk:
            (chunk, lambda: seed_scan(sb.data, sb.replicated,
                                      np.int32(0))),
        "new_chunk%d" % chunk:
            (chunk, lambda: new_step(nb.data, nb.replicated,
                                     np.int32(0))),
        "new_chunk%d_ce%d" % (chunk, cost_every):
            (chunk, lambda: ce_step(nb.data, nb.replicated, np.int32(0),
                                    last_out)),
        "new_chunk%d_cchunk" % chunk:
            (chunk, lambda: cc_step(nb.data, nb.replicated, np.int32(0),
                                    last_cost)),
    }
    if big != chunk:
        calls["new_chunk%d_cchunk" % big] = (
            big, lambda: cc_big(nb.data, nb.replicated, np.int32(0),
                                last_cost))
    for _, fn in calls.values():              # compile + warm
        jax.block_until_ready(fn())
    times = {k: [] for k in calls}
    for _ in range(reps):
        for label, (k, fn) in calls.items():
            t0 = _t.perf_counter()
            jax.block_until_ready(fn())
            times[label].append((_t.perf_counter() - t0) / k * 1e6)

    records = []
    base = float(np.median(times["seed_per_step"]))
    base_chunk = float(np.median(times["seed_chunk%d" % chunk]))
    for label, ts in times.items():
        us = float(np.median(ts))
        rec = {"name": f"scdl_overhaul/GS_K{K}_A{A}_{label}",
               "us_per_iter": round(us, 1),
               "vs_seed": round(us / base, 3),
               "vs_seed_chunk": round(us / base_chunk, 3),
               "traj_match": True}
        records.append(rec)
        print("BENCH " + json.dumps(rec), flush=True)
        emit(f"scdl/GS_K{K}_A{A}_{label}", us, f"x_seed={us / base:.3f}")
    write_bench_json("BENCH_scdl.json", records)
    return records


def fig9_speedup(K=4096, atoms=(128, 256, 512)):
    for tag, (p_dim, m_dim) in SHAPES.items():
        for A in atoms:
            S_h, S_l = coupled_patches(K, p_dim, m_dim, min(A, K // 4),
                                       seed=2)
            cfg = SCDLConfig(n_atoms=A)
            bundle = build_bundle(S_h, S_l, cfg)
            step = make_step(make_step_fn(cfg), bundle, donate=False)
            t = time_call(step, bundle.data, bundle.replicated, iters=3)
            # comm per iteration: psum of S W^T + W W^T (fp32)
            comm_bytes = 4 * (p_dim * A + m_dim * A + 2 * A * A)
            t_comm_us = comm_bytes / 50e9 * 1e6 * np.log2(X_CORES)
            derived = t / (t / X_CORES + t_comm_us + 0.02 * t)
            emit(f"fig9/scdl_{tag}_A{A}", t,
                 f"modeled_speedup_24w={derived:.2f}")


def fig10_scaling(K=4096):
    S_h, S_l = coupled_patches(K, 25, 9, 128, seed=2)
    cfg = SCDLConfig(n_atoms=256)
    bundle = build_bundle(S_h, S_l, cfg)
    step = make_step(make_step_fn(cfg), bundle, donate=False)
    t = time_call(step, bundle.data, bundle.replicated, iters=3)
    for cores in (4, 8, 16, 24, 48):
        derived = t / (t / cores + 100.0)
        emit(f"fig10/scdl_scaling_cores{cores}", t,
             f"modeled_speedup={derived:.2f}")


def fig13_persistence(K=4096, A=256):
    """memory-only (device-resident) vs memory-and-disk (host spill)."""
    S_h, S_l = coupled_patches(K, 289, 81, 128, seed=3)
    cfg = SCDLConfig(n_atoms=A)
    bundle = build_bundle(S_h, S_l, cfg)
    step = make_step(make_step_fn(cfg), bundle, donate=False)
    refresh = make_refresh_fn(cfg)

    # MEMORY_ONLY: bundle stays on device across iterations
    data, rep = bundle.data, bundle.replicated
    t0 = _t.perf_counter()
    for _ in range(5):
        data, out = step(data, rep)
        rep = refresh(rep, out)
    jax.block_until_ready(data)
    t_mem = (_t.perf_counter() - t0) / 5 * 1e6

    # MEMORY_AND_DISK: spill + re-admit every iteration
    data, rep = bundle.data, bundle.replicated
    t0 = _t.perf_counter()
    for _ in range(5):
        host = P.spill(bundle.with_data(data))
        data = P.restore(bundle, host).data
        data, out = step(data, rep)
        rep = refresh(rep, out)
    jax.block_until_ready(data)
    t_disk = (_t.perf_counter() - t0) / 5 * 1e6

    bytes_spilled = sum(x.size * x.dtype.itemsize
                        for x in jax.tree.leaves(bundle.data))
    emit("fig13/scdl_memory_only", t_mem, "policy=device_resident")
    emit("fig13/scdl_memory_and_disk", t_disk,
         f"policy=spill;bytes_per_iter={bytes_spilled}")


def fig14_convergence(K=2048, A=64, iters=20):
    S_h, S_l = coupled_patches(K, 289, 81, A, seed=4)
    cfg = SCDLConfig(n_atoms=A, max_iter=iters)
    t0 = _t.perf_counter()
    log = solve(SCDLProblem(cfg), S_h, S_l).log
    t = _t.perf_counter() - t0
    emit("fig14/scdl_convergence", t / iters * 1e6,
         f"nrmse_first={log.costs[0]:.4f};nrmse_final={log.costs[-1]:.4f}")
    assert log.costs[-1] < log.costs[0]


def run(smoke: bool = False):
    if smoke:
        step_overhaul(smoke=True)
        return
    fig9_speedup()
    fig10_scaling()
    fig13_persistence()
    fig14_convergence()
    step_overhaul()


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    run(smoke=args.smoke)
