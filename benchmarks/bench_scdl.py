"""Paper Fig. 9 / Fig. 10 / Fig. 13 / Fig. 14 — SCDL benchmarks.

Fig. 9   per-iteration time & modeled speedup vs dictionary atoms
         A in {512, 1024, 2056} for HS (P=25, M=9) and GS (P=289, M=81)
         patch shapes, vs N partitions.
Fig. 10  scalability vs cores (modeled; one physical core here).
Fig. 13  persistence policies: MEMORY_ONLY (device-resident, remat) vs
         MEMORY_AND_DISK (host spill each iteration) — this one is a REAL
         measured effect on this host (device<->host copies).
Fig. 14  convergence: NRMSE trajectories sequential vs distributed.
"""
from __future__ import annotations

import time as _t

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_call
from repro.core.bundle import Bundle
from repro.core.engine import make_step
from repro.core import persistence as P
from repro.data.synthetic import coupled_patches
from repro.imaging.scdl import SCDLConfig, build_bundle, make_step_fn, train

X_CORES = 24
SHAPES = {"HS": (25, 9), "GS": (289, 81)}


def fig9_speedup(K=4096, atoms=(128, 256, 512)):
    for tag, (p_dim, m_dim) in SHAPES.items():
        for A in atoms:
            S_h, S_l = coupled_patches(K, p_dim, m_dim, min(A, K // 4),
                                       seed=2)
            cfg = SCDLConfig(n_atoms=A)
            bundle = build_bundle(S_h, S_l, cfg)
            step = make_step(make_step_fn(cfg), bundle, donate=False)
            t = time_call(step, bundle.data, bundle.replicated, iters=3)
            # comm per iteration: psum of S W^T + W W^T (fp32)
            comm_bytes = 4 * (p_dim * A + m_dim * A + 2 * A * A)
            t_comm_us = comm_bytes / 50e9 * 1e6 * np.log2(X_CORES)
            derived = t / (t / X_CORES + t_comm_us + 0.02 * t)
            emit(f"fig9/scdl_{tag}_A{A}", t,
                 f"modeled_speedup_24w={derived:.2f}")


def fig10_scaling(K=4096):
    S_h, S_l = coupled_patches(K, 25, 9, 128, seed=2)
    cfg = SCDLConfig(n_atoms=256)
    bundle = build_bundle(S_h, S_l, cfg)
    step = make_step(make_step_fn(cfg), bundle, donate=False)
    t = time_call(step, bundle.data, bundle.replicated, iters=3)
    for cores in (4, 8, 16, 24, 48):
        derived = t / (t / cores + 100.0)
        emit(f"fig10/scdl_scaling_cores{cores}", t,
             f"modeled_speedup={derived:.2f}")


def fig13_persistence(K=4096, A=256):
    """memory-only (device-resident) vs memory-and-disk (host spill)."""
    S_h, S_l = coupled_patches(K, 289, 81, 128, seed=3)
    cfg = SCDLConfig(n_atoms=A)
    bundle = build_bundle(S_h, S_l, cfg)
    step = make_step(make_step_fn(cfg), bundle, donate=False)

    # MEMORY_ONLY: bundle stays on device across iterations
    data, rep = bundle.data, bundle.replicated
    t0 = _t.perf_counter()
    for _ in range(5):
        data, out = step(data, rep)
        rep = {"Xh": out["Xh"], "Xl": out["Xl"]}
    jax.block_until_ready(data)
    t_mem = (_t.perf_counter() - t0) / 5 * 1e6

    # MEMORY_AND_DISK: spill + re-admit every iteration
    data, rep = bundle.data, bundle.replicated
    t0 = _t.perf_counter()
    for _ in range(5):
        host = P.spill(bundle.with_data(data))
        data = P.restore(bundle, host).data
        data, out = step(data, rep)
        rep = {"Xh": out["Xh"], "Xl": out["Xl"]}
    jax.block_until_ready(data)
    t_disk = (_t.perf_counter() - t0) / 5 * 1e6

    bytes_spilled = sum(x.size * x.dtype.itemsize
                        for x in jax.tree.leaves(bundle.data))
    emit("fig13/scdl_memory_only", t_mem, "policy=device_resident")
    emit("fig13/scdl_memory_and_disk", t_disk,
         f"policy=spill;bytes_per_iter={bytes_spilled}")


def fig14_convergence(K=2048, A=64, iters=20):
    S_h, S_l = coupled_patches(K, 289, 81, A, seed=4)
    cfg = SCDLConfig(n_atoms=A, max_iter=iters)
    t0 = _t.perf_counter()
    Xh, Xl, log = train(S_h, S_l, cfg)
    t = _t.perf_counter() - t0
    emit("fig14/scdl_convergence", t / iters * 1e6,
         f"nrmse_first={log.costs[0]:.4f};nrmse_final={log.costs[-1]:.4f}")
    assert log.costs[-1] < log.costs[0]


def run():
    fig9_speedup()
    fig10_scaling()
    fig13_persistence()
    fig14_convergence()
