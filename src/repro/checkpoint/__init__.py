from repro.checkpoint.checkpointer import (Checkpointer, latest_step,  # noqa
                                           restore, save)
