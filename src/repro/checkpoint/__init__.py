from repro.checkpoint.checkpointer import (Checkpointer,  # noqa
                                           CheckpointCorruptError,
                                           CheckpointError,
                                           CheckpointWriteError,
                                           latest_step, latest_valid_step,
                                           restore, save,
                                           validate_checkpoint)
from repro.checkpoint.wal import WriteAheadLog  # noqa
