"""Append-only, crc-per-record write-ahead log (DESIGN.md §21).

The durability primitive under the serving layer's request journal:
each record is one line, ``<crc32 hex8> <compact json>\n``, with the
checksum computed over the serialized payload bytes.  Appends are
flushed (and optionally fsynced) before the caller proceeds, so a
record either fully lands or is a torn tail the reader skips —
mirroring the per-leaf crc32 discipline of ``checkpoint.checkpointer``
at line granularity.

Reads are tolerant by design: a crash mid-append leaves at most one
torn final line, and any line that fails to parse or checksum is
counted and dropped rather than failing the replay (a journal that
cannot be read at all is worse than one missing its last record).
"""
from __future__ import annotations

import json
import os
import zlib
from pathlib import Path
from typing import Any, List, Tuple


def _encode(record: Any) -> bytes:
    payload = json.dumps(record, separators=(",", ":"),
                         sort_keys=True).encode("utf-8")
    crc = zlib.crc32(payload) & 0xFFFFFFFF
    return b"%08x %s\n" % (crc, payload)


def _decode_line(line: bytes) -> Any:
    """Parse one WAL line; raises ``ValueError`` on any corruption."""
    head, _, payload = line.rstrip(b"\n").partition(b" ")
    if len(head) != 8 or not payload:
        raise ValueError("malformed WAL line")
    if int(head, 16) != (zlib.crc32(payload) & 0xFFFFFFFF):
        raise ValueError("WAL line checksum mismatch")
    return json.loads(payload.decode("utf-8"))


class WriteAheadLog:
    """One append-only log file; create parents lazily, append-then-
    flush per record.  ``fsync=True`` trades append latency for
    power-loss durability (the default covers process crashes, the
    serving drill's failure model)."""

    def __init__(self, path, *, fsync: bool = False):
        self.path = Path(path)
        self.fsync = fsync
        self.path.parent.mkdir(parents=True, exist_ok=True)
        self._fh = open(self.path, "ab")

    def append(self, record: Any) -> None:
        self._fh.write(_encode(record))
        self._fh.flush()
        if self.fsync:
            os.fsync(self._fh.fileno())

    def close(self) -> None:
        if not self._fh.closed:
            self._fh.close()

    def __enter__(self) -> "WriteAheadLog":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    @staticmethod
    def read(path) -> Tuple[List[Any], int]:
        """All valid records in append order plus the number of
        skipped (torn/corrupt) lines.  A missing file reads as empty —
        the cold-start case."""
        path = Path(path)
        if not path.exists():
            return [], 0
        records: List[Any] = []
        skipped = 0
        with open(path, "rb") as fh:
            for line in fh:
                try:
                    records.append(_decode_line(line))
                except (ValueError, json.JSONDecodeError):
                    skipped += 1
        return records, skipped
