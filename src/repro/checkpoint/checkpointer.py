"""Fault-tolerant checkpointing: sharded, atomic, async, elastic.

The paper gets worker-failure recovery from RDD lineage; at LM-training
scale lineage replay from step 0 is not viable, so the production answer
is periodic checkpoints + deterministic replay from the last one
(counter-based data order makes the replay bit-exact; DESIGN.md §2).

Layout (one directory per step):
    <dir>/step_000123.tmp/...   -> atomic rename -> <dir>/step_000123/
        manifest.json            tree structure, shapes, dtypes, meta
        leaf_000000.npy ...      one host .npy per leaf (full arrays)

Properties:
  - atomic: readers never observe a partial checkpoint (tmp + rename);
  - async: ``Checkpointer.save_async`` snapshots to host and writes on a
    background thread, overlapping I/O with the next training steps;
  - elastic: restore takes the *current* mesh/sharding — a checkpoint
    written on 256 chips restores onto 8 or 512 (the RDD-repartitioning
    analogue), because leaves are stored as full host arrays and
    re-device_put under the new sharding;
  - self-describing: the manifest carries a config fingerprint checked on
    restore.

On a real multi-host pod each host would write only its addressable
shards (process-local npy + a shard index in the manifest); single-host
here, full arrays are written — the format keeps the per-leaf layout so
the multi-host writer is a drop-in.
"""
from __future__ import annotations

import json
import shutil
import threading
import time
from pathlib import Path
from typing import Any, Callable, Dict, Optional

import jax
import numpy as np


def _flatten(tree) -> tuple:
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def save(directory, step: int, tree, *, meta: Optional[dict] = None
         ) -> Path:
    """Synchronous atomic checkpoint write."""
    directory = Path(directory)
    final = directory / f"step_{step:08d}"
    tmp = directory / f"step_{step:08d}.tmp"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)
    leaves, treedef = _flatten(tree)
    manifest = {
        "step": step,
        "n_leaves": len(leaves),
        "treedef": str(treedef),
        "meta": meta or {},
        "time": time.time(),
        "leaves": [],
    }
    for i, leaf in enumerate(leaves):
        arr = np.asarray(jax.device_get(leaf))
        np.save(tmp / f"leaf_{i:06d}.npy", arr)
        manifest["leaves"].append(
            {"shape": list(arr.shape), "dtype": str(arr.dtype)})
    (tmp / "manifest.json").write_text(json.dumps(manifest))
    if final.exists():
        shutil.rmtree(final)
    tmp.rename(final)
    return final


def latest_step(directory) -> Optional[int]:
    directory = Path(directory)
    if not directory.exists():
        return None
    steps = [int(p.name.split("_")[1]) for p in directory.iterdir()
             if p.is_dir() and p.name.startswith("step_")
             and not p.name.endswith(".tmp")]
    return max(steps) if steps else None


def restore(directory, step: int, like, *, shardings=None,
            expect_meta: Optional[Callable[[dict], bool]] = None):
    """Restore onto the CURRENT topology (elastic).

    ``like``: a pytree matching the saved structure (shapes may be
    device-sharded differently).  ``shardings``: optional tree of
    NamedSharding to place leaves under (None = default device).
    """
    directory = Path(directory) / f"step_{step:08d}"
    manifest = json.loads((directory / "manifest.json").read_text())
    if expect_meta is not None and not expect_meta(manifest["meta"]):
        raise ValueError(f"manifest meta check failed: {manifest['meta']}")
    leaves, treedef = _flatten(like)
    if len(leaves) != manifest["n_leaves"]:
        raise ValueError(
            f"checkpoint has {manifest['n_leaves']} leaves; current tree "
            f"has {len(leaves)} — config mismatch")
    shard_leaves = (jax.tree.flatten(shardings)[0] if shardings is not None
                    else [None] * len(leaves))
    out = []
    for i, (ref, shd) in enumerate(zip(leaves, shard_leaves)):
        arr = np.load(directory / f"leaf_{i:06d}.npy")
        if tuple(arr.shape) != tuple(ref.shape):
            raise ValueError(f"leaf {i}: saved {arr.shape} != {ref.shape}")
        out.append(jax.device_put(arr, shd) if shd is not None
                   else jax.device_put(arr))
    return jax.tree.unflatten(treedef, out), manifest


class Checkpointer:
    """Async checkpoint manager with retention."""

    def __init__(self, directory, *, keep: int = 3,
                 meta: Optional[dict] = None):
        self.directory = Path(directory)
        self.keep = keep
        self.meta = meta or {}
        self._thread: Optional[threading.Thread] = None
        self.saved_steps: list = []

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def save_async(self, step: int, tree):
        """Snapshot to host (blocking only for the copy), write in a
        background thread — I/O overlaps subsequent steps."""
        self.wait()
        host_tree = jax.tree.map(
            lambda x: np.asarray(jax.device_get(x)), tree)

        def _write():
            save(self.directory, step, host_tree, meta=self.meta)
            self.saved_steps.append(step)
            self._gc()

        self._thread = threading.Thread(target=_write, daemon=True)
        self._thread.start()

    def save(self, step: int, tree):
        self.wait()
        save(self.directory, step, tree, meta=self.meta)
        self.saved_steps.append(step)
        self._gc()

    def _gc(self):
        steps = sorted(
            int(p.name.split("_")[1]) for p in self.directory.iterdir()
            if p.is_dir() and p.name.startswith("step_")
            and not p.name.endswith(".tmp"))
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(self.directory / f"step_{s:08d}",
                          ignore_errors=True)
