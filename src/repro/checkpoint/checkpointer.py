"""Fault-tolerant checkpointing: sharded, atomic, async, elastic.

The paper gets worker-failure recovery from RDD lineage; at LM-training
scale lineage replay from step 0 is not viable, so the production answer
is periodic checkpoints + deterministic replay from the last one
(counter-based data order makes the replay bit-exact; DESIGN.md §2).

Layout (one directory per step):
    <dir>/step_000123.tmp/...   -> atomic rename -> <dir>/step_000123/
        manifest.json            tree structure, shapes, dtypes, meta
        leaf_000000.npy ...      one host .npy per leaf (full arrays)

Properties:
  - atomic: readers never observe a partial checkpoint (tmp + rename);
  - async: ``Checkpointer.save_async`` snapshots to host and writes on a
    background thread, overlapping I/O with the next training steps;
  - elastic: restore takes the *current* mesh/sharding — a checkpoint
    written on 256 chips restores onto 8 or 512 (the RDD-repartitioning
    analogue), because leaves are stored as full host arrays and
    re-device_put under the new sharding;
  - self-describing: the manifest carries a config fingerprint checked on
    restore.

On a real multi-host pod each host would write only its addressable
shards (process-local npy + a shard index in the manifest); single-host
here, full arrays are written — the format keeps the per-leaf layout so
the multi-host writer is a drop-in.

Hardening (DESIGN.md §18): the manifest carries a crc32 per leaf,
:func:`validate_checkpoint` re-checks files against it without
deserialising the tree, and :func:`latest_valid_step` scans
newest-to-oldest so a truncated/corrupted newest checkpoint (killed
writer, chaos ``ckpt_corrupt`` fault) falls back to the previous
retention entry instead of poisoning restore.
"""
from __future__ import annotations

import json
import shutil
import threading
import time
import zlib
from pathlib import Path
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.resilience import chaos as _chaos


class CheckpointError(RuntimeError):
    """Base class for checkpoint persistence failures."""


class CheckpointWriteError(CheckpointError):
    """A checkpoint write failed (surfaced from the async thread too)."""


class CheckpointCorruptError(CheckpointError):
    """An on-disk checkpoint failed integrity validation."""


def _flatten(tree) -> tuple:
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def _crc32_file(path: Path) -> int:
    crc = 0
    with open(path, "rb") as f:
        for block in iter(lambda: f.read(1 << 20), b""):
            crc = zlib.crc32(block, crc)
    return crc


def save(directory, step: int, tree, *, meta: Optional[dict] = None
         ) -> Path:
    """Synchronous atomic checkpoint write (crc32 per leaf in the
    manifest; chaos fault points ``ckpt_write`` / ``ckpt_corrupt``)."""
    _chaos.maybe_raise("ckpt_write", step=step)
    directory = Path(directory)
    final = directory / f"step_{step:08d}"
    tmp = directory / f"step_{step:08d}.tmp"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)
    leaves, treedef = _flatten(tree)
    manifest = {
        "step": step,
        "n_leaves": len(leaves),
        "treedef": str(treedef),
        "meta": meta or {},
        "time": time.time(),
        "leaves": [],
    }
    for i, leaf in enumerate(leaves):
        arr = np.asarray(jax.device_get(leaf))
        path = tmp / f"leaf_{i:06d}.npy"
        np.save(path, arr)
        manifest["leaves"].append(
            {"shape": list(arr.shape), "dtype": str(arr.dtype),
             "crc32": _crc32_file(path)})
    (tmp / "manifest.json").write_text(json.dumps(manifest))
    # chaos: simulate a writer killed mid-flight — the damaged payload
    # still gets renamed into place, exactly the hazard validation guards
    _chaos.corrupt_checkpoint_files("ckpt_corrupt", tmp, step=step)
    if final.exists():
        shutil.rmtree(final)
    tmp.rename(final)
    return final


def _saved_steps(directory: Path) -> List[int]:
    if not directory.exists():
        return []
    return sorted(int(p.name.split("_")[1]) for p in directory.iterdir()
                  if p.is_dir() and p.name.startswith("step_")
                  and not p.name.endswith(".tmp"))


def latest_step(directory) -> Optional[int]:
    steps = _saved_steps(Path(directory))
    return max(steps) if steps else None


def validate_checkpoint(directory, step: int) -> Optional[str]:
    """Integrity check of one saved step without deserialising the tree.

    Returns ``None`` when the checkpoint is intact, else a human-readable
    reason: missing/unparseable manifest, missing leaf file, or a crc32
    mismatch against the manifest (legacy manifests without checksums
    only get the existence checks)."""
    root = Path(directory) / f"step_{step:08d}"
    mpath = root / "manifest.json"
    if not mpath.exists():
        return "manifest.json missing"
    try:
        manifest = json.loads(mpath.read_text())
    except (json.JSONDecodeError, OSError) as e:
        return f"manifest unreadable: {e}"
    entries = manifest.get("leaves", [])
    if manifest.get("n_leaves") != len(entries):
        return (f"manifest lists {len(entries)} leaves, "
                f"declares n_leaves={manifest.get('n_leaves')}")
    for i, entry in enumerate(entries):
        path = root / f"leaf_{i:06d}.npy"
        if not path.exists():
            return f"leaf {i} missing"
        want = entry.get("crc32")
        if want is not None and _crc32_file(path) != want:
            return f"leaf {i} crc32 mismatch"
    return None


def latest_valid_step(directory) -> Tuple[Optional[int], List[int]]:
    """Newest step that passes :func:`validate_checkpoint`, scanning
    newest-to-oldest.  Returns ``(step_or_None, corrupt_steps_skipped)``
    — the skipped list lets callers warn that the newest entry was
    damaged and an older one is being used."""
    skipped: List[int] = []
    for step in reversed(_saved_steps(Path(directory))):
        if validate_checkpoint(directory, step) is None:
            return step, skipped
        skipped.append(step)
    return None, skipped


def restore(directory, step: int, like, *, shardings=None,
            expect_meta: Optional[Callable[[dict], bool]] = None):
    """Restore onto the CURRENT topology (elastic).

    ``like``: a pytree matching the saved structure (shapes may be
    device-sharded differently).  ``shardings``: optional tree of
    NamedSharding to place leaves under (None = default device).
    """
    reason = validate_checkpoint(directory, step)
    if reason is not None:
        raise CheckpointCorruptError(
            f"checkpoint step {step} under {str(directory)!r} failed "
            f"integrity validation ({reason}); run "
            f"latest_valid_step() to locate an intact fallback")
    directory = Path(directory) / f"step_{step:08d}"
    manifest = json.loads((directory / "manifest.json").read_text())
    if expect_meta is not None and not expect_meta(manifest["meta"]):
        raise ValueError(f"manifest meta check failed: {manifest['meta']}")
    leaves, treedef = _flatten(like)
    if len(leaves) != manifest["n_leaves"]:
        raise ValueError(
            f"checkpoint has {manifest['n_leaves']} leaves; current tree "
            f"has {len(leaves)} — config mismatch")
    shard_leaves = (jax.tree.flatten(shardings)[0] if shardings is not None
                    else [None] * len(leaves))
    out = []
    for i, (ref, shd) in enumerate(zip(leaves, shard_leaves)):
        arr = np.load(directory / f"leaf_{i:06d}.npy")
        if tuple(arr.shape) != tuple(ref.shape):
            raise ValueError(f"leaf {i}: saved {arr.shape} != {ref.shape}")
        out.append(jax.device_put(arr, shd) if shd is not None
                   else jax.device_put(arr))
    return jax.tree.unflatten(treedef, out), manifest


class Checkpointer:
    """Async checkpoint manager with retention.

    A failure on the background write thread must not vanish with the
    thread: it is stashed and re-raised as :class:`CheckpointWriteError`
    at the next synchronisation point — ``wait()``, the next ``save()``
    / ``save_async()``, or ``close()`` — so the training loop learns
    its checkpoint cadence is broken while it can still react."""

    def __init__(self, directory, *, keep: int = 3,
                 meta: Optional[dict] = None):
        self.directory = Path(directory)
        self.keep = keep
        self.meta = meta or {}
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None
        self.saved_steps: list = []

    def _record_failure(self, exc: BaseException) -> None:
        """Async-thread exception router: stash for re-raise at the
        next synchronisation point (``classify``-compatible: the
        surfaced ``CheckpointWriteError`` chains the original)."""
        self._error = exc

    def _raise_pending(self):
        if self._error is not None:
            exc, self._error = self._error, None
            raise CheckpointWriteError(
                f"async checkpoint write failed: "
                f"{type(exc).__name__}: {exc}") from exc

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        self._raise_pending()

    def save_async(self, step: int, tree):
        """Snapshot to host (blocking only for the copy), write in a
        background thread — I/O overlaps subsequent steps."""
        self.wait()
        host_tree = jax.tree.map(
            lambda x: np.asarray(jax.device_get(x)), tree)

        def _write():
            try:
                save(self.directory, step, host_tree, meta=self.meta)
                self.saved_steps.append(step)
                self._gc()
            except BaseException as e:  # surfaces via _raise_pending
                self._record_failure(e)

        self._thread = threading.Thread(target=_write, daemon=True)
        self._thread.start()

    def save(self, step: int, tree):
        self.wait()
        save(self.directory, step, tree, meta=self.meta)
        self.saved_steps.append(step)
        self._gc()

    def close(self):
        """Drain the writer thread and surface any pending failure."""
        self.wait()

    def _gc(self):
        steps = sorted(
            int(p.name.split("_")[1]) for p in self.directory.iterdir()
            if p.is_dir() and p.name.startswith("step_")
            and not p.name.endswith(".tmp"))
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(self.directory / f"step_{s:08d}",
                          ignore_errors=True)
