"""Oracle for the fused SCDL ADMM elementwise tail (Algorithm 2, step 8):
given fresh codes Wh/Wl (K, A) and the stacked multiplier state
``YZ = [Y1, Y2, Y3, Z1, Z2]`` (K, 5, A), soft-threshold the splitting
variables and take the three dual ascent steps:

    P  = soft(Wh - Y1/c1, t1),  t1 = lam_h/c1
    Q  = soft(Wl - Y2/c2, t2),  t2 = lam_l/c2
    Y1 = Y1 + c1 (P - Wh)
    Y2 = Y2 + c2 (Q - Wl)
    Y3 = Y3 + c3 (Wh - Wl)

P and Q are consumed by the next iteration's W solves only through the
right-hand-side combinations, so instead of the raw splitting variables
the state carries those directly (with the updated multipliers and the
fresh codes folded in):

    Z1 = c1 P + Y1 - Y3 + c3 Wl      (everything rhs_h needs besides S)
    Z2 = c2 Q + Y2 + Y3              (rhs_l adds c3 Wh_fresh itself)

Returns the updated (K, 5, A) stack.  Keeping the five planes in ONE
array matters beyond the TPU kernel: XLA fuses the whole tail into a
single output loop (one write) instead of five separately-rooted
fusions that re-read their shared inputs.  Accumulation in fp32, result
cast back to the input dtype (the kernel contract)."""
from __future__ import annotations

import jax.numpy as jnp


def admm_elwise_ref(Wh, Wl, YZ, *, c1, c2, c3, t1, t2):
    # with soft(V, t) = V - clip(V, -t, t) and V1 = Wh - Y1/c1, the dual
    # step collapses: Y1' = Y1 + c1 (soft(V1) - Wh) = -c1 clip(V1), and
    # c1 P = (c1 Wh - Y1) + Y1' — so the whole tail is clamps and axpys
    dt = YZ.dtype
    wh, wl = Wh.astype(jnp.float32), Wl.astype(jnp.float32)
    yz = YZ.astype(jnp.float32)
    y1, y2, y3 = yz[:, 0], yz[:, 1], yz[:, 2]
    Y1n = -c1 * jnp.clip(wh - y1 / c1, -t1, t1)
    Y2n = -c2 * jnp.clip(wl - y2 / c2, -t2, t2)
    Y3n = y3 + c3 * (wh - wl)
    Z1 = (c1 * wh - y1) + 2.0 * Y1n - Y3n + c3 * wl
    Z2 = (c2 * wl - y2) + 2.0 * Y2n + Y3n
    return jnp.stack([Y1n, Y2n, Y3n, Z1, Z2], axis=1).astype(dt)
