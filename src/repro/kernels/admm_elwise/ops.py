"""Public wrapper for the fused ADMM elementwise tail.

``use_kernel=None`` auto-selects: the Pallas kernel where it compiles to
Mosaic (TPU), the pure-jnp oracle elsewhere — on CPU/GPU hosts the
stacked-state oracle already collapses to one fused XLA loop, and the
interpreter would only add overhead inside the training scan.  Tests
pass ``use_kernel=True`` to exercise the kernel in interpreter mode on
any backend.

The kernel path routes through ``kernels.common.degraded_call``, so a
Pallas failure degrades the ``admm_elwise`` family compiled → interpret
→ ref once per process with a recorded warning (DESIGN.md §18).
"""
from __future__ import annotations

from functools import partial

import jax

from repro.kernels.admm_elwise.kernel import admm_elwise_fwd
from repro.kernels.common import auto_interpret, degraded_call
from repro.kernels.admm_elwise.ref import admm_elwise_ref

FAMILY = "admm_elwise"


@partial(jax.jit, static_argnames=("c1", "c2", "c3", "t1", "t2",
                                   "block_k", "interpret"))
def _admm_kernel(Wh, Wl, YZ, *, c1, c2, c3, t1, t2, block_k: int,
                 interpret: bool):
    return admm_elwise_fwd(Wh, Wl, YZ, c1=c1, c2=c2, c3=c3,
                           t1=t1, t2=t2, block_k=block_k,
                           interpret=interpret)


@partial(jax.jit, static_argnames=("c1", "c2", "c3", "t1", "t2"))
def _admm_ref(Wh, Wl, YZ, *, c1, c2, c3, t1, t2):
    return admm_elwise_ref(Wh, Wl, YZ, c1=c1, c2=c2, c3=c3,
                           t1=t1, t2=t2)


def admm_elwise(Wh, Wl, YZ, *, c1, c2, c3, t1, t2,
                use_kernel=None, block_k: int = 256, interpret=None):
    if use_kernel is None:
        use_kernel = not auto_interpret()
    if not use_kernel:
        return _admm_ref(Wh, Wl, YZ, c1=c1, c2=c2, c3=c3, t1=t1, t2=t2)
    return degraded_call(
        FAMILY,
        kernel=lambda interp: _admm_kernel(
            Wh, Wl, YZ, c1=c1, c2=c2, c3=c3, t1=t1, t2=t2,
            block_k=block_k, interpret=interp),
        ref=lambda: _admm_ref(Wh, Wl, YZ, c1=c1, c2=c2, c3=c3,
                              t1=t1, t2=t2),
        requested_interpret=interpret)
