"""Fused SCDL ADMM elementwise tail — Pallas TPU kernel.

After the W ridge solves, Algorithm 2's step 8 finishes with a
soft-threshold of each splitting variable and three dual ascent updates.
As separate jnp ops that is ~5 full HBM passes over five (K_loc, A)
arrays per iteration; at the GS shape (K=40k, A=512) each array is
~80 MB, so the chain is purely HBM-bound.  The fused kernel streams one
(block_k, 5, A) tile of the stacked multiplier state ``YZ = [Y1, Y2,
Y3, Z1, Z2]`` plus the two fresh code tiles through VMEM and writes the
updated stack in the same pass — one read + one write per array total.
The splitting variables P/Q stay VMEM-internal; the Z planes are the
pre-folded right-hand-side terms the next W solves consume (see
``ref.py`` for the algebra).

Grid: (K / block_k,) over the sample axis, embarrassingly parallel
(dimension_semantics: parallel); every program touches disjoint rows.
The ADMM constants (c1, c2, c3 and the thresholds t1 = lam_h/c1,
t2 = lam_l/c2) are static configuration, baked into the kernel body.
VMEM per program: ~12 x block_k x A x 4 B ~ 6 MB at block_k = 256,
A = 512.  Sample counts that don't divide ``block_k`` zero-pad up to a
whole block (pad rows produce pad rows; the caller slices them off).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import auto_interpret, pad_leading


def _admm_kernel(wh_ref, wl_ref, yz_ref, out_ref, *, c1, c2, c3, t1, t2):
    wh = wh_ref[...].astype(jnp.float32)
    wl = wl_ref[...].astype(jnp.float32)
    yz = yz_ref[...].astype(jnp.float32)                # (bk, 5, A)
    y1, y2, y3 = yz[:, 0], yz[:, 1], yz[:, 2]

    # soft(V, t) = V - clip(V) collapses the dual step to a clamp:
    # Y' = Y + c (soft(V) - W) = -c clip(V), c P = (c W - Y) + Y'
    y1n = -c1 * jnp.clip(wh - y1 / c1, -t1, t1)
    y2n = -c2 * jnp.clip(wl - y2 / c2, -t2, t2)
    y3n = y3 + c3 * (wh - wl)
    z1 = (c1 * wh - y1) + 2.0 * y1n - y3n + c3 * wl
    z2 = (c2 * wl - y2) + 2.0 * y2n + y3n
    out_ref[...] = jnp.stack([y1n, y2n, y3n, z1, z2],
                             axis=1).astype(out_ref.dtype)


def admm_elwise_fwd(Wh, Wl, YZ, *, c1, c2, c3, t1, t2,
                    block_k: int = 256, interpret=None):
    """Wh/Wl: (K, A); YZ: (K, 5, A).  Returns the updated (K, 5, A)."""
    if interpret is None:
        interpret = auto_interpret()
    K, A = Wh.shape
    block_k = min(block_k, K)
    ins, k_full = pad_leading([Wh, Wl, YZ], block_k)
    pad = k_full - K

    kernel = functools.partial(_admm_kernel, c1=c1, c2=c2, c3=c3,
                               t1=t1, t2=t2)
    out = pl.pallas_call(
        kernel,
        grid=(k_full // block_k,),
        in_specs=[
            pl.BlockSpec((block_k, A), lambda i: (i, 0)),
            pl.BlockSpec((block_k, A), lambda i: (i, 0)),
            pl.BlockSpec((block_k, 5, A), lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((block_k, 5, A), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((k_full, 5, A), YZ.dtype),
        interpret=interpret,
    )(*ins)
    return out[:K] if pad else out
