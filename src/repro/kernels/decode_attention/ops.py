"""Jitted public wrapper for the flash-decode kernel."""
from __future__ import annotations

from functools import partial

import jax

from repro.kernels.decode_attention.kernel import decode_attention_fwd
from repro.kernels.decode_attention.ref import decode_attention_ref


@partial(jax.jit, static_argnames=("window", "use_kernel", "block_k",
                                   "interpret"))
def decode_attention(q, k, v, lengths, *, window: int = 0,
                     use_kernel: bool = True, block_k: int = 512,
                     interpret: bool = True):
    if not use_kernel:
        return decode_attention_ref(q, k, v, lengths, window=window)
    return decode_attention_fwd(q, k, v, lengths, window=window,
                                block_k=block_k, interpret=interpret)
