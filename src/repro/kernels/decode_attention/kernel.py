"""Flash-decode Pallas kernel: one query token against a long KV cache.

The decode_32k / long_500k serve steps are HBM-bandwidth bound on the KV
cache read; the kernel streams (block_k x D) cache tiles through VMEM
once, with the online-softmax state (m, l, acc) held in registers/VMEM.
To fill the MXU/VPU lanes despite a single query row, all H query heads
that share a kv head are processed together: the score tile is
(group x block_k), so MQA (group=48) and GQA fill lanes naturally.

Grid: (B, K) — one program per (sequence, kv head).  VMEM per program:
k/v tiles 2 x block_k x D (f32), scores group x block_k, accumulators
group x (D + 2).  block_k = 512, D = 128: ~530 KB.

Sliding-window decode clips the streamed range to the last ``window``
positions — the local-attention layers of gemma3/hymba decode in O(w)
regardless of cache length.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -2.0e38


def _dec_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, *, scale, block_k,
                seq_k, window):
    group, d = q_ref.shape
    q = q_ref[...].astype(jnp.float32) * scale           # (G, D)
    length = len_ref[0]

    m = jnp.full((group,), NEG_INF, jnp.float32)
    l = jnp.zeros((group,), jnp.float32)
    acc = jnp.zeros((group, d), jnp.float32)

    hi = pl.cdiv(length, block_k)
    lo = 0
    if window:
        lo = jnp.maximum((length - window) // block_k, 0)

    def body(kv_i, carry):
        m, l, acc = carry
        k = pl.load(k_ref, (pl.dslice(kv_i * block_k, block_k),
                            slice(None))).astype(jnp.float32)
        v = pl.load(v_ref, (pl.dslice(kv_i * block_k, block_k),
                            slice(None))).astype(jnp.float32)
        s = q @ k.T                                      # (G, bk)
        pos = kv_i * block_k + jax.lax.iota(jnp.int32, block_k)
        keep = pos[None, :] < length
        if window:
            keep &= pos[None, :] >= (length - window)
        s = jnp.where(keep, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_new = l * alpha + jnp.sum(p, axis=1)
        acc_new = acc * alpha[:, None] + p @ v
        return m_new, l_new, acc_new

    m, l, acc = jax.lax.fori_loop(lo, hi, body, (m, l, acc))
    o_ref[...] = (acc / l[:, None]).astype(o_ref.dtype)


def decode_attention_fwd(q, k, v, lengths, *, window: int = 0, scale=None,
                         block_k: int = 512, interpret: bool = True):
    """q: (B, H, D); k/v: (B, K, T, D); lengths: (B,). -> (B, H, D)."""
    B, H, D = q.shape
    K, T = k.shape[1], k.shape[2]
    group = H // K
    scale = D ** -0.5 if scale is None else scale
    block_k = min(block_k, T)
    assert T % block_k == 0

    q4 = q.reshape(B, K, group, D)
    grid = (B, K)
    kernel = functools.partial(_dec_kernel, scale=scale, block_k=block_k,
                               seq_k=T, window=window)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda b, h: (b,)),
            pl.BlockSpec((None, None, group, D), lambda b, h: (b, h, 0, 0)),
            pl.BlockSpec((None, None, T, D), lambda b, h: (b, h, 0, 0)),
            pl.BlockSpec((None, None, T, D), lambda b, h: (b, h, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, None, group, D),
                               lambda b, h: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, K, group, D), q.dtype),
        interpret=interpret,
    )(lengths, q4, k, v)
    return out.reshape(B, H, D)
