"""Oracle for single-token cached decode attention.

q: (B, H, D) one query per sequence; k/v caches: (B, K, T, D);
lengths: (B,) valid prefix lengths (the new token sits at length-1).
Optional sliding window.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -2.0e38


def decode_attention_ref(q, k, v, lengths, *, window: int = 0,
                         scale=None):
    B, H, D = q.shape
    K, T = k.shape[1], k.shape[2]
    group = H // K
    scale = D ** -0.5 if scale is None else scale
    k_rep = jnp.repeat(k, group, axis=1)
    v_rep = jnp.repeat(v, group, axis=1)
    logits = jnp.einsum("bhd,bhtd->bht", q.astype(jnp.float32),
                        k_rep.astype(jnp.float32)) * scale
    pos = jnp.arange(T)[None, None, :]
    last = (lengths - 1)[:, None, None]
    keep = pos <= last
    if window:
        keep &= pos > (last - window)
    logits = jnp.where(keep, logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bht,bhtd->bhd", p,
                      v_rep.astype(jnp.float32)).astype(q.dtype)
