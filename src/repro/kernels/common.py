"""Shared helpers for the Pallas kernel wrappers."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def auto_interpret() -> bool:
    """Compile the Mosaic kernel on TPU; fall back to interpreter mode
    everywhere else (CPU/GPU hosts run the same traced jnp ops)."""
    return jax.default_backend() != "tpu"


def pad_leading(arrays, block: int):
    """Zero-pad a shared leading axis to a whole number of ``block``
    rows (pad rows are inert for the kernels using this: they produce
    pad rows or contribute zero to accumulators).  Returns the padded
    list and the padded length."""
    n = arrays[0].shape[0]
    pad = -n % block
    if pad:
        arrays = [jnp.concatenate(
            [a, jnp.zeros((pad,) + a.shape[1:], a.dtype)]) for a in arrays]
    return arrays, n + pad
