"""Shared helpers for the Pallas kernel wrappers."""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp


def auto_interpret() -> bool:
    """Compile the Mosaic kernel on TPU; fall back to interpreter mode
    everywhere else (CPU/GPU hosts run the same traced jnp ops).

    ``REPRO_FORCE_INTERPRET=1`` overrides the backend probe and forces
    interpreter mode even on TPU — the escape hatch for debugging a
    Mosaic miscompile or bisecting kernel-vs-oracle divergence on
    hardware (set to ``0``/``false``/empty to disable; any other value
    forces).  The env var is read per call, so tests can monkeypatch
    it without re-importing kernel modules.
    """
    forced = os.environ.get("REPRO_FORCE_INTERPRET", "")
    if forced.strip().lower() not in ("", "0", "false", "no"):
        return True
    return jax.default_backend() != "tpu"


def pad_leading(arrays, block: int):
    """Zero-pad a shared leading axis to a whole number of ``block``
    rows (pad rows are inert for the kernels using this: they produce
    pad rows or contribute zero to accumulators).  Returns the padded
    list and the padded length."""
    n = arrays[0].shape[0]
    pad = -n % block
    if pad:
        arrays = [jnp.concatenate(
            [a, jnp.zeros((pad,) + a.shape[1:], a.dtype)]) for a in arrays]
    return arrays, n + pad
