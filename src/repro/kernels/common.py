"""Shared helpers for the Pallas kernel wrappers.

Besides the backend probe (:func:`auto_interpret`) and padding helper,
this module owns **graceful kernel degradation** (DESIGN.md §18): every
kernel family's public wrapper routes its implementation choice through
:func:`degraded_call`, so a Pallas construction/lowering failure (or an
injected ``kernel`` chaos fault) drops the family compiled → interpret
→ ref *once per process*, with a recorded warning, instead of killing a
survey-scale run over one miscompiling kernel.
"""
from __future__ import annotations

import os
import threading
import warnings
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.resilience import chaos as _chaos


def auto_interpret() -> bool:
    """Compile the Mosaic kernel on TPU; fall back to interpreter mode
    everywhere else (CPU/GPU hosts run the same traced jnp ops).

    ``REPRO_FORCE_INTERPRET=1`` overrides the backend probe and forces
    interpreter mode even on TPU — the escape hatch for debugging a
    Mosaic miscompile or bisecting kernel-vs-oracle divergence on
    hardware (set to ``0``/``false``/empty to disable; any other value
    forces).  The env var is read per call, so tests can monkeypatch
    it without re-importing kernel modules.
    """
    forced = os.environ.get("REPRO_FORCE_INTERPRET", "")
    if forced.strip().lower() not in ("", "0", "false", "no"):
        return True
    return jax.default_backend() != "tpu"


def pad_leading(arrays, block: int):
    """Zero-pad a shared leading axis to a whole number of ``block``
    rows (pad rows are inert for the kernels using this: they produce
    pad rows or contribute zero to accumulators).  Returns the padded
    list and the padded length."""
    n = arrays[0].shape[0]
    pad = -n % block
    if pad:
        arrays = [jnp.concatenate(
            [a, jnp.zeros((pad,) + a.shape[1:], a.dtype)]) for a in arrays]
    return arrays, n + pad


# ---------------------------------------------------------------------------
# Graceful kernel degradation: compiled -> interpret -> ref, once per family.
# ---------------------------------------------------------------------------

# Per-family degradation level.  Absent = 0 (honour the caller's request);
# 1 = force interpret mode; 2 = force the pure-jnp reference path.  The dict
# is process-global on purpose: once a family's compiled kernel has failed,
# every later call in the process (including other solves) skips straight to
# the surviving level instead of re-failing per call.
_DEGRADED: Dict[str, int] = {}
_FALLBACK_EVENTS: List[dict] = []
_LOCK = threading.Lock()

_LEVEL_NAMES = ("compiled", "interpret", "ref")


def kernel_fallbacks() -> Tuple[dict, ...]:
    """Degradation events recorded so far (process lifetime), oldest
    first.  ``Supervisor.finalize`` slices off the per-run suffix for
    ``Solution.recovery``."""
    return tuple(_FALLBACK_EVENTS)


def reset_degradation() -> None:
    """Forget all degradation state and events (test isolation)."""
    with _LOCK:
        _DEGRADED.clear()
        _FALLBACK_EVENTS.clear()


def _degrade(family: str, level: int, exc: BaseException) -> None:
    with _LOCK:
        if _DEGRADED.get(family, 0) < level:
            _DEGRADED[family] = level
            event = {"family": family,
                     "to": _LEVEL_NAMES[level],
                     "error": f"{type(exc).__name__}: {exc}"}
            _FALLBACK_EVENTS.append(event)
            warnings.warn(
                f"kernel family {family!r} degraded to "
                f"{_LEVEL_NAMES[level]} after "
                f"{type(exc).__name__}: {exc}", RuntimeWarning,
                stacklevel=3)


def degraded_call(family: str, *, kernel: Callable[[bool], Any],
                  ref: Callable[[], Any],
                  requested_interpret: Optional[bool] = None) -> Any:
    """Run a kernel family's implementation at the highest level that
    still works: compiled Mosaic, then interpreter mode, then the pure
    jnp reference — degrading the *family* (not the call) on the first
    failure, with a recorded ``RuntimeWarning``.

    ``kernel(interpret)`` must build-and-call the Pallas path;
    ``ref()`` the reference path.  Only errors raised at Python level
    are catchable — kernel *construction*/trace/lowering failures and
    injected ``kernel`` chaos faults.  A Mosaic abort inside an already
    compiled program surfaces at the dispatch host sync instead, where
    the resilience supervisor's retry loop owns it (DESIGN.md §18).

    ``requested_interpret=None`` defers to :func:`auto_interpret`;
    explicit True counts as starting at the interpret level.
    """
    interpret = (auto_interpret() if requested_interpret is None
                 else requested_interpret)
    level = _DEGRADED.get(family, 0)
    if level == 0 and not interpret:
        try:
            _chaos.maybe_raise("kernel", tag=family)
            return kernel(False)
        except Exception as e:  # degrade the family, not the run
            _degrade(family, 1, e)
            level = 1
    if level <= 1:
        try:
            _chaos.maybe_raise("kernel", tag=family)
            return kernel(True)
        except Exception as e:  # last resort: the jnp reference
            _degrade(family, 2, e)
    return ref()
