"""Chunked selective scan — Pallas TPU kernel.

TPU adaptation of the CUDA mamba scan (DESIGN.md §8): the GPU kernel
serialises time inside one SM with warp shuffles; on TPU we keep the
running state (dI_blk, dS) resident in VMEM across the whole sequence
and walk it chunk by chunk, vectorising each chunk over the (8,128)
VPU lanes via a within-chunk prefix product.  Channels are independent,
so the grid tiles (batch, d_inner / block_d) and the time loop is
sequential per program — the state never leaves VMEM (the HBM win the
CUDA kernel gets from SRAM residency).

VMEM per program: a/b chunk tiles 2 x chunk x block_d x dS (f32),
C chunk (chunk, dS), state block_d x dS, y chunk chunk x block_d.
chunk = 64, block_d = 256, dS = 16: ~2.2 MB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _scan_kernel(a_ref, b_ref, c_ref, h0_ref, y_ref, hout_ref, *,
                 chunk, seq_len, block_d, d_state):
    h = h0_ref[...].astype(jnp.float32)                  # (bd, dS)
    n_chunks = seq_len // chunk

    def outer(ci, carry):
        h = carry
        a = pl.load(a_ref, (pl.dslice(ci * chunk, chunk), slice(None),
                            slice(None))).astype(jnp.float32)
        b = pl.load(b_ref, (pl.dslice(ci * chunk, chunk), slice(None),
                            slice(None))).astype(jnp.float32)
        c = pl.load(c_ref, (pl.dslice(ci * chunk, chunk),
                            slice(None))).astype(jnp.float32)

        # within-chunk inclusive scan (log-depth, lane-parallel over
        # (block_d, dS)): (a, b) o (a', b') = (a a', b a' + b')
        def combine(x, y):
            ax, bx = x
            ay, by = y
            return ax * ay, bx * ay + by

        a_run, b_run = jax.lax.associative_scan(combine, (a, b), axis=0)
        h_all = a_run * h[None] + b_run                  # (chunk, bd, dS)
        y = jnp.einsum("tds,ts->td", h_all, c)
        pl.store(y_ref, (pl.dslice(ci * chunk, chunk), slice(None)),
                 y.astype(y_ref.dtype))
        return h_all[-1]

    h = jax.lax.fori_loop(0, n_chunks, outer, h)
    hout_ref[...] = h.astype(hout_ref.dtype)


def selective_scan_fwd(a, b, C, h0, *, chunk: int = 64,
                       block_d: int = 256, interpret: bool = True):
    """a, b: (B, L, dI, dS); C: (B, L, dS); h0: (B, dI, dS).

    Returns (y (B, L, dI) f32, h_last (B, dI, dS) f32).
    """
    B, L, dI, dS = a.shape
    block_d = min(block_d, dI)
    chunk = min(chunk, L)
    assert dI % block_d == 0 and L % chunk == 0

    grid = (B, dI // block_d)
    kernel = functools.partial(_scan_kernel, chunk=chunk, seq_len=L,
                               block_d=block_d, d_state=dS)
    y, h_last = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, L, block_d, dS), lambda i, j: (i, 0, j, 0)),
            pl.BlockSpec((None, L, block_d, dS), lambda i, j: (i, 0, j, 0)),
            pl.BlockSpec((None, L, dS), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((None, block_d, dS), lambda i, j: (i, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((None, L, block_d), lambda i, j: (i, 0, j)),
            pl.BlockSpec((None, block_d, dS), lambda i, j: (i, j, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, L, dI), jnp.float32),
            jax.ShapeDtypeStruct((B, dI, dS), jnp.float32),
        ],
        interpret=interpret,
    )(a, b, C, h0)
    return y, h_last
