"""Jitted public wrapper for the selective-scan kernel."""
from __future__ import annotations

from functools import partial

import jax

from repro.kernels.selective_scan.kernel import selective_scan_fwd
from repro.kernels.selective_scan.ref import selective_scan_ref


@partial(jax.jit, static_argnames=("use_kernel", "chunk", "block_d",
                                   "interpret"))
def selective_scan(a, b, C, h0, *, use_kernel: bool = True,
                   chunk: int = 64, block_d: int = 256,
                   interpret: bool = True):
    if not use_kernel:
        return selective_scan_ref(a, b, C, h0)
    return selective_scan_fwd(a, b, C, h0, chunk=chunk, block_d=block_d,
                              interpret=interpret)
