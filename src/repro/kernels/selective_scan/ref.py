"""Oracle for the chunked mamba selective scan.

Sequential-in-time reference: h_t = a_t * h_{t-1} + b_t; y_t = <h_t, C_t>.
a, b: (B, L, dI, dS) f32; C: (B, L, dS) f32; h0: (B, dI, dS).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def selective_scan_ref(a, b, C, h0):
    def step(h, xs):
        at, bt, ct = xs
        h = at * h + bt                                  # (B, dI, dS)
        y = jnp.einsum("bds,bs->bd", h, ct)
        return h, y

    xs = (a.swapaxes(0, 1), b.swapaxes(0, 1), C.swapaxes(0, 1))
    h_last, ys = jax.lax.scan(step, h0, xs)
    return ys.swapaxes(0, 1), h_last                     # (B, L, dI), state
