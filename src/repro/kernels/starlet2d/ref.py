"""Oracle for the batched starlet scale kernel: one B3 a-trous smoothing
over a batch of stamps (periodic boundaries), matching
``repro.imaging.starlet.smooth``."""
from __future__ import annotations

import jax.numpy as jnp

_K = jnp.array([1.0, 4.0, 6.0, 4.0, 1.0]) / 16.0


def smooth_ref(imgs, scale: int):
    """imgs: (N, H, W) -> (N, H, W), one smoothing at dyadic ``scale``."""
    step = 1 << scale
    out = imgs
    for axis in (-1, -2):
        acc = _K[2] * out
        for t, off in ((0, -2), (1, -1), (3, 1), (4, 2)):
            acc = acc + _K[t] * jnp.roll(out, off * step, axis=axis)
        out = acc
    return out
