"""Jitted public wrapper for the starlet-smoothing kernel, plus the full
batched decomposition built from it."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.starlet2d.kernel import smooth_fwd
from repro.kernels.starlet2d.ref import smooth_ref


@partial(jax.jit, static_argnames=("scale", "use_kernel", "block_n",
                                   "interpret"))
def smooth(imgs, *, scale: int, use_kernel: bool = True,
           block_n: int = 128, interpret: bool = True):
    if not use_kernel:
        return smooth_ref(imgs, scale)
    return smooth_fwd(imgs, scale, block_n=block_n, interpret=interpret)


def decompose(imgs, n_scales: int, **kw):
    """Batched starlet analysis via the kernel: (N,H,W) -> (J+1,N,H,W)."""
    scales = []
    c = imgs
    for j in range(n_scales):
        c_next = smooth(c, scale=j, **kw)
        scales.append(c - c_next)
        c = c_next
    scales.append(c)
    return jnp.stack(scales)
