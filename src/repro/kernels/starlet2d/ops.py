"""Public wrappers for the starlet-smoothing kernel, plus the full
batched transforms built from it.

``forward`` / ``adjoint`` are the batched counterparts of
``repro.imaging.starlet.forward``/``adjoint`` operating on a whole
(N, H, W) stamp stack at once — the layout the Condat solver's dual
updates use every iteration.  The adjoint shares cumulative smoothing
products across scales (Horner evaluation, 2J - 1 kernel launches
instead of O(J^2)).

The kernel path routes through ``kernels.common.degraded_call``, so a
Pallas failure degrades the ``starlet2d`` family compiled → interpret
→ ref once per process with a recorded warning (DESIGN.md §18)."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.common import degraded_call
from repro.kernels.starlet2d.kernel import smooth_fwd
from repro.kernels.starlet2d.ref import smooth_ref

FAMILY = "starlet2d"


@partial(jax.jit, static_argnames=("scale", "block_n", "interpret"))
def _smooth_kernel(imgs, *, scale: int, block_n: int, interpret: bool):
    return smooth_fwd(imgs, scale, block_n=block_n, interpret=interpret)


@partial(jax.jit, static_argnames=("scale",))
def _smooth_ref(imgs, *, scale: int):
    return smooth_ref(imgs, scale)


def smooth(imgs, *, scale: int, use_kernel: bool = True,
           block_n: int = 128, interpret=None):
    if not use_kernel:
        return _smooth_ref(imgs, scale=scale)
    return degraded_call(
        FAMILY,
        kernel=lambda interp: _smooth_kernel(imgs, scale=scale,
                                             block_n=block_n,
                                             interpret=interp),
        ref=lambda: _smooth_ref(imgs, scale=scale),
        requested_interpret=interpret)


def decompose(imgs, n_scales: int, **kw):
    """Batched starlet analysis via the kernel: (N,H,W) -> (J+1,N,H,W)."""
    scales = []
    c = imgs
    for j in range(n_scales):
        c_next = smooth(c, scale=j, **kw)
        scales.append(c - c_next)
        c = c_next
    scales.append(c)
    return jnp.stack(scales)


def forward(imgs, n_scales: int, **kw):
    """Batched Phi: detail scales only, (N,H,W) -> (J,N,H,W)."""
    return decompose(imgs, n_scales, **kw)[:-1]


def adjoint(coeffs, n_scales: int, **kw):
    """Batched Phi^T: (J,N,H,W) -> (N,H,W).

    Horner evaluation of the cascade transpose (see
    ``repro.imaging.starlet.adjoint``): v_j = (I - H_j) w_j, then
    acc_j = v_j + H_j acc_{j+1} from the finest carried scale down.
    """
    acc = coeffs[n_scales - 1] - smooth(coeffs[n_scales - 1],
                                        scale=n_scales - 1, **kw)
    for j in range(n_scales - 2, -1, -1):
        v = coeffs[j] - smooth(coeffs[j], scale=j, **kw)
        acc = v + smooth(acc, scale=j, **kw)
    return acc
