"""Batched starlet (a-trous B3) smoothing — Pallas TPU kernel.

The PSF use case applies Phi / Phi^T to every 41x41 stamp every
iteration: 2 x n_scales x 10k+ small separable convolutions — the
compute hotspot of the paper's sparse solver.  A 41x41 stamp is far
below MXU/VPU tile granularity, so the TPU-native layout batches
``block_n`` stamps into one VMEM-resident (block_n, H, W) block and
vectorises the 5-tap correlation over the stamp *batch* lane dimension
(block_n multiple of 128) — each program does 10 shifted multiply-adds
on a (block_n, H*W) tile, all in VMEM, no HBM round-trips between the
two separable passes.

VMEM per program: in/out/scratch 3 x block_n x 41 x 41 x 4 B ~ 2.6 MB
at block_n = 128.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import auto_interpret

_TAPS = ((0, 1.0 / 16), (1, 4.0 / 16), (2, 6.0 / 16), (3, 4.0 / 16),
         (4, 1.0 / 16))


def _starlet_kernel(x_ref, o_ref, *, step, height, width):
    x = x_ref[...].astype(jnp.float32)                  # (bn, H, W)

    def pass_axis(arr, axis, size):
        acc = jnp.zeros_like(arr)
        for t, w in _TAPS:
            off = (t - 2) * step
            acc = acc + w * jnp.roll(arr, -off, axis=axis)
        return acc

    y = pass_axis(x, 2, width)
    y = pass_axis(y, 1, height)
    o_ref[...] = y.astype(o_ref.dtype)


def smooth_fwd(imgs, scale: int, *, block_n: int = 128,
               interpret=None):
    """imgs: (N, H, W) float; one B3 smoothing at dyadic ``scale``.

    Arbitrary batch sizes are supported: the stamp batch is padded up to
    a whole number of ``block_n`` blocks (the smoothing is per-stamp, so
    pad stamps never contaminate real ones) and the result sliced back.
    On TPU the full 128-lane block is always kept so every program sees
    an aligned tile; in interpreter mode (no alignment constraint) the
    batch collapses to a single block when padding would cost more than
    half a block, so the pad-and-slice path still runs — and is CI-
    covered — for moderate misalignment without pathological waste.
    """
    if interpret is None:
        interpret = auto_interpret()
    N, H, W = imgs.shape
    block_n = min(block_n, N) if interpret else block_n
    if interpret and (-N % block_n) > block_n // 2:
        block_n = N
    n_pad = -N % block_n
    if n_pad:
        imgs = jnp.concatenate(
            [imgs, jnp.zeros((n_pad,) + imgs.shape[1:], imgs.dtype)])
    n_full = N + n_pad
    kernel = functools.partial(_starlet_kernel, step=1 << scale,
                               height=H, width=W)
    out = pl.pallas_call(
        kernel,
        grid=(n_full // block_n,),
        in_specs=[pl.BlockSpec((block_n, H, W), lambda i: (i, 0, 0))],
        out_specs=pl.BlockSpec((block_n, H, W), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((n_full, H, W), imgs.dtype),
        interpret=interpret,
    )(imgs)
    return out[:N] if n_pad else out
