"""Public wrappers for the fused Condat elementwise tails.

``use_kernel=None`` auto-selects: the Pallas kernel where it compiles to
Mosaic (TPU), the pure-jnp oracle elsewhere — on CPU/GPU hosts the
oracle already collapses to one fused XLA loop per pass, and the
interpreter would only add overhead inside the solver scan.  Tests pass
``use_kernel=True`` to exercise the kernel in interpreter mode on any
backend.

The kernel path routes through ``kernels.common.degraded_call``: a
Pallas construction failure (or injected ``kernel`` chaos fault)
degrades the ``condat_elwise`` family compiled → interpret → ref once
per process with a recorded warning (DESIGN.md §18).  Selection happens
at Python level; both implementations underneath stay jitted.

Both wrappers accept arbitrary leading batch shape: ``condat_dual``
flattens the (scale, record) leading axes of the dual stack into the
kernel's 1-D grid axis (the weight column broadcasts per leading index,
shaped (..., 1, 1) like ``condat.weight_matrix`` emits).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.common import auto_interpret, degraded_call
from repro.kernels.condat_elwise.kernel import (condat_dual_fwd,
                                                condat_primal_fwd)
from repro.kernels.condat_elwise.ref import (condat_dual_ref,
                                             condat_primal_ref)

FAMILY = "condat_elwise"


@partial(jax.jit, static_argnames=("with_xbar", "block_n", "interpret"))
def _primal_kernel(X, U_adj, grad, tau, *, with_xbar: bool,
                   block_n: int, interpret: bool):
    lead = X.shape[:-2]
    flat = (-1,) + X.shape[-2:]
    out = condat_primal_fwd(X.reshape(flat), U_adj.reshape(flat),
                            grad.reshape(flat), tau, with_xbar=with_xbar,
                            block_n=block_n, interpret=interpret)
    if with_xbar:
        return (out[0].reshape(lead + X.shape[-2:]),
                out[1].reshape(lead + X.shape[-2:]))
    return out.reshape(lead + X.shape[-2:])


@partial(jax.jit, static_argnames=("with_xbar",))
def _primal_ref(X, U_adj, grad, tau, *, with_xbar: bool):
    return condat_primal_ref(X, U_adj, grad, tau, with_xbar=with_xbar)


def condat_primal(X, U_adj, grad, tau, *, with_xbar: bool = False,
                  use_kernel=None, block_n: int = 128, interpret=None):
    if use_kernel is None:
        use_kernel = not auto_interpret()
    if not use_kernel:
        return _primal_ref(X, U_adj, grad, tau, with_xbar=with_xbar)
    return degraded_call(
        FAMILY,
        kernel=lambda interp: _primal_kernel(
            X, U_adj, grad, tau, with_xbar=with_xbar, block_n=block_n,
            interpret=interp),
        ref=lambda: _primal_ref(X, U_adj, grad, tau, with_xbar=with_xbar),
        requested_interpret=interpret)


@partial(jax.jit, static_argnames=("block_m", "interpret"))
def _dual_kernel(U, C_new, C_old, W, sig, *, block_m: int,
                 interpret: bool):
    lead = U.shape[:-2]
    flat = (-1,) + U.shape[-2:]
    w = jnp.broadcast_to(W, lead + (1, 1)).reshape((-1, 1, 1))
    out = condat_dual_fwd(U.reshape(flat), C_new.reshape(flat),
                          C_old.reshape(flat), w, sig,
                          block_m=block_m, interpret=interpret)
    return out.reshape(U.shape)


_dual_ref = jax.jit(condat_dual_ref)


def condat_dual(U, C_new, C_old, W, sig, *, use_kernel=None,
                block_m: int = 128, interpret=None):
    if use_kernel is None:
        use_kernel = not auto_interpret()
    if not use_kernel:
        return _dual_ref(U, C_new, C_old, W, sig)
    return degraded_call(
        FAMILY,
        kernel=lambda interp: _dual_kernel(U, C_new, C_old, W, sig,
                                           block_m=block_m,
                                           interpret=interp),
        ref=lambda: _dual_ref(U, C_new, C_old, W, sig),
        requested_interpret=interpret)
