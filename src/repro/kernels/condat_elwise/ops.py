"""Jitted public wrappers for the fused Condat elementwise tails.

``use_kernel=None`` auto-selects: the Pallas kernel where it compiles to
Mosaic (TPU), the pure-jnp oracle elsewhere — on CPU/GPU hosts the
oracle already collapses to one fused XLA loop per pass, and the
interpreter would only add overhead inside the solver scan.  Tests pass
``use_kernel=True`` to exercise the kernel in interpreter mode on any
backend.

Both wrappers accept arbitrary leading batch shape: ``condat_dual``
flattens the (scale, record) leading axes of the dual stack into the
kernel's 1-D grid axis (the weight column broadcasts per leading index,
shaped (..., 1, 1) like ``condat.weight_matrix`` emits).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.common import auto_interpret
from repro.kernels.condat_elwise.kernel import (condat_dual_fwd,
                                                condat_primal_fwd)
from repro.kernels.condat_elwise.ref import (condat_dual_ref,
                                             condat_primal_ref)


@partial(jax.jit, static_argnames=("with_xbar", "use_kernel", "block_n",
                                   "interpret"))
def condat_primal(X, U_adj, grad, tau, *, with_xbar: bool = False,
                  use_kernel=None, block_n: int = 128, interpret=None):
    if use_kernel is None:
        use_kernel = not auto_interpret()
    if not use_kernel:
        return condat_primal_ref(X, U_adj, grad, tau, with_xbar=with_xbar)
    lead = X.shape[:-2]
    flat = (-1,) + X.shape[-2:]
    out = condat_primal_fwd(X.reshape(flat), U_adj.reshape(flat),
                            grad.reshape(flat), tau, with_xbar=with_xbar,
                            block_n=block_n, interpret=interpret)
    if with_xbar:
        return (out[0].reshape(lead + X.shape[-2:]),
                out[1].reshape(lead + X.shape[-2:]))
    return out.reshape(lead + X.shape[-2:])


@partial(jax.jit, static_argnames=("use_kernel", "block_m", "interpret"))
def condat_dual(U, C_new, C_old, W, sig, *, use_kernel=None,
                block_m: int = 128, interpret=None):
    if use_kernel is None:
        use_kernel = not auto_interpret()
    if not use_kernel:
        return condat_dual_ref(U, C_new, C_old, W, sig)
    lead = U.shape[:-2]
    flat = (-1,) + U.shape[-2:]
    w = jnp.broadcast_to(W, lead + (1, 1)).reshape((-1, 1, 1))
    out = condat_dual_fwd(U.reshape(flat), C_new.reshape(flat),
                          C_old.reshape(flat), w, sig,
                          block_m=block_m, interpret=interpret)
    return out.reshape(U.shape)
