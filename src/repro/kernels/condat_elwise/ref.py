"""Oracle for the fused Condat elementwise tails (Algorithm 1's
primal-dual iteration, DESIGN.md §16).

The iteration's elementwise work forms two islands separated by the
starlet transform (the dual clamp consumes Phi of the fresh primal, so
no single pass can span both):

  primal:  X_new = max(X - tau grad - tau Phi^T U, 0)        [prox of >=0]
  dual:    U_new = clip(U + sig (2 C_new - C_old), -W, W)

The dual form folds the over-relaxation through the linear transform:
Phi(2 X_new - X) = 2 Phi(X_new) - Phi(X), with C = Phi(X) carried
across iterations — so X_bar is never materialised on the sparse path
and the iteration runs ONE starlet forward (the seed ran two: one on
X_bar for the dual, one on X_new for the objective; the carried C_new
now serves both).  ``with_xbar=True`` (the low-rank path, whose dual
prox is an SVT over the stack, L = I) additionally emits
X_bar = 2 X_new - X from the same read of X.

Accumulation in fp32, results cast back to the input dtype (the kernel
contract, matching ``admm_elwise``)."""
from __future__ import annotations

import jax.numpy as jnp


def condat_primal_ref(X, U_adj, grad, tau, *, with_xbar: bool = False):
    dt = X.dtype
    x = X.astype(jnp.float32)
    t = jnp.float32(tau)
    xn = jnp.maximum(x - t * grad.astype(jnp.float32)
                     - t * U_adj.astype(jnp.float32), 0.0)
    if with_xbar:
        return xn.astype(dt), (2.0 * xn - x).astype(dt)
    return xn.astype(dt)


def condat_dual_ref(U, C_new, C_old, W, sig):
    dt = U.dtype
    s = jnp.float32(sig)
    v = U.astype(jnp.float32) + s * (2.0 * C_new.astype(jnp.float32)
                                     - C_old.astype(jnp.float32))
    w = W.astype(jnp.float32)
    return jnp.clip(v, -w, w).astype(dt)
