"""Fused Condat primal/dual elementwise tails — Pallas TPU kernels.

Two grid passes per iteration (the starlet forward between them is a
hard data dependency — see ``ref.py``):

- ``condat_primal_fwd``: one (block_n, S, S) tile of X, Phi^T U and the
  gradient streams through VMEM and writes the fresh primal (and, for
  the low-rank path, the over-relaxed X_bar from the same read) — one
  read of each operand, one write per output, vs the seed's ~3
  separately-rooted elementwise fusions.
- ``condat_dual_fwd``: one (block_m, S, S) tile of the dual stack U and
  the two starlet coefficient stacks, plus the matching (block_m, 1, 1)
  noise-weight column, fused over-relaxation + clamp in a single pass
  over the (J x n)-times-larger dual state.

The step sizes tau/sig are *traced* scalars (they live in the bundle's
replicated state), so they enter through SMEM rather than being baked
into the kernel body like ``admm_elwise``'s static ADMM constants.

Grids are 1-D over the flattened leading (record/scale) axis,
embarrassingly parallel; non-dividing leading sizes zero-pad up to a
whole block (pad rows produce pad rows; the caller slices them off).
VMEM per program at block 128, S = 41: ~5 x 128 x 41 x 41 x 4 B ~ 4 MB.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import auto_interpret, pad_leading


def _primal_kernel(tau_ref, x_ref, ua_ref, g_ref, xn_ref):
    t = tau_ref[0]
    x = x_ref[...].astype(jnp.float32)
    xn = jnp.maximum(x - t * g_ref[...].astype(jnp.float32)
                     - t * ua_ref[...].astype(jnp.float32), 0.0)
    xn_ref[...] = xn.astype(xn_ref.dtype)


def _primal_xbar_kernel(tau_ref, x_ref, ua_ref, g_ref, xn_ref, xb_ref):
    t = tau_ref[0]
    x = x_ref[...].astype(jnp.float32)
    xn = jnp.maximum(x - t * g_ref[...].astype(jnp.float32)
                     - t * ua_ref[...].astype(jnp.float32), 0.0)
    xn_ref[...] = xn.astype(xn_ref.dtype)
    xb_ref[...] = (2.0 * xn - x).astype(xb_ref.dtype)


def _dual_kernel(sig_ref, u_ref, cn_ref, co_ref, w_ref, out_ref):
    s = sig_ref[0]
    v = u_ref[...].astype(jnp.float32) + \
        s * (2.0 * cn_ref[...].astype(jnp.float32)
             - co_ref[...].astype(jnp.float32))
    w = w_ref[...].astype(jnp.float32)                # (bm, 1, 1)
    out_ref[...] = jnp.clip(v, -w, w).astype(out_ref.dtype)


def _scalar_spec():
    return pl.BlockSpec(memory_space=pltpu.SMEM)


def condat_primal_fwd(X, U_adj, grad, tau, *, with_xbar: bool = False,
                      block_n: int = 128, interpret=None):
    """X/U_adj/grad: (N, S, S); tau scalar.  Returns X_new (and X_bar)."""
    if interpret is None:
        interpret = auto_interpret()
    n, s = X.shape[0], X.shape[-1]
    block_n = min(block_n, n)
    ins, n_full = pad_leading([X, U_adj, grad], block_n)
    tau = jnp.asarray(tau, jnp.float32).reshape((1,))

    blk = pl.BlockSpec((block_n, s, s), lambda i: (i, 0, 0))
    shape = jax.ShapeDtypeStruct((n_full, s, s), X.dtype)
    kernel = _primal_xbar_kernel if with_xbar else _primal_kernel
    out = pl.pallas_call(
        kernel,
        grid=(n_full // block_n,),
        in_specs=[_scalar_spec(), blk, blk, blk],
        out_specs=[blk, blk] if with_xbar else blk,
        out_shape=[shape, shape] if with_xbar else shape,
        interpret=interpret,
    )(tau, *ins)
    if with_xbar:
        return out[0][:n], out[1][:n]
    return out[:n]


def condat_dual_fwd(U, C_new, C_old, W, sig, *, block_m: int = 128,
                    interpret=None):
    """U/C_new/C_old: (M, S, S); W: (M, 1, 1); sig scalar."""
    if interpret is None:
        interpret = auto_interpret()
    m, s = U.shape[0], U.shape[-1]
    block_m = min(block_m, m)
    ins, m_full = pad_leading([U, C_new, C_old, W], block_m)
    sig = jnp.asarray(sig, jnp.float32).reshape((1,))

    blk = pl.BlockSpec((block_m, s, s), lambda i: (i, 0, 0))
    out = pl.pallas_call(
        _dual_kernel,
        grid=(m_full // block_m,),
        in_specs=[_scalar_spec(), blk, blk, blk,
                  pl.BlockSpec((block_m, 1, 1), lambda i: (i, 0, 0))],
        out_specs=blk,
        out_shape=jax.ShapeDtypeStruct((m_full, s, s), U.dtype),
        interpret=interpret,
    )(sig, *ins)
    return out[:m]
