"""Flash attention forward — Pallas TPU kernel with explicit VMEM tiling.

TPU adaptation of the CUDA flash-attention idea: instead of warp-level
softmax accumulation in SM shared memory, we stream (block_q x block_k)
score tiles through VMEM and keep the online-softmax running max/denom
as (block_q, 128)-shaped VREG-friendly accumulators.  The MXU consumes
(block_q, D) x (D, block_k) tiles; D (the head dim, 64/128 in all
assigned archs) stays resident.

Grid: (B, H, S / block_q) — one q tile per program, scanning kv blocks.
The kv block index range is causally clipped per q tile (no wasted
blocks above the diagonal); sliding windows additionally clip from
below.  VMEM footprint per program:
    q tile        block_q x D           (bf16/f32)
    k/v tiles     2 x block_k x D
    score tile    block_q x block_k     (f32)
    accumulators  block_q x (D + 2)     (f32)
With block_q = block_k = 128, D = 128: ~230 KB — comfortably in the
~16 MB/core VMEM with headroom for double-buffered pipelines.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -2.0e38


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, *, scale, block_q, block_k,
               seq_k, causal, window, q_offset):
    qi = pl.program_id(2)
    q = q_ref[...].astype(jnp.float32) * scale          # (bq, D)
    bq, d = q.shape

    m = jnp.full((bq,), NEG_INF, jnp.float32)
    l = jnp.zeros((bq,), jnp.float32)
    acc = jnp.zeros((bq, d), jnp.float32)

    q_pos = qi * block_q + jax.lax.iota(jnp.int32, bq) + q_offset

    # causal clip: kv blocks strictly above the diagonal are never read
    n_blocks = seq_k // block_k
    if causal:
        hi = jnp.minimum((q_pos[-1] // block_k) + 1, n_blocks)
    else:
        hi = n_blocks
    lo = 0
    if window:
        lo = jnp.maximum((q_pos[0] - window + 1) // block_k, 0)

    def body(kv_i, carry):
        m, l, acc = carry
        k = pl.load(k_ref, (pl.dslice(kv_i * block_k, block_k),
                            slice(None))).astype(jnp.float32)
        v = pl.load(v_ref, (pl.dslice(kv_i * block_k, block_k),
                            slice(None))).astype(jnp.float32)
        s = q @ k.T                                      # (bq, bk)
        k_pos = kv_i * block_k + jax.lax.iota(jnp.int32, block_k)
        keep = jnp.ones((bq, block_k), bool)
        if causal:
            keep &= k_pos[None, :] <= q_pos[:, None]
        if window:
            keep &= k_pos[None, :] > (q_pos[:, None] - window)
        s = jnp.where(keep, s, NEG_INF)

        m_new = jnp.maximum(m, jnp.max(s, axis=1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_new = l * alpha + jnp.sum(p, axis=1)
        acc_new = acc * alpha[:, None] + p @ v
        return m_new, l_new, acc_new

    m, l, acc = jax.lax.fori_loop(lo, hi, body, (m, l, acc))
    o_ref[...] = (acc / l[:, None]).astype(o_ref.dtype)


def flash_attention_fwd(q, k, v, *, causal: bool = True, window: int = 0,
                        scale=None, block_q: int = 128,
                        block_k: int = 128, interpret: bool = True):
    """q: (B, H, S, D); k/v: (B, K, T, D). Returns (B, H, S, D).

    GQA: each program reads the kv head ``h // group``.  The q sequence is
    right-aligned against the kv sequence (prefill convention).
    """
    B, H, S, D = q.shape
    K, T = k.shape[1], k.shape[2]
    group = H // K
    scale = D ** -0.5 if scale is None else scale
    block_q = min(block_q, S)
    block_k = min(block_k, T)
    assert S % block_q == 0 and T % block_k == 0

    grid = (B, H, S // block_q)
    kernel = functools.partial(
        _fa_kernel, scale=scale, block_q=block_q, block_k=block_k,
        seq_k=T, causal=causal, window=window, q_offset=T - S)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, None, block_q, D),
                         lambda b, h, i: (b, h, i, 0)),
            pl.BlockSpec((None, None, T, D),
                         lambda b, h, i: (b, h // group, 0, 0)),
            pl.BlockSpec((None, None, T, D),
                         lambda b, h, i: (b, h // group, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, None, block_q, D),
                               lambda b, h, i: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, S, D), q.dtype),
        interpret=interpret,
    )(q, k, v)
