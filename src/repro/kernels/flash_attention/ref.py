"""Pure-jnp oracle for the flash-attention kernel.

Computes exact causal (optionally sliding-window) GQA attention for one
batch of heads.  Shapes follow the kernel's layout:
    q: (B, H, S, D);  k, v: (B, K, T, D)  with H = K * group.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -2.0e38


def attention_ref(q, k, v, *, causal: bool = True, window: int = 0,
                  scale: float | None = None):
    B, H, S, D = q.shape
    K = k.shape[1]
    group = H // K
    scale = D ** -0.5 if scale is None else scale
    k_rep = jnp.repeat(k, group, axis=1)
    v_rep = jnp.repeat(v, group, axis=1)
    logits = jnp.einsum("bhsd,bhtd->bhst", q.astype(jnp.float32),
                        k_rep.astype(jnp.float32)) * scale
    T = k.shape[2]
    q_pos = jnp.arange(S)[:, None] + (T - S)      # align ends (prefill)
    k_pos = jnp.arange(T)[None, :]
    keep = jnp.ones((S, T), bool)
    if causal:
        keep &= k_pos <= q_pos
    if window:
        keep &= k_pos > (q_pos - window)
    logits = jnp.where(keep, logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhst,bhtd->bhsd", p,
                      v_rep.astype(jnp.float32)).astype(q.dtype)
