"""Jitted public wrapper for the flash-attention kernel.

``flash_attention`` dispatches to the Pallas kernel (interpret-mode on
CPU, compiled on TPU) or the jnp oracle; the model's attention layer can
call this with ``use_kernel=True`` on TPU deployments.
"""
from __future__ import annotations

from functools import partial

import jax

from repro.kernels.flash_attention.kernel import flash_attention_fwd
from repro.kernels.flash_attention.ref import attention_ref


@partial(jax.jit, static_argnames=("causal", "window", "use_kernel",
                                   "block_q", "block_k", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    use_kernel: bool = True, block_q: int = 128,
                    block_k: int = 128, interpret: bool = True):
    if not use_kernel:
        return attention_ref(q, k, v, causal=causal, window=window)
    return flash_attention_fwd(q, k, v, causal=causal, window=window,
                               block_q=block_q, block_k=block_k,
                               interpret=interpret)
