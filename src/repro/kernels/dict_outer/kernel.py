"""Fused SW^T / WW^T outer products — Pallas TPU kernel.

The SCDL dictionary update (paper Eq. 6-7) reduces S^T W (P x A) and
W^T W (A x A) over the sample axis K ~ 40k every iteration.  Doing the
two einsums separately streams W from HBM twice; the fused kernel reads
each (block_k x A) code tile once and feeds BOTH accumulators while the
tile is in VMEM — the arithmetic-intensity fix for the use case's
dominant reduction (and the local half of the paper's step-9 map-reduce;
the psum over shards happens outside).

:func:`dict_outer_pair_fwd` extends this to Algorithm 2's coupled
high/low-resolution pairs: one grid pass over K accumulates all four
reductions (Sh^T Wh, Sl^T Wl, Wh^T Wh, Wl^T Wl), so each code tile is
read from HBM exactly once per iteration instead of twice per pair.

Grid: (K / block_k,) sequential accumulation into VMEM-resident (P, A)
and (A, A) fp32 accumulators (dimension_semantics: arbitrary — the
revisit order is the accumulation).  VMEM bound: the accumulators must
fit on-chip — (P+A) x A x 4 B for the single kernel, (P+M+2A) x A x 4 B
for the pair — which holds through the paper's default A = 512
(~2.3 MB / ~4.3 MB at the GS shape) but NOT at its A = 2056 sweep
point; an A-axis-blocked variant would be needed there.  Sample counts
that don't divide ``block_k`` are zero-padded up to a whole block (zero
rows contribute nothing to either accumulator).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import auto_interpret, pad_leading


def _outer_kernel(s_ref, w_ref, sw_ref, ww_ref):
    ki = pl.program_id(0)
    s = s_ref[...].astype(jnp.float32)                  # (bk, P)
    w = w_ref[...].astype(jnp.float32)                  # (bk, A_blk)

    @pl.when(ki == 0)
    def _init():
        sw_ref[...] = jnp.zeros_like(sw_ref)
        ww_ref[...] = jnp.zeros_like(ww_ref)

    sw_ref[...] += s.T @ w
    ww_ref[...] += w.T @ w


def dict_outer_fwd(S, W, *, block_k: int = 512, interpret=None):
    """S: (K, P); W: (K, A). Returns (S^T W (P, A), W^T W (A, A)) fp32."""
    if interpret is None:
        interpret = auto_interpret()
    K, P = S.shape
    A = W.shape[1]
    block_k = min(block_k, K)
    (S, W), k_full = pad_leading([S, W], block_k)

    return pl.pallas_call(
        _outer_kernel,
        grid=(k_full // block_k,),
        in_specs=[
            pl.BlockSpec((block_k, P), lambda i: (i, 0)),
            pl.BlockSpec((block_k, A), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((P, A), lambda i: (0, 0)),
            pl.BlockSpec((A, A), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((P, A), jnp.float32),
            jax.ShapeDtypeStruct((A, A), jnp.float32),
        ],
        interpret=interpret,
    )(S, W)


def _outer_pair_kernel(sh_ref, sl_ref, wh_ref, wl_ref,
                       shwh_ref, slwl_ref, ph_ref, pll_ref):
    ki = pl.program_id(0)
    sh = sh_ref[...].astype(jnp.float32)                # (bk, P)
    sl = sl_ref[...].astype(jnp.float32)                # (bk, M)
    wh = wh_ref[...].astype(jnp.float32)                # (bk, A)
    wl = wl_ref[...].astype(jnp.float32)                # (bk, A)

    @pl.when(ki == 0)
    def _init():
        shwh_ref[...] = jnp.zeros_like(shwh_ref)
        slwl_ref[...] = jnp.zeros_like(slwl_ref)
        ph_ref[...] = jnp.zeros_like(ph_ref)
        pll_ref[...] = jnp.zeros_like(pll_ref)

    # each W tile feeds both of its accumulators while resident in VMEM
    shwh_ref[...] += sh.T @ wh
    ph_ref[...] += wh.T @ wh
    slwl_ref[...] += sl.T @ wl
    pll_ref[...] += wl.T @ wl


def dict_outer_pair_fwd(Sh, Sl, Wh, Wl, *, block_k: int = 512,
                        interpret=None):
    """Coupled-pair fusion: Sh (K, P), Sl (K, M), Wh/Wl (K, A) ->
    (Sh^T Wh (P, A), Sl^T Wl (M, A), Wh^T Wh, Wl^T Wl (A, A)) fp32."""
    if interpret is None:
        interpret = auto_interpret()
    K, P = Sh.shape
    M = Sl.shape[1]
    A = Wh.shape[1]
    block_k = min(block_k, K)
    (Sh, Sl, Wh, Wl), k_full = pad_leading([Sh, Sl, Wh, Wl], block_k)

    return pl.pallas_call(
        _outer_pair_kernel,
        grid=(k_full // block_k,),
        in_specs=[
            pl.BlockSpec((block_k, P), lambda i: (i, 0)),
            pl.BlockSpec((block_k, M), lambda i: (i, 0)),
            pl.BlockSpec((block_k, A), lambda i: (i, 0)),
            pl.BlockSpec((block_k, A), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((P, A), lambda i: (0, 0)),
            pl.BlockSpec((M, A), lambda i: (0, 0)),
            pl.BlockSpec((A, A), lambda i: (0, 0)),
            pl.BlockSpec((A, A), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((P, A), jnp.float32),
            jax.ShapeDtypeStruct((M, A), jnp.float32),
            jax.ShapeDtypeStruct((A, A), jnp.float32),
            jax.ShapeDtypeStruct((A, A), jnp.float32),
        ],
        interpret=interpret,
    )(Sh, Sl, Wh, Wl)
