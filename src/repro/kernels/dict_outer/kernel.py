"""Fused SW^T / WW^T outer products — Pallas TPU kernel.

The SCDL dictionary update (paper Eq. 6-7) reduces S^T W (P x A) and
W^T W (A x A) over the sample axis K ~ 40k every iteration.  Doing the
two einsums separately streams W from HBM twice; the fused kernel reads
each (block_k x A) code tile once and feeds BOTH accumulators while the
tile is in VMEM — the arithmetic-intensity fix for the use case's
dominant reduction (and the local half of the paper's step-9 map-reduce;
the psum over shards happens outside).

Grid: (K / block_k,) sequential accumulation into VMEM-resident (P, A)
and (A, A) fp32 accumulators (dimension_semantics: arbitrary — the
revisit order is the accumulation).  A <= 2056 pads to 2176 lanes;
P <= 289 rows. VMEM: acc tiles (P+A) x A x 4 B ~ 19 MB at the GS
maximum — block the A axis at 1024 when above (ops.py picks).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _outer_kernel(s_ref, w_ref, sw_ref, ww_ref):
    ki = pl.program_id(0)
    s = s_ref[...].astype(jnp.float32)                  # (bk, P)
    w = w_ref[...].astype(jnp.float32)                  # (bk, A_blk)

    @pl.when(ki == 0)
    def _init():
        sw_ref[...] = jnp.zeros_like(sw_ref)
        ww_ref[...] = jnp.zeros_like(ww_ref)

    sw_ref[...] += s.T @ w
    ww_ref[...] += w.T @ w


def dict_outer_fwd(S, W, *, block_k: int = 512, interpret: bool = True):
    """S: (K, P); W: (K, A). Returns (S^T W (P, A), W^T W (A, A)) fp32."""
    K, P = S.shape
    A = W.shape[1]
    block_k = min(block_k, K)
    assert K % block_k == 0

    return pl.pallas_call(
        _outer_kernel,
        grid=(K // block_k,),
        in_specs=[
            pl.BlockSpec((block_k, P), lambda i: (i, 0)),
            pl.BlockSpec((block_k, A), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((P, A), lambda i: (0, 0)),
            pl.BlockSpec((A, A), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((P, A), jnp.float32),
            jax.ShapeDtypeStruct((A, A), jnp.float32),
        ],
        interpret=interpret,
    )(S, W)
