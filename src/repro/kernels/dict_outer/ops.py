"""Public wrappers for the fused dictionary outer products.

``use_kernel=None`` auto-selects: the Pallas kernel where it compiles to
Mosaic (TPU), the pure-jnp oracle elsewhere — on CPU/GPU hosts XLA's own
GEMM fusion beats running the kernel through the interpreter inside the
training scan.  Tests pass ``use_kernel=True`` to exercise the kernel in
interpreter mode on any backend.

The kernel path routes through ``kernels.common.degraded_call``, so a
Pallas failure degrades the ``dict_outer`` family compiled → interpret
→ ref once per process with a recorded warning (DESIGN.md §18).
"""
from __future__ import annotations

from functools import partial

import jax

from repro.kernels.common import auto_interpret, degraded_call
from repro.kernels.dict_outer.kernel import (dict_outer_fwd,
                                             dict_outer_pair_fwd)
from repro.kernels.dict_outer.ref import dict_outer_pair_ref, dict_outer_ref

FAMILY = "dict_outer"


@partial(jax.jit, static_argnames=("block_k", "interpret"))
def _outer_kernel(S, W, *, block_k: int, interpret: bool):
    return dict_outer_fwd(S, W, block_k=block_k, interpret=interpret)


_outer_ref = jax.jit(dict_outer_ref)


def dict_outer(S, W, *, use_kernel=None, block_k: int = 512,
               interpret=None):
    if use_kernel is None:
        use_kernel = not auto_interpret()
    if not use_kernel:
        return _outer_ref(S, W)
    return degraded_call(
        FAMILY,
        kernel=lambda interp: _outer_kernel(S, W, block_k=block_k,
                                            interpret=interp),
        ref=lambda: _outer_ref(S, W),
        requested_interpret=interpret)


@partial(jax.jit, static_argnames=("block_k", "interpret"))
def _pair_kernel(Sh, Sl, Wh, Wl, *, block_k: int, interpret: bool):
    return dict_outer_pair_fwd(Sh, Sl, Wh, Wl, block_k=block_k,
                               interpret=interpret)


_pair_ref = jax.jit(dict_outer_pair_ref)


def dict_outer_pair(Sh, Sl, Wh, Wl, *, use_kernel=None,
                    block_k: int = 512, interpret=None):
    """One pass over the coupled pair: (Sh^T Wh, Sl^T Wl, phi_h, phi_l)."""
    if use_kernel is None:
        use_kernel = not auto_interpret()
    if not use_kernel:
        return _pair_ref(Sh, Sl, Wh, Wl)
    return degraded_call(
        FAMILY,
        kernel=lambda interp: _pair_kernel(Sh, Sl, Wh, Wl,
                                           block_k=block_k,
                                           interpret=interp),
        ref=lambda: _pair_ref(Sh, Sl, Wh, Wl),
        requested_interpret=interpret)
