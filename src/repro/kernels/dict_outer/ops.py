"""Jitted public wrapper for the fused dictionary outer products."""
from __future__ import annotations

from functools import partial

import jax

from repro.kernels.dict_outer.kernel import dict_outer_fwd
from repro.kernels.dict_outer.ref import dict_outer_ref


@partial(jax.jit, static_argnames=("use_kernel", "block_k", "interpret"))
def dict_outer(S, W, *, use_kernel: bool = True, block_k: int = 512,
               interpret: bool = True):
    if not use_kernel:
        return dict_outer_ref(S, W)
    return dict_outer_fwd(S, W, block_k=block_k, interpret=interpret)
