"""Jitted public wrappers for the fused dictionary outer products.

``use_kernel=None`` auto-selects: the Pallas kernel where it compiles to
Mosaic (TPU), the pure-jnp oracle elsewhere — on CPU/GPU hosts XLA's own
GEMM fusion beats running the kernel through the interpreter inside the
training scan.  Tests pass ``use_kernel=True`` to exercise the kernel in
interpreter mode on any backend.
"""
from __future__ import annotations

from functools import partial

import jax

from repro.kernels.common import auto_interpret
from repro.kernels.dict_outer.kernel import (dict_outer_fwd,
                                             dict_outer_pair_fwd)
from repro.kernels.dict_outer.ref import dict_outer_pair_ref, dict_outer_ref


@partial(jax.jit, static_argnames=("use_kernel", "block_k", "interpret"))
def dict_outer(S, W, *, use_kernel=None, block_k: int = 512,
               interpret=None):
    if use_kernel is None:
        use_kernel = not auto_interpret()
    if not use_kernel:
        return dict_outer_ref(S, W)
    return dict_outer_fwd(S, W, block_k=block_k, interpret=interpret)


@partial(jax.jit, static_argnames=("use_kernel", "block_k", "interpret"))
def dict_outer_pair(Sh, Sl, Wh, Wl, *, use_kernel=None,
                    block_k: int = 512, interpret=None):
    """One pass over the coupled pair: (Sh^T Wh, Sl^T Wl, phi_h, phi_l)."""
    if use_kernel is None:
        use_kernel = not auto_interpret()
    if not use_kernel:
        return dict_outer_pair_ref(Sh, Sl, Wh, Wl)
    return dict_outer_pair_fwd(Sh, Sl, Wh, Wl, block_k=block_k,
                               interpret=interpret)
