"""Oracle for the fused SCDL outer-product accumulation (Algorithm 2,
step 9): given a sample block S (K, P) and codes W (K, A), produce
S^T W (P, A) and W^T W (A, A) in fp32.  ``dict_outer_pair_ref`` is the
coupled high/low-resolution variant the dictionary update consumes."""
from __future__ import annotations

import jax.numpy as jnp


def dict_outer_ref(S, W):
    Sf = S.astype(jnp.float32)
    Wf = W.astype(jnp.float32)
    return Sf.T @ Wf, Wf.T @ Wf


def dict_outer_pair_ref(Sh, Sl, Wh, Wl):
    ShWh, phi_h = dict_outer_ref(Sh, Wh)
    SlWl, phi_l = dict_outer_ref(Sl, Wl)
    return ShWh, SlWl, phi_h, phi_l
