"""Oracle for the fused SCDL outer-product accumulation (Algorithm 2,
step 9): given a sample block S (K, P) and codes W (K, A), produce
S^T W (P, A) and W^T W (A, A) in fp32."""
from __future__ import annotations

import jax.numpy as jnp


def dict_outer_ref(S, W):
    Sf = S.astype(jnp.float32)
    Wf = W.astype(jnp.float32)
    return Sf.T @ Wf, Wf.T @ Wf
