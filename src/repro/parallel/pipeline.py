"""GPipe-style pipeline parallelism over a `stage` mesh axis.

For very deep assigned archs (granite-34b: 88 layers) pure TP+DP leaves
the per-chip parameter floor high; an optional pipeline axis splits the
layer stack into S stages of L/S layers, microbatches flowing through a
collective-permute ring.

Implementation: the classic shard_map schedule —
  - params stacked (S, L/S, ...): stage axis sharded over 'stage';
  - loop t in [0, M + S - 1): each stage applies its block to its
    current microbatch (bubble masked), then the activations
    collective-permute to the next stage;
  - loss computed on the last stage, grads flow back through the
    transposed permutes automatically (shard_map AD).

This module is deliberately self-contained (used by tests and the
granite-34b §Perf experiments); the dry-run default path remains DP x TP.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Callable, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh
from jax.sharding import PartitionSpec as P

from repro.core.compat import axis_size, shard_map


def pipeline_apply(layer_fn: Callable, stage_params, x_micro, *,
                   stage_axis: str = "stage"):
    """Run microbatches through the pipeline ring (inside shard_map).

    layer_fn(params_block, x) -> x : applies one stage's layer block.
    stage_params: this stage's (L/S, ...) param slice.
    x_micro: (M, mb, ...) all microbatches, resident on every stage
        (stage 0 consumes them in order; later stages ignore the feed and
        use the ring input).
    Returns (M, mb, ...) outputs as produced by the LAST stage, rolled
    back into order.
    """
    n_stage = axis_size(stage_axis)
    stage_id = jax.lax.axis_index(stage_axis)
    M = x_micro.shape[0]
    mb_shape = x_micro.shape[1:]
    total = M + n_stage - 1

    def body(t, carry):
        ring, outputs = carry
        # stage 0 ingests microbatch t (if in range), others take ring
        feed_idx = jnp.clip(t, 0, M - 1)
        feed = x_micro[feed_idx]
        x_in = jnp.where(stage_id == 0, feed, ring)
        y = layer_fn(stage_params, x_in)
        # last stage records its output at slot (t - n_stage + 1)
        out_idx = jnp.clip(t - (n_stage - 1), 0, M - 1)
        is_valid = (t >= n_stage - 1)
        outputs = jax.lax.cond(
            is_valid & (stage_id == n_stage - 1),
            lambda o: jax.lax.dynamic_update_index_in_dim(
                o, y, out_idx, 0),
            lambda o: o, outputs)
        # rotate activations stage i -> i+1
        ring_next = jax.lax.ppermute(
            y, stage_axis,
            [(i, (i + 1) % n_stage) for i in range(n_stage)])
        return ring_next, outputs

    ring0 = jnp.zeros(mb_shape, x_micro.dtype)
    outputs0 = jnp.zeros((M,) + mb_shape, x_micro.dtype)
    _, outputs = jax.lax.fori_loop(0, total, body, (ring0, outputs0))
    # every stage returns `outputs`; only the last stage's is real — make
    # it consistent across the axis for the out_spec
    outputs = jax.lax.psum(
        jnp.where(stage_id == n_stage - 1, outputs, 0.0), stage_axis)
    return outputs


def make_pipelined_forward(layer_fn: Callable, mesh: Mesh, *,
                           n_micro: int, stage_axis: str = "stage",
                           data_axes=("data",)):
    """Build forward(params_staged, x) with pipeline+data parallelism.

    params_staged leaves: (S, L/S, ...) — S sharded over `stage`.
    x: (B, ...) with B % n_micro == 0; microbatch dim scanned through
    the ring.
    """
    def fwd(params_staged, x):
        def local(pstage, xloc):
            M = n_micro
            xm = xloc.reshape((M, xloc.shape[0] // M) + xloc.shape[1:])
            pstage = jax.tree.map(lambda a: a[0], pstage)  # drop stage dim
            ym = pipeline_apply(layer_fn, pstage, xm,
                                stage_axis=stage_axis)
            return ym.reshape(xloc.shape)

        pspec = jax.tree.map(lambda _: P(stage_axis), params_staged)
        xspec = P(data_axes)
        return shard_map(local, mesh=mesh,
                             in_specs=(pspec, xspec),
                             out_specs=xspec, check_vma=False)(
            params_staged, x)

    return fwd
