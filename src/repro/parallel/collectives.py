"""Distributed-optimization collectives: hierarchical reduction and
int8 error-feedback gradient compression.

At 2+ pods the data-parallel gradient reduction crosses the inter-pod
DCI links, which are far slower than intra-pod ICI.  Two standard tricks,
both expressed as pure shard_map functions so they compose with the
trainer:

  hierarchical_psum : reduce-scatter within the pod, all-reduce the
      scattered shard across pods (1/pod_size of the bytes on the slow
      link), all-gather within the pod — the classic 2-level schedule.

  CompressedReducer : int8 quantisation with error feedback for the
      cross-pod hop.  The quantisation residual is carried to the next
      step (EF-SGD), keeping convergence unbiased to first order; the
      scale factor is per-tensor.  Compression is applied only on the
      `pod` axis where bandwidth is scarce.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh
from jax.sharding import PartitionSpec as P

from repro.core.compat import axis_size


def hierarchical_psum_local(x, *, pod_axis: str = "pod",
                            data_axis: str = "data"):
    """2-level mean-reduction, callable inside shard_map.

    Equivalent to psum over (pod, data) but scheduled as
    reduce_scatter(data) -> psum(pod) -> all_gather(data): the inter-pod
    link carries 1/data_size of the tensor.
    """
    n = x.shape[0]
    data_size = axis_size(data_axis)
    if n % data_size == 0:
        shard = jax.lax.psum_scatter(x, data_axis, scatter_dimension=0,
                                     tiled=True)
        shard = jax.lax.psum(shard, pod_axis)
        return jax.lax.all_gather(shard, data_axis, axis=0, tiled=True)
    # ragged first dim: fall back to flat psum
    return jax.lax.psum(jax.lax.psum(x, data_axis), pod_axis)


def quantize_int8(x) -> Tuple[jax.Array, jax.Array]:
    """Symmetric per-tensor int8 quantisation."""
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q, scale):
    return q.astype(jnp.float32) * scale


def compressed_cross_pod_mean(x, error, *, pod_axis: str = "pod"):
    """EF-int8 mean over the pod axis (inside shard_map).

    Returns (mean_estimate, new_error).  The residual (what int8 lost)
    is added back to next step's tensor before quantising — standard
    error feedback.
    """
    pod_size = axis_size(pod_axis)
    corrected = x + error
    q, scale = quantize_int8(corrected)
    decoded = dequantize_int8(q, scale)
    new_error = corrected - decoded
    # int8 payload all-reduce: sum of dequantised views (the wire format
    # would be int8 + one f32 scale per pod; jax models the math)
    summed = jax.lax.psum(decoded, pod_axis)
    return summed / pod_size, new_error


class CompressedReducer:
    """Gradient reducer with persistent error-feedback state.

    Usage in the trainer (per step, inside shard_map over ('pod','data')):
        mean_g, ef = reducer.reduce(g, ef)
    """

    def __init__(self, mesh: Mesh, *, pod_axis: str = "pod",
                 data_axis: str = "data"):
        self.mesh = mesh
        self.pod_axis = pod_axis
        self.data_axis = data_axis

    def init_error(self, grads):
        return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32),
                            grads)

    def reduce_local(self, grads, error):
        """Inside shard_map: intra-pod exact mean, cross-pod EF-int8."""
        def one(g, e):
            g = jax.lax.pmean(g, self.data_axis)
            if self.pod_axis in self.mesh.shape:
                return compressed_cross_pod_mean(g, e,
                                                 pod_axis=self.pod_axis)
            return g, e
        flat_g, td = jax.tree.flatten(grads)
        flat_e = td.flatten_up_to(error)
        out = [one(g, e) for g, e in zip(flat_g, flat_e)]
        return (jax.tree.unflatten(td, [o[0] for o in out]),
                jax.tree.unflatten(td, [o[1] for o in out]))
