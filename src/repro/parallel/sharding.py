"""Sharding rules: how every parameter / activation / cache maps onto the
production mesh (pod, data, model).

The rules are *functions of the config*, not hand-written per arch:
  - attention projections are head-sharded over `model` iff the head count
    divides the model-axis size (hymba's 25 heads and granite-moe's 24
    don't — those attentions run with replicated weights and the model
    axis is carried by the mamba/MoE branch instead; see DESIGN.md §6);
  - KV projections shard iff n_kv_heads divides (MQA/GQA-2 replicate);
  - MoE experts shard over `model` (expert parallelism), padded up;
  - mamba inner channels shard over `model`;
  - batch shards over (pod, data); for batch-1 long-context decode the KV
    cache sequence axis shards over (pod, data) instead (sequence
    parallelism for the decode read).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig


@dataclass(frozen=True)
class MeshRules:
    mesh: Optional[Mesh]
    tp_axis: str = "model"
    dp_axes: Tuple[str, ...] = ("pod", "data")
    shard_cache_seq: bool = False     # long_500k: shard KV seq over dp
    seq_shard_activations: bool = False  # SP stash: shard residual d over tp
    fsdp: bool = False                # ZeRO-3: shard params over dp too
    dp_only: bool = False             # small-model remap: batch over ALL
    #   mesh axes, params replicated (no TP) — §Perf/D.  FSDP composes.

    @property
    def tp(self) -> int:
        if self.mesh is None or self.dp_only:
            return 1
        return self.mesh.shape[self.tp_axis]

    @property
    def t_ax(self) -> Optional[str]:
        """tp axis name for activation specs (None under dp_only)."""
        return None if self.dp_only else self.tp_axis

    @property
    def dp(self) -> Tuple[str, ...]:
        """dp axes actually present in the mesh (single-pod has no 'pod')."""
        if self.mesh is None:
            return ()
        axes = self.dp_axes + ((self.tp_axis,) if self.dp_only else ())
        return tuple(a for a in axes if a in self.mesh.shape)

    @property
    def dp_size(self) -> int:
        if self.mesh is None:
            return 1
        n = 1
        for a in self.dp:
            n *= self.mesh.shape[a]
        return n

    def sharding(self, spec: P) -> Optional[NamedSharding]:
        if self.mesh is None:
            return None
        return NamedSharding(self.mesh, spec)

    def cs(self, x, spec: P):
        """with_sharding_constraint when a mesh is present, else identity."""
        if self.mesh is None:
            return x
        return jax.lax.with_sharding_constraint(x, self.sharding(spec))

    # ---------------- canonical activation specs ----------------------
    def batch_spec(self, extra_dims: int = 1) -> P:
        dp = self.dp
        return P(dp if dp else None, *([None] * extra_dims))

    def act_spec(self, cfg: ModelConfig) -> P:
        """Residual stream (B, S, d)."""
        dp = self.dp
        d_ax = (self.tp_axis if self.seq_shard_activations
                and not self.dp_only and
                cfg.d_model % max(self.tp, 1) == 0 else None)
        return P(dp if dp else None, None, d_ax)


def head_shardable(n_heads: int, tp: int) -> bool:
    return n_heads > 0 and n_heads % tp == 0


def for_mesh(mesh: Optional[Mesh], **kw) -> MeshRules:
    return MeshRules(mesh=mesh, **kw)


# ---------------------------------------------------------------------
# Parameter partition specs, by path
# ---------------------------------------------------------------------

def param_pspecs(cfg: ModelConfig, rules: MeshRules, params_tree):
    """PartitionSpec pytree matching ``params_tree`` (shapes or arrays).

    Leaf dispatch is by dict path; every leaf under "layers" carries a
    leading stacked-layer axis (never sharded).
    """
    tp = rules.tp
    t = rules.tp_axis if not rules.dp_only else None
    heads_ok = head_shardable(cfg.n_heads, tp) and t is not None
    kv_ok = head_shardable(cfg.n_kv_heads, tp) and t is not None

    def spec_for(path: Tuple[str, ...], ndim: int) -> P:
        name = path[-1]
        in_layers = "layers" in path
        L = (None,) if in_layers else ()

        if name == "embed":
            # vocab-sharded in both tied and untied cases: the lookup
            # becomes a masked-gather + all-reduce of (tokens, d) — small
            # next to TP reductions — while the d-sharded alternative
            # trips an XLA SPMD partitioner bug (invalid dynamic-slice)
            # when combined with sequence-sharded activations.
            return P(t, None)
        if name == "head":
            return P(None, t)               # logits vocab-sharded
        if "norm" in name or name in ("ln1", "ln2"):
            return P(*L, *([None] * (ndim - len(L))))
        if name in ("conv_b", "dt_bias", "D"):   # (L, dI): shard channels
            return P(*L, t)
        # attention
        if name == "wq":
            return P(*L, None, t if heads_ok else None)
        if name in ("wk", "wv"):
            return P(*L, None, t if kv_ok else None)
        if name == "wo":
            return P(*L, t if heads_ok else None, None)
        # mamba (dI always divides tp: dI = 2*d_model, d_model % tp == 0)
        if name == "in_proj":
            return P(*L, None, t)
        if name == "conv_w":
            return P(*L, None, t)
        if name == "x_proj":
            return P(*L, t, None)
        if name == "dt_proj":
            return P(*L, None, t)
        if name == "A_log":
            return P(*L, t, None)
        if name == "out_proj":
            return P(*L, t, None)
        # moe
        if name == "router":
            return P(*L, None, None)
        if name in ("we1", "we3", "we2"):
            return P(*L, t, None, None)     # expert-parallel
        if name in ("ws1", "ws3"):
            return P(*L, None, t)
        if name == "ws2":
            return P(*L, t, None)
        # dense ffn
        if name in ("w1", "w3"):
            return P(*L, None, t)
        if name == "w2":
            return P(*L, t, None)
        raise ValueError(f"no sharding rule for param {'/'.join(path)}")

    def fsdp_refine(spec: P, shape) -> P:
        """ZeRO-3/FSDP: additionally shard the largest still-free,
        dp-divisible dim of every big leaf over the data axes (falling
        back to a single dp axis for odd dims — see optim.zero_assign).
        XLA inserts the per-layer all-gather (params) and reduce-scatter
        (grads) this implies."""
        from repro.optim.adamw import zero_assign
        dims = shape.shape if hasattr(shape, "shape") else shape
        n_elems = 1
        for d in dims:
            n_elems *= d
        if n_elems < (1 << 20) or not rules.dp:  # small leaves replicate
            return spec
        parts = list(spec) + [None] * (len(dims) - len(spec))
        zero_assign(parts, dims, rules.dp,
                    dict(rules.mesh.shape) if rules.mesh else None)
        return P(*parts)

    def walk(node, path):
        if isinstance(node, dict):
            return {k: walk(v, path + (k,)) for k, v in node.items()}
        if node is None:
            return None
        if hasattr(node, "_fields"):        # NamedTuple
            return type(node)(*(walk(getattr(node, f), path + (f,))
                                for f in node._fields))
        spec = spec_for(path, len(node.shape))
        if rules.fsdp and "layers" in path:
            spec = fsdp_refine(spec, node)
        return spec

    return walk(params_tree, ())


def cache_pspecs(cfg: ModelConfig, rules: MeshRules, cache_tree,
                 batch_size: int):
    """Specs for the decode cache {k, v, conv, ssm} (leading layer axis)."""
    t = rules.tp_axis if not rules.dp_only else None
    dp = rules.dp
    kv_ok = head_shardable(cfg.n_kv_heads, rules.tp) and t is not None
    batch_ok = dp and batch_size % max(rules.dp_size, 1) == 0
    b_ax = dp if batch_ok else None
    seq_ax = dp if (rules.shard_cache_seq and not batch_ok) else None

    specs = {}
    for name, leaf in cache_tree.items():
        if leaf is None:
            specs[name] = None
        elif name in ("k", "v"):            # (L, B, T, K, hd)
            if kv_ok:
                kv_ax, t_seq = t, None
            else:
                # kv heads don't divide the model axis (MQA/GQA-2/8):
                # shard the SEQUENCE axis over `model` instead — split-KV
                # flash-decode semantics; XLA reduces the partial
                # softmaxes over the axis.  Otherwise a 32k cache
                # replicates 16x and blows HBM.
                kv_ax, t_seq = None, t
            specs[name] = P(None, b_ax, seq_ax or t_seq, kv_ax, None)
        elif name in ("k_scale", "v_scale"):  # (L, B, T, K)
            kv_ax2, t_seq2 = (t, None) if kv_ok else (None, t)
            specs[name] = P(None, b_ax, seq_ax or t_seq2, kv_ax2)
        elif name == "conv":                # (L, B, dc-1, dI)
            specs[name] = P(None, b_ax, None, t)
        elif name == "ssm":                 # (L, B, dI, dS)
            specs[name] = P(None, b_ax, t, None)
        else:
            raise ValueError(name)
    return specs
