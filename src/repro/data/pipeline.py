"""Host->device input pipeline with prefetch and sharded placement.

A production loader: a background thread generates/loads the next
batches while the device computes, and each batch is device_put with the
global batch sharding so every host only materialises its addressable
shards (here: single host, full arrays; the placement API is the same).
"""
from __future__ import annotations

import queue
import threading
from typing import Callable, Dict, Iterator, Optional

import jax

from repro.configs.base import ModelConfig
from repro.data.synthetic import lm_batch
from repro.parallel.sharding import MeshRules


class PrefetchLoader:
    """Wrap a ``make_batch(step) -> pytree`` fn with N-deep prefetch."""

    def __init__(self, make_batch: Callable[[int], Dict], rules: MeshRules,
                 *, depth: int = 2, start_step: int = 0):
        self.make_batch = make_batch
        self.rules = rules
        self.depth = depth
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._step = start_step
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _place(self, batch):
        if self.rules.mesh is None:
            return batch
        shd = self.rules.sharding(self.rules.batch_spec(1))
        shd3 = self.rules.sharding(self.rules.batch_spec(2))
        return {k: jax.device_put(v, shd3 if v.ndim == 3 else shd)
                for k, v in batch.items()}

    def _worker(self):
        while not self._stop.is_set():
            batch = self._place(self.make_batch(self._step))
            self._q.put((self._step, batch))
            self._step += 1

    def __iter__(self) -> Iterator:
        return self

    def __next__(self):
        return self._q.get()

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass


def lm_loader(cfg: ModelConfig, rules: MeshRules, *, batch: int, seq: int,
              seed: int = 0, start_step: int = 0, depth: int = 2
              ) -> PrefetchLoader:
    """Deterministic LM token loader; resume = pass ``start_step``."""
    return PrefetchLoader(
        lambda step: lm_batch(cfg, batch, seq, seed, step),
        rules, depth=depth, start_step=start_step)
