"""Deterministic synthetic data generators.

Everything is a pure function of (seed, step) — counter-based RNG via
``jax.random.fold_in`` — so a restarted (or re-sharded) run regenerates
the identical sample order: the determinism that makes checkpoint-replay
recovery bit-exact (DESIGN.md §2), and the stand-in for the paper's
non-redistributable datasets (§9).
"""
from __future__ import annotations

from typing import Dict, Iterator, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig


def lm_batch(cfg: ModelConfig, batch: int, seq: int, seed: int, step: int
             ) -> Dict[str, jax.Array]:
    """Markov-ish token stream: next-token structure a model can learn.

    tokens[t+1] = (a * tokens[t] + drift + noise) mod V — low-entropy
    transitions give a learnable signal (loss drops measurably within
    hundreds of steps at 10-100M scale).
    """
    key = jax.random.fold_in(jax.random.PRNGKey(seed), step)
    V = cfg.vocab_size
    k1, k2, k3 = jax.random.split(key, 3)
    start = jax.random.randint(k1, (batch, 1), 0, V)
    drift = jax.random.randint(k2, (batch, 1), 1, 7)
    noise = jax.random.bernoulli(k3, 0.05, (batch, seq + 1))
    ar = jnp.arange(seq + 1)[None, :]
    stream = (start + drift * ar + noise.cumsum(-1)) % V
    stream = stream.astype(jnp.int32)
    out = {"labels": stream[:, 1:]}
    if cfg.frontend == "embed":
        emb_key = jax.random.fold_in(jax.random.PRNGKey(seed + 1), step)
        out["embeds"] = 0.02 * jax.random.normal(
            emb_key, (batch, seq, cfg.d_model), jnp.float32)
    else:
        out["tokens"] = stream[:, :-1]
    return out


def coupled_patches(n: int, p_dim: int, m_dim: int, n_atoms: int,
                    seed: int = 0, sparsity: float = 0.08,
                    noise: float = 0.01) -> Tuple[jax.Array, jax.Array]:
    """Coupled HR/LR patch pairs for SCDL (HS: P=25/M=9, GS: P=289/M=81).

    HR patches are sparse combinations of a ground-truth dictionary; LR
    patches are a fixed blur/downsample projection of the HR ones — the
    'same statistical process under different resolution' assumption of
    the paper's Eq. (4).
    """
    key = jax.random.PRNGKey(seed)
    kd, kc, kr, kn = jax.random.split(key, 4)
    D = jax.random.normal(kd, (p_dim, n_atoms))
    D = D / jnp.linalg.norm(D, axis=0, keepdims=True)
    codes = jax.random.normal(kc, (n_atoms, n)) * \
        (jax.random.uniform(jax.random.fold_in(kc, 1),
                            (n_atoms, n)) < sparsity)
    S_h = D @ codes
    R = jax.random.normal(kr, (m_dim, p_dim)) / np.sqrt(p_dim)
    S_l = R @ S_h
    S_h = S_h + noise * jax.random.normal(kn, S_h.shape)
    S_l = S_l + noise * jax.random.normal(jax.random.fold_in(kn, 1),
                                          S_l.shape)
    return S_h.astype(jnp.float32), S_l.astype(jnp.float32)
