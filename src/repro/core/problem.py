"""Declarative workload API: declare a `Problem` once, `solve()` it.

The paper's architecture is one generic driver (configure ->
parallelize -> iterate) serving *variant* imaging workloads.  After the
engine grew chunked scans, broadcast carries and per-chunk objectives
(DESIGN.md §12/§13), expressing a workload meant hand-assembling up to
four step variants plus the driver kwargs wiring them together.  This
module collapses that to a declaration:

    class MyProblem(Problem):
        def init_bundle(self, inputs, mesh): ...   # phases (a)+(b)
        def full_step(self, d, rep, axes): ...     # phase (c), one iter
        # optional: light_step / cost / refresh_replicated

    sol = solve(MyProblem(cfg), *inputs, mesh=mesh, max_iter=100)

``solve()`` derives the entire driver wiring — scan-step vs
chunk-cost-step selection, broadcast-carry updates, light/cost variants,
checkpoint hooks — from which optional methods the Problem defines plus
its static metadata (``replicated_in_carry``, ``default_chunk``,
``default_cost_every``).  The derivation rules are spelled out in
DESIGN.md §14.

Workloads register under a string key (``@register("scdl")``); the
registry is importable as ``repro.problems`` and lazily imports the
built-in workloads, so ``solve("scdl", S_h, S_l)`` works without any
imaging import on the caller's side.
"""
from __future__ import annotations

import importlib
import warnings
from dataclasses import dataclass, replace
from typing import (Any, Callable, ClassVar, Dict, List, Optional, Tuple,
                    Type, Union)

import dataclasses

import jax
import numpy as np

from repro.core import batching, checks, persistence
from repro.core.bundle import Bundle, _dp_axes, gather
from repro.core.driver import (BatchedDriver, IterativeDriver, RunLog,
                               RunOptions)
from repro.resilience import chaos as _chaos

# --------------------------------------------------------------------
# The Problem declaration
# --------------------------------------------------------------------


class Problem:
    """One workload, declared once.

    Required hooks (phases of the paper's driver program):

    - ``init_bundle(inputs, mesh) -> Bundle`` — configuration +
      parallelization: build the co-partitioned bundle (and its
      replicated/broadcast side) from the raw input arrays.
    - ``full_step(d, rep, axes) -> (d', out)`` — one learning iteration
      over a local block; ``out`` is a scalar cost or a dict with a
      ``"cost"`` entry (plus any reduced state feeding
      ``refresh_replicated``).  Must psum over ``axes`` itself.

    Optional hooks (``None`` at class level means "not declared"; the
    wiring derivation in :func:`solve` keys off their presence):

    - ``light_step(d, rep, axes)`` — the same iteration without the
      objective evaluation.  Returns bare ``d'`` normally, or
      ``(d', out_partial)`` when ``replicated_in_carry`` is set.
      Enables ``cost_every > 1`` skipping.
    - ``cost(d, rep, axes) -> out`` — standalone objective over the
      *post-iteration* state.  Together with ``light_step`` it enables
      the fastest observability mode, ``cost_every="chunk"``
      (``engine.make_chunk_cost_step``).
    - ``refresh_replicated(rep, out) -> rep'`` — fold the reduced output
      back into the broadcast state each iteration (the paper's step-7
      driver broadcast, run inside the scan carry).

    Static metadata:

    - ``replicated_in_carry`` — the broadcast state is part of the
      iterate and must advance on *every* iteration, evaluated or not
      (SCDL's dictionaries).  Implies ``light_step`` returns
      ``(d', out_partial)``.
    - ``default_chunk`` / ``default_cost_every`` — per-workload defaults
      for the fused-dispatch granularity and objective cadence.

    ``finalize(bundle, log) -> (x, aux)`` turns the final bundle into
    the workload's primary result (default: the gathered data tree).
    """

    name: ClassVar[Optional[str]] = None      # set by @register
    replicated_in_carry: ClassVar[bool] = False
    default_chunk: ClassVar[int] = 8
    default_cost_every: ClassVar[Union[int, str]] = 1

    # optional hooks — subclasses declare them as methods
    light_step: Optional[Callable] = None
    cost: Optional[Callable] = None
    refresh_replicated: Optional[Callable] = None

    # ------------------------------------------------------- required
    def init_bundle(self, inputs: Tuple, mesh) -> Bundle:
        raise NotImplementedError

    def full_step(self, d, rep, axes):
        raise NotImplementedError

    # ------------------------------------------------------- optional
    def default_options(self) -> RunOptions:
        """Per-workload RunOptions defaults: ``max_iter``/``tol`` come
        from the workload's config dataclass when it has them (the
        ``self.cfg`` convention), chunking/cadence from the class
        metadata."""
        base = RunOptions()
        cfg = getattr(self, "cfg", None)
        return RunOptions(
            max_iter=getattr(cfg, "max_iter", base.max_iter),
            tol=getattr(cfg, "tol", base.tol),
            chunk=self.default_chunk,
            cost_every=self.default_cost_every)

    def finalize(self, bundle: Bundle, log: RunLog) -> Tuple[Any, Dict]:
        return gather(bundle), {}

    def batch_axes(self) -> batching.BatchAxes:
        """How instances of this workload batch under :func:`solve_many`
        (DESIGN.md §19): the record axis of each raw input, whether
        record padding is allowed, which replicated keys are shared
        across a bucket, and which constructor attributes are declared
        instance-invariant (consumed by lint rule RPL801).  The default
        declares record axis 0 on every input, full padding, and no
        shared state."""
        return batching.BatchAxes()

    # ------------------------------------------------------- plumbing
    def _declared(self, hook: str) -> Optional[Callable]:
        fn = getattr(self, hook, None)
        return fn if callable(fn) else None


@dataclass
class Solution:
    """What ``solve()`` returns: the workload's primary result ``x``,
    secondary outputs ``aux``, the driver's convergence log, and the
    final bundle (for chained solves / inspection).  ``recovery`` is
    the resilience ledger (``repro.resilience.RecoveryReport``) of a
    supervised run — ``None`` when resilience was off."""
    x: Any
    aux: Dict[str, Any]
    log: RunLog
    bundle: Bundle
    problem: Problem
    recovery: Optional[Any] = None

    @property
    def costs(self):
        return self.log.costs

    def percentiles(self, qs=(50, 90, 99)) -> Dict[str, float]:
        """p50/p90/p99 (seconds) over the per-iteration wall times the
        run recorded — the same summary the serving metrics registry
        reports for request latencies (``RunLog.percentiles``)."""
        return self.log.percentiles(qs)


# --------------------------------------------------------------------
# Workload registry
# --------------------------------------------------------------------

_REGISTRY: Dict[str, Type[Problem]] = {}

# built-in workloads, imported lazily on first lookup so that
# ``solve("scdl", ...)`` works without the caller importing imaging code
_BUILTIN_MODULES: Dict[str, str] = {
    "deconvolve": "repro.imaging.deconvolve",
    "lowrank": "repro.imaging.lowrank",
    "scdl": "repro.imaging.scdl",
}


def register(name: str):
    """Class decorator: ``@register("scdl")`` puts the Problem subclass
    into the string-keyed workload registry and stamps ``cls.name``."""

    def deco(cls: Type[Problem]) -> Type[Problem]:
        if not (isinstance(cls, type) and issubclass(cls, Problem)):
            raise TypeError(f"@register({name!r}) expects a Problem "
                            f"subclass, got {cls!r}")
        prev = _REGISTRY.get(name)
        if prev is not None and prev is not cls:
            raise ValueError(
                f"workload {name!r} already registered to "
                f"{prev.__module__}.{prev.__name__}")
        cls.name = name
        _REGISTRY[name] = cls
        return cls

    return deco


def get(name: str) -> Type[Problem]:
    """Look up a registered Problem class by key (lazily importing the
    built-in workload modules)."""
    if name not in _REGISTRY and name in _BUILTIN_MODULES:
        importlib.import_module(_BUILTIN_MODULES[name])
    if name not in _REGISTRY:
        raise KeyError(
            f"unknown workload {name!r}; registered workloads: "
            f"{available()}.  Define a Problem subclass and decorate it "
            f"with @repro.core.problem.register({name!r}) to add one "
            f"(DESIGN.md §14).")
    return _REGISTRY[name]


def available() -> Tuple[str, ...]:
    """All known workload keys (registered + lazily importable)."""
    return tuple(sorted(set(_REGISTRY) | set(_BUILTIN_MODULES)))


# --------------------------------------------------------------------
# Wiring derivation + the single entry point
# --------------------------------------------------------------------

_RUN_CONTROL_KEYS = ("max_iter", "tol", "chunk", "cost_every",
                     "cost_window", "straggler_factor",
                     "checkpoint_every", "checkpoint_fn", "checks",
                     "resilience", "progress_fn")


def derive_options(problem: Problem, base: RunOptions) -> RunOptions:
    """The wiring derivation rules (DESIGN.md §14): map a Problem's
    declared hooks + metadata onto the driver's step-variant fields.

    1. ``light_step`` declared          -> ``step_fn_light`` (enables
       integer ``cost_every`` skipping; required for it).
    2. ``cost_every == "chunk"``        -> requires ``cost`` AND
       ``light_step``; wires ``step_fn_cost`` so the driver picks
       ``engine.make_chunk_cost_step`` (no per-iteration cond, one
       objective evaluation per dispatch).  Otherwise ``step_fn_cost``
       stays unset and the driver uses ``engine.make_scan_step``.
    3. ``refresh_replicated`` declared  -> ``update_replicated``.
    4. ``replicated_in_carry`` metadata -> ``light_updates_replicated``
       (the light step feeds the broadcast update every iteration).
    """
    light = problem._declared("light_step")
    cost = problem._declared("cost")
    refresh = problem._declared("refresh_replicated")
    per_chunk = base.cost_every == "chunk"
    if per_chunk and (cost is None or light is None):
        raise ValueError(
            f'{type(problem).__name__}: cost_every="chunk" needs both a '
            f"light_step and a standalone cost declaration")
    if (not per_chunk and int(base.cost_every) > 1 and light is None):
        raise ValueError(
            f"{type(problem).__name__}: cost_every={base.cost_every} "
            f"needs a light_step declaration (the cost-free iteration)")
    if problem.replicated_in_carry and refresh is None:
        raise ValueError(
            f"{type(problem).__name__}: replicated_in_carry requires a "
            f"refresh_replicated declaration")
    if per_chunk and refresh is not None \
            and not problem.replicated_in_carry:
        # the chunk-cost scan body feeds update_replicated from the
        # light step's aux output, but a bare-return light step (the
        # non-carry contract) has none — the broadcast state would
        # never advance inside the chunk
        raise ValueError(
            f'{type(problem).__name__}: cost_every="chunk" with '
            f"refresh_replicated requires replicated_in_carry (the "
            f"light_step must return (d', out_partial) to feed the "
            f"broadcast update every iteration)")
    return replace(base,
                   step_fn_light=light,
                   step_fn_cost=cost if per_chunk else None,
                   update_replicated=refresh,
                   light_updates_replicated=problem.replicated_in_carry)


def _config_fingerprint(problem: Problem) -> str:
    """Checkpoint-manifest fingerprint of the workload's config.

    Excludes run-control fields (``max_iter``/``tol``): they never enter
    the step math, and extending ``max_iter`` on resume is the canonical
    continue-a-finished-run workflow."""
    cfg = getattr(problem, "cfg", None)
    if dataclasses.is_dataclass(cfg) and not isinstance(cfg, type):
        kept = {k: v for k, v in sorted(dataclasses.asdict(cfg).items())
                if k not in ("max_iter", "tol")}
        return f"{type(cfg).__name__}({kept!r})"
    return repr(cfg)


def _as_problem(problem: Union[str, Problem, Type[Problem]],
                cfg) -> Problem:
    if isinstance(problem, str):
        cls = get(problem)
        return cls(cfg) if cfg is not None else cls()
    if isinstance(problem, type) and issubclass(problem, Problem):
        return problem(cfg) if cfg is not None else problem()
    if not isinstance(problem, Problem):
        raise TypeError(
            f"solve() expects a workload key, Problem class, or Problem "
            f"instance as its first argument, got "
            f"{type(problem).__name__!r} (did you mean "
            f'solve("<workload>", ..., cfg=...)?)')
    if cfg is not None:
        raise TypeError(
            "cfg= is only valid with a workload key/class; the Problem "
            "instance already carries its config")
    return problem


def _resolved_options(problem: Problem, options: Optional[RunOptions],
                      run_opts: Dict[str, Any]) -> RunOptions:
    """Shared option resolution of :func:`solve` / :func:`solve_many`:
    reject non-run-control kwargs and pre-wired step options, merge
    per-call overrides over the problem's defaults, honour the
    REPRO_CHECKS force-enable."""
    bad = set(run_opts) - set(_RUN_CONTROL_KEYS)
    if bad:
        raise TypeError(
            f"got unexpected run options {sorted(bad)}; valid: "
            f"{list(_RUN_CONTROL_KEYS)}.  Step wiring "
            f"(step_fn_light/step_fn_cost/update_replicated/...) is "
            f"derived from the Problem declaration, not passed to "
            f"solve().")
    if options is not None:
        defaults = RunOptions()
        wired = [f for f in ("step_fn_light", "step_fn_cost",
                             "update_replicated",
                             "light_updates_replicated")
                 if getattr(options, f) != getattr(defaults, f)]
        if wired:
            raise TypeError(
                f"options= carries step wiring {wired}, which solve() "
                f"derives from the Problem declaration and would "
                f"overwrite; declare the hooks on the Problem instead "
                f"(DESIGN.md §14)")
    opts = options if options is not None else problem.default_options()
    opts = opts.merged_with(**run_opts)
    # runtime contract sanitizers: checks=True per call, or REPRO_CHECKS=1
    # force-enables for every solve() in the process (repro.core.checks)
    if checks.checks_enabled(opts.checks) and not opts.checks:
        opts = replace(opts, checks=True)
    return opts


def solve(problem: Union[str, Problem, Type[Problem]], *inputs,
          cfg=None, mesh=None, options: Optional[RunOptions] = None,
          checkpoint_dir=None, resume: Union[bool, int] = False,
          **run_opts) -> Solution:
    """The single entry point: configure, parallelize, iterate.

    ``problem`` is a registry key (``"scdl"``), a Problem class, or an
    instance (for workload-specific constructor args).  ``*inputs`` are
    the raw input arrays, handed to ``problem.init_bundle``.

    Run control: ``options=RunOptions(...)`` replaces the problem's
    defaults wholesale; individual ``**run_opts`` (``max_iter=``,
    ``tol=``, ``chunk=``, ``cost_every=``, ...) override field-wise on
    top.  Step wiring is *derived* from the Problem declaration
    (:func:`derive_options`) and cannot be passed here.

    ``checks=True`` (or env ``REPRO_CHECKS=1``) turns on the runtime
    contract sanitizers (``repro.core.checks``, DESIGN.md §17):
    finite-state guards at every host sync, an ``eval_shape``
    carry-contract pre-flight, and finite-cost validation — zero extra
    dispatches when off.

    Checkpointing: ``checkpoint_dir=`` + ``checkpoint_every=k`` writes
    an atomic full-state checkpoint (data + replicated, via
    ``core.persistence.spill_bundle``) every k iterations;
    ``resume=True`` (or an explicit step number) restores the latest
    (or given) checkpoint from ``checkpoint_dir`` into the freshly
    built bundle and continues iterating from there — the cost
    trajectory continues exactly where the checkpointed run left off.
    """
    problem = _as_problem(problem, cfg)
    opts = _resolved_options(problem, options, run_opts)

    if opts.resilience is not None:
        # kernel degradations can happen while *building* the problem
        # (e.g. operator-norm power iterations tracing the kernels), so
        # the recovery report's baseline is taken here, not at the
        # driver's Supervisor construction
        from repro.kernels import common as _kcommon
        kernel_baseline = len(_kcommon.kernel_fallbacks())

    bundle = problem.init_bundle(tuple(inputs), mesh)
    start_iter = 0
    writer = None
    if checkpoint_dir is not None:
        from pathlib import Path

        from repro.checkpoint import checkpointer as ckpt
        # the config fingerprint makes resuming under a *changed* config
        # (same shapes, different lam/steps/...) fail loudly instead of
        # silently mixing restored state with new step closures
        meta = {"problem": problem.name or type(problem).__name__,
                "config": _config_fingerprint(problem)}
        if resume is not False:
            latest = ckpt.latest_step(checkpoint_dir)
            if isinstance(resume, int) and not isinstance(resume, bool):
                # an explicit step is a contract: never silently
                # substitute another one — missing or corrupt is an error
                step = resume
                if not (Path(checkpoint_dir) / f"step_{step:08d}"
                        / "manifest.json").exists():
                    raise ValueError(
                        f"no checkpoint for step {step} under "
                        f"{checkpoint_dir!r} (latest saved step: "
                        f"{latest})")
            else:
                if latest is None:
                    raise ValueError(
                        f"resume=True but no checkpoints found under "
                        f"{checkpoint_dir!r} — wrong directory, or the "
                        f"first checkpoint was never written")
                # the newest checkpoint may be a torn write (writer
                # killed mid-flight): restore the newest *valid* one
                step, corrupt = ckpt.latest_valid_step(checkpoint_dir)
                if step is None:
                    raise ValueError(
                        f"resume=True but every checkpoint under "
                        f"{checkpoint_dir!r} failed integrity "
                        f"validation (corrupt steps: {corrupt}); "
                        f"latest saved step: {latest}")
                if corrupt:
                    warnings.warn(
                        f"newest checkpoint(s) {corrupt} under "
                        f"{checkpoint_dir!r} failed integrity "
                        f"validation (torn write?); resuming from "
                        f"step {step} instead", RuntimeWarning,
                        stacklevel=2)
            # shape/tree template only — checkpointer.restore reads
            # leaf shapes and the treedef, never the values, so hand it
            # the device arrays rather than a host spill of the bundle;
            # the shardings put each leaf straight onto the mesh (no
            # materialize-on-one-device step, elastic across topologies)
            like = {"data": bundle.data, "replicated": bundle.replicated}
            state, _ = ckpt.restore(
                checkpoint_dir, step, like,
                shardings=persistence.bundle_shardings(bundle),
                expect_meta=lambda m: m.get("problem") == meta["problem"]
                and m.get("config") == meta["config"])
            bundle = bundle.with_data(state["data"],
                                      replicated=state["replicated"])
            start_iter = step
        if opts.checkpoint_every and opts.checkpoint_fn is None:
            # async writer + retention gc: the run blocks only for the
            # host snapshot; .npy I/O overlaps the next chunks, and old
            # steps are garbage-collected (Checkpointer keep=3)
            writer = ckpt.Checkpointer(checkpoint_dir, meta=meta)

            def checkpoint_fn(b: Bundle, i: int) -> None:
                # i is the last completed iteration index -> i+1
                # iterations are in the state being saved
                writer.save_async(i + 1, persistence.spill_bundle(b))

            opts = replace(opts, checkpoint_fn=checkpoint_fn)
        elif not opts.checkpoint_every and opts.checkpoint_fn is None \
                and resume is False:
            raise ValueError(
                "checkpoint_dir= given but neither checkpoint_every= "
                "nor resume= requested — no checkpoint would ever be "
                "read or written")
    else:
        if resume is not False:
            raise ValueError("resume= requires checkpoint_dir=")
        if opts.checkpoint_every and opts.checkpoint_fn is None:
            raise ValueError(
                "checkpoint_every= without checkpoint_dir= (or a "
                "custom checkpoint_fn) would silently write nothing")

    if opts.resilience is not None and checkpoint_dir is not None \
            and opts.resilience.checkpoint_dir is None:
        # divergence rollback falls back to disk once the snapshot ring
        # is dry — point it at this run's own checkpoint directory
        opts = replace(opts, resilience=dataclasses.replace(
            opts.resilience, checkpoint_dir=str(checkpoint_dir)))

    driver = IterativeDriver(problem.full_step, bundle,
                             options=derive_options(problem, opts))
    # REPRO_CHAOS activates the fault plan for exactly this run (inert
    # when unset or when a test already holds active_chaos())
    with _chaos.maybe_from_env():
        out = driver.run(start_iter=start_iter)
    if writer is not None:
        writer.wait()           # in-flight async writes land before
    x, aux = problem.finalize(out, driver.log)   # the run is "done"
    if driver.recovery is not None:
        events = _kcommon.kernel_fallbacks()[kernel_baseline:]
        driver.recovery.kernel_fallbacks = [dict(e) for e in events]
    return Solution(x=x, aux=aux, log=driver.log, bundle=out,
                    problem=problem, recovery=driver.recovery)


# --------------------------------------------------------------------
# Batched multi-instance entry point (DESIGN.md §19)
# --------------------------------------------------------------------


def solve_many(problem: Union[str, Problem, Type[Problem]],
               instances, *, cfg=None, mesh=None,
               options: Optional[RunOptions] = None,
               checkpoint_dir=None, resume: bool = False,
               waste_budget: float = 0.25,
               recompact_below: float = 0.5,
               **run_opts) -> List[Solution]:
    """Solve many independent instances of one workload in batched
    device programs (DESIGN.md §19).

    ``instances`` is a sequence of input tuples, each exactly what the
    corresponding single :func:`solve` call would receive.  Instances
    are grouped into buckets by static signature (``Problem.
    batch_axes``), record-padded up to the bucket capacity within
    ``waste_budget``, stacked along a leading batch axis, and run
    through the fused chunked engine — K iterations across ALL of a
    bucket's instances per dispatch.  Per-instance convergence is
    tracked by an active mask: a converged instance's lane freezes (its
    ``Solution.log.iters_run`` stops growing) and the bucket re-compacts
    to the live lanes once the active fraction drops below
    ``recompact_below``.

    Composes with the single-solve production knobs: ``resilience=``
    supervises each bucket's dispatches (retry/rollback with batch-
    aware snapshots), and ``checkpoint_dir=`` + ``checkpoint_every=``
    writes per-bucket full-layout checkpoints under
    ``<checkpoint_dir>/bucket_<key>`` (deterministic bucket keys, so
    ``resume=True`` re-plans the same buckets and restores each from
    its newest valid step).

    Returns one :class:`Solution` per instance, in input order.
    """
    problem = _as_problem(problem, cfg)
    opts = _resolved_options(problem, options, run_opts)
    instances = [tuple(inst) for inst in instances]
    if not instances:
        return []
    axes = problem.batch_axes()
    if not isinstance(axes, batching.BatchAxes):
        raise TypeError(
            f"{type(problem).__name__}.batch_axes() must return a "
            f"batching.BatchAxes, got {type(axes).__name__}")
    if axes.shared_in_batch and \
            problem._declared("refresh_replicated") is not None:
        raise ValueError(
            f"{type(problem).__name__}: shared_in_batch="
            f"{axes.shared_in_batch} cannot combine with "
            f"refresh_replicated — the per-iteration broadcast update "
            f"rewrites the replicated tree, so no key is guaranteed "
            f"instance-independent across a bucket")
    salt = (f"{problem.name or type(problem).__name__}|"
            f"{_config_fingerprint(problem)}")
    plan = batching.plan_buckets(instances, axes,
                                 waste_budget=waste_budget, salt=salt)

    if checkpoint_dir is not None:
        from pathlib import Path

        from repro.checkpoint import checkpointer as ckpt
        if isinstance(resume, int) and not isinstance(resume, bool):
            raise ValueError(
                "solve_many resumes each bucket from its newest valid "
                "step — pass resume=True, not an explicit step number")
        if resume:
            found = any(
                ckpt.latest_step(Path(checkpoint_dir)
                                 / f"bucket_{b.key}") is not None
                for b in plan)
            if not found:
                raise ValueError(
                    f"resume=True but no bucket checkpoints found under "
                    f"{checkpoint_dir!r} — wrong directory, a different "
                    f"instance plan (bucket keys changed), or the first "
                    f"checkpoint was never written")
        elif not opts.checkpoint_every and opts.checkpoint_fn is None:
            raise ValueError(
                "checkpoint_dir= given but neither checkpoint_every= "
                "nor resume= requested — no checkpoint would ever be "
                "read or written")
    else:
        if resume is not False:
            raise ValueError("resume= requires checkpoint_dir=")
        if opts.checkpoint_every and opts.checkpoint_fn is None:
            raise ValueError(
                "checkpoint_every= without checkpoint_dir= (or a "
                "custom checkpoint_fn) would silently write nothing")

    if opts.resilience is not None:
        from repro.kernels import common as _kcommon
        kernel_baseline = len(_kcommon.kernel_fallbacks())

    solutions: List[Optional[Solution]] = [None] * len(instances)
    with _chaos.maybe_from_env():
        for bucket in plan:
            _run_bucket(problem, bucket, instances, opts, mesh, axes,
                        checkpoint_dir, resume, recompact_below,
                        solutions)
    if opts.resilience is not None:
        # kernel degradations during bundle building happen before each
        # bucket's supervisor exists — rebase every report on the
        # call-level baseline (mirrors solve())
        events = _kcommon.kernel_fallbacks()[kernel_baseline:]
        for report in {id(s.recovery): s.recovery for s in solutions
                       if s is not None and s.recovery is not None
                       }.values():
            report.kernel_fallbacks = [dict(e) for e in events]
    return solutions


def _run_bucket(problem: Problem, bucket: batching.Bucket, instances,
                opts: RunOptions, mesh, axes: batching.BatchAxes,
                checkpoint_dir, resume, recompact_below: float,
                solutions: List[Optional[Solution]]) -> None:
    """Stack, run, and unpack one bucket, writing Solutions in place."""
    import jax.numpy as jnp

    # init_bundle runs per instance on the UNPADDED inputs with no mesh:
    # derived replicated state (operator norms from shape-dependent
    # power iterations, step sizes) must match the single solve exactly;
    # padding is applied to the built bundle's record axes instead
    # (zero rows are inert through every builtin step)
    bundles = [problem.init_bundle(instances[j], None)
               for j in bucket.indices]
    shared_keys = tuple(axes.shared_in_batch)

    def split_rep(rep):
        if not shared_keys:
            return None, rep
        if not isinstance(rep, dict):
            raise TypeError(
                f"{type(problem).__name__}: shared_in_batch="
                f"{shared_keys} requires dict-shaped replicated state")
        missing = [k for k in shared_keys if k not in rep]
        if missing:
            raise ValueError(
                f"{type(problem).__name__}: batch_axes declares shared "
                f"replicated keys {missing} absent from init_bundle's "
                f"replicated tree {sorted(rep)}")
        return ({k: rep[k] for k in shared_keys},
                {k: v for k, v in rep.items() if k not in shared_keys})

    shared, _ = split_rep(bundles[0].replicated)
    state_d = batching.stack_trees(
        [batching.pad_tree_records(b.data, bucket.capacity)
         for b in bundles])
    state_r = batching.stack_trees(
        [split_rep(b.replicated)[1] for b in bundles])
    orig = np.asarray(bucket.indices, dtype=np.int64)
    parts = 1
    if mesh is not None:
        for a in _dp_axes(mesh):
            parts *= mesh.shape[a]
    need = (-len(orig)) % max(parts, 1)
    if need:
        # mesh alignment: duplicate the last instance into filler lanes
        # (inactive from the start, never reported) so the batch axis
        # divides across the data-parallel submesh
        def dup(x):
            return jnp.concatenate([x] + [x[-1:]] * need, axis=0)

        state_d = jax.tree.map(dup, state_d)
        state_r = jax.tree.map(dup, state_r)
        orig = np.concatenate([orig, np.full(need, -1, np.int64)])
    bundle = Bundle.create({"d": state_d, "r": state_r}, mesh=mesh,
                           replicated=shared)

    bopts = opts
    writer = None
    bdir = None
    start_iter = 0
    if checkpoint_dir is not None:
        from pathlib import Path

        from repro.checkpoint import checkpointer as ckpt
        bdir = Path(checkpoint_dir) / f"bucket_{bucket.key}"
        meta = {"problem": problem.name or type(problem).__name__,
                "config": _config_fingerprint(problem),
                "bucket": bucket.key,
                "capacity": int(bucket.capacity),
                "instances": [int(j) for j in bucket.indices]}
        if bopts.checkpoint_every and bopts.checkpoint_fn is None:
            writer = ckpt.Checkpointer(bdir, meta=meta)

            def checkpoint_fn(payload, i: int,
                              _writer=writer) -> None:
                _writer.save_async(i + 1, payload)

            bopts = replace(bopts, checkpoint_fn=checkpoint_fn)
    if bopts.resilience is not None and bdir is not None \
            and bopts.resilience.checkpoint_dir is None:
        bopts = replace(bopts, resilience=dataclasses.replace(
            bopts.resilience, checkpoint_dir=str(bdir)))

    driver = BatchedDriver(problem.full_step, bundle,
                           options=derive_options(problem, bopts),
                           orig_indices=orig,
                           recompact_below=recompact_below)
    if bdir is not None and resume:
        step, corrupt = ckpt.latest_valid_step(bdir)
        if step is not None:
            if corrupt:
                warnings.warn(
                    f"newest checkpoint(s) {corrupt} under {str(bdir)!r} "
                    f"failed integrity validation (torn write?); "
                    f"resuming bucket from step {step} instead",
                    RuntimeWarning, stacklevel=3)
            payload, _ = ckpt.restore(
                bdir, step, driver.payload_template(),
                expect_meta=lambda m: m.get("problem") == meta["problem"]
                and m.get("config") == meta["config"]
                and m.get("bucket") == meta["bucket"])
            driver.load_payload(payload)
            start_iter = step
        # a bucket with no checkpoint yet simply starts from scratch —
        # the plan-level pre-scan already guaranteed the resume is sane

    driver.run(start_iter=start_iter)
    if writer is not None:
        writer.wait()

    shared_host = (persistence.to_host(shared)
                   if shared is not None else None)
    states = driver.host_states()
    for row, j in enumerate(orig.tolist()):
        if j < 0:
            continue                               # filler lane
        inst = states[row]
        n = bucket.records[row]
        d_host = jax.tree.map(lambda x, _n=n: x[:_n], inst["d"])
        rep = inst["r"]
        if shared_host is not None:
            rep = {**shared_host, **rep} if isinstance(rep, dict) \
                else shared_host
        b_inst = Bundle(data=d_host, replicated=rep, mesh=None, axes=())
        log = driver.logs[row]
        x, aux = problem.finalize(b_inst, log)
        solutions[j] = Solution(x=x, aux=aux, log=log, bundle=b_inst,
                                problem=problem,
                                recovery=driver.recovery)
