"""Declarative workload API: declare a `Problem` once, `solve()` it.

The paper's architecture is one generic driver (configure ->
parallelize -> iterate) serving *variant* imaging workloads.  After the
engine grew chunked scans, broadcast carries and per-chunk objectives
(DESIGN.md §12/§13), expressing a workload meant hand-assembling up to
four step variants plus the driver kwargs wiring them together.  This
module collapses that to a declaration:

    class MyProblem(Problem):
        def init_bundle(self, inputs, mesh): ...   # phases (a)+(b)
        def full_step(self, d, rep, axes): ...     # phase (c), one iter
        # optional: light_step / cost / refresh_replicated

    sol = solve(MyProblem(cfg), *inputs, mesh=mesh, max_iter=100)

``solve()`` derives the entire driver wiring — scan-step vs
chunk-cost-step selection, broadcast-carry updates, light/cost variants,
checkpoint hooks — from which optional methods the Problem defines plus
its static metadata (``replicated_in_carry``, ``default_chunk``,
``default_cost_every``).  The derivation rules are spelled out in
DESIGN.md §14.

Workloads register under a string key (``@register("scdl")``); the
registry is importable as ``repro.problems`` and lazily imports the
built-in workloads, so ``solve("scdl", S_h, S_l)`` works without any
imaging import on the caller's side.
"""
from __future__ import annotations

import importlib
import warnings
from dataclasses import dataclass, replace
from typing import (Any, Callable, ClassVar, Dict, Optional, Tuple, Type,
                    Union)

import dataclasses

from repro.core import checks, persistence
from repro.core.bundle import Bundle, gather
from repro.core.driver import IterativeDriver, RunLog, RunOptions
from repro.resilience import chaos as _chaos

# --------------------------------------------------------------------
# The Problem declaration
# --------------------------------------------------------------------


class Problem:
    """One workload, declared once.

    Required hooks (phases of the paper's driver program):

    - ``init_bundle(inputs, mesh) -> Bundle`` — configuration +
      parallelization: build the co-partitioned bundle (and its
      replicated/broadcast side) from the raw input arrays.
    - ``full_step(d, rep, axes) -> (d', out)`` — one learning iteration
      over a local block; ``out`` is a scalar cost or a dict with a
      ``"cost"`` entry (plus any reduced state feeding
      ``refresh_replicated``).  Must psum over ``axes`` itself.

    Optional hooks (``None`` at class level means "not declared"; the
    wiring derivation in :func:`solve` keys off their presence):

    - ``light_step(d, rep, axes)`` — the same iteration without the
      objective evaluation.  Returns bare ``d'`` normally, or
      ``(d', out_partial)`` when ``replicated_in_carry`` is set.
      Enables ``cost_every > 1`` skipping.
    - ``cost(d, rep, axes) -> out`` — standalone objective over the
      *post-iteration* state.  Together with ``light_step`` it enables
      the fastest observability mode, ``cost_every="chunk"``
      (``engine.make_chunk_cost_step``).
    - ``refresh_replicated(rep, out) -> rep'`` — fold the reduced output
      back into the broadcast state each iteration (the paper's step-7
      driver broadcast, run inside the scan carry).

    Static metadata:

    - ``replicated_in_carry`` — the broadcast state is part of the
      iterate and must advance on *every* iteration, evaluated or not
      (SCDL's dictionaries).  Implies ``light_step`` returns
      ``(d', out_partial)``.
    - ``default_chunk`` / ``default_cost_every`` — per-workload defaults
      for the fused-dispatch granularity and objective cadence.

    ``finalize(bundle, log) -> (x, aux)`` turns the final bundle into
    the workload's primary result (default: the gathered data tree).
    """

    name: ClassVar[Optional[str]] = None      # set by @register
    replicated_in_carry: ClassVar[bool] = False
    default_chunk: ClassVar[int] = 8
    default_cost_every: ClassVar[Union[int, str]] = 1

    # optional hooks — subclasses declare them as methods
    light_step: Optional[Callable] = None
    cost: Optional[Callable] = None
    refresh_replicated: Optional[Callable] = None

    # ------------------------------------------------------- required
    def init_bundle(self, inputs: Tuple, mesh) -> Bundle:
        raise NotImplementedError

    def full_step(self, d, rep, axes):
        raise NotImplementedError

    # ------------------------------------------------------- optional
    def default_options(self) -> RunOptions:
        """Per-workload RunOptions defaults: ``max_iter``/``tol`` come
        from the workload's config dataclass when it has them (the
        ``self.cfg`` convention), chunking/cadence from the class
        metadata."""
        base = RunOptions()
        cfg = getattr(self, "cfg", None)
        return RunOptions(
            max_iter=getattr(cfg, "max_iter", base.max_iter),
            tol=getattr(cfg, "tol", base.tol),
            chunk=self.default_chunk,
            cost_every=self.default_cost_every)

    def finalize(self, bundle: Bundle, log: RunLog) -> Tuple[Any, Dict]:
        return gather(bundle), {}

    # ------------------------------------------------------- plumbing
    def _declared(self, hook: str) -> Optional[Callable]:
        fn = getattr(self, hook, None)
        return fn if callable(fn) else None


@dataclass
class Solution:
    """What ``solve()`` returns: the workload's primary result ``x``,
    secondary outputs ``aux``, the driver's convergence log, and the
    final bundle (for chained solves / inspection).  ``recovery`` is
    the resilience ledger (``repro.resilience.RecoveryReport``) of a
    supervised run — ``None`` when resilience was off."""
    x: Any
    aux: Dict[str, Any]
    log: RunLog
    bundle: Bundle
    problem: Problem
    recovery: Optional[Any] = None

    @property
    def costs(self):
        return self.log.costs


# --------------------------------------------------------------------
# Workload registry
# --------------------------------------------------------------------

_REGISTRY: Dict[str, Type[Problem]] = {}

# built-in workloads, imported lazily on first lookup so that
# ``solve("scdl", ...)`` works without the caller importing imaging code
_BUILTIN_MODULES: Dict[str, str] = {
    "deconvolve": "repro.imaging.deconvolve",
    "lowrank": "repro.imaging.lowrank",
    "scdl": "repro.imaging.scdl",
}


def register(name: str):
    """Class decorator: ``@register("scdl")`` puts the Problem subclass
    into the string-keyed workload registry and stamps ``cls.name``."""

    def deco(cls: Type[Problem]) -> Type[Problem]:
        if not (isinstance(cls, type) and issubclass(cls, Problem)):
            raise TypeError(f"@register({name!r}) expects a Problem "
                            f"subclass, got {cls!r}")
        prev = _REGISTRY.get(name)
        if prev is not None and prev is not cls:
            raise ValueError(
                f"workload {name!r} already registered to "
                f"{prev.__module__}.{prev.__name__}")
        cls.name = name
        _REGISTRY[name] = cls
        return cls

    return deco


def get(name: str) -> Type[Problem]:
    """Look up a registered Problem class by key (lazily importing the
    built-in workload modules)."""
    if name not in _REGISTRY and name in _BUILTIN_MODULES:
        importlib.import_module(_BUILTIN_MODULES[name])
    if name not in _REGISTRY:
        raise KeyError(
            f"unknown workload {name!r}; registered workloads: "
            f"{available()}.  Define a Problem subclass and decorate it "
            f"with @repro.core.problem.register({name!r}) to add one "
            f"(DESIGN.md §14).")
    return _REGISTRY[name]


def available() -> Tuple[str, ...]:
    """All known workload keys (registered + lazily importable)."""
    return tuple(sorted(set(_REGISTRY) | set(_BUILTIN_MODULES)))


# --------------------------------------------------------------------
# Wiring derivation + the single entry point
# --------------------------------------------------------------------

_RUN_CONTROL_KEYS = ("max_iter", "tol", "chunk", "cost_every",
                     "cost_window", "straggler_factor",
                     "checkpoint_every", "checkpoint_fn", "checks",
                     "resilience")


def derive_options(problem: Problem, base: RunOptions) -> RunOptions:
    """The wiring derivation rules (DESIGN.md §14): map a Problem's
    declared hooks + metadata onto the driver's step-variant fields.

    1. ``light_step`` declared          -> ``step_fn_light`` (enables
       integer ``cost_every`` skipping; required for it).
    2. ``cost_every == "chunk"``        -> requires ``cost`` AND
       ``light_step``; wires ``step_fn_cost`` so the driver picks
       ``engine.make_chunk_cost_step`` (no per-iteration cond, one
       objective evaluation per dispatch).  Otherwise ``step_fn_cost``
       stays unset and the driver uses ``engine.make_scan_step``.
    3. ``refresh_replicated`` declared  -> ``update_replicated``.
    4. ``replicated_in_carry`` metadata -> ``light_updates_replicated``
       (the light step feeds the broadcast update every iteration).
    """
    light = problem._declared("light_step")
    cost = problem._declared("cost")
    refresh = problem._declared("refresh_replicated")
    per_chunk = base.cost_every == "chunk"
    if per_chunk and (cost is None or light is None):
        raise ValueError(
            f'{type(problem).__name__}: cost_every="chunk" needs both a '
            f"light_step and a standalone cost declaration")
    if (not per_chunk and int(base.cost_every) > 1 and light is None):
        raise ValueError(
            f"{type(problem).__name__}: cost_every={base.cost_every} "
            f"needs a light_step declaration (the cost-free iteration)")
    if problem.replicated_in_carry and refresh is None:
        raise ValueError(
            f"{type(problem).__name__}: replicated_in_carry requires a "
            f"refresh_replicated declaration")
    if per_chunk and refresh is not None \
            and not problem.replicated_in_carry:
        # the chunk-cost scan body feeds update_replicated from the
        # light step's aux output, but a bare-return light step (the
        # non-carry contract) has none — the broadcast state would
        # never advance inside the chunk
        raise ValueError(
            f'{type(problem).__name__}: cost_every="chunk" with '
            f"refresh_replicated requires replicated_in_carry (the "
            f"light_step must return (d', out_partial) to feed the "
            f"broadcast update every iteration)")
    return replace(base,
                   step_fn_light=light,
                   step_fn_cost=cost if per_chunk else None,
                   update_replicated=refresh,
                   light_updates_replicated=problem.replicated_in_carry)


def _config_fingerprint(problem: Problem) -> str:
    """Checkpoint-manifest fingerprint of the workload's config.

    Excludes run-control fields (``max_iter``/``tol``): they never enter
    the step math, and extending ``max_iter`` on resume is the canonical
    continue-a-finished-run workflow."""
    cfg = getattr(problem, "cfg", None)
    if dataclasses.is_dataclass(cfg) and not isinstance(cfg, type):
        kept = {k: v for k, v in sorted(dataclasses.asdict(cfg).items())
                if k not in ("max_iter", "tol")}
        return f"{type(cfg).__name__}({kept!r})"
    return repr(cfg)


def _as_problem(problem: Union[str, Problem, Type[Problem]],
                cfg) -> Problem:
    if isinstance(problem, str):
        cls = get(problem)
        return cls(cfg) if cfg is not None else cls()
    if isinstance(problem, type) and issubclass(problem, Problem):
        return problem(cfg) if cfg is not None else problem()
    if not isinstance(problem, Problem):
        raise TypeError(
            f"solve() expects a workload key, Problem class, or Problem "
            f"instance as its first argument, got "
            f"{type(problem).__name__!r} (did you mean "
            f'solve("<workload>", ..., cfg=...)?)')
    if cfg is not None:
        raise TypeError(
            "cfg= is only valid with a workload key/class; the Problem "
            "instance already carries its config")
    return problem


def solve(problem: Union[str, Problem, Type[Problem]], *inputs,
          cfg=None, mesh=None, options: Optional[RunOptions] = None,
          checkpoint_dir=None, resume: Union[bool, int] = False,
          **run_opts) -> Solution:
    """The single entry point: configure, parallelize, iterate.

    ``problem`` is a registry key (``"scdl"``), a Problem class, or an
    instance (for workload-specific constructor args).  ``*inputs`` are
    the raw input arrays, handed to ``problem.init_bundle``.

    Run control: ``options=RunOptions(...)`` replaces the problem's
    defaults wholesale; individual ``**run_opts`` (``max_iter=``,
    ``tol=``, ``chunk=``, ``cost_every=``, ...) override field-wise on
    top.  Step wiring is *derived* from the Problem declaration
    (:func:`derive_options`) and cannot be passed here.

    ``checks=True`` (or env ``REPRO_CHECKS=1``) turns on the runtime
    contract sanitizers (``repro.core.checks``, DESIGN.md §17):
    finite-state guards at every host sync, an ``eval_shape``
    carry-contract pre-flight, and finite-cost validation — zero extra
    dispatches when off.

    Checkpointing: ``checkpoint_dir=`` + ``checkpoint_every=k`` writes
    an atomic full-state checkpoint (data + replicated, via
    ``core.persistence.spill_bundle``) every k iterations;
    ``resume=True`` (or an explicit step number) restores the latest
    (or given) checkpoint from ``checkpoint_dir`` into the freshly
    built bundle and continues iterating from there — the cost
    trajectory continues exactly where the checkpointed run left off.
    """
    bad = set(run_opts) - set(_RUN_CONTROL_KEYS)
    if bad:
        raise TypeError(
            f"solve() got unexpected run options {sorted(bad)}; valid: "
            f"{list(_RUN_CONTROL_KEYS)}.  Step wiring "
            f"(step_fn_light/step_fn_cost/update_replicated/...) is "
            f"derived from the Problem declaration, not passed to "
            f"solve().")
    problem = _as_problem(problem, cfg)
    if options is not None:
        defaults = RunOptions()
        wired = [f for f in ("step_fn_light", "step_fn_cost",
                             "update_replicated",
                             "light_updates_replicated")
                 if getattr(options, f) != getattr(defaults, f)]
        if wired:
            raise TypeError(
                f"options= carries step wiring {wired}, which solve() "
                f"derives from the Problem declaration and would "
                f"overwrite; declare the hooks on the Problem instead "
                f"(DESIGN.md §14)")
    opts = options if options is not None else problem.default_options()
    opts = opts.merged_with(**run_opts)
    # runtime contract sanitizers: checks=True per call, or REPRO_CHECKS=1
    # force-enables for every solve() in the process (repro.core.checks)
    if checks.checks_enabled(opts.checks) and not opts.checks:
        opts = replace(opts, checks=True)

    if opts.resilience is not None:
        # kernel degradations can happen while *building* the problem
        # (e.g. operator-norm power iterations tracing the kernels), so
        # the recovery report's baseline is taken here, not at the
        # driver's Supervisor construction
        from repro.kernels import common as _kcommon
        kernel_baseline = len(_kcommon.kernel_fallbacks())

    bundle = problem.init_bundle(tuple(inputs), mesh)
    start_iter = 0
    writer = None
    if checkpoint_dir is not None:
        from pathlib import Path

        from repro.checkpoint import checkpointer as ckpt
        # the config fingerprint makes resuming under a *changed* config
        # (same shapes, different lam/steps/...) fail loudly instead of
        # silently mixing restored state with new step closures
        meta = {"problem": problem.name or type(problem).__name__,
                "config": _config_fingerprint(problem)}
        if resume is not False:
            latest = ckpt.latest_step(checkpoint_dir)
            if isinstance(resume, int) and not isinstance(resume, bool):
                # an explicit step is a contract: never silently
                # substitute another one — missing or corrupt is an error
                step = resume
                if not (Path(checkpoint_dir) / f"step_{step:08d}"
                        / "manifest.json").exists():
                    raise ValueError(
                        f"no checkpoint for step {step} under "
                        f"{checkpoint_dir!r} (latest saved step: "
                        f"{latest})")
            else:
                if latest is None:
                    raise ValueError(
                        f"resume=True but no checkpoints found under "
                        f"{checkpoint_dir!r} — wrong directory, or the "
                        f"first checkpoint was never written")
                # the newest checkpoint may be a torn write (writer
                # killed mid-flight): restore the newest *valid* one
                step, corrupt = ckpt.latest_valid_step(checkpoint_dir)
                if step is None:
                    raise ValueError(
                        f"resume=True but every checkpoint under "
                        f"{checkpoint_dir!r} failed integrity "
                        f"validation (corrupt steps: {corrupt}); "
                        f"latest saved step: {latest}")
                if corrupt:
                    warnings.warn(
                        f"newest checkpoint(s) {corrupt} under "
                        f"{checkpoint_dir!r} failed integrity "
                        f"validation (torn write?); resuming from "
                        f"step {step} instead", RuntimeWarning,
                        stacklevel=2)
            # shape/tree template only — checkpointer.restore reads
            # leaf shapes and the treedef, never the values, so hand it
            # the device arrays rather than a host spill of the bundle;
            # the shardings put each leaf straight onto the mesh (no
            # materialize-on-one-device step, elastic across topologies)
            like = {"data": bundle.data, "replicated": bundle.replicated}
            state, _ = ckpt.restore(
                checkpoint_dir, step, like,
                shardings=persistence.bundle_shardings(bundle),
                expect_meta=lambda m: m.get("problem") == meta["problem"]
                and m.get("config") == meta["config"])
            bundle = bundle.with_data(state["data"],
                                      replicated=state["replicated"])
            start_iter = step
        if opts.checkpoint_every and opts.checkpoint_fn is None:
            # async writer + retention gc: the run blocks only for the
            # host snapshot; .npy I/O overlaps the next chunks, and old
            # steps are garbage-collected (Checkpointer keep=3)
            writer = ckpt.Checkpointer(checkpoint_dir, meta=meta)

            def checkpoint_fn(b: Bundle, i: int) -> None:
                # i is the last completed iteration index -> i+1
                # iterations are in the state being saved
                writer.save_async(i + 1, persistence.spill_bundle(b))

            opts = replace(opts, checkpoint_fn=checkpoint_fn)
        elif not opts.checkpoint_every and opts.checkpoint_fn is None \
                and resume is False:
            raise ValueError(
                "checkpoint_dir= given but neither checkpoint_every= "
                "nor resume= requested — no checkpoint would ever be "
                "read or written")
    else:
        if resume is not False:
            raise ValueError("resume= requires checkpoint_dir=")
        if opts.checkpoint_every and opts.checkpoint_fn is None:
            raise ValueError(
                "checkpoint_every= without checkpoint_dir= (or a "
                "custom checkpoint_fn) would silently write nothing")

    if opts.resilience is not None and checkpoint_dir is not None \
            and opts.resilience.checkpoint_dir is None:
        # divergence rollback falls back to disk once the snapshot ring
        # is dry — point it at this run's own checkpoint directory
        opts = replace(opts, resilience=dataclasses.replace(
            opts.resilience, checkpoint_dir=str(checkpoint_dir)))

    driver = IterativeDriver(problem.full_step, bundle,
                             options=derive_options(problem, opts))
    # REPRO_CHAOS activates the fault plan for exactly this run (inert
    # when unset or when a test already holds active_chaos())
    with _chaos.maybe_from_env():
        out = driver.run(start_iter=start_iter)
    if writer is not None:
        writer.wait()           # in-flight async writes land before
    x, aux = problem.finalize(out, driver.log)   # the run is "done"
    if driver.recovery is not None:
        events = _kcommon.kernel_fallbacks()[kernel_baseline:]
        driver.recovery.kernel_fallbacks = [dict(e) for e in events]
    return Solution(x=x, aux=aux, log=driver.log, bundle=out,
                    problem=problem, recovery=driver.recovery)
