"""IterativeDriver: the paper's driver program, generalized.

Runs phase (a) configuration, (b) parallelization (bundle creation), and
(c) iterative task execution with convergence tracking — plus the parts a
production system needs that Spark gave the paper for free or not at all:
checkpoint/restart hooks, straggler watchdog (step-time EMA), and elastic
re-partitioning on restore (``repro.checkpoint``).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

import jax
import numpy as np

from repro.core.bundle import Bundle
from repro.core.engine import make_step


@dataclass
class RunLog:
    costs: List[float] = field(default_factory=list)
    times: List[float] = field(default_factory=list)
    straggler_steps: List[int] = field(default_factory=list)
    converged_at: Optional[int] = None

    @property
    def total_seconds(self) -> float:
        return float(np.sum(self.times)) if self.times else 0.0


class IterativeDriver:
    """Drive step(state) -> (state, cost) to convergence.

    ``step_fn(data_local, replicated, axes) -> (data_local', cost)`` is
    compiled once via ``core.engine.make_step`` and applied until the
    relative cost change drops below ``tol`` (the paper's epsilon) or
    ``max_iter`` is hit.
    """

    def __init__(self, step_fn: Callable, bundle: Bundle, *,
                 max_iter: int = 300, tol: float = 1e-4,
                 cost_window: int = 3,
                 straggler_factor: float = 3.0,
                 checkpoint_every: int = 0,
                 checkpoint_fn: Optional[Callable] = None):
        self.bundle = bundle
        self.step = make_step(step_fn, bundle)
        self.max_iter = max_iter
        self.tol = tol
        self.cost_window = cost_window
        self.straggler_factor = straggler_factor
        self.checkpoint_every = checkpoint_every
        self.checkpoint_fn = checkpoint_fn
        self.log = RunLog()

    def _converged(self) -> bool:
        c = self.log.costs
        w = self.cost_window
        if len(c) <= w:
            return False
        prev, cur = c[-w - 1], c[-1]
        return abs(prev - cur) <= self.tol * max(abs(prev), 1e-12)

    def run(self, start_iter: int = 0) -> Bundle:
        data, rep = self.bundle.data, self.bundle.replicated
        ema = None
        for i in range(start_iter, self.max_iter):
            t0 = time.perf_counter()
            data, cost = self.step(data, rep)
            cost = jax.tree.map(lambda x: x.block_until_ready(), cost)
            dt = time.perf_counter() - t0
            self.log.times.append(dt)
            self.log.costs.append(float(np.asarray(jax.device_get(
                cost if not isinstance(cost, dict) else cost["cost"]))))
            # straggler watchdog: a step far beyond the EMA is logged and
            # (in multi-host deployment) triggers an early checkpoint
            if ema is not None and dt > self.straggler_factor * ema:
                self.log.straggler_steps.append(i)
                if self.checkpoint_fn is not None:
                    self.checkpoint_fn(self.bundle.with_data(data), i)
            ema = dt if ema is None else 0.9 * ema + 0.1 * dt
            if (self.checkpoint_every and self.checkpoint_fn is not None
                    and (i + 1) % self.checkpoint_every == 0):
                self.checkpoint_fn(self.bundle.with_data(data), i)
            if self._converged():
                self.log.converged_at = i
                break
        return self.bundle.with_data(data)
