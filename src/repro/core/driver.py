"""IterativeDriver: the paper's driver program, generalized.

Runs phase (a) configuration, (b) parallelization (bundle creation), and
(c) iterative task execution with convergence tracking — plus the parts a
production system needs that Spark gave the paper for free or not at all:
checkpoint/restart hooks, straggler watchdog (step-time EMA), and elastic
re-partitioning on restore (``repro.checkpoint``).

Execution modes (DESIGN.md §12):

- ``chunk=1``  — one dispatch + one host sync per iteration (the paper's
  Spark driver loop, and the baseline for ``benchmarks/bench_driver``);
- ``chunk=K>1`` — K iterations fused on-device via
  ``core.engine.make_scan_step``: the host sees one dispatch, one
  ``(K,)`` cost buffer, and one convergence check per chunk.  Broadcast
  state (``update_replicated``) is folded into the scan carry, so
  learners with per-iteration driver broadcasts (SCDL's dictionaries)
  run through this same generic loop.
"""
from __future__ import annotations

import time
import warnings
from collections import deque
from dataclasses import dataclass, field, fields, replace
from typing import Any, Callable, Dict, List, Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import checks as _checks
from repro.core import persistence as _persist
from repro.core.bundle import Bundle
from repro.core.engine import (init_batched_cost_like,
                               init_batched_out_like, init_cost_like,
                               init_out_like, make_batched_chunk_cost_step,
                               make_batched_scan_step, make_chunk_cost_step,
                               make_scan_step, make_step)
# dependency-light resilience pieces (chaos injectors are no-ops unless a
# ChaosConfig is activated; the supervisor itself is imported lazily only
# when RunOptions.resilience is set)
from repro.resilience import chaos as _chaos
from repro.resilience.errors import DivergenceError
from repro.resilience.recovery import RecoveryReport, ResilienceConfig


@dataclass(frozen=True)
class RunOptions:
    """Everything the driver needs beyond ``(step_fn, bundle)``.

    One dataclass replaces the former kwarg sprawl of
    ``IterativeDriver.__init__`` (DESIGN.md §14).  Two kinds of fields:

    - *run control* — iteration budget, convergence, chunking,
      observability and checkpoint cadence.  These are what callers of
      :func:`repro.core.problem.solve` override per run.
    - *step wiring* — the cost-free/objective-only step variants and the
      broadcast-update hook.  Hand-wired drivers set these directly;
      ``solve()`` derives them from a :class:`~repro.core.problem.Problem`
      declaration.

    ``cost_every`` accepts an int (evaluate the objective every k-th
    iteration; requires ``step_fn_light``) or the string ``"chunk"``
    (one evaluation per dispatched chunk on its final state; requires
    ``step_fn_cost`` — the fastest observability mode, DESIGN.md §13).
    """
    # run control
    max_iter: int = 300
    tol: float = 1e-4
    chunk: int = 8
    cost_every: Union[int, str] = 1
    cost_window: int = 3
    straggler_factor: float = 3.0
    checkpoint_every: int = 0
    checkpoint_fn: Optional[Callable] = None
    # runtime contract sanitizers (repro.core.checks; also force-enabled
    # by REPRO_CHECKS=1 when going through solve()).  Off by default:
    # the disabled path adds zero dispatches or host transfers.
    checks: bool = False
    # supervised execution (repro.resilience, DESIGN.md §18): retry,
    # divergence rollback, recovery report.  None = unsupervised; the
    # disabled path adds zero dispatches or host transfers.
    resilience: Optional[ResilienceConfig] = None
    # per-chunk observability hook (repro.serve, DESIGN.md §20): called
    # at every chunk-boundary host sync with a progress-event dict —
    # iteration range, evaluated costs, wall time, convergence state
    # (and per-instance entries for batched runs).  The callback runs on
    # the driver's thread at an already-paid sync point, so a cheap
    # callback adds no dispatches; exceptions propagate and abort the
    # run (relays must do their own shielding).
    #
    # Control return (§21): the callback may return a dict to steer the
    # run — {"stop": True} halts at this chunk boundary (RunLog records
    # cancelled_at), and for batched drivers {"cancel_instances": [j..]}
    # freezes the named original-index lanes exactly like converged
    # ones (re-compacted on the next pass, siblings unperturbed).  A
    # None/falsy return (the common case) changes nothing.
    progress_fn: Optional[Callable] = None
    # step wiring
    step_fn_light: Optional[Callable] = None
    step_fn_cost: Optional[Callable] = None
    update_replicated: Optional[Callable] = None
    light_updates_replicated: bool = False

    def __post_init__(self):
        if isinstance(self.cost_every, str):
            if self.cost_every != "chunk":
                raise ValueError(
                    f'cost_every must be a positive int or the string '
                    f'"chunk", got {self.cost_every!r}')
        elif int(self.cost_every) <= 0:
            raise ValueError(
                f'cost_every must be a positive int or the string '
                f'"chunk", got {self.cost_every!r} (0 or negative would '
                f'never evaluate the objective)')
        if int(self.chunk) <= 0:
            raise ValueError(
                f"chunk must be a positive int (iterations fused per "
                f"dispatch), got {self.chunk!r}")

    def merged_with(self, **overrides) -> "RunOptions":
        """A copy with the non-None entries of ``overrides`` applied
        (unknown keys raise, matching dataclasses.replace)."""
        return replace(self, **{k: v for k, v in overrides.items()
                                if v is not None})


_RUN_OPTION_NAMES = tuple(f.name for f in fields(RunOptions))


def percentiles(values, qs=(50, 90, 99)) -> Dict[str, float]:
    """``{"p50": ..., "p90": ..., "p99": ...}`` summary of a sample.

    The one timing-summary helper shared by :meth:`RunLog.percentiles`
    (per-iteration wall times a run already records) and the serving
    metrics registry (request latencies, ``repro.serve.metrics``) — so
    a ``Solution`` and a server report the same statistic the same way.
    Empty input returns an empty dict rather than NaNs.
    """
    vals = np.asarray(list(values), dtype=np.float64)
    if vals.size == 0:
        return {}
    return {f"p{int(q) if float(q).is_integer() else q}":
            float(np.percentile(vals, q)) for q in qs}


@dataclass
class RunLog:
    costs: List[float] = field(default_factory=list)
    times: List[float] = field(default_factory=list)
    straggler_steps: List[int] = field(default_factory=list)
    converged_at: Optional[int] = None
    # iterations this instance actually advanced — for single solves
    # that equals len(costs); for solve_many lanes frozen by the active
    # mask it stops growing at convergence while the bucket runs on
    iters_run: Optional[int] = None
    # set when the run was halted by a progress_fn control return
    # (serve-layer cancel / deadline expiry, §21) rather than by
    # convergence — the last iteration the instance advanced through
    cancelled_at: Optional[int] = None

    @property
    def total_seconds(self) -> float:
        return float(np.sum(self.times)) if self.times else 0.0

    def percentiles(self, qs=(50, 90, 99)) -> Dict[str, float]:
        """Percentile summary (seconds) of the per-iteration wall times
        this log already records — the per-chunk dt is amortized over
        the chunk's iterations, so p50/p99 read as time-per-iteration.
        Empty log -> empty dict."""
        return percentiles(self.times, qs)


class IterativeDriver:
    """Drive step(state) -> (state, cost) to convergence.

    ``step_fn(data_local, replicated, axes) -> (data_local', out)`` is
    compiled once (per chunk length) and applied until the relative cost
    change drops below ``tol`` (the paper's epsilon) or ``max_iter`` is
    hit.  ``out`` is either a scalar cost or a dict with a ``"cost"``
    entry plus optional replicated state consumed by
    ``options.update_replicated``.

    All remaining configuration lives in one :class:`RunOptions`.  The
    former individual kwargs (``max_iter=``, ``step_fn_light=``, ...) are
    still accepted but deprecated: they are mapped onto ``options`` with
    a ``DeprecationWarning``.
    """

    def __init__(self, step_fn: Callable, bundle: Bundle, *,
                 options: Optional[RunOptions] = None, **legacy):
        if legacy:
            unknown = set(legacy) - set(_RUN_OPTION_NAMES)
            if unknown:
                raise TypeError(
                    f"IterativeDriver got unexpected kwargs {sorted(unknown)}; "
                    f"valid RunOptions fields: {list(_RUN_OPTION_NAMES)}")
            warnings.warn(
                "passing IterativeDriver configuration as individual "
                f"kwargs ({sorted(legacy)}) is deprecated; pass "
                "options=RunOptions(...) instead (DESIGN.md §14)",
                DeprecationWarning, stacklevel=2)
            options = replace(options or RunOptions(), **legacy)
        self.options = options = options or RunOptions()
        self.bundle = bundle
        self.step_fn = step_fn
        self.step_fn_light = options.step_fn_light
        self.step_fn_cost = options.step_fn_cost
        self.update_replicated = options.update_replicated
        self.light_updates_replicated = options.light_updates_replicated
        self.max_iter = options.max_iter
        self.tol = options.tol
        self.cost_window = options.cost_window
        self.straggler_factor = options.straggler_factor
        self.checkpoint_every = options.checkpoint_every
        self.checkpoint_fn = options.checkpoint_fn
        self.checks = options.checks
        self.progress_fn = options.progress_fn
        # a chunk longer than the whole run would compile a scan program
        # that only ever executes its shorter tail — clamp so the one
        # program that runs is the one that was asked for
        self.chunk = max(min(int(options.chunk),
                             max(int(options.max_iter), 1)), 1)
        # same clamp for the checkpoint cadence (0 stays "disabled"): a
        # cadence longer than the run would otherwise never fire, and the
        # final state is exactly what a resume needs
        if self.checkpoint_every:
            self.checkpoint_every = min(int(self.checkpoint_every),
                                        max(int(options.max_iter), 1))
        self._per_chunk = options.cost_every == "chunk"
        if self._per_chunk:
            # both halves of the per-chunk contract, or the driver would
            # silently fall back to evaluating the objective every
            # iteration (see _cost_per_chunk)
            if options.step_fn_cost is None or options.step_fn_light is None:
                raise ValueError(
                    'cost_every="chunk" requires step_fn_cost (a '
                    "standalone objective over the post-iteration "
                    "state) AND step_fn_light (the cost-free step the "
                    "scan body runs)")
            self.cost_every = 1
        else:
            if options.step_fn_cost is not None:
                raise ValueError(
                    "step_fn_cost is only consumed by the per-chunk "
                    'objective mode — pass cost_every="chunk" with it, '
                    f"not cost_every={options.cost_every!r} (which "
                    f"would silently ignore it)")
            self.cost_every = max(int(options.cost_every), 1)
        self.log = RunLog()
        # RecoveryReport from the last supervised run (None when
        # resilience is off or run() has not executed yet)
        self.recovery = None
        self._compiled: Dict[int, Callable] = {}

    # ------------------------------------------------------ compilation
    def _scan_step(self, k: int) -> Callable:
        """Fused K-iteration step, compiled once per distinct chunk
        length (the tail chunk of a run compiles a second, shorter
        program)."""
        if k not in self._compiled:
            if self._cost_per_chunk:
                self._compiled[k] = make_chunk_cost_step(
                    self.step_fn_light, self.step_fn_cost, self.bundle,
                    chunk=k, update_replicated=self.update_replicated)
            else:
                self._compiled[k] = make_scan_step(
                    self.step_fn, self.bundle, chunk=k,
                    update_replicated=self.update_replicated,
                    fn_light=self.step_fn_light,
                    cost_every=self.cost_every,
                    light_updates_replicated=self.light_updates_replicated)
        return self._compiled[k]

    @property
    def step(self) -> Callable:
        """The per-iteration compiled step (chunk=1 legacy path)."""
        if "per_step" not in self._compiled:
            self._compiled["per_step"] = make_step(self.step_fn,
                                                   self.bundle)
        return self._compiled["per_step"]

    @property
    def _light_step(self) -> Callable:
        """Cost-free per-iteration step (chunk=1 path, off-grid
        iterations of ``cost_every``).  When the light step feeds the
        broadcast update (``light_updates_replicated``) it already has
        the ``(data', out)`` shape ``make_step`` expects; otherwise wrap
        its bare data return with a dummy scalar."""
        if "per_step_light" not in self._compiled:
            fn_light = self.step_fn_light
            if self.light_updates_replicated:
                light = fn_light
            else:
                def light(d, rep, axes):
                    return fn_light(d, rep, axes), jnp.float32(0.0)

            self._compiled["per_step_light"] = make_step(light,
                                                         self.bundle)
        return self._compiled["per_step_light"]

    # ----------------------------------------------------- convergence
    def _converged(self) -> bool:
        if not self.tol:
            return False
        c = self.log.costs
        # when cost skipping is active the log repeats each evaluated
        # objective; compare costs cost_window *evaluations* apart
        stride = (self.chunk if self._cost_per_chunk
                  else self.cost_every if self._skips_cost else 1)
        w = self.cost_window * stride
        if len(c) <= w:
            return False
        prev, cur = c[-w - 1], c[-1]
        return abs(prev - cur) <= self.tol * max(abs(prev), 1e-12)

    # ------------------------------------------------------ sanitizers
    def _last_init(self):
        """Initial value of the carried last-output slot (``None`` when
        the mode carries no extra output between chunks)."""
        return (init_cost_like(self.step_fn_cost, self.bundle)
                if self._cost_per_chunk
                else init_out_like(self.step_fn, self.bundle)
                if self._skips_cost else None)

    def _assert_contracts(self, start_iter: int) -> None:
        """checks=True pre-flight (repro.core.checks): the initial
        state is finite and the compiled step's carry is structure/
        shape/dtype-stable — the latter via ``jax.eval_shape``, so
        nothing is dispatched before the verdict."""
        data, rep = self.bundle.data, self.bundle.replicated
        _checks.assert_all_finite(
            {"data": data, "replicated": rep}, "initial bundle state")
        if self.chunk == 1:
            spec = _checks.eval_step_spec(self.step, data, rep)
            _checks.assert_carry_stable(
                self.step, data, spec[0], "per-step data carry")
            return
        k = min(self.chunk, max(self.max_iter - start_iter, 1))
        step = self._scan_step(k)
        last = self._last_init()
        if last is not None:
            spec = _checks.eval_step_spec(step, data, rep,
                                          np.int32(start_iter), last)
        else:
            spec = _checks.eval_step_spec(step, data, rep,
                                          np.int32(start_iter))
        _checks.assert_carry_stable(
            step, (data, rep), (spec[0], spec[1]), "chunked scan carry")

    # ------------------------------------------------------------- run
    def run(self, start_iter: int = 0) -> Bundle:
        if self.checks:
            self._assert_contracts(start_iter)
        if self.chunk == 1 and self.options.resilience is None:
            return self._run_per_step(start_iter)
        # supervised runs always take the chunked loop: its chunk-boundary
        # host sync is where snapshots, validation and rollback live, and
        # make_scan_step(chunk=1) reproduces per-step semantics exactly
        return self._run_chunked(start_iter)

    @property
    def _skips_cost(self) -> bool:
        return self.cost_every > 1 and self.step_fn_light is not None

    @property
    def _cost_per_chunk(self) -> bool:
        """Chunk-granular objective (``engine.make_chunk_cost_step``):
        the scan runs only the cost-free step and the objective is
        evaluated once per dispatch, on the chunk's final state.  Keyed
        on the *requested* ``cost_every="chunk"`` (an integer cadence
        with a step_fn_cost present must honor the integer, not switch
        modes); per-step runs (chunk=1) evaluate every iteration
        anyway, so they use the plain path."""
        return self._per_chunk and self.chunk > 1

    def _progress_event(self, start: int, k: int, dt: float) -> dict:
        """One chunk-boundary progress event (``RunOptions.progress_fn``,
        DESIGN.md §20): iteration range just completed, the newest
        evaluated objective, wall time, and the convergence verdict."""
        return {"kind": "chunk", "start": int(start), "iters": int(k),
                "done": int(start + k),
                "cost": (self.log.costs[-1] if self.log.costs else None),
                "dt_s": float(dt),
                "converged_at": self.log.converged_at}

    def _dispatch_chunk(self, data, rep, last, i: int, k: int):
        """One fused-chunk dispatch + its host sync, as a unit the
        resilience supervisor can retry (the ``dispatch`` chaos fault
        point lives here, so injected failures tick per attempt)."""
        _chaos.maybe_raise("dispatch", step=i)
        if self._cost_per_chunk or self._skips_cost:
            data, rep, last, trace = self._scan_step(k)(
                data, rep, np.int32(i), last)
        else:
            data, rep, trace = self._scan_step(k)(data, rep, np.int32(i))
        costs = trace["cost"] if isinstance(trace, dict) else trace
        costs = np.asarray(jax.device_get(jax.block_until_ready(costs)))
        return data, rep, last, costs

    def _run_chunked(self, start_iter: int) -> Bundle:
        data, rep = self.bundle.data, self.bundle.replicated
        last = self._last_init()
        sup = None
        if self.options.resilience is not None:
            from repro.resilience.supervisor import Supervisor
            sup = Supervisor(self.options.resilience, self.bundle,
                             start_iter=start_iter,
                             last_init=self._last_init)
        ema = None
        compiled_ks = set()
        i = start_iter
        while i < self.max_iter:
            k = min(self.chunk, self.max_iter - i)
            first_call = k not in compiled_ks
            compiled_ks.add(k)
            t0 = time.perf_counter()
            if sup is not None:
                sup.begin_chunk(data, rep, last, i, len(self.log.costs))
                try:
                    data, rep, last, costs = sup.dispatch(
                        self._dispatch_chunk, data, rep, last, i, k)
                    if _chaos.is_active():  # silent-corruption injector
                        data = _chaos.poison_tree("carry_nan", data,
                                                  step=i)
                    sup.validate(data, rep, costs, i + k - 1)
                except DivergenceError as e:
                    sup.report.wall_time_lost_s += \
                        time.perf_counter() - t0
                    data, rep, last, i = sup.rollback(e, self.log)
                    ema = None  # timings across a rollback don't compare
                    continue
            else:
                data, rep, last, costs = self._dispatch_chunk(
                    data, rep, last, i, k)
                if _chaos.is_active():
                    data = _chaos.poison_tree("carry_nan", data, step=i)
            dt = time.perf_counter() - t0
            if self.checks:
                _checks.assert_costs_finite(
                    costs, f"chunk ending at iteration {i + k - 1}")
                _checks.assert_all_finite(
                    {"data": data, "replicated": rep},
                    f"state after iteration {i + k - 1}")
            self.log.times.extend([dt / k] * k)
            self.log.costs.extend(float(c) for c in np.ravel(costs))
            # a chunk length's first dispatch includes XLA compilation
            # (e.g. the shorter tail program) — keep it out of the
            # straggler watchdog and its EMA
            if not first_call:
                if ema is not None and dt > self.straggler_factor * ema:
                    self.log.straggler_steps.append(i)
                    if self.checkpoint_fn is not None:
                        self.checkpoint_fn(
                            self.bundle.with_data(data, replicated=rep),
                            i + k - 1)
                ema = dt if ema is None else 0.9 * ema + 0.1 * dt
            if (self.checkpoint_every and self.checkpoint_fn is not None
                    and (i + k) // self.checkpoint_every
                    > i // self.checkpoint_every):
                self.checkpoint_fn(
                    self.bundle.with_data(data, replicated=rep), i + k - 1)
            i += k
            # a local verdict, not `converged_at is not None`: a rerun of
            # a warmed driver (benchmarks' timed_round) must not break on
            # a previous run's convergence record
            conv = self._converged()
            if conv:
                self.log.converged_at = i - 1
            if self.progress_fn is not None:
                ctl = self.progress_fn(self._progress_event(i - k, k, dt))
                # only a dict return is a control signal — callbacks
                # that happen to return something else (a logging
                # listcomp, an appended list) must stay inert
                if isinstance(ctl, dict) and ctl.get("stop"):
                    self.log.cancelled_at = i - 1
                    break
            if conv:
                break
        # accumulate across reruns of a warmed driver, mirroring the
        # batched driver's per-instance counter
        self.log.iters_run = (self.log.iters_run or 0) + (i - start_iter)
        if sup is not None:
            self.recovery = sup.finalize()
        return self.bundle.with_data(data, replicated=rep)

    def _run_per_step(self, start_iter: int) -> Bundle:
        data, rep = self.bundle.data, self.bundle.replicated
        ema = None
        n_done = 0
        for i in range(start_iter, self.max_iter):
            t0 = time.perf_counter()
            if _chaos.is_active():  # unsupervised: a fault kills the run
                _chaos.maybe_raise("dispatch", step=i)
            if self._skips_cost and i % self.cost_every != 0:
                # off the cost grid: run the objective-free step and
                # carry the last evaluated cost forward
                data, aux = self._light_step(data, rep)
                if self.light_updates_replicated and \
                        self.update_replicated is not None:
                    rep = self.update_replicated(rep, aux)
                jax.block_until_ready(jax.tree.leaves(data)[0])
                dt = time.perf_counter() - t0
                self.log.times.append(dt)
                self.log.costs.append(self.log.costs[-1]
                                      if self.log.costs else float("inf"))
            else:
                data, out = self.step(data, rep)
                cost = out["cost"] if isinstance(out, dict) else out
                cost = cost.block_until_ready()
                dt = time.perf_counter() - t0
                self.log.times.append(dt)
                cost_val = float(np.asarray(jax.device_get(cost)))
                if self.checks:
                    _checks.assert_costs_finite(
                        np.asarray([cost_val]), f"iteration {i}")
                    _checks.assert_all_finite(
                        {"data": data}, f"state after iteration {i}")
                self.log.costs.append(cost_val)
                if self.update_replicated is not None:
                    rep = self.update_replicated(rep, out)
            if _chaos.is_active():
                data = _chaos.poison_tree("carry_nan", data, step=i)
            # straggler watchdog: a step far beyond the EMA is logged and
            # (in multi-host deployment) triggers an early checkpoint
            if ema is not None and dt > self.straggler_factor * ema:
                self.log.straggler_steps.append(i)
                if self.checkpoint_fn is not None:
                    self.checkpoint_fn(
                        self.bundle.with_data(data, replicated=rep), i)
            ema = dt if ema is None else 0.9 * ema + 0.1 * dt
            if (self.checkpoint_every and self.checkpoint_fn is not None
                    and (i + 1) % self.checkpoint_every == 0):
                self.checkpoint_fn(
                    self.bundle.with_data(data, replicated=rep), i)
            n_done += 1
            conv = self._converged()
            if conv:
                self.log.converged_at = i
            if self.progress_fn is not None:
                ctl = self.progress_fn(self._progress_event(i, 1, dt))
                if isinstance(ctl, dict) and ctl.get("stop"):
                    self.log.cancelled_at = i
                    break
            if conv:
                break
        self.log.iters_run = (self.log.iters_run or 0) + n_done
        return self.bundle.with_data(data, replicated=rep)


# --------------------------------------------------------------------
# Batched multi-instance execution (solve_many, DESIGN.md §19)
# --------------------------------------------------------------------


class BatchedDriver:
    """Drive one *bucket* of stacked instances to per-instance
    convergence.

    Same knobs as :class:`IterativeDriver` (one ``RunOptions``), same
    chunked loop — but the carry is the batched state ``{"d", "r"
    [, "last"]}`` (every leaf leading with the instance axis B) plus a
    bucket-shared replicated tree, and convergence/logging/early exit
    are per instance:

    - each instance gets its own :class:`RunLog` (costs, times,
      ``converged_at``, ``iters_run``);
    - a converged instance's lane freezes via the active mask
      (``engine.freeze_where``) and stops accruing ``iters_run`` —
      frozen lanes still occupy device FLOPs until re-compaction;
    - when the active fraction drops below ``recompact_below`` the
      bucket re-compacts: retired lanes spill to host, live lanes
      re-stack into a smaller program (the jitted step retraces once
      per distinct batch size — at most ``log2(B)`` recompiles);
    - checkpoints always use the *full-bucket* layout
      (:meth:`snapshot_payload` scatters the compacted state + retired
      spills back to B0 rows), so restore is independent of when
      compaction happened;
    - ``RunOptions.resilience`` wraps each dispatch in the same
      classify → bounded-retry → ring-then-disk rollback discipline as
      the single-instance ``Supervisor``, with snapshots extended to
      the batch bookkeeping (:class:`_BatchSupervisor`).

    ``orig_indices`` maps each stacked row to its position in the
    caller's instance list; ``-1`` marks mesh-alignment filler lanes
    (duplicated data, inactive from the start, never reported).
    """

    def __init__(self, step_fn: Callable, bundle: Bundle, *,
                 options: Optional[RunOptions] = None,
                 orig_indices=None, recompact_below: float = 0.5):
        self.options = options = options or RunOptions()
        self.step_fn = step_fn
        self.step_fn_light = options.step_fn_light
        self.step_fn_cost = options.step_fn_cost
        self.update_replicated = options.update_replicated
        self.light_updates_replicated = options.light_updates_replicated
        self.max_iter = options.max_iter
        self.tol = options.tol
        self.cost_window = options.cost_window
        self.checkpoint_fn = options.checkpoint_fn
        self.checks = options.checks
        self.progress_fn = options.progress_fn
        self.chunk = max(min(int(options.chunk),
                             max(int(options.max_iter), 1)), 1)
        self.checkpoint_every = options.checkpoint_every
        if self.checkpoint_every:
            self.checkpoint_every = min(int(self.checkpoint_every),
                                        max(int(options.max_iter), 1))
        self._per_chunk = options.cost_every == "chunk"
        if self._per_chunk:
            if options.step_fn_cost is None or options.step_fn_light is None:
                raise ValueError(
                    'cost_every="chunk" requires step_fn_cost AND '
                    "step_fn_light (see IterativeDriver)")
            self.cost_every = 1
        else:
            if options.step_fn_cost is not None:
                raise ValueError(
                    "step_fn_cost is only consumed by the per-chunk "
                    'objective mode — pass cost_every="chunk" with it')
            self.cost_every = max(int(options.cost_every), 1)
        self.recompact_below = float(recompact_below)

        state = dict(bundle.data)
        if set(state) != {"d", "r"}:
            raise ValueError(
                f'BatchedDriver expects bundle.data == {{"d", "r"}} '
                f"(batched data + batched replicated), got "
                f"{sorted(state)}")
        B = jax.tree.leaves(state["d"])[0].shape[0]
        if self._cost_per_chunk:
            state["last"] = init_batched_cost_like(
                self.step_fn_cost, state, bundle.replicated)
        elif self._skips_cost:
            state["last"] = init_batched_out_like(
                self.step_fn, state, bundle.replicated)
        self.bundle = bundle.with_data(state)
        self.state = state
        self.B0 = B
        self.orig = (np.asarray(orig_indices, dtype=np.int64)
                     if orig_indices is not None
                     else np.arange(B, dtype=np.int64))
        if len(self.orig) != B:
            raise ValueError(
                f"orig_indices has {len(self.orig)} entries for a "
                f"batch of {B}")
        # bookkeeping lives in full-layout row space [0, B0); ``slots``
        # maps the current compacted position s -> row slots[s]
        self.slots = np.arange(B, dtype=np.int64)
        self.active = self.orig >= 0
        self.iters_run = np.zeros(B, np.int64)
        self.converged_at = np.full(B, -1, np.int64)
        self.logs = [RunLog(iters_run=0) for _ in range(B)]
        self.retired: Dict[int, Any] = {}    # row -> host instance state
        self.recovery = None
        self._iters_at_start = self.iters_run.copy()
        self._compiled: Dict[int, Callable] = {}

    # ------------------------------------------------------ compilation
    @property
    def _skips_cost(self) -> bool:
        return self.cost_every > 1 and self.step_fn_light is not None

    @property
    def _cost_per_chunk(self) -> bool:
        return self._per_chunk and self.chunk > 1

    def _scan_step(self, k: int) -> Callable:
        """Batched fused step, compiled once per chunk length; batch-
        size changes from re-compaction retrace inside the same jit."""
        if k not in self._compiled:
            if self._cost_per_chunk:
                self._compiled[k] = make_batched_chunk_cost_step(
                    self.step_fn_light, self.step_fn_cost, self.bundle,
                    self.state, chunk=k,
                    update_replicated=self.update_replicated)
            else:
                self._compiled[k] = make_batched_scan_step(
                    self.step_fn, self.bundle, self.state, chunk=k,
                    update_replicated=self.update_replicated,
                    fn_light=self.step_fn_light,
                    cost_every=self.cost_every,
                    light_updates_replicated=self.light_updates_replicated)
        return self._compiled[k]

    # ------------------------------------------------------ convergence
    def _converged_log(self, log: RunLog) -> bool:
        if not self.tol:
            return False
        c = log.costs
        stride = (self.chunk if self._cost_per_chunk
                  else self.cost_every if self._skips_cost else 1)
        w = self.cost_window * stride
        if len(c) <= w:
            return False
        prev, cur = c[-w - 1], c[-1]
        return abs(prev - cur) <= self.tol * max(abs(prev), 1e-12)

    # -------------------------------------------------------- dispatch
    def _dispatch_chunk(self, state, mask, i: int, k: int):
        _chaos.maybe_raise("dispatch", step=i)
        state, trace = self._scan_step(k)(
            state, self.bundle.replicated, mask, np.int32(i))
        costs = trace["cost"] if isinstance(trace, dict) else trace
        costs = np.asarray(jax.device_get(jax.block_until_ready(costs)))
        return state, costs                      # costs: (k, B_current)

    def _log_chunk(self, costs, dt: float, i: int, k: int) -> None:
        per = dt / max(k, 1)
        for s, row in enumerate(self.slots):
            row = int(row)
            if not self.active[row]:
                continue
            log = self.logs[row]
            log.costs.extend(float(c) for c in costs[:, s])
            log.times.extend([per] * k)
            self.iters_run[row] += k
            log.iters_run = int(self.iters_run[row])
            if self._converged_log(log):
                self.active[row] = False
                self.converged_at[row] = i + k - 1
                log.converged_at = i + k - 1

    def _progress_event(self, start: int, k: int, dt: float) -> dict:
        """Chunk-boundary progress event with a per-instance section
        keyed by the caller's original instance index.  Lanes retired by
        re-compaction no longer appear — their final state was already
        reported in the chunk event that marked them converged."""
        inst = {}
        for row in self.slots:
            row = int(row)
            j = int(self.orig[row])
            if j < 0:
                continue                         # mesh-alignment filler
            log = self.logs[row]
            inst[j] = {"cost": (log.costs[-1] if log.costs else None),
                       "iters_run": int(self.iters_run[row]),
                       "converged_at": (int(self.converged_at[row])
                                        if self.converged_at[row] >= 0
                                        else None)}
        return {"kind": "chunk", "start": int(start), "iters": int(k),
                "done": int(start + k), "dt_s": float(dt),
                "instances": inst}

    def _apply_control(self, ctl: dict, it: int) -> None:
        """Apply a ``progress_fn`` control return (§21): freeze the
        named original-index instances' lanes at this chunk boundary
        exactly like converged ones — deactivated here, retired by the
        ``_maybe_recompact`` pass that follows the progress callback —
        so sibling lanes' trajectories are unperturbed.  ``stop`` ends
        the whole bucket (every still-active lane is cancelled)."""
        if ctl.get("stop"):
            targets = [int(j) for j in self.orig if j >= 0]
        else:
            targets = [int(j) for j in (ctl.get("cancel_instances")
                                        or ())]
        for j in targets:
            rows = np.flatnonzero(self.orig == j)
            if rows.size == 0:
                continue
            row = int(rows[0])
            if not self.active[row]:
                continue
            self.active[row] = False
            self.logs[row].cancelled_at = it

    # ---------------------------------------------------- re-compaction
    def _maybe_recompact(self) -> None:
        cur = self.active[self.slots]
        n_act = int(cur.sum())
        B = len(self.slots)
        if n_act == 0 or n_act >= self.recompact_below * B:
            return
        keep = np.flatnonzero(cur)
        parts = max(self.bundle.n_partitions, 1)
        if parts > 1:
            need = (-len(keep)) % parts
            if need:
                # keep some frozen lanes as filler so the batch axis
                # stays divisible across the mesh
                frozen = np.flatnonzero(~cur)[:need]
                keep = np.sort(np.concatenate([keep, frozen]))
        if len(keep) == B:
            return
        host = _persist.to_host(self.state)
        keep_set = set(keep.tolist())
        for s in range(B):
            if s not in keep_set:
                self.retired[int(self.slots[s])] = jax.tree.map(
                    lambda x, _s=s: x[_s], host)
        compact = jax.tree.map(lambda x: x[keep], host)
        self.state = _persist.readmit_batched(self.bundle, compact)
        self.bundle = self.bundle.with_data(self.state)
        self.slots = self.slots[keep]

    # ------------------------------------------------------ checkpoints
    def payload_template(self) -> Dict[str, Any]:
        """Shape/tree template of :meth:`snapshot_payload` — hand it to
        ``checkpoint.checkpointer.restore`` as ``like``.  The state side
        is always the full B0-row layout, so the template is independent
        of the current compaction."""
        full = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(
                (self.B0,) + tuple(x.shape[1:]), x.dtype), self.state)
        return {"state": full,
                "batch": {"active": np.zeros(self.B0, bool),
                          "iters_run": np.zeros(self.B0, np.int64),
                          "converged_at": np.zeros(self.B0, np.int64)}}

    def snapshot_payload(self) -> Dict[str, Any]:
        """Full-bucket checkpoint payload: the compacted device state
        scattered back to B0 rows, retired host spills filled in, plus
        the per-instance bookkeeping arrays."""
        host = _persist.to_host(self.state)
        full = _persist.scatter_batched(host, self.slots, self.B0)
        for row, inst in self.retired.items():
            _persist.set_instance(full, row, inst)
        return {"state": full,
                "batch": {"active": self.active.copy(),
                          "iters_run": self.iters_run.copy(),
                          "converged_at": self.converged_at.copy()}}

    def load_payload(self, payload, *, rewind_logs: bool = False) -> None:
        """Adopt a full-layout payload: resume (fresh logs from the
        restored boundary, mirroring single-instance resume) or mid-run
        disk rollback (``rewind_logs=True`` truncates each lane's log to
        the iterations it had logged at the checkpoint)."""
        batch = payload["batch"]
        iters = np.asarray(jax.device_get(batch["iters_run"]),
                           dtype=np.int64)
        conv = np.asarray(jax.device_get(batch["converged_at"]),
                          dtype=np.int64)
        act = np.asarray(jax.device_get(batch["active"])).astype(bool)
        if rewind_logs:
            base = self._iters_at_start
            for row in range(self.B0):
                n = int(max(iters[row] - base[row], 0))
                log = self.logs[row]
                del log.costs[n:]
                del log.times[n:]
                log.iters_run = int(iters[row])
                log.converged_at = (int(conv[row]) if conv[row] >= 0
                                    else None)
        else:
            self.logs = [RunLog(iters_run=int(iters[r]),
                                converged_at=(int(conv[r])
                                              if conv[r] >= 0 else None))
                         for r in range(self.B0)]
        self.active, self.iters_run, self.converged_at = act, iters, conv
        self.slots = np.arange(self.B0, dtype=np.int64)
        self.retired = {}
        self.state = _persist.readmit_batched(self.bundle,
                                              payload["state"])
        self.bundle = self.bundle.with_data(self.state)

    # ---------------------------------------------------------- results
    def host_states(self) -> Dict[int, Any]:
        """Per-row final instance states (host): current lanes sliced
        out of the device state, retired lanes from their spills."""
        host = _persist.to_host(self.state)
        out = dict(self.retired)
        for s, row in enumerate(self.slots):
            out[int(row)] = jax.tree.map(lambda x, _s=s: x[_s], host)
        return out

    # ------------------------------------------------------------- run
    def run(self, start_iter: int = 0) -> "BatchedDriver":
        self._iters_at_start = self.iters_run.copy()
        sup = None
        if self.options.resilience is not None:
            sup = _BatchSupervisor(self.options.resilience, self)
        i = start_iter
        while i < self.max_iter and bool(self.active.any()):
            k = min(self.chunk, self.max_iter - i)
            mask = jnp.asarray(self.active[self.slots])
            t0 = time.perf_counter()
            if sup is not None:
                sup.begin_chunk(i)
                try:
                    state, costs = sup.dispatch(
                        self._dispatch_chunk, self.state, mask, i, k)
                    if _chaos.is_active():
                        state = dict(state, d=_chaos.poison_tree(
                            "carry_nan", state["d"], step=i))
                    sup.validate(state, costs, i + k - 1)
                except DivergenceError as e:
                    sup.report.wall_time_lost_s += \
                        time.perf_counter() - t0
                    i = sup.rollback(e)
                    continue
            else:
                state, costs = self._dispatch_chunk(
                    self.state, mask, i, k)
                if _chaos.is_active():
                    state = dict(state, d=_chaos.poison_tree(
                        "carry_nan", state["d"], step=i))
            self.state = state
            self.bundle = self.bundle.with_data(state)
            dt = time.perf_counter() - t0
            if self.checks:
                _checks.assert_costs_finite(
                    costs, f"bucket chunk ending at iteration {i + k - 1}")
                _checks.assert_all_finite(
                    {"data": state["d"], "replicated": state["r"]},
                    f"bucket state after iteration {i + k - 1}")
            self._log_chunk(costs, dt, i, k)
            if (self.checkpoint_every and self.checkpoint_fn is not None
                    and (i + k) // self.checkpoint_every
                    > i // self.checkpoint_every):
                self.checkpoint_fn(self.snapshot_payload(), i + k - 1)
            i += k
            if self.progress_fn is not None:
                ctl = self.progress_fn(self._progress_event(i - k, k, dt))
                if isinstance(ctl, dict):
                    self._apply_control(ctl, i - 1)
            self._maybe_recompact()
        if sup is not None:
            self.recovery = sup.finalize()
        return self


class _BatchSupervisor:
    """Retry/rollback supervision for one solve_many bucket.

    The single-instance ``Supervisor`` snapshots ``(data, rep, last)``
    and rewinds one RunLog; a bucket's recovery state additionally
    spans the active mask, per-instance counters and logs, the slot
    map, and the retired spills — so the batched driver carries its own
    snapshot ring with the same classify → bounded-retry →
    ring-then-disk rollback discipline (DESIGN.md §18/§19).  Disk
    fallback restores the full-bucket checkpoint layout written by
    :meth:`BatchedDriver.snapshot_payload`.
    """

    def __init__(self, cfg: ResilienceConfig, driver: BatchedDriver):
        from repro.kernels import common as _kcommon
        self.cfg = cfg
        self.driver = driver
        self.report = RecoveryReport()
        self.ring: deque = deque(maxlen=cfg.ring)
        # mirror Supervisor: the chaos seed wins during a drill so
        # recovery reports replay deterministically
        _seed = _chaos.active_seed()
        self.rng = np.random.default_rng(cfg.seed if _seed is None
                                         else _seed)
        self._rollbacks_done = 0
        self._last_restored_it: Optional[int] = None
        self._kernel_baseline = len(_kcommon.kernel_fallbacks())

    # ------------------------------------------------------- snapshots
    def begin_chunk(self, it: int) -> None:
        d = self.driver
        self.ring.append({
            "it": it,
            "state": _persist.to_host(d.state),
            "slots": d.slots.copy(), "active": d.active.copy(),
            "iters": d.iters_run.copy(), "conv": d.converged_at.copy(),
            "logs_len": [len(log.costs) for log in d.logs],
            "retired": dict(d.retired)})

    def _restore(self, snap) -> int:
        d = self.driver
        d.slots = snap["slots"].copy()
        d.active = snap["active"].copy()
        d.iters_run = snap["iters"].copy()
        d.converged_at = snap["conv"].copy()
        d.retired = dict(snap["retired"])
        for row in range(d.B0):
            log = d.logs[row]
            n = snap["logs_len"][row]
            del log.costs[n:]
            del log.times[n:]
            log.iters_run = int(d.iters_run[row])
            log.converged_at = (int(d.converged_at[row])
                                if d.converged_at[row] >= 0 else None)
        d.state = _persist.readmit_batched(d.bundle, snap["state"])
        d.bundle = d.bundle.with_data(d.state)
        return snap["it"]

    def _exhausted(self, msg: str):
        """Budget-exhaustion error carrying the recovery ledger, so the
        serving quarantine path (§21) can attach it per request."""
        from repro.resilience.errors import ResilienceExhausted
        err = ResilienceExhausted(msg)
        err.report = self.finalize()
        return err

    # --------------------------------------------------------- dispatch
    def dispatch(self, fn: Callable, state, mask, i: int, k: int):
        from repro.resilience.errors import classify
        attempt = 0
        while True:
            t0 = time.perf_counter()
            try:
                return fn(state, mask, i, k)
            except Exception as e:
                kind = classify(e, self.cfg.transient_types)
                self.report.record_fault("dispatch", i, e)
                self.report.wall_time_lost_s += time.perf_counter() - t0
                if kind != "transient":
                    raise
                if attempt >= self.cfg.max_retries:
                    raise self._exhausted(
                        f"bucket chunk dispatch at iteration {i} still "
                        f"failing after {attempt} retries: {e}") from e
                t1 = time.perf_counter()
                self.report.retries += 1
                time.sleep(self._backoff(attempt))
                # the failed call may have consumed the donated state
                state = _persist.readmit_batched(
                    self.driver.bundle, self.ring[-1]["state"])
                self.report.wall_time_lost_s += time.perf_counter() - t1
                attempt += 1

    def _backoff(self, attempt: int) -> float:
        base = self.cfg.backoff_s * self.cfg.backoff_factor ** attempt
        return base * (1.0 + self.cfg.jitter
                       * float(self.rng.uniform(-1.0, 1.0)))

    # ------------------------------------------------------- divergence
    def validate(self, state, costs, it: int) -> None:
        try:
            _checks.assert_costs_finite(
                costs, f"resilience: bucket chunk ending at "
                       f"iteration {it}")
            _checks.assert_all_finite(
                {"data": state["d"], "replicated": state["r"]},
                f"resilience: bucket state after iteration {it}")
        except _checks.CheckError as e:
            raise DivergenceError(str(e), step=it) from e

    def rollback(self, err: DivergenceError) -> int:
        self.report.record_fault("divergence", err.step, err)
        if self._rollbacks_done >= self.cfg.max_rollbacks:
            raise self._exhausted(
                f"rollback budget ({self.cfg.max_rollbacks}) exhausted; "
                f"latest divergence: {err}") from err
        self._rollbacks_done += 1
        self.report.rollbacks += 1
        t0 = time.perf_counter()
        # same-boundary walk-back (see Supervisor.rollback): restoring
        # the boundary that already diverged once would replay the same
        # divergence unless a rescale hook perturbs it
        if (self.ring and self.cfg.rollback_rescale is None
                and self.ring[-1]["it"] == self._last_restored_it):
            self.ring.pop()
        if self.ring:
            it = self._restore(self.ring.pop())
        else:
            it = self._restore_from_disk(err)
        self._last_restored_it = it
        if self.cfg.rollback_rescale is not None:
            d = self.driver
            d.state = dict(d.state, r=self.cfg.rollback_rescale(
                d.state["r"], self._rollbacks_done))
            d.bundle = d.bundle.with_data(d.state)
        self.report.wall_time_lost_s += time.perf_counter() - t0
        return it

    def _restore_from_disk(self, err: DivergenceError) -> int:
        if self.cfg.checkpoint_dir is None:
            raise self._exhausted(
                "snapshot ring exhausted and no checkpoint_dir to fall "
                "back to; latest divergence: " + str(err)) from err
        from repro.checkpoint import checkpointer as ckpt
        step, _skipped = ckpt.latest_valid_step(self.cfg.checkpoint_dir)
        if step is None:
            raise self._exhausted(
                f"snapshot ring exhausted and no valid checkpoint under "
                f"{self.cfg.checkpoint_dir!r}; latest divergence: {err}"
            ) from err
        payload, _ = ckpt.restore(self.cfg.checkpoint_dir, step,
                                  self.driver.payload_template())
        self.driver.load_payload(payload, rewind_logs=True)
        self.report.checkpoint_restores += 1
        return step

    # --------------------------------------------------------- wrap-up
    def finalize(self):
        from repro.kernels import common as _kcommon
        events = _kcommon.kernel_fallbacks()[self._kernel_baseline:]
        self.report.kernel_fallbacks = [dict(e) for e in events]
        return self.report
