"""IterativeDriver: the paper's driver program, generalized.

Runs phase (a) configuration, (b) parallelization (bundle creation), and
(c) iterative task execution with convergence tracking — plus the parts a
production system needs that Spark gave the paper for free or not at all:
checkpoint/restart hooks, straggler watchdog (step-time EMA), and elastic
re-partitioning on restore (``repro.checkpoint``).

Execution modes (DESIGN.md §12):

- ``chunk=1``  — one dispatch + one host sync per iteration (the paper's
  Spark driver loop, and the baseline for ``benchmarks/bench_driver``);
- ``chunk=K>1`` — K iterations fused on-device via
  ``core.engine.make_scan_step``: the host sees one dispatch, one
  ``(K,)`` cost buffer, and one convergence check per chunk.  Broadcast
  state (``update_replicated``) is folded into the scan carry, so
  learners with per-iteration driver broadcasts (SCDL's dictionaries)
  run through this same generic loop.
"""
from __future__ import annotations

import time
import warnings
from dataclasses import dataclass, field, fields, replace
from typing import Any, Callable, Dict, List, Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import checks as _checks
from repro.core.bundle import Bundle
from repro.core.engine import (init_cost_like, init_out_like,
                               make_chunk_cost_step, make_scan_step,
                               make_step)
# dependency-light resilience pieces (chaos injectors are no-ops unless a
# ChaosConfig is activated; the supervisor itself is imported lazily only
# when RunOptions.resilience is set)
from repro.resilience import chaos as _chaos
from repro.resilience.errors import DivergenceError
from repro.resilience.recovery import ResilienceConfig


@dataclass(frozen=True)
class RunOptions:
    """Everything the driver needs beyond ``(step_fn, bundle)``.

    One dataclass replaces the former kwarg sprawl of
    ``IterativeDriver.__init__`` (DESIGN.md §14).  Two kinds of fields:

    - *run control* — iteration budget, convergence, chunking,
      observability and checkpoint cadence.  These are what callers of
      :func:`repro.core.problem.solve` override per run.
    - *step wiring* — the cost-free/objective-only step variants and the
      broadcast-update hook.  Hand-wired drivers set these directly;
      ``solve()`` derives them from a :class:`~repro.core.problem.Problem`
      declaration.

    ``cost_every`` accepts an int (evaluate the objective every k-th
    iteration; requires ``step_fn_light``) or the string ``"chunk"``
    (one evaluation per dispatched chunk on its final state; requires
    ``step_fn_cost`` — the fastest observability mode, DESIGN.md §13).
    """
    # run control
    max_iter: int = 300
    tol: float = 1e-4
    chunk: int = 8
    cost_every: Union[int, str] = 1
    cost_window: int = 3
    straggler_factor: float = 3.0
    checkpoint_every: int = 0
    checkpoint_fn: Optional[Callable] = None
    # runtime contract sanitizers (repro.core.checks; also force-enabled
    # by REPRO_CHECKS=1 when going through solve()).  Off by default:
    # the disabled path adds zero dispatches or host transfers.
    checks: bool = False
    # supervised execution (repro.resilience, DESIGN.md §18): retry,
    # divergence rollback, recovery report.  None = unsupervised; the
    # disabled path adds zero dispatches or host transfers.
    resilience: Optional[ResilienceConfig] = None
    # step wiring
    step_fn_light: Optional[Callable] = None
    step_fn_cost: Optional[Callable] = None
    update_replicated: Optional[Callable] = None
    light_updates_replicated: bool = False

    def __post_init__(self):
        if isinstance(self.cost_every, str) and self.cost_every != "chunk":
            raise ValueError(
                f'cost_every must be a positive int or the string '
                f'"chunk", got {self.cost_every!r}')

    def merged_with(self, **overrides) -> "RunOptions":
        """A copy with the non-None entries of ``overrides`` applied
        (unknown keys raise, matching dataclasses.replace)."""
        return replace(self, **{k: v for k, v in overrides.items()
                                if v is not None})


_RUN_OPTION_NAMES = tuple(f.name for f in fields(RunOptions))


@dataclass
class RunLog:
    costs: List[float] = field(default_factory=list)
    times: List[float] = field(default_factory=list)
    straggler_steps: List[int] = field(default_factory=list)
    converged_at: Optional[int] = None

    @property
    def total_seconds(self) -> float:
        return float(np.sum(self.times)) if self.times else 0.0


class IterativeDriver:
    """Drive step(state) -> (state, cost) to convergence.

    ``step_fn(data_local, replicated, axes) -> (data_local', out)`` is
    compiled once (per chunk length) and applied until the relative cost
    change drops below ``tol`` (the paper's epsilon) or ``max_iter`` is
    hit.  ``out`` is either a scalar cost or a dict with a ``"cost"``
    entry plus optional replicated state consumed by
    ``options.update_replicated``.

    All remaining configuration lives in one :class:`RunOptions`.  The
    former individual kwargs (``max_iter=``, ``step_fn_light=``, ...) are
    still accepted but deprecated: they are mapped onto ``options`` with
    a ``DeprecationWarning``.
    """

    def __init__(self, step_fn: Callable, bundle: Bundle, *,
                 options: Optional[RunOptions] = None, **legacy):
        if legacy:
            unknown = set(legacy) - set(_RUN_OPTION_NAMES)
            if unknown:
                raise TypeError(
                    f"IterativeDriver got unexpected kwargs {sorted(unknown)}; "
                    f"valid RunOptions fields: {list(_RUN_OPTION_NAMES)}")
            warnings.warn(
                "passing IterativeDriver configuration as individual "
                f"kwargs ({sorted(legacy)}) is deprecated; pass "
                "options=RunOptions(...) instead (DESIGN.md §14)",
                DeprecationWarning, stacklevel=2)
            options = replace(options or RunOptions(), **legacy)
        self.options = options = options or RunOptions()
        self.bundle = bundle
        self.step_fn = step_fn
        self.step_fn_light = options.step_fn_light
        self.step_fn_cost = options.step_fn_cost
        self.update_replicated = options.update_replicated
        self.light_updates_replicated = options.light_updates_replicated
        self.max_iter = options.max_iter
        self.tol = options.tol
        self.cost_window = options.cost_window
        self.straggler_factor = options.straggler_factor
        self.checkpoint_every = options.checkpoint_every
        self.checkpoint_fn = options.checkpoint_fn
        self.checks = options.checks
        # a chunk longer than the whole run would compile a scan program
        # that only ever executes its shorter tail — clamp so the one
        # program that runs is the one that was asked for
        self.chunk = max(min(int(options.chunk),
                             max(int(options.max_iter), 1)), 1)
        self._per_chunk = options.cost_every == "chunk"
        if self._per_chunk:
            # both halves of the per-chunk contract, or the driver would
            # silently fall back to evaluating the objective every
            # iteration (see _cost_per_chunk)
            if options.step_fn_cost is None or options.step_fn_light is None:
                raise ValueError(
                    'cost_every="chunk" requires step_fn_cost (a '
                    "standalone objective over the post-iteration "
                    "state) AND step_fn_light (the cost-free step the "
                    "scan body runs)")
            self.cost_every = 1
        else:
            if options.step_fn_cost is not None:
                raise ValueError(
                    "step_fn_cost is only consumed by the per-chunk "
                    'objective mode — pass cost_every="chunk" with it, '
                    f"not cost_every={options.cost_every!r} (which "
                    f"would silently ignore it)")
            self.cost_every = max(int(options.cost_every), 1)
        self.log = RunLog()
        # RecoveryReport from the last supervised run (None when
        # resilience is off or run() has not executed yet)
        self.recovery = None
        self._compiled: Dict[int, Callable] = {}

    # ------------------------------------------------------ compilation
    def _scan_step(self, k: int) -> Callable:
        """Fused K-iteration step, compiled once per distinct chunk
        length (the tail chunk of a run compiles a second, shorter
        program)."""
        if k not in self._compiled:
            if self._cost_per_chunk:
                self._compiled[k] = make_chunk_cost_step(
                    self.step_fn_light, self.step_fn_cost, self.bundle,
                    chunk=k, update_replicated=self.update_replicated)
            else:
                self._compiled[k] = make_scan_step(
                    self.step_fn, self.bundle, chunk=k,
                    update_replicated=self.update_replicated,
                    fn_light=self.step_fn_light,
                    cost_every=self.cost_every,
                    light_updates_replicated=self.light_updates_replicated)
        return self._compiled[k]

    @property
    def step(self) -> Callable:
        """The per-iteration compiled step (chunk=1 legacy path)."""
        if "per_step" not in self._compiled:
            self._compiled["per_step"] = make_step(self.step_fn,
                                                   self.bundle)
        return self._compiled["per_step"]

    @property
    def _light_step(self) -> Callable:
        """Cost-free per-iteration step (chunk=1 path, off-grid
        iterations of ``cost_every``).  When the light step feeds the
        broadcast update (``light_updates_replicated``) it already has
        the ``(data', out)`` shape ``make_step`` expects; otherwise wrap
        its bare data return with a dummy scalar."""
        if "per_step_light" not in self._compiled:
            fn_light = self.step_fn_light
            if self.light_updates_replicated:
                light = fn_light
            else:
                def light(d, rep, axes):
                    return fn_light(d, rep, axes), jnp.float32(0.0)

            self._compiled["per_step_light"] = make_step(light,
                                                         self.bundle)
        return self._compiled["per_step_light"]

    # ----------------------------------------------------- convergence
    def _converged(self) -> bool:
        if not self.tol:
            return False
        c = self.log.costs
        # when cost skipping is active the log repeats each evaluated
        # objective; compare costs cost_window *evaluations* apart
        stride = (self.chunk if self._cost_per_chunk
                  else self.cost_every if self._skips_cost else 1)
        w = self.cost_window * stride
        if len(c) <= w:
            return False
        prev, cur = c[-w - 1], c[-1]
        return abs(prev - cur) <= self.tol * max(abs(prev), 1e-12)

    # ------------------------------------------------------ sanitizers
    def _last_init(self):
        """Initial value of the carried last-output slot (``None`` when
        the mode carries no extra output between chunks)."""
        return (init_cost_like(self.step_fn_cost, self.bundle)
                if self._cost_per_chunk
                else init_out_like(self.step_fn, self.bundle)
                if self._skips_cost else None)

    def _assert_contracts(self, start_iter: int) -> None:
        """checks=True pre-flight (repro.core.checks): the initial
        state is finite and the compiled step's carry is structure/
        shape/dtype-stable — the latter via ``jax.eval_shape``, so
        nothing is dispatched before the verdict."""
        data, rep = self.bundle.data, self.bundle.replicated
        _checks.assert_all_finite(
            {"data": data, "replicated": rep}, "initial bundle state")
        if self.chunk == 1:
            spec = _checks.eval_step_spec(self.step, data, rep)
            _checks.assert_carry_stable(
                self.step, data, spec[0], "per-step data carry")
            return
        k = min(self.chunk, max(self.max_iter - start_iter, 1))
        step = self._scan_step(k)
        last = self._last_init()
        if last is not None:
            spec = _checks.eval_step_spec(step, data, rep,
                                          np.int32(start_iter), last)
        else:
            spec = _checks.eval_step_spec(step, data, rep,
                                          np.int32(start_iter))
        _checks.assert_carry_stable(
            step, (data, rep), (spec[0], spec[1]), "chunked scan carry")

    # ------------------------------------------------------------- run
    def run(self, start_iter: int = 0) -> Bundle:
        if self.checks:
            self._assert_contracts(start_iter)
        if self.chunk == 1 and self.options.resilience is None:
            return self._run_per_step(start_iter)
        # supervised runs always take the chunked loop: its chunk-boundary
        # host sync is where snapshots, validation and rollback live, and
        # make_scan_step(chunk=1) reproduces per-step semantics exactly
        return self._run_chunked(start_iter)

    @property
    def _skips_cost(self) -> bool:
        return self.cost_every > 1 and self.step_fn_light is not None

    @property
    def _cost_per_chunk(self) -> bool:
        """Chunk-granular objective (``engine.make_chunk_cost_step``):
        the scan runs only the cost-free step and the objective is
        evaluated once per dispatch, on the chunk's final state.  Keyed
        on the *requested* ``cost_every="chunk"`` (an integer cadence
        with a step_fn_cost present must honor the integer, not switch
        modes); per-step runs (chunk=1) evaluate every iteration
        anyway, so they use the plain path."""
        return self._per_chunk and self.chunk > 1

    def _dispatch_chunk(self, data, rep, last, i: int, k: int):
        """One fused-chunk dispatch + its host sync, as a unit the
        resilience supervisor can retry (the ``dispatch`` chaos fault
        point lives here, so injected failures tick per attempt)."""
        _chaos.maybe_raise("dispatch", step=i)
        if self._cost_per_chunk or self._skips_cost:
            data, rep, last, trace = self._scan_step(k)(
                data, rep, np.int32(i), last)
        else:
            data, rep, trace = self._scan_step(k)(data, rep, np.int32(i))
        costs = trace["cost"] if isinstance(trace, dict) else trace
        costs = np.asarray(jax.device_get(jax.block_until_ready(costs)))
        return data, rep, last, costs

    def _run_chunked(self, start_iter: int) -> Bundle:
        data, rep = self.bundle.data, self.bundle.replicated
        last = self._last_init()
        sup = None
        if self.options.resilience is not None:
            from repro.resilience.supervisor import Supervisor
            sup = Supervisor(self.options.resilience, self.bundle,
                             start_iter=start_iter,
                             last_init=self._last_init)
        ema = None
        compiled_ks = set()
        i = start_iter
        while i < self.max_iter:
            k = min(self.chunk, self.max_iter - i)
            first_call = k not in compiled_ks
            compiled_ks.add(k)
            t0 = time.perf_counter()
            if sup is not None:
                sup.begin_chunk(data, rep, last, i, len(self.log.costs))
                try:
                    data, rep, last, costs = sup.dispatch(
                        self._dispatch_chunk, data, rep, last, i, k)
                    if _chaos.is_active():  # silent-corruption injector
                        data = _chaos.poison_tree("carry_nan", data,
                                                  step=i)
                    sup.validate(data, rep, costs, i + k - 1)
                except DivergenceError as e:
                    sup.report.wall_time_lost_s += \
                        time.perf_counter() - t0
                    data, rep, last, i = sup.rollback(e, self.log)
                    ema = None  # timings across a rollback don't compare
                    continue
            else:
                data, rep, last, costs = self._dispatch_chunk(
                    data, rep, last, i, k)
                if _chaos.is_active():
                    data = _chaos.poison_tree("carry_nan", data, step=i)
            dt = time.perf_counter() - t0
            if self.checks:
                _checks.assert_costs_finite(
                    costs, f"chunk ending at iteration {i + k - 1}")
                _checks.assert_all_finite(
                    {"data": data, "replicated": rep},
                    f"state after iteration {i + k - 1}")
            self.log.times.extend([dt / k] * k)
            self.log.costs.extend(float(c) for c in np.ravel(costs))
            # a chunk length's first dispatch includes XLA compilation
            # (e.g. the shorter tail program) — keep it out of the
            # straggler watchdog and its EMA
            if not first_call:
                if ema is not None and dt > self.straggler_factor * ema:
                    self.log.straggler_steps.append(i)
                    if self.checkpoint_fn is not None:
                        self.checkpoint_fn(
                            self.bundle.with_data(data, replicated=rep),
                            i + k - 1)
                ema = dt if ema is None else 0.9 * ema + 0.1 * dt
            if (self.checkpoint_every and self.checkpoint_fn is not None
                    and (i + k) // self.checkpoint_every
                    > i // self.checkpoint_every):
                self.checkpoint_fn(
                    self.bundle.with_data(data, replicated=rep), i + k - 1)
            i += k
            if self._converged():
                self.log.converged_at = i - 1
                break
        if sup is not None:
            self.recovery = sup.finalize()
        return self.bundle.with_data(data, replicated=rep)

    def _run_per_step(self, start_iter: int) -> Bundle:
        data, rep = self.bundle.data, self.bundle.replicated
        ema = None
        for i in range(start_iter, self.max_iter):
            t0 = time.perf_counter()
            if _chaos.is_active():  # unsupervised: a fault kills the run
                _chaos.maybe_raise("dispatch", step=i)
            if self._skips_cost and i % self.cost_every != 0:
                # off the cost grid: run the objective-free step and
                # carry the last evaluated cost forward
                data, aux = self._light_step(data, rep)
                if self.light_updates_replicated and \
                        self.update_replicated is not None:
                    rep = self.update_replicated(rep, aux)
                jax.block_until_ready(jax.tree.leaves(data)[0])
                dt = time.perf_counter() - t0
                self.log.times.append(dt)
                self.log.costs.append(self.log.costs[-1]
                                      if self.log.costs else float("inf"))
            else:
                data, out = self.step(data, rep)
                cost = out["cost"] if isinstance(out, dict) else out
                cost = cost.block_until_ready()
                dt = time.perf_counter() - t0
                self.log.times.append(dt)
                cost_val = float(np.asarray(jax.device_get(cost)))
                if self.checks:
                    _checks.assert_costs_finite(
                        np.asarray([cost_val]), f"iteration {i}")
                    _checks.assert_all_finite(
                        {"data": data}, f"state after iteration {i}")
                self.log.costs.append(cost_val)
                if self.update_replicated is not None:
                    rep = self.update_replicated(rep, out)
            if _chaos.is_active():
                data = _chaos.poison_tree("carry_nan", data, step=i)
            # straggler watchdog: a step far beyond the EMA is logged and
            # (in multi-host deployment) triggers an early checkpoint
            if ema is not None and dt > self.straggler_factor * ema:
                self.log.straggler_steps.append(i)
                if self.checkpoint_fn is not None:
                    self.checkpoint_fn(
                        self.bundle.with_data(data, replicated=rep), i)
            ema = dt if ema is None else 0.9 * ema + 0.1 * dt
            if (self.checkpoint_every and self.checkpoint_fn is not None
                    and (i + 1) % self.checkpoint_every == 0):
                self.checkpoint_fn(
                    self.bundle.with_data(data, replicated=rep), i)
            if self._converged():
                self.log.converged_at = i
                break
        return self.bundle.with_data(data, replicated=rep)
