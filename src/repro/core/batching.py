"""Pad-and-bucket planning for batched multi-instance solves.

Production traffic for the paper's architecture is thousands of small
*independent* problem instances (galaxy stamps, scenes, patients), not
one big one.  ``solve_many`` (repro.core.problem) amortizes fixed
per-dispatch costs by stacking compatible instances into one leading
batch axis and running the fused chunked engine across all of them at
once.  This module owns the planning half of that path (DESIGN.md §19):

- group instances whose *static* signature matches (same per-input
  dtypes and non-record shape dims — one XLA program per group);
- within a group, pad each instance's record axis up to a shared bucket
  capacity, subject to a padding-waste budget (``waste_budget`` bounds
  the fraction of padded rows per bucket, so a 5-record instance never
  rides in a 4096-capacity bucket);
- emit deterministic bucket keys (hash of problem config salt + static
  signature + capacity + membership) so per-bucket checkpoint
  directories are stable across runs and resumable.

The module is deliberately a leaf: numpy + hashlib only, no repro
imports, so the driver/engine/problem layers can all use it freely.
"""
from __future__ import annotations

import hashlib
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple, Union

import numpy as np


@dataclass(frozen=True)
class BatchAxes:
    """A Problem's declaration of how its instances batch
    (``Problem.batch_axes()``, DESIGN.md §19).

    - ``record_axes``: which axis of each raw input is the record axis
      (the one ``Bundle.validate`` treats as leading in the built
      bundle).  A single int broadcasts over all inputs; a tuple gives
      one entry per input, with ``None`` marking non-array inputs that
      carry no records.
    - ``pad_records=False`` opts a workload out of record padding:
      instances then bucket only with *exact* record-count matches.
      Declare this when the step couples records through reductions
      whose floating-point grouping the workload is sensitive to (e.g.
      SCDL's per-iteration Gram matrices over the sample axis).
    - ``shared_in_batch``: top-level keys of the bundle's replicated
      dict that are instance-independent (derived from config only,
      e.g. the low-rank test matrix ``omega``) — stored once per bucket
      and broadcast, instead of stacked per instance.
    - ``instance_invariant``: constructor attributes read by
      ``init_bundle`` that are *declared* identical across instances
      (e.g. a shared noise level).  Consumed by lint rule RPL801, which
      flags undeclared per-instance constructor state.
    """
    record_axes: Union[int, Tuple[Optional[int], ...]] = 0
    pad_records: bool = True
    shared_in_batch: Tuple[str, ...] = ()
    instance_invariant: Tuple[str, ...] = ()

    def axis_for(self, i: int) -> Optional[int]:
        if isinstance(self.record_axes, tuple):
            if i >= len(self.record_axes):
                raise ValueError(
                    f"BatchAxes.record_axes declares {len(self.record_axes)} "
                    f"inputs but instance has more (input #{i})")
            return self.record_axes[i]
        return self.record_axes


@dataclass(frozen=True)
class Bucket:
    """One planned bucket: a set of instances sharing an XLA program.

    ``indices`` are positions into the original instance list (the
    planner's output preserves a total assignment: every instance lands
    in exactly one bucket).  ``records[j]`` is the true record count of
    ``indices[j]``; all are padded to ``capacity`` at stacking time.
    ``key`` is deterministic across runs for identical inputs — the
    per-bucket checkpoint directory name hangs off it.
    """
    key: str
    capacity: int
    indices: Tuple[int, ...]
    records: Tuple[int, ...]
    signature: Tuple = field(repr=False, default=())

    @property
    def waste(self) -> float:
        """Fraction of padded (dead) rows in the stacked bucket."""
        total = self.capacity * len(self.indices)
        return (total - sum(self.records)) / total if total else 0.0


def _leaf_sig(x: Any, axis: Optional[int]) -> Tuple:
    arr = np.asarray(x) if not hasattr(x, "shape") else x
    shape = tuple(arr.shape)
    dtype = str(arr.dtype)
    if axis is None:
        return (dtype, shape)
    ax = axis % len(shape) if shape else 0
    if not shape:
        raise ValueError(
            f"record axis {axis} declared for a scalar input")
    masked = shape[:ax] + ("N",) + shape[ax + 1:]
    return (dtype, masked)


def instance_records(instance: Sequence, axes: BatchAxes) -> int:
    """Record count of one instance; every input carrying a record axis
    must agree."""
    counts = []
    for i, x in enumerate(instance):
        ax = axes.axis_for(i)
        if ax is None:
            continue
        arr = np.asarray(x) if not hasattr(x, "shape") else x
        if not arr.shape:
            raise ValueError(
                f"input #{i}: record axis {ax} declared for a scalar")
        counts.append(int(arr.shape[ax % len(arr.shape)]))
    if not counts:
        raise ValueError(
            "instance declares no record axes — nothing to batch over")
    if len(set(counts)) > 1:
        raise ValueError(
            f"instance inputs disagree on record count: {counts}")
    return counts[0]


def static_signature(instance: Sequence, axes: BatchAxes) -> Tuple:
    """Hashable per-instance signature of everything that must be equal
    for two instances to share one compiled program: per-input dtypes
    and every shape dim except the (padded) record axis."""
    return tuple(_leaf_sig(x, axes.axis_for(i))
                 for i, x in enumerate(instance))


def bucket_key(salt: str, signature: Tuple, capacity: int,
               members: Sequence[Tuple[int, int]]) -> str:
    """Deterministic 12-hex-digit bucket id.  ``members`` is the
    ``(index, records)`` list; the key pins the exact membership so a
    resumed run refuses a checkpoint written under a different plan."""
    desc = f"{salt}|{signature!r}|cap={capacity}|{sorted(members)!r}"
    return hashlib.sha1(desc.encode()).hexdigest()[:12]


def plan_buckets(instances: Sequence[Sequence], axes: BatchAxes, *,
                 waste_budget: float = 0.25,
                 salt: str = "") -> List[Bucket]:
    """Partition ``instances`` into buckets.

    Greedy first-fit-decreasing within each static-signature group:
    instances are placed largest-first, each into the first open bucket
    whose capacity fits and whose post-placement padding fraction stays
    within ``waste_budget``; otherwise a new bucket opens at the
    instance's own record count.  ``waste_budget=0`` degenerates to
    exact-size buckets.  With ``axes.pad_records`` False the record
    count joins the signature, so only exact matches share a bucket.

    The returned list is deterministically ordered (largest stacked
    workload first) and covers every instance exactly once.
    """
    if not 0.0 <= waste_budget < 1.0:
        raise ValueError(
            f"waste_budget must be in [0, 1), got {waste_budget}")
    groups = {}
    for idx, inst in enumerate(instances):
        n = instance_records(inst, axes)
        sig = static_signature(inst, axes)
        if not axes.pad_records:
            sig = sig + (("records", n),)
        groups.setdefault(sig, []).append((idx, n))

    out: List[Bucket] = []
    for sig in sorted(groups, key=repr):
        members = sorted(groups[sig], key=lambda t: (-t[1], t[0]))
        open_: List[dict] = []
        for idx, n in members:
            placed = False
            for b in open_:
                pad = sum(b["cap"] - m_n for _, m_n in b["items"])
                pad += b["cap"] - n
                if pad <= waste_budget * b["cap"] * (len(b["items"]) + 1):
                    b["items"].append((idx, n))
                    placed = True
                    break
            if not placed:
                # descending order guarantees cap >= every later n
                open_.append({"cap": n, "items": [(idx, n)]})
        for b in open_:
            items = sorted(b["items"])
            out.append(Bucket(
                key=bucket_key(salt, sig, b["cap"], items),
                capacity=b["cap"],
                indices=tuple(i for i, _ in items),
                records=tuple(n for _, n in items),
                signature=sig))
    out.sort(key=lambda b: (-b.capacity * len(b.indices), b.key))
    return out


# --------------------------------------------------------------------
# Incremental (open-bucket) planning — the serving admission question
# --------------------------------------------------------------------

class OpenBucket:
    """One still-admitting bucket of an :class:`OpenBucketPlanner`.

    Unlike :func:`plan_buckets` (which sees the whole population and
    packs largest-first, so a bucket's capacity is fixed at its first
    member), an open bucket admits members in *arrival* order: its
    capacity grows to the largest member seen so far, and every
    admission re-checks the waste rule under the candidate capacity —
    the same ``pad <= waste_budget * capacity * n_members`` boundary
    the offline planner uses (exactly-at-budget admits; one-over opens
    a new bucket).
    """

    __slots__ = ("signature", "capacity", "members", "waste_budget",
                 "max_members", "deadlines")

    def __init__(self, signature: Tuple, waste_budget: float,
                 max_members: Optional[int] = None):
        self.signature = signature
        self.capacity = 0
        self.members: List[Tuple[Any, int]] = []   # (token, records)
        self.waste_budget = float(waste_budget)
        self.max_members = max_members
        # token -> absolute deadline (per-request deadline_s, §21): the
        # scheduler arms its coalescing timer against the earliest one
        # so a tight-deadline member never waits out the whole window
        self.deadlines: Dict[Any, float] = {}

    def try_admit(self, token, records: int) -> bool:
        """Admit ``token`` if the post-admission padding fraction stays
        within the waste budget (capacity may grow to ``records``)."""
        if self.max_members is not None \
                and len(self.members) >= self.max_members:
            return False
        cap = max(self.capacity, int(records))
        pad = sum(cap - n for _, n in self.members) + (cap - records)
        if pad > self.waste_budget * cap * (len(self.members) + 1):
            return False
        self.capacity = cap
        self.members.append((token, int(records)))
        return True

    def remove(self, token) -> bool:
        """Withdraw a member (request cancellation); the capacity
        shrinks back to the largest remaining member."""
        for j, (t, _) in enumerate(self.members):
            if t == token:
                del self.members[j]
                self.deadlines.pop(token, None)
                self.capacity = max((n for _, n in self.members),
                                    default=0)
                return True
        return False

    @property
    def earliest_deadline(self) -> Optional[float]:
        """The soonest member deadline, or ``None`` when no member has
        one — the bound a deadline-aware scheduler dispatches by."""
        return min(self.deadlines.values()) if self.deadlines else None

    def __len__(self) -> int:
        return len(self.members)


class OpenBucketPlanner:
    """Streaming counterpart of :func:`plan_buckets` (DESIGN.md §20).

    A serving frontend cannot plan over the whole population — requests
    arrive one at a time and the scheduler's question is incremental:
    *can this request ride an already-open bucket within the waste
    budget, or does it open a new one?*  ``offer`` answers it with the
    same signature-grouping and padding rule as the offline planner;
    ``close`` seals an open bucket into a :class:`Bucket` whose key is
    computed by the same :func:`bucket_key` (membership is sorted, so
    the key is independent of arrival order).

    Tokens are caller-chosen hashable ids (the service uses monotonic
    ints, so ``Bucket.indices`` ordering matches admission order after
    the sort).  The planner is not thread-safe; the asyncio service
    drives it from its event loop only.
    """

    def __init__(self, axes: BatchAxes, *, waste_budget: float = 0.25,
                 salt: str = "", max_members: Optional[int] = None):
        if not 0.0 <= waste_budget < 1.0:
            raise ValueError(
                f"waste_budget must be in [0, 1), got {waste_budget}")
        self.axes = axes
        self.waste_budget = float(waste_budget)
        self.salt = salt
        self.max_members = max_members
        self._open: List[OpenBucket] = []

    def offer(self, token, instance: Sequence, *,
              deadline: Optional[float] = None) -> OpenBucket:
        """Place one instance: first open bucket of matching signature
        with budget headroom, else a fresh bucket.  Returns the (still
        open) bucket the instance joined.  ``deadline`` (absolute time)
        is recorded on the bucket for deadline-aware dispatch."""
        n = instance_records(instance, self.axes)
        sig = static_signature(instance, self.axes)
        if not self.axes.pad_records:
            sig = sig + (("records", n),)
        for b in self._open:
            if b.signature == sig and b.try_admit(token, n):
                if deadline is not None:
                    b.deadlines[token] = float(deadline)
                return b
        b = OpenBucket(sig, self.waste_budget, self.max_members)
        b.try_admit(token, n)       # sole member: pad 0, always admits
        if deadline is not None:
            b.deadlines[token] = float(deadline)
        self._open.append(b)
        return b

    def discard(self, bucket: OpenBucket, token) -> None:
        """Withdraw a member; an emptied bucket closes unreported."""
        bucket.remove(token)
        if not bucket.members and bucket in self._open:
            self._open.remove(bucket)

    def close(self, bucket: OpenBucket) -> Bucket:
        """Seal an open bucket for dispatch.  The resulting key matches
        what :func:`plan_buckets` would emit for the same membership."""
        self._open.remove(bucket)
        items = sorted(bucket.members)
        return Bucket(
            key=bucket_key(self.salt, bucket.signature, bucket.capacity,
                           items),
            capacity=bucket.capacity,
            indices=tuple(t for t, _ in items),
            records=tuple(n for _, n in items),
            signature=bucket.signature)

    def drain(self) -> List[Bucket]:
        """Close every open bucket (service shutdown / deadline flush)."""
        return [self.close(b) for b in list(self._open)]

    @property
    def open_buckets(self) -> Tuple[OpenBucket, ...]:
        return tuple(self._open)


# --------------------------------------------------------------------
# Stacking helpers (operate on already-built per-instance bundles)
# --------------------------------------------------------------------

def pad_tree_records(tree, capacity: int):
    """Zero-pad the leading (record) axis of every leaf to ``capacity``.

    Padding happens on the *built bundle*, never on the raw inputs:
    derived replicated state (operator norms from shape-dependent power
    iterations, step sizes) must match the unpadded single solve
    bit-for-bit, and zero record rows are inert through every builtin
    step (they convolve/threshold/accumulate to zero).
    """
    import jax
    import jax.numpy as jnp

    def pad(x):
        x = jnp.asarray(x)
        n = x.shape[0]
        if n > capacity:
            raise ValueError(
                f"leaf has {n} records, exceeds bucket capacity "
                f"{capacity}")
        if n == capacity:
            return x
        width = [(0, capacity - n)] + [(0, 0)] * (x.ndim - 1)
        return jnp.pad(x, width)

    return jax.tree.map(pad, tree)


def stack_trees(trees: Sequence):
    """Stack per-instance pytrees along a new leading batch axis."""
    import jax
    import jax.numpy as jnp
    return jax.tree.map(lambda *ls: jnp.stack(ls, axis=0), *trees)
