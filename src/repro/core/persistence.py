"""Persistence policies: the paper's memory-only vs memory-and-disk.

Spark semantics -> this system:

  MEMORY_ONLY    : partitions live in device memory; anything evicted is
                   *recomputed from lineage*.  JAX analogue: bundle stays
                   device-resident between iterations, and intermediate
                   activations inside a step are rematerialised
                   (``jax.checkpoint`` around the step body).
  MEMORY_AND_DISK: evicted partitions are *spilled*.  JAX analogue: the
                   bundle round-trips through host RAM ("disk") each
                   iteration; intermediates are saved, not recomputed.

The paper's finding (Fig. 13) is that spill beats recompute when the
working set exceeds worker memory (GS dictionary learning) and loses when
it fits (PSF, HS) — the benchmark ``bench_persistence`` reproduces the
trade-off shape with these two policies.
"""
from __future__ import annotations

import enum
from typing import Any, Callable

import jax
import numpy as np

from repro.core.bundle import Bundle


class Policy(enum.Enum):
    MEMORY_ONLY = "memory_only"
    MEMORY_AND_DISK = "memory_and_disk"


def wrap_step(step_fn: Callable, policy: Policy) -> Callable:
    """Apply the recompute-vs-save discipline to a step function."""
    if policy is Policy.MEMORY_ONLY:
        # recompute-from-lineage: remat everything inside the step
        def rematted(data, rep, axes):
            inner = jax.checkpoint(lambda d: step_fn(d, rep, axes))
            return inner(data)
        return rematted
    return step_fn


def _to_host(tree: Any) -> Any:
    """The eviction discipline: device tree -> host ndarray tree."""
    return jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)


def to_host(tree: Any) -> Any:
    """Public host spill of an arbitrary pytree — the snapshot-ring
    primitive of ``repro.resilience.supervisor`` (bit-exact: fp32 round-
    trips through ``np.asarray``/``device_put`` unchanged)."""
    return _to_host(tree)


def spill(bundle: Bundle) -> Any:
    """MEMORY_AND_DISK eviction: pull the bundle to host buffers."""
    return _to_host(bundle.data)


def restore(bundle: Bundle, host_data: Any) -> Bundle:
    """Re-admit spilled partitions (device_put with the bundle's specs)."""
    if bundle.mesh is None:
        data = jax.tree.map(jax.numpy.asarray, host_data)
        return bundle.with_data(data)
    from jax.sharding import NamedSharding
    shard = NamedSharding(bundle.mesh, bundle.record_spec())
    data = jax.tree.map(lambda x: jax.device_put(x, shard), host_data)
    return bundle.with_data(data)


def spill_bundle(bundle: Bundle) -> Any:
    """Full-state eviction: data AND replicated sides as host trees —
    the checkpoint payload of ``repro.core.problem.solve`` (the broadcast
    variables are part of the iterate for carry-riding learners like
    SCDL, so a data-only spill could not resume them)."""
    return {"data": spill(bundle),
            "replicated": _to_host(bundle.replicated)}


def readmit_replicated(bundle: Bundle, host_tree: Any) -> Any:
    """Device-place a replicated host tree (broadcast state, carried
    outputs) under the bundle's mesh — ``P()`` on every leaf."""
    if bundle.mesh is None:
        return jax.tree.map(jax.numpy.asarray, host_tree)
    from jax.sharding import NamedSharding
    from jax.sharding import PartitionSpec as P
    shard = NamedSharding(bundle.mesh, P())
    return jax.tree.map(lambda x: jax.device_put(x, shard), host_tree)


def readmit_state(bundle: Bundle, host_state: Any) -> Any:
    """Inverse of :func:`spill_bundle`: place a ``{"data", "replicated"}``
    host tree back on the bundle's mesh (record-sharded data leaves,
    replicated broadcast leaves).  The rollback/retry restore path of
    ``repro.resilience.supervisor``."""
    if bundle.mesh is None:
        return jax.tree.map(jax.numpy.asarray, host_state)
    from jax.sharding import NamedSharding
    dshard = NamedSharding(bundle.mesh, bundle.record_spec())
    return {
        "data": jax.tree.map(lambda x: jax.device_put(x, dshard),
                             host_state["data"]),
        "replicated": readmit_replicated(bundle, host_state["replicated"]),
    }


# --------------------------------------------------------------------
# Batched (solve_many) spill/readmit helpers — DESIGN.md §19.  A bucket's
# state tree {"d", "r"[, "last"]} leads every leaf with the instance
# axis, which is also the sharded one, so one record-spec sharding
# covers the whole tree.
# --------------------------------------------------------------------


def readmit_batched(bundle: Bundle, host_state: Any) -> Any:
    """Device-place a batched state tree under the bundle's mesh: every
    leaf splits on its leading instance axis (``record_spec``)."""
    if bundle.mesh is None:
        return jax.tree.map(jax.numpy.asarray, host_state)
    from jax.sharding import NamedSharding
    shard = NamedSharding(bundle.mesh, bundle.record_spec())
    return jax.tree.map(lambda x: jax.device_put(x, shard), host_state)


def scatter_batched(host_state: Any, slots, total: int) -> Any:
    """Expand a compacted batched host state back to the full bucket
    layout: output row ``slots[s]`` takes compacted slice ``s``; rows
    not covered stay zero (the caller overwrites them from retired
    spills).  Checkpoints always use the full layout so restore is
    independent of when re-compaction happened."""
    slots = np.asarray(slots)

    def scatter(x):
        x = np.asarray(x)
        out = np.zeros((total,) + x.shape[1:], x.dtype)
        out[slots] = x
        return out

    return jax.tree.map(scatter, host_state)


def slice_instance(host_state: Any, row: int) -> Any:
    """One instance's slice of a batched host state tree."""
    return jax.tree.map(lambda x: x[row], host_state)


def set_instance(host_state: Any, row: int, inst: Any) -> None:
    """Write one instance's slices into a batched host state in place
    (numpy leaves; leaf order is canonical pytree order)."""
    for dst, src in zip(jax.tree.leaves(host_state),
                        jax.tree.leaves(inst)):
        dst[row] = src


def bundle_shardings(bundle: Bundle) -> Any:
    """NamedSharding trees matching :func:`spill_bundle`'s layout —
    hand these to ``checkpoint.checkpointer.restore(shardings=...)`` so
    restored leaves land sharded directly (one device_put, no
    materialize-on-one-device step).  None when the bundle has no
    mesh."""
    if bundle.mesh is None:
        return None
    from jax.sharding import NamedSharding
    from jax.sharding import PartitionSpec as P
    dshard = NamedSharding(bundle.mesh, bundle.record_spec())
    rshard = NamedSharding(bundle.mesh, P())
    return {"data": jax.tree.map(lambda _: dshard, bundle.data),
            "replicated": jax.tree.map(lambda _: rshard,
                                       bundle.replicated)}


