"""Persistence policies: the paper's memory-only vs memory-and-disk.

Spark semantics -> this system:

  MEMORY_ONLY    : partitions live in device memory; anything evicted is
                   *recomputed from lineage*.  JAX analogue: bundle stays
                   device-resident between iterations, and intermediate
                   activations inside a step are rematerialised
                   (``jax.checkpoint`` around the step body).
  MEMORY_AND_DISK: evicted partitions are *spilled*.  JAX analogue: the
                   bundle round-trips through host RAM ("disk") each
                   iteration; intermediates are saved, not recomputed.

The paper's finding (Fig. 13) is that spill beats recompute when the
working set exceeds worker memory (GS dictionary learning) and loses when
it fits (PSF, HS) — the benchmark ``bench_persistence`` reproduces the
trade-off shape with these two policies.
"""
from __future__ import annotations

import enum
from typing import Any, Callable

import jax
import numpy as np

from repro.core.bundle import Bundle


class Policy(enum.Enum):
    MEMORY_ONLY = "memory_only"
    MEMORY_AND_DISK = "memory_and_disk"


def wrap_step(step_fn: Callable, policy: Policy) -> Callable:
    """Apply the recompute-vs-save discipline to a step function."""
    if policy is Policy.MEMORY_ONLY:
        # recompute-from-lineage: remat everything inside the step
        def rematted(data, rep, axes):
            inner = jax.checkpoint(lambda d: step_fn(d, rep, axes))
            return inner(data)
        return rematted
    return step_fn


def spill(bundle: Bundle) -> Any:
    """MEMORY_AND_DISK eviction: pull the bundle to host buffers."""
    return jax.tree.map(lambda x: np.asarray(jax.device_get(x)),
                        bundle.data)


def restore(bundle: Bundle, host_data: Any) -> Bundle:
    """Re-admit spilled partitions (device_put with the bundle's specs)."""
    if bundle.mesh is None:
        data = jax.tree.map(jax.numpy.asarray, host_data)
        return bundle.with_data(data)
    from jax.sharding import NamedSharding
    shard = NamedSharding(bundle.mesh, bundle.record_spec())
    data = jax.tree.map(lambda x: jax.device_put(x, shard), host_data)
    return bundle.with_data(data)
