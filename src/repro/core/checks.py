"""Runtime contract sanitizers: ``solve(..., checks=True)``.

The static pass (``repro.lint``) catches what never has to run; this
module catches what only fails on real values.  Enabled per run via
``RunOptions.checks`` / ``solve(..., checks=True)`` or globally via the
``REPRO_CHECKS=1`` environment variable (the env var force-enables; it
is read once per solve so CI can flip it per job).  When disabled —
the default — the driver performs **zero** additional dispatches or
host transfers (the ``bench_api`` solve-overhead gate runs with checks
off and holds the ≤2% line).

Three guard families (DESIGN.md §17):

- **finite guards** — ``init_bundle`` output and the evolving
  data/replicated state at every host sync must be NaN/Inf-free;
- **carry-contract guards** — the compiled step's output pytree
  structure, shapes and dtypes must match its input carry exactly,
  asserted via ``jax.eval_shape`` *before the first dispatch* (a dtype
  flip in the carry means every chunk silently recompiles — the
  classic scan-carry bug);
- **cost guards** — freshly evaluated objectives must be finite.
  ``+inf`` is exempt: the engine seeds not-yet-evaluated cost slots
  with ``+inf`` by convention (``engine.init_cost_like``), so only NaN
  and ``-inf`` are hard failures.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import numpy as np

_ENV_VAR = "REPRO_CHECKS"


class CheckError(RuntimeError):
    """A runtime contract sanitizer tripped (checks=True mode)."""


def checks_enabled(flag: bool = False) -> bool:
    """``flag`` OR the ``REPRO_CHECKS`` env var (force-enable)."""
    import os
    env = os.environ.get(_ENV_VAR, "").strip().lower()
    return bool(flag) or env not in ("", "0", "false", "no")


# --------------------------------------------------------------------
# Finite guards
# --------------------------------------------------------------------

def _leaf_label(path) -> str:
    return jax.tree_util.keystr(path) or "<root>"


def assert_all_finite(tree: Any, what: str) -> None:
    """Host-side NaN/Inf sweep over every float leaf of ``tree``.

    Costs one device_get per leaf — only ever called in checks mode.
    """
    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    for path, leaf in leaves:
        arr = np.asarray(jax.device_get(leaf))
        if not np.issubdtype(arr.dtype, np.floating) and \
                not np.issubdtype(arr.dtype, np.complexfloating):
            continue
        bad = ~np.isfinite(arr)
        if bad.any():
            n = int(bad.sum())
            kinds = []
            if np.isnan(arr).any():
                kinds.append("NaN")
            if np.isposinf(arr).any():
                kinds.append("+inf")
            if np.isneginf(arr).any():
                kinds.append("-inf")
            raise CheckError(
                f"checks=True: {what}: leaf '{_leaf_label(path)}' has "
                f"{n}/{arr.size} non-finite values "
                f"({'/'.join(kinds)}) — the run is poisoned; inspect "
                f"the step math or lower the step sizes")


def assert_costs_finite(costs: np.ndarray, what: str) -> None:
    """NaN / ``-inf`` objectives are hard failures; ``+inf`` is the
    engine's not-yet-evaluated seed and passes."""
    costs = np.asarray(costs, dtype=np.float64)
    bad = np.isnan(costs) | np.isneginf(costs)
    if bad.any():
        idx = int(np.argmax(bad))
        raise CheckError(
            f"checks=True: {what}: objective value is "
            f"{costs.ravel()[idx]!r} at position {idx} of this sync — "
            f"the iterate diverged (NaN/-inf cost)")


# --------------------------------------------------------------------
# Carry-contract guards (trace-time, zero dispatch)
# --------------------------------------------------------------------

def _spec_of(tree: Any):
    """(treedef, [(shape, dtype)…]) — works for arrays *and* for the
    ShapeDtypeStructs that ``jax.eval_shape`` returns."""
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return treedef, [(tuple(np.shape(x)), np.dtype(jax.numpy.result_type(x)))
                     for x in leaves]


def assert_carry_stable(fn, in_carry, out_carry_spec, what: str) -> None:
    """Compare an input carry against the step's *abstract* output.

    ``out_carry_spec`` is the matching slice of ``jax.eval_shape(fn,
    ...)`` — metadata only, nothing was dispatched.  A structure
    mismatch, shape drift, or dtype flip raises with the leaf path:
    any of them would make ``lax.scan`` reject the carry or silently
    recompile every chunk.
    """
    in_def, in_leaves = _spec_of(in_carry)
    out_def, out_leaves = _spec_of(out_carry_spec)
    if in_def != out_def:
        raise CheckError(
            f"checks=True: {what}: step output carry has a different "
            f"pytree structure than its input —\n  in : {in_def}\n"
            f"  out: {out_def}\nthe scan carry must be "
            f"structure-stable")
    paths = [_leaf_label(p) for p, _ in
             jax.tree_util.tree_flatten_with_path(in_carry)[0]]
    for label, (si, di), (so, do) in zip(paths, in_leaves, out_leaves):
        if si != so:
            raise CheckError(
                f"checks=True: {what}: carry leaf '{label}' changes "
                f"shape {si} -> {so} across one step")
        if di != do:
            raise CheckError(
                f"checks=True: {what}: carry leaf '{label}' changes "
                f"dtype {di} -> {do} across one step — every chunk "
                f"would recompile and the objective silently runs in "
                f"{do}")


def eval_step_spec(fn, *args):
    """``jax.eval_shape`` with the sanitizer's error framing."""
    try:
        return jax.eval_shape(fn, *args)
    except CheckError:
        raise
    except Exception as e:
        raise CheckError(
            f"checks=True: step function failed to trace under "
            f"eval_shape (before any dispatch): {e}") from e
