"""Distributed iteration engine: one shard_map per learning iteration.

The paper's execution model (Fig. 1b) is: driver fires an action -> the
task manager ships one stage per partition to the workers -> partial
results reduce back to the driver.  Here a learning iteration is ONE
``shard_map``-wrapped pure function over the bundle:

    step(local_blocks, replicated) -> (new_local_blocks, reduced_scalars)

Everything record-local runs without communication; anything cross-
partition (cost sums, Gram matrices, dictionary outer products) is a
``psum`` inside the step — the all-reduce that replaces Spark's driver
round-trip.  The returned step is jit-compiled once and reused across
iterations (Spark's lazy DAG -> XLA's staged graph).
"""
from __future__ import annotations

from typing import Any, Callable, Optional, Tuple

import jax
from jax.sharding import PartitionSpec as P

from repro.core.bundle import Bundle


def make_step(fn: Callable, bundle: Bundle, *, donate: bool = True,
              static_replicated: bool = False):
    """Compile ``fn(data_local, replicated, axes) -> (data_local', out)``
    into a jitted distributed step over the bundle's mesh.

    ``axes`` is the tuple of mesh axis names to psum over (empty when the
    bundle is unpartitioned, e.g. the sequential reference).  ``out`` must
    be replicated-safe (i.e. already psum-reduced by ``fn``).
    """
    axes = bundle.axes

    if bundle.mesh is None:
        def local_step(data, rep):
            return fn(data, rep, ())
        return jax.jit(local_step, donate_argnums=(0,) if donate else ())

    data_spec = jax.tree.map(lambda _: bundle.record_spec(), bundle.data)
    rep_spec = jax.tree.map(lambda _: P(), bundle.replicated)
    out_data_shape, out_shape = jax.eval_shape(
        lambda d, r: fn(d, r, ()),
        _local_shapes(bundle), bundle.replicated)
    out_data_spec = jax.tree.map(lambda _: bundle.record_spec(),
                                 out_data_shape)
    out_rep_spec = jax.tree.map(lambda _: P(), out_shape)

    def local(data, rep):
        return fn(data, rep, axes)

    mapped = jax.shard_map(
        local, mesh=bundle.mesh,
        in_specs=(data_spec, rep_spec),
        out_specs=(out_data_spec, out_rep_spec),
        check_vma=False)
    return jax.jit(mapped, donate_argnums=(0,) if donate else ())


def _local_shapes(bundle: Bundle):
    n = max(bundle.n_partitions, 1)
    return jax.tree.map(
        lambda x: jax.ShapeDtypeStruct((x.shape[0] // n,) + x.shape[1:],
                                       x.dtype), bundle.data)
