"""Distributed iteration engine: one shard_map per learning iteration.

The paper's execution model (Fig. 1b) is: driver fires an action -> the
task manager ships one stage per partition to the workers -> partial
results reduce back to the driver.  Here a learning iteration is ONE
``shard_map``-wrapped pure function over the bundle:

    step(local_blocks, replicated) -> (new_local_blocks, reduced_scalars)

Everything record-local runs without communication; anything cross-
partition (cost sums, Gram matrices, dictionary outer products) is a
``psum`` inside the step — the all-reduce that replaces Spark's driver
round-trip.  The returned step is jit-compiled once and reused across
iterations (Spark's lazy DAG -> XLA's staged graph).

:func:`make_scan_step` goes one level further (DESIGN.md §12): K
iterations are fused into ONE dispatch via ``jax.lax.scan`` inside the
shard_map, carrying ``(data, replicated)`` on-device and accumulating a
``(K,)`` cost buffer — the host only syncs once per chunk, removing the
per-iteration driver round-trip that the paper identifies as Spark's
dominant overhead.
"""
from __future__ import annotations

from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.bundle import Bundle
from repro.core.compat import shard_map


def make_step(fn: Callable, bundle: Bundle, *, donate: bool = True,
              static_replicated: bool = False):
    """Compile ``fn(data_local, replicated, axes) -> (data_local', out)``
    into a jitted distributed step over the bundle's mesh.

    ``axes`` is the tuple of mesh axis names to psum over (empty when the
    bundle is unpartitioned, e.g. the sequential reference).  ``out`` must
    be replicated-safe (i.e. already psum-reduced by ``fn``).
    """
    axes = bundle.axes

    if bundle.mesh is None:
        def local_step(data, rep):
            return fn(data, rep, ())
        return jax.jit(local_step, donate_argnums=(0,) if donate else ())

    data_spec = jax.tree.map(lambda _: bundle.record_spec(), bundle.data)
    rep_spec = jax.tree.map(lambda _: P(), bundle.replicated)
    out_data_shape, out_shape = jax.eval_shape(
        lambda d, r: fn(d, r, ()),
        _local_shapes(bundle), bundle.replicated)
    out_data_spec = jax.tree.map(lambda _: bundle.record_spec(),
                                 out_data_shape)
    out_rep_spec = jax.tree.map(lambda _: P(), out_shape)

    def local(data, rep):
        return fn(data, rep, axes)

    mapped = shard_map(
        local, mesh=bundle.mesh,
        in_specs=(data_spec, rep_spec),
        out_specs=(out_data_spec, out_rep_spec),
        check_vma=False)
    return jax.jit(mapped, donate_argnums=(0,) if donate else ())


def _local_shapes(bundle: Bundle):
    n = max(bundle.n_partitions, 1)
    return jax.tree.map(
        lambda x: jax.ShapeDtypeStruct((x.shape[0] // n,) + x.shape[1:],
                                       x.dtype), bundle.data)


def _scalar_trace(out):
    """The per-iteration trace kept by the fused scan: scalar leaves only
    (costs/metrics).  Matrix-valued outputs (e.g. SCDL's dictionaries)
    feed the replicated carry instead of being stacked K times."""
    if isinstance(out, dict):
        kept = {k: v for k, v in out.items() if jnp.ndim(v) == 0}
        return kept if kept else out
    return out


def out_struct(fn: Callable, bundle: Bundle):
    """Shape/dtype structure of ``fn``'s reduced output (the ``out`` of
    ``fn(data_local, replicated, axes) -> (data', out)``)."""
    _, shape = jax.eval_shape(lambda d, r: fn(d, r, ()),
                              _local_shapes(bundle), bundle.replicated)
    return shape


def _seed_like(shapes):
    """Seed a shape tree with the "not yet evaluated" convention: float
    leaves get +inf (a resume landing off the cost grid then logs inf,
    which can never fake convergence), other dtypes zeros."""
    def seed(s):
        if jnp.issubdtype(s.dtype, jnp.floating):
            return jnp.full(s.shape, jnp.inf, s.dtype)
        return jnp.zeros(s.shape, s.dtype)
    return jax.tree.map(seed, shapes)


def init_out_like(fn: Callable, bundle: Bundle):
    """Initial carried output for a ``cost_every``-skipping scan step."""
    return _seed_like(out_struct(fn, bundle))


def init_cost_like(fn_cost: Callable, bundle: Bundle):
    """Initial carried objective for the per-chunk cost mode:
    ``fn_cost(data_local, replicated, axes) -> out`` (no data return)."""
    return _seed_like(jax.eval_shape(lambda d, r: fn_cost(d, r, ()),
                                     _local_shapes(bundle),
                                     bundle.replicated))


def make_scan_step(fn: Callable, bundle: Bundle, *, chunk: int = 8,
                   donate: bool = True,
                   update_replicated: Optional[Callable] = None,
                   fn_light: Optional[Callable] = None,
                   cost_every: int = 1,
                   light_updates_replicated: bool = False):
    """Fuse ``chunk`` iterations of ``fn`` into one on-device dispatch.

    Compiles ``step(data, replicated, start) -> (data', replicated',
    trace)`` where ``trace`` stacks the scalar leaves of ``fn``'s reduced
    output into ``(chunk,)`` buffers.  ``start`` is the global iteration
    index of the chunk's first iteration (drives ``cost_every`` phasing).

    - ``update_replicated(replicated, out) -> replicated'`` folds each
      iteration's reduced output back into the broadcast state *inside*
      the scan carry — the paper's per-iteration driver broadcast (SCDL
      step 7) without leaving the device.  The hook may post-process the
      reduced output (e.g. factor the SCDL Gram matrices into broadcast
      solve operators, DESIGN.md §13) — its result replaces the whole
      replicated carry.
    - ``fn_light(data, replicated, axes) -> data'`` is the cost-free
      variant of ``fn``; when given and ``cost_every > 1``, iterations
      off the cost grid run it and carry the last computed output
      forward instead of re-evaluating the objective.  The step then
      takes a fourth argument and returns it updated — ``step(data,
      replicated, start, last_out) -> (data', replicated', last_out',
      trace)`` — so the carried output survives chunk boundaries (seed
      it with :func:`init_out_like`; iteration 0 always evaluates).
    - ``light_updates_replicated=True`` declares that the broadcast
      state must advance on *every* iteration, not just evaluated ones
      (SCDL's dictionary update is part of the iterate, not of the
      objective).  ``fn_light`` then returns ``(data', out_partial)``
      where ``out_partial`` is a dict holding the subset of ``fn``'s
      output keys that feed ``update_replicated``; off-grid iterations
      merge it over the carried output (fresh broadcast inputs, stale
      scalars) and apply the hook unconditionally.
    """
    axes = bundle.axes
    use_light = fn_light is not None and cost_every > 1

    def body(carry, i):
        d, r, last = carry
        if use_light and light_updates_replicated:
            def on_grid(dd, rr, lo):
                return fn(dd, rr, axes)

            def off_grid(dd, rr, lo):
                d2, aux = fn_light(dd, rr, axes)
                return d2, {**lo, **aux}

            d2, out = jax.lax.cond(i % cost_every == 0,
                                   on_grid, off_grid, d, r, last)
            r2 = update_replicated(r, out) if update_replicated else r
        elif use_light:
            d2, out = jax.lax.cond(
                i % cost_every == 0,
                lambda dd, rr, lo: fn(dd, rr, axes),
                lambda dd, rr, lo: (fn_light(dd, rr, axes), lo),
                d, r, last)
            # apply the broadcast update only on evaluated iterations —
            # ``out`` is the stale carry otherwise, and the per-step
            # driver path skips the update there too
            r2 = (jax.lax.cond(i % cost_every == 0,
                               lambda: update_replicated(r, out),
                               lambda: r)
                  if update_replicated else r)
        else:
            d2, out = fn(d, r, axes)
            r2 = update_replicated(r, out) if update_replicated else r
        return (d2, r2, out), _scalar_trace(out)

    if use_light:
        def chunk_fn(data, rep, start, last):
            (d, r, last2), trace = jax.lax.scan(
                body, (data, rep, last), start + jnp.arange(chunk))
            return d, r, last2, trace
    else:
        def chunk_fn(data, rep, start):
            init = init_out_like(fn, bundle)      # never observed
            (d, r, _), trace = jax.lax.scan(
                body, (data, rep, init), start + jnp.arange(chunk))
            return d, r, trace

    # donate the carried-output buffer alongside the data blocks: the
    # step returns an identically-shaped tree, so XLA aliases it
    # in-place instead of allocating per dispatch
    donated = ((0, 3) if use_light else (0,)) if donate else ()
    if bundle.mesh is None:
        return jax.jit(chunk_fn, donate_argnums=donated)

    out_shape = out_struct(fn, bundle)
    data_spec = jax.tree.map(lambda _: bundle.record_spec(), bundle.data)
    rep_spec = jax.tree.map(lambda _: P(), bundle.replicated)
    out_spec = jax.tree.map(lambda _: P(), out_shape)
    trace_spec = jax.tree.map(lambda _: P(), _scalar_trace(out_shape))
    if use_light:
        in_specs = (data_spec, rep_spec, P(), out_spec)
        out_specs = (data_spec, rep_spec, out_spec, trace_spec)
    else:
        in_specs = (data_spec, rep_spec, P())
        out_specs = (data_spec, rep_spec, trace_spec)

    mapped = shard_map(
        chunk_fn, mesh=bundle.mesh,
        in_specs=in_specs, out_specs=out_specs, check_vma=False)
    return jax.jit(mapped, donate_argnums=donated)


def make_chunk_cost_step(fn_light: Callable, fn_cost: Callable,
                         bundle: Bundle, *, chunk: int = 8,
                         donate: bool = True,
                         update_replicated: Optional[Callable] = None):
    """Chunk-granular objective: the fastest execution mode (DESIGN.md
    §13).  The scan body runs ONLY the cost-free step — no ``lax.cond``,
    no stale-output carry threading through the scan — and the objective
    is evaluated once per dispatch, on the chunk's final state.  That is
    exactly the granularity the host observes anyway: the driver syncs
    and checks convergence once per chunk.

    - ``fn_light(data, replicated, axes) -> (data', out_partial)`` with
      ``out_partial`` feeding ``update_replicated`` every iteration (the
      ``light_updates_replicated`` contract).  When ``update_replicated``
      is ``None`` the broadcast state is constant across the scan and
      ``fn_light`` may return bare ``data'`` instead (the plain
      cost-free-step contract, e.g. deconvolution) — the Problem-API
      wiring rules in DESIGN.md §14 rely on this.
    - ``fn_cost(data, replicated, axes) -> out`` evaluates the objective
      scalars from the *post-iteration* state (the broadcast carry holds
      the iteration's reduced results).

    Compiles ``step(data, replicated, start, last) -> (data',
    replicated', out, trace)`` where ``trace`` holds ``last`` (the
    previous chunk's objective, +inf before the first evaluation —
    :func:`init_cost_like`) for the first ``chunk - 1`` slots and the
    fresh objective in the last slot.
    """
    axes = bundle.axes

    def body(carry, _):
        d, r = carry
        if update_replicated is None:
            d2 = fn_light(d, r, axes)
            r2 = r
        else:
            d2, aux = fn_light(d, r, axes)
            r2 = update_replicated(r, aux)
        return (d2, r2), None

    def chunk_fn(data, rep, start, last):
        (d, r), _ = jax.lax.scan(body, (data, rep), None, length=chunk)
        fresh = fn_cost(d, r, axes)
        trace = jax.tree.map(
            lambda s, f: jnp.concatenate(
                [jnp.broadcast_to(s, (chunk - 1,) + jnp.shape(s)),
                 jnp.asarray(f)[None]]), last, fresh)
        return d, r, fresh, trace

    donated = (0, 3) if donate else ()
    if bundle.mesh is None:
        return jax.jit(chunk_fn, donate_argnums=donated)

    cost_shape = jax.eval_shape(lambda d, r: fn_cost(d, r, ()),
                                _local_shapes(bundle), bundle.replicated)
    data_spec = jax.tree.map(lambda _: bundle.record_spec(), bundle.data)
    rep_spec = jax.tree.map(lambda _: P(), bundle.replicated)
    cost_spec = jax.tree.map(lambda _: P(), cost_shape)
    mapped = shard_map(
        chunk_fn, mesh=bundle.mesh,
        in_specs=(data_spec, rep_spec, P(), cost_spec),
        out_specs=(data_spec, rep_spec, cost_spec, cost_spec),
        check_vma=False)
    return jax.jit(mapped, donate_argnums=donated)
