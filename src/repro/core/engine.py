"""Distributed iteration engine: one shard_map per learning iteration.

The paper's execution model (Fig. 1b) is: driver fires an action -> the
task manager ships one stage per partition to the workers -> partial
results reduce back to the driver.  Here a learning iteration is ONE
``shard_map``-wrapped pure function over the bundle:

    step(local_blocks, replicated) -> (new_local_blocks, reduced_scalars)

Everything record-local runs without communication; anything cross-
partition (cost sums, Gram matrices, dictionary outer products) is a
``psum`` inside the step — the all-reduce that replaces Spark's driver
round-trip.  The returned step is jit-compiled once and reused across
iterations (Spark's lazy DAG -> XLA's staged graph).

:func:`make_scan_step` goes one level further (DESIGN.md §12): K
iterations are fused into ONE dispatch via ``jax.lax.scan`` inside the
shard_map, carrying ``(data, replicated)`` on-device and accumulating a
``(K,)`` cost buffer — the host only syncs once per chunk, removing the
per-iteration driver round-trip that the paper identifies as Spark's
dominant overhead.
"""
from __future__ import annotations

from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.bundle import Bundle
from repro.core.compat import shard_map


def make_step(fn: Callable, bundle: Bundle, *, donate: bool = True,
              static_replicated: bool = False):
    """Compile ``fn(data_local, replicated, axes) -> (data_local', out)``
    into a jitted distributed step over the bundle's mesh.

    ``axes`` is the tuple of mesh axis names to psum over (empty when the
    bundle is unpartitioned, e.g. the sequential reference).  ``out`` must
    be replicated-safe (i.e. already psum-reduced by ``fn``).
    """
    axes = bundle.axes

    if bundle.mesh is None:
        def local_step(data, rep):
            return fn(data, rep, ())
        return jax.jit(local_step, donate_argnums=(0,) if donate else ())

    data_spec = jax.tree.map(lambda _: bundle.record_spec(), bundle.data)
    rep_spec = jax.tree.map(lambda _: P(), bundle.replicated)
    out_data_shape, out_shape = jax.eval_shape(
        lambda d, r: fn(d, r, ()),
        _local_shapes(bundle), bundle.replicated)
    out_data_spec = jax.tree.map(lambda _: bundle.record_spec(),
                                 out_data_shape)
    out_rep_spec = jax.tree.map(lambda _: P(), out_shape)

    def local(data, rep):
        return fn(data, rep, axes)

    mapped = shard_map(
        local, mesh=bundle.mesh,
        in_specs=(data_spec, rep_spec),
        out_specs=(out_data_spec, out_rep_spec),
        check_vma=False)
    return jax.jit(mapped, donate_argnums=(0,) if donate else ())


def _local_shapes(bundle: Bundle):
    n = max(bundle.n_partitions, 1)
    return jax.tree.map(
        lambda x: jax.ShapeDtypeStruct((x.shape[0] // n,) + x.shape[1:],
                                       x.dtype), bundle.data)


def _scalar_trace(out):
    """The per-iteration trace kept by the fused scan: scalar leaves only
    (costs/metrics).  Matrix-valued outputs (e.g. SCDL's dictionaries)
    feed the replicated carry instead of being stacked K times."""
    if isinstance(out, dict):
        kept = {k: v for k, v in out.items() if jnp.ndim(v) == 0}
        return kept if kept else out
    return out


def out_struct(fn: Callable, bundle: Bundle):
    """Shape/dtype structure of ``fn``'s reduced output (the ``out`` of
    ``fn(data_local, replicated, axes) -> (data', out)``)."""
    _, shape = jax.eval_shape(lambda d, r: fn(d, r, ()),
                              _local_shapes(bundle), bundle.replicated)
    return shape


def _seed_like(shapes):
    """Seed a shape tree with the "not yet evaluated" convention: float
    leaves get +inf (a resume landing off the cost grid then logs inf,
    which can never fake convergence), other dtypes zeros."""
    def seed(s):
        if jnp.issubdtype(s.dtype, jnp.floating):
            return jnp.full(s.shape, jnp.inf, s.dtype)
        return jnp.zeros(s.shape, s.dtype)
    return jax.tree.map(seed, shapes)


def init_out_like(fn: Callable, bundle: Bundle):
    """Initial carried output for a ``cost_every``-skipping scan step."""
    return _seed_like(out_struct(fn, bundle))


def init_cost_like(fn_cost: Callable, bundle: Bundle):
    """Initial carried objective for the per-chunk cost mode:
    ``fn_cost(data_local, replicated, axes) -> out`` (no data return)."""
    return _seed_like(jax.eval_shape(lambda d, r: fn_cost(d, r, ()),
                                     _local_shapes(bundle),
                                     bundle.replicated))


def make_scan_step(fn: Callable, bundle: Bundle, *, chunk: int = 8,
                   donate: bool = True,
                   update_replicated: Optional[Callable] = None,
                   fn_light: Optional[Callable] = None,
                   cost_every: int = 1,
                   light_updates_replicated: bool = False):
    """Fuse ``chunk`` iterations of ``fn`` into one on-device dispatch.

    Compiles ``step(data, replicated, start) -> (data', replicated',
    trace)`` where ``trace`` stacks the scalar leaves of ``fn``'s reduced
    output into ``(chunk,)`` buffers.  ``start`` is the global iteration
    index of the chunk's first iteration (drives ``cost_every`` phasing).

    - ``update_replicated(replicated, out) -> replicated'`` folds each
      iteration's reduced output back into the broadcast state *inside*
      the scan carry — the paper's per-iteration driver broadcast (SCDL
      step 7) without leaving the device.  The hook may post-process the
      reduced output (e.g. factor the SCDL Gram matrices into broadcast
      solve operators, DESIGN.md §13) — its result replaces the whole
      replicated carry.
    - ``fn_light(data, replicated, axes) -> data'`` is the cost-free
      variant of ``fn``; when given and ``cost_every > 1``, iterations
      off the cost grid run it and carry the last computed output
      forward instead of re-evaluating the objective.  The step then
      takes a fourth argument and returns it updated — ``step(data,
      replicated, start, last_out) -> (data', replicated', last_out',
      trace)`` — so the carried output survives chunk boundaries (seed
      it with :func:`init_out_like`; iteration 0 always evaluates).
    - ``light_updates_replicated=True`` declares that the broadcast
      state must advance on *every* iteration, not just evaluated ones
      (SCDL's dictionary update is part of the iterate, not of the
      objective).  ``fn_light`` then returns ``(data', out_partial)``
      where ``out_partial`` is a dict holding the subset of ``fn``'s
      output keys that feed ``update_replicated``; off-grid iterations
      merge it over the carried output (fresh broadcast inputs, stale
      scalars) and apply the hook unconditionally.
    """
    axes = bundle.axes
    use_light = fn_light is not None and cost_every > 1

    def body(carry, i):
        d, r, last = carry
        if use_light and light_updates_replicated:
            def on_grid(dd, rr, lo):
                return fn(dd, rr, axes)

            def off_grid(dd, rr, lo):
                d2, aux = fn_light(dd, rr, axes)
                return d2, {**lo, **aux}

            d2, out = jax.lax.cond(i % cost_every == 0,
                                   on_grid, off_grid, d, r, last)
            r2 = update_replicated(r, out) if update_replicated else r
        elif use_light:
            d2, out = jax.lax.cond(
                i % cost_every == 0,
                lambda dd, rr, lo: fn(dd, rr, axes),
                lambda dd, rr, lo: (fn_light(dd, rr, axes), lo),
                d, r, last)
            # apply the broadcast update only on evaluated iterations —
            # ``out`` is the stale carry otherwise, and the per-step
            # driver path skips the update there too
            r2 = (jax.lax.cond(i % cost_every == 0,
                               lambda: update_replicated(r, out),
                               lambda: r)
                  if update_replicated else r)
        else:
            d2, out = fn(d, r, axes)
            r2 = update_replicated(r, out) if update_replicated else r
        return (d2, r2, out), _scalar_trace(out)

    if use_light:
        def chunk_fn(data, rep, start, last):
            (d, r, last2), trace = jax.lax.scan(
                body, (data, rep, last), start + jnp.arange(chunk))
            return d, r, last2, trace
    else:
        def chunk_fn(data, rep, start):
            init = init_out_like(fn, bundle)      # never observed
            (d, r, _), trace = jax.lax.scan(
                body, (data, rep, init), start + jnp.arange(chunk))
            return d, r, trace

    # donate the carried-output buffer alongside the data blocks: the
    # step returns an identically-shaped tree, so XLA aliases it
    # in-place instead of allocating per dispatch
    donated = ((0, 3) if use_light else (0,)) if donate else ()
    if bundle.mesh is None:
        return jax.jit(chunk_fn, donate_argnums=donated)

    out_shape = out_struct(fn, bundle)
    data_spec = jax.tree.map(lambda _: bundle.record_spec(), bundle.data)
    rep_spec = jax.tree.map(lambda _: P(), bundle.replicated)
    out_spec = jax.tree.map(lambda _: P(), out_shape)
    trace_spec = jax.tree.map(lambda _: P(), _scalar_trace(out_shape))
    if use_light:
        in_specs = (data_spec, rep_spec, P(), out_spec)
        out_specs = (data_spec, rep_spec, out_spec, trace_spec)
    else:
        in_specs = (data_spec, rep_spec, P())
        out_specs = (data_spec, rep_spec, trace_spec)

    mapped = shard_map(
        chunk_fn, mesh=bundle.mesh,
        in_specs=in_specs, out_specs=out_specs, check_vma=False)
    return jax.jit(mapped, donate_argnums=donated)


# --------------------------------------------------------------------
# Batched multi-instance steps (solve_many, DESIGN.md §19)
# --------------------------------------------------------------------
#
# The batched state is ``{"d": data, "r": replicated_batched[, "last":
# carried_out]}`` with every leaf carrying a leading instance axis B;
# the bucket-shared replicated tree (``BatchAxes.shared_in_batch``)
# rides separately and is broadcast.  The per-instance step runs under
# ``vmap`` with ``axes=()`` — instances never psum into each other;
# cross-device sharding splits the *batch* axis instead of the record
# axis, so each device owns whole instances.


def _bcast_mask(active, leaf):
    return jnp.reshape(active, active.shape + (1,) * (leaf.ndim - 1))


def freeze_where(active, new, old):
    """Per-instance freeze: re-select ``old`` wherever the active mask
    is False, so converged (or padded-filler) lanes stay bitwise
    constant while live lanes advance.  Frozen lanes still *compute* —
    masking discards the result — which is the price of keeping one
    fused program; re-compaction (BatchedDriver) reclaims the FLOPs
    once enough lanes retire."""
    return jax.tree.map(
        lambda n, o: jnp.where(_bcast_mask(active, n), n, o), new, old)


def _instance_struct(tree):
    """Shape/dtype structure of one instance (leading batch axis
    dropped)."""
    return jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(tuple(x.shape[1:]), x.dtype),
        tree)


def _merge_rep(r_i, shared):
    """One instance's full replicated view: its batched slice overlaid
    on the bucket-shared tree.  Non-dict replicated trees cannot split,
    so they are all-batched (shared must be empty/None)."""
    if shared is None:
        return r_i
    if isinstance(shared, dict) and isinstance(r_i, dict):
        return {**shared, **r_i} if shared else r_i
    if not shared:
        return r_i
    raise TypeError(
        "shared_in_batch requires dict-shaped replicated state")


def _split_rep(rep_full, r_i):
    """Project an updated full replicated view back onto the batched
    keys (the shared part is constant by declaration)."""
    if isinstance(r_i, dict):
        return {k: rep_full[k] for k in r_i}
    return rep_full


def _seed_like_batched(shapes, batch: int):
    return jax.tree.map(
        lambda s: (jnp.full((batch,) + tuple(s.shape), jnp.inf, s.dtype)
                   if jnp.issubdtype(s.dtype, jnp.floating)
                   else jnp.zeros((batch,) + tuple(s.shape), s.dtype)),
        shapes)


def _batch_size(state) -> int:
    return jax.tree.leaves(state["d"])[0].shape[0]


def _instance_out_struct(fn: Callable, state, shared):
    d_i = _instance_struct(state["d"])
    rep_i = _merge_rep(_instance_struct(state["r"]), shared)
    return jax.eval_shape(lambda d, r: fn(d, r, ()), d_i, rep_i)


def init_batched_out_like(fn: Callable, state, shared):
    """(B,)-stacked +inf seed of ``fn``'s per-instance reduced output
    (the carried slot for cost-skipping batched scans)."""
    _, out = _instance_out_struct(fn, state, shared)
    return _seed_like_batched(out, _batch_size(state))


def init_batched_cost_like(fn_cost: Callable, state, shared):
    """(B,)-stacked +inf seed of the per-instance objective (per-chunk
    cost mode)."""
    out = _instance_out_struct(fn_cost, state, shared)
    return _seed_like_batched(out, _batch_size(state))


def _batched_specs(bundle: Bundle, state):
    """shard_map specs for the batched step: state leaves split on the
    batch axis, shared replicated + the start index stay replicated,
    traces are (chunk, B) with B split."""
    bspec = bundle.record_spec()
    state_spec = jax.tree.map(lambda _: bspec, state)
    shared_spec = jax.tree.map(lambda _: P(), bundle.replicated)
    trace_spec = P(None, bundle.axes) if bundle.axes else P()
    return bspec, state_spec, shared_spec, trace_spec


def make_batched_scan_step(fn: Callable, bundle: Bundle, state, *,
                           chunk: int = 8, donate: bool = True,
                           update_replicated: Optional[Callable] = None,
                           fn_light: Optional[Callable] = None,
                           cost_every: int = 1,
                           light_updates_replicated: bool = False):
    """Fuse ``chunk`` iterations across a whole bucket of instances
    into one dispatch: the batched analogue of :func:`make_scan_step`.

    Compiles ``step(state, shared, active, start) -> (state', trace)``
    where ``state`` is the batched carry described above, ``shared`` is
    the bucket-shared replicated tree, ``active`` is the per-instance
    convergence mask (frozen lanes re-select their previous carry via
    :func:`freeze_where` every iteration) and ``trace`` stacks the
    per-instance scalar outputs into ``(chunk, B)`` buffers.  The
    ``cost_every``/``fn_light``/``update_replicated`` semantics mirror
    the single-instance factory, applied per instance under ``vmap``
    (the cost-grid ``lax.cond`` predicate is batch-invariant, so it
    stays a real branch).
    """
    use_light = fn_light is not None and cost_every > 1
    has_last = "last" in state

    def iter_i(d_i, r_i, shared, last_i, i):
        rep = _merge_rep(r_i, shared)
        if use_light and light_updates_replicated:
            def on_grid(dd, lo):
                return fn(dd, rep, ())

            def off_grid(dd, lo):
                d2, aux = fn_light(dd, rep, ())
                return d2, {**lo, **aux}

            d2, out = jax.lax.cond(i % cost_every == 0,
                                   on_grid, off_grid, d_i, last_i)
            r2 = (_split_rep(update_replicated(rep, out), r_i)
                  if update_replicated else r_i)
        elif use_light:
            d2, out = jax.lax.cond(
                i % cost_every == 0,
                lambda dd, lo: fn(dd, rep, ()),
                lambda dd, lo: (fn_light(dd, rep, ()), lo),
                d_i, last_i)
            r2 = (jax.lax.cond(
                i % cost_every == 0,
                lambda: _split_rep(update_replicated(rep, out), r_i),
                lambda: r_i)
                if update_replicated else r_i)
        else:
            d2, out = fn(d_i, rep, ())
            r2 = (_split_rep(update_replicated(rep, out), r_i)
                  if update_replicated else r_i)
        return d2, r2, out, _scalar_trace(out)

    biter = jax.vmap(iter_i,
                     in_axes=(0, 0, None, 0 if has_last else None, None))

    def chunk_fn(state, shared, active, start):
        def body(st, i):
            last = st["last"] if has_last else None
            d2, r2, out, tr = biter(st["d"], st["r"], shared, last, i)
            new = {"d": d2, "r": r2}
            if has_last:
                new["last"] = out
            return freeze_where(active, new, st), tr

        st, trace = jax.lax.scan(body, state, start + jnp.arange(chunk))
        return st, trace

    donated = (0,) if donate else ()
    if bundle.mesh is None:
        return jax.jit(chunk_fn, donate_argnums=donated)

    bspec, state_spec, shared_spec, trace_spec = _batched_specs(
        bundle, state)
    _, out = _instance_out_struct(fn, state, bundle.replicated)
    traces = jax.tree.map(lambda _: trace_spec, _scalar_trace(out))
    mapped = shard_map(
        chunk_fn, mesh=bundle.mesh,
        in_specs=(state_spec, shared_spec, bspec, P()),
        out_specs=(state_spec, traces), check_vma=False)
    return jax.jit(mapped, donate_argnums=donated)


def make_batched_chunk_cost_step(fn_light: Callable, fn_cost: Callable,
                                 bundle: Bundle, state, *,
                                 chunk: int = 8, donate: bool = True,
                                 update_replicated: Optional[Callable]
                                 = None):
    """Batched analogue of :func:`make_chunk_cost_step`: the scan body
    runs only the vmapped cost-free step; the per-instance objective is
    evaluated once per dispatch on the chunk's final state and carried
    in ``state["last"]``.  Frozen lanes keep their previous objective —
    the trace a converged instance reports never moves again.

    Same compiled signature as :func:`make_batched_scan_step`:
    ``step(state, shared, active, start) -> (state', trace)``.
    """

    def light_i(d_i, r_i, shared):
        rep = _merge_rep(r_i, shared)
        if update_replicated is None:
            return fn_light(d_i, rep, ()), r_i
        d2, aux = fn_light(d_i, rep, ())
        return d2, _split_rep(update_replicated(rep, aux), r_i)

    def cost_i(d_i, r_i, shared):
        return fn_cost(d_i, _merge_rep(r_i, shared), ())

    blight = jax.vmap(light_i, in_axes=(0, 0, None))
    bcost = jax.vmap(cost_i, in_axes=(0, 0, None))

    def chunk_fn(state, shared, active, start):
        def body(st, _):
            d2, r2 = blight(st["d"], st["r"], shared)
            return freeze_where(active, {"d": d2, "r": r2}, st), None

        core, _ = jax.lax.scan(
            body, {"d": state["d"], "r": state["r"]}, None, length=chunk)
        fresh = bcost(core["d"], core["r"], shared)
        fresh = freeze_where(active, fresh, state["last"])
        trace = jax.tree.map(
            lambda s, f: jnp.concatenate(
                [jnp.broadcast_to(s, (chunk - 1,) + jnp.shape(s)),
                 jnp.asarray(f)[None]]), state["last"], fresh)
        return dict(core, last=fresh), trace

    donated = (0,) if donate else ()
    if bundle.mesh is None:
        return jax.jit(chunk_fn, donate_argnums=donated)

    bspec, state_spec, shared_spec, trace_spec = _batched_specs(
        bundle, state)
    cost_shape = _instance_out_struct(fn_cost, state, bundle.replicated)
    traces = jax.tree.map(lambda _: trace_spec, cost_shape)
    mapped = shard_map(
        chunk_fn, mesh=bundle.mesh,
        in_specs=(state_spec, shared_spec, bspec, P()),
        out_specs=(state_spec, traces), check_vma=False)
    return jax.jit(mapped, donate_argnums=donated)


def make_chunk_cost_step(fn_light: Callable, fn_cost: Callable,
                         bundle: Bundle, *, chunk: int = 8,
                         donate: bool = True,
                         update_replicated: Optional[Callable] = None):
    """Chunk-granular objective: the fastest execution mode (DESIGN.md
    §13).  The scan body runs ONLY the cost-free step — no ``lax.cond``,
    no stale-output carry threading through the scan — and the objective
    is evaluated once per dispatch, on the chunk's final state.  That is
    exactly the granularity the host observes anyway: the driver syncs
    and checks convergence once per chunk.

    - ``fn_light(data, replicated, axes) -> (data', out_partial)`` with
      ``out_partial`` feeding ``update_replicated`` every iteration (the
      ``light_updates_replicated`` contract).  When ``update_replicated``
      is ``None`` the broadcast state is constant across the scan and
      ``fn_light`` may return bare ``data'`` instead (the plain
      cost-free-step contract, e.g. deconvolution) — the Problem-API
      wiring rules in DESIGN.md §14 rely on this.
    - ``fn_cost(data, replicated, axes) -> out`` evaluates the objective
      scalars from the *post-iteration* state (the broadcast carry holds
      the iteration's reduced results).

    Compiles ``step(data, replicated, start, last) -> (data',
    replicated', out, trace)`` where ``trace`` holds ``last`` (the
    previous chunk's objective, +inf before the first evaluation —
    :func:`init_cost_like`) for the first ``chunk - 1`` slots and the
    fresh objective in the last slot.
    """
    axes = bundle.axes

    def body(carry, _):
        d, r = carry
        if update_replicated is None:
            d2 = fn_light(d, r, axes)
            r2 = r
        else:
            d2, aux = fn_light(d, r, axes)
            r2 = update_replicated(r, aux)
        return (d2, r2), None

    def chunk_fn(data, rep, start, last):
        (d, r), _ = jax.lax.scan(body, (data, rep), None, length=chunk)
        fresh = fn_cost(d, r, axes)
        trace = jax.tree.map(
            lambda s, f: jnp.concatenate(
                [jnp.broadcast_to(s, (chunk - 1,) + jnp.shape(s)),
                 jnp.asarray(f)[None]]), last, fresh)
        return d, r, fresh, trace

    donated = (0, 3) if donate else ()
    if bundle.mesh is None:
        return jax.jit(chunk_fn, donate_argnums=donated)

    cost_shape = jax.eval_shape(lambda d, r: fn_cost(d, r, ()),
                                _local_shapes(bundle), bundle.replicated)
    data_spec = jax.tree.map(lambda _: bundle.record_spec(), bundle.data)
    rep_spec = jax.tree.map(lambda _: P(), bundle.replicated)
    cost_spec = jax.tree.map(lambda _: P(), cost_shape)
    mapped = shard_map(
        chunk_fn, mesh=bundle.mesh,
        in_specs=(data_spec, rep_spec, P(), cost_spec),
        out_specs=(data_spec, rep_spec, cost_spec, cost_spec),
        check_vma=False)
    return jax.jit(mapped, donate_argnums=donated)
