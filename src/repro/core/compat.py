"""Version-compatibility shims for the jax API surface this repo uses.

The code targets current jax (``jax.shard_map``, ``check_vma``,
``jax.make_mesh(axis_types=...)``); older releases (< 0.5) ship the same
functionality as ``jax.experimental.shard_map`` with ``check_rep`` and a
``make_mesh`` without ``axis_types``.  Routing every call site through
this module keeps the rest of the codebase written against the modern
API only.
"""
from __future__ import annotations

import jax

if hasattr(jax, "shard_map"):
    shard_map = jax.shard_map
else:
    from jax.experimental.shard_map import shard_map as _shard_map

    def shard_map(f, *, mesh, in_specs, out_specs, check_vma=True):
        return _shard_map(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_rep=check_vma)


def axis_size(axis_name):
    """``jax.lax.axis_size`` (newer jax) with a psum(1) fallback that
    works inside any collective context on older releases."""
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis_name)
    return jax.lax.psum(1, axis_name)


def make_mesh(shape, axes):
    """``jax.make_mesh`` with explicit (Auto) axis types where supported."""
    if hasattr(jax.sharding, "AxisType"):
        return jax.make_mesh(
            shape, axes,
            axis_types=(jax.sharding.AxisType.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)
