"""The paper's core abstraction: the bundled distributed dataset.

Panousopoulou et al. zip k co-partitioned RDDs into one bundled RDD
``D = [D_1 ... D_k]`` (their Fig. 2) so that heterogeneous imaging arrays
that must be processed *jointly* (noisy stamps, per-object PSFs, primal &
dual optimization variables, weighting matrices, multipliers) travel
together through iterative map/reduce learning.

TPU adaptation (DESIGN.md §2): a ``Bundle`` is a pytree of arrays that all
share the same leading-axis partitioning over the mesh's data axes.  The
paper's RDD Bundle / Unbundle components become:

  - ``Bundle.create``  — co-shard k arrays with one PartitionSpec (Bundle);
  - ``bundle_map``     — ``shard_map`` a per-partition function; the user
    function sees plain local arrays, exactly like the worker-side code of
    the paper ("the core principles of the original learning algorithm
    [stay] intact");
  - ``bundle_reduce``  — ``jax.lax.psum`` over the data axes replaces the
    tree-reduce-to-driver: the "driver result" materialises replicated on
    every chip, removing the Spark driver bottleneck.

The number of partitions N maps to the number of data shards (and the
microbatch factor for iterative learners); the persistence model maps to
remat/offload policies in ``core.persistence``.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.core.compat import shard_map


def _dp_axes(mesh: Optional[Mesh], axes: Optional[Tuple[str, ...]] = None
             ) -> Tuple[str, ...]:
    if mesh is None:
        return ()
    if axes is None:
        axes = ("pod", "data")
    return tuple(a for a in axes if a in mesh.shape)


@dataclass
class Bundle:
    """k co-partitioned arrays + the mesh/axis they are partitioned over.

    ``data`` is any pytree whose every leaf has the same leading dimension
    N_records; the leading dimension is sharded over ``axes`` of ``mesh``.
    A leaf may opt out of partitioning (broadcast state, e.g. dictionaries)
    by living in ``replicated`` instead — the paper's broadcast variables.
    """
    data: Any
    replicated: Any
    mesh: Optional[Mesh]
    axes: Tuple[str, ...]

    # -------------------------------------------------- construction
    @classmethod
    def create(cls, data: Any, *, mesh: Optional[Mesh] = None,
               replicated: Any = None,
               axes: Optional[Tuple[str, ...]] = None) -> "Bundle":
        axes = _dp_axes(mesh, axes)
        b = cls(data=data, replicated=replicated, mesh=mesh, axes=axes)
        b.validate()
        if mesh is not None:
            dshard = NamedSharding(mesh, b.record_spec())
            rshard = NamedSharding(mesh, P())
            data = jax.tree.map(lambda x: jax.device_put(x, dshard), b.data)
            rep = jax.tree.map(lambda x: jax.device_put(x, rshard),
                               b.replicated)
        else:
            # copy so the iteration engine may donate bundle buffers
            # without invalidating caller-held arrays
            data = jax.tree.map(lambda x: jnp.array(x, copy=True), b.data)
            rep = b.replicated
        return cls(data=data, replicated=rep, mesh=mesh, axes=axes)

    def record_spec(self, extra: int = 0) -> P:
        ax = self.axes if self.axes else None
        return P(ax, *([None] * extra)) if ax else P()

    @property
    def n_records(self) -> int:
        leaves = jax.tree.leaves(self.data)
        return int(leaves[0].shape[0]) if leaves else 0

    @property
    def n_partitions(self) -> int:
        if self.mesh is None:
            return 1
        n = 1
        for a in self.axes:
            n *= self.mesh.shape[a]
        return n

    def validate(self) -> None:
        """The RDD-Bundle invariant: identical leading axis everywhere,
        divisible by the partition count."""
        leaves = jax.tree.leaves(self.data)
        if not leaves:
            return
        n = leaves[0].shape[0]
        for leaf in leaves:
            if leaf.shape[0] != n:
                raise ValueError(
                    f"bundle leaves disagree on leading axis: "
                    f"{leaf.shape[0]} != {n}")
        if self.n_partitions and n % self.n_partitions != 0:
            raise ValueError(
                f"{n} records not divisible into {self.n_partitions} "
                f"partitions")

    # -------------------------------------------------- transformations
    def with_data(self, data: Any, replicated: Any = "keep") -> "Bundle":
        rep = self.replicated if replicated == "keep" else replicated
        return Bundle(data=data, replicated=rep, mesh=self.mesh,
                      axes=self.axes)

    def zip(self, other: "Bundle") -> "Bundle":
        """The paper's RDD.zip: combine two co-partitioned bundles."""
        if other.n_records != self.n_records:
            raise ValueError("zip requires equal record counts")
        return self.with_data((self.data, other.data))


def bundle_map(fn: Callable, bundle: Bundle, *, has_replicated: bool = False
               ) -> Bundle:
    """map: apply ``fn`` partition-wise; no communication.

    ``fn(local_data)`` (or ``fn(local_data, replicated)``) sees the local
    block of every bundled array — the Unbundle component — and returns a
    pytree of updated blocks with unchanged leading axes.
    """
    if bundle.mesh is None:
        out = (fn(bundle.data, bundle.replicated) if has_replicated
               else fn(bundle.data))
        return bundle.with_data(out)

    spec_in = jax.tree.map(lambda _: bundle.record_spec(), bundle.data)
    local_shapes = _local_view(bundle.data, bundle)
    if has_replicated:
        rep_spec = jax.tree.map(lambda _: P(), bundle.replicated)
        local = lambda d, r: fn(d, r)
        out_shape = jax.eval_shape(fn, local_shapes, bundle.replicated)
        spec_out = jax.tree.map(lambda _: bundle.record_spec(), out_shape)
        mapped = shard_map(local, mesh=bundle.mesh,
                               in_specs=(spec_in, rep_spec),
                               out_specs=spec_out, check_vma=False)
        return bundle.with_data(mapped(bundle.data, bundle.replicated))
    out_shape = jax.eval_shape(fn, local_shapes)
    spec_out = jax.tree.map(lambda _: bundle.record_spec(), out_shape)
    mapped = shard_map(fn, mesh=bundle.mesh, in_specs=(spec_in,),
                           out_specs=spec_out, check_vma=False)
    return bundle.with_data(mapped(bundle.data))


def _local_view(data, bundle: Bundle):
    n = max(bundle.n_partitions, 1)
    return jax.tree.map(
        lambda x: jax.ShapeDtypeStruct((x.shape[0] // n,) + x.shape[1:],
                                       x.dtype), data)


def bundle_map_reduce(map_fn: Callable, bundle: Bundle, *,
                      has_replicated: bool = False):
    """map+reduce fused: ``map_fn`` returns per-partition partials that are
    psum-reduced over the data axes — the paper's ``map().reduce(add)``
    without the driver round-trip.  Returns a replicated pytree.
    """
    if bundle.mesh is None:
        return (map_fn(bundle.data, bundle.replicated) if has_replicated
                else map_fn(bundle.data))

    axes = bundle.axes

    def local(*args):
        part = map_fn(*args)
        return jax.tree.map(lambda x: jax.lax.psum(x, axes), part)

    spec_in = jax.tree.map(lambda _: bundle.record_spec(), bundle.data)
    local_shapes = _local_view(bundle.data, bundle)
    if has_replicated:
        rep_spec = jax.tree.map(lambda _: P(), bundle.replicated)
        out_shape = jax.eval_shape(map_fn, local_shapes,
                                   bundle.replicated)
        spec_out = jax.tree.map(lambda _: P(), out_shape)
        return shard_map(local, mesh=bundle.mesh,
                             in_specs=(spec_in, rep_spec),
                             out_specs=spec_out, check_vma=False)(
            bundle.data, bundle.replicated)
    out_shape = jax.eval_shape(map_fn, local_shapes)
    spec_out = jax.tree.map(lambda _: P(), out_shape)
    return shard_map(local, mesh=bundle.mesh, in_specs=(spec_in,),
                         out_specs=spec_out, check_vma=False)(bundle.data)


def gather(bundle: Bundle) -> Any:
    """collect(): bring the bundle back to a single host array tree."""
    return jax.tree.map(lambda x: np.asarray(jax.device_get(x)),
                        bundle.data)
