"""``python -m repro.lint src tests benchmarks`` — the CLI runner.

Exit status: 0 when clean, 1 when any finding survives suppression,
2 on usage errors.  ``--report PATH`` additionally writes a JSON
artifact (list of findings + rule table) for CI upload.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import List, Optional

from repro.lint.core import all_rules, iter_py_files, lint_file


def _parser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(
        prog="python -m repro.lint",
        description="JAX/Pallas-aware static analysis for this repo")
    p.add_argument("paths", nargs="*", default=["src"],
                   help="files or directories to lint (default: src)")
    p.add_argument("--report", metavar="PATH", default=None,
                   help="write a JSON findings report to PATH")
    p.add_argument("--select", metavar="RULES", default=None,
                   help="comma-separated rule IDs/slugs to keep "
                        "(default: all)")
    p.add_argument("--list-rules", action="store_true",
                   help="print the rule table and exit")
    p.add_argument("-q", "--quiet", action="store_true",
                   help="suppress the per-finding lines (summary only)")
    return p


def main(argv: Optional[List[str]] = None) -> int:
    args = _parser().parse_args(argv)

    if args.list_rules:
        for r in all_rules():
            print(f"{r.id}  {r.slug:<22} {r.summary}")
        return 0

    selected = None
    if args.select:
        selected = {t.strip().lower() for t in args.select.split(",")
                    if t.strip()}

    files = iter_py_files(args.paths)
    if not files:
        print(f"repro.lint: no .py files under {args.paths}",
              file=sys.stderr)
        return 2

    findings = []
    for f in files:
        found = lint_file(f)
        if selected is not None:
            found = [x for x in found
                     if {x.rule.id.lower(), x.rule.slug.lower()}
                     & selected]
        findings.extend(found)

    if not args.quiet:
        for f in findings:
            print(f.format())

    if args.report:
        report = {
            "files_checked": len(files),
            "findings": [f.to_json() for f in findings],
            "rules": [{"id": r.id, "slug": r.slug, "summary": r.summary}
                      for r in all_rules()],
        }
        with open(args.report, "w") as fh:
            json.dump(report, fh, indent=2)

    n = len(findings)
    print(f"repro.lint: {len(files)} files checked, {n} finding"
          f"{'' if n == 1 else 's'}")
    return 1 if findings else 0


if __name__ == "__main__":      # pragma: no cover
    sys.exit(main())
