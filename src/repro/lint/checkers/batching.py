"""Batch-axes hygiene for ``@register``-ed workloads (DESIGN.md §19).

RPL801 batch-axes : a registered Problem's ``init_bundle`` closes over
                    per-instance constructor state (``self.<attr>``)
                    that its ``batch_axes()`` declaration never
                    mentions.  ``solve_many`` builds ONE problem object
                    and calls ``init_bundle`` once per instance, so any
                    attribute the hook reads is silently shared across
                    the whole batch.  That is only sound when the author
                    says so — by naming the attribute in the
                    ``instance_invariant``/``shared_in_batch`` tuples of
                    the :class:`repro.core.batching.BatchAxes` the hook
                    returns.  An undeclared closure is the classic
                    batched-solve bug: per-instance noise levels or RNG
                    keys frozen to the first instance's value.

``self.cfg`` (the config object every Problem carries) and reads of the
class's own methods are exempt; so are private ``self._*`` caches.
"""
from __future__ import annotations

import ast
from typing import List, Set

from repro.lint.checkers._ast_util import import_aliases
from repro.lint.checkers.protocol import _methods, _registered
from repro.lint.core import Finding, ModuleSource, Rule, register_checker

RPL801 = Rule("RPL801", "batch-axes",
              "init_bundle closes over per-instance state not declared "
              "in batch_axes()")


def _self_reads(fn: ast.AST) -> Set[str]:
    """Names of ``self.<attr>`` loads anywhere in the function body."""
    reads: Set[str] = set()
    for node in ast.walk(fn):
        if isinstance(node, ast.Attribute) and \
                isinstance(node.ctx, ast.Load) and \
                isinstance(node.value, ast.Name) and \
                node.value.id == "self":
            reads.add(node.attr)
    return reads


def _declared_names(fn: ast.AST) -> Set[str]:
    """Every string literal in the batch_axes body — the union of the
    ``shared_in_batch``/``instance_invariant`` tuples regardless of how
    the BatchAxes call is spelled (conditionals, helper vars)."""
    return {node.value for node in ast.walk(fn)
            if isinstance(node, ast.Constant) and
            isinstance(node.value, str)}


def _check_class(mod, cls, findings) -> None:
    methods = _methods(cls)
    init = methods.get("init_bundle")
    if init is None:
        return                      # RPL501's problem, not ours
    attrs = {a for a in _self_reads(init)
             if a != "cfg" and not a.startswith("_")
             and a not in methods}
    if not attrs:
        return
    ba = methods.get("batch_axes")
    if ba is None:
        findings.append(mod.finding(
            RPL801, init,
            f"'{cls.name}.init_bundle' reads constructor state "
            f"({', '.join(sorted(attrs))}) but '{cls.name}' declares no "
            f"batch_axes() — solve_many would silently share these "
            f"across every instance; declare them in BatchAxes("
            f"instance_invariant=...) or shared_in_batch"))
        return
    declared = _declared_names(ba)
    for attr in sorted(attrs - declared):
        findings.append(mod.finding(
            RPL801, init,
            f"'{cls.name}.init_bundle' reads self.{attr}, which "
            f"batch_axes() never declares — under solve_many every "
            f"instance gets the same {attr}; add it to "
            f"instance_invariant (or shared_in_batch) if that is "
            f"intended"))


@register_checker("batching", [RPL801])
def check(mod: ModuleSource):
    aliases = import_aliases(mod.tree)
    findings: List[Finding] = []
    for node in ast.walk(mod.tree):
        if isinstance(node, ast.ClassDef) and _registered(node, aliases):
            _check_class(mod, node, findings)
    return findings
