"""RPL701 swallowed-exception: recovery-path code must not silently
swallow broad exceptions.

In ``repro/{core,checkpoint,resilience}`` an ``except:`` /
``except Exception:`` / ``except BaseException:`` handler that neither
re-raises nor routes the exception through the resilience machinery
turns a worker failure into silent state corruption — the exact
failure mode the supervised solve loop exists to make loud (DESIGN.md
§18).  Outside those packages broad handlers are left to review; inside
them every caught exception must either propagate (``raise``) or reach
a recognised router: the transient/fatal classifier or a
record/surface hook (``classify``, ``record_fault``,
``_record_failure``, ``_raise_pending``, ...).
"""
from __future__ import annotations

import ast
from typing import List

from repro.lint.core import Finding, ModuleSource, Rule, register_checker

RPL701 = Rule("RPL701", "swallowed-exception",
              "broad except clause swallows exceptions in recovery-path "
              "code without re-raising or routing them")

#: path fragments that put a module in scope (posix-normalised)
_SCOPED = ("repro/core/", "repro/checkpoint/", "repro/resilience/")

#: call targets that count as routing the exception into the resilience
#: machinery (bare names or method attributes)
_ROUTERS = frozenset({"classify", "classify_error", "record_fault",
                      "record_failure", "_record_failure",
                      "_raise_pending"})

#: exception names whose handlers are considered overbroad
_BROAD = frozenset({"Exception", "BaseException"})


def _in_scope(mod: ModuleSource) -> bool:
    return any(frag in mod.path.as_posix() for frag in _SCOPED)


def _broad_name(handler: ast.ExceptHandler) -> str:
    """The overbroad catch spelling, or '' when the handler is narrow."""
    t = handler.type
    if t is None:
        return "bare except:"
    names = t.elts if isinstance(t, ast.Tuple) else [t]
    for n in names:
        if isinstance(n, ast.Name) and n.id in _BROAD:
            return f"except {n.id}"
        if isinstance(n, ast.Attribute) and n.attr in _BROAD:
            return f"except {n.attr}"
    return ""


def _handled(handler: ast.ExceptHandler) -> bool:
    """True when the handler body re-raises or calls a router."""
    for node in ast.walk(handler):
        if isinstance(node, ast.Raise):
            return True
        if isinstance(node, ast.Call):
            fn = node.func
            name = (fn.id if isinstance(fn, ast.Name)
                    else fn.attr if isinstance(fn, ast.Attribute)
                    else None)
            if name in _ROUTERS:
                return True
    return False


@register_checker("resilience", [RPL701])
def check(mod: ModuleSource):
    findings: List[Finding] = []
    if not _in_scope(mod):
        return findings
    for node in ast.walk(mod.tree):
        if not isinstance(node, ast.ExceptHandler):
            continue
        broad = _broad_name(node)
        if not broad or _handled(node):
            continue
        findings.append(mod.finding(
            RPL701, node,
            f"{broad} swallows the exception — re-raise it or route it "
            f"through the resilience error machinery "
            f"(repro.resilience.errors.classify / record_fault / "
            f"_record_failure); silent recovery-path failures corrupt "
            f"state (DESIGN.md §18)"))
    return findings
