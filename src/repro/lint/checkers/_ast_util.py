"""Shared AST helpers for the checkers: dotted-name resolution, import
alias tracking, decorator matching, and source-order statement walking."""
from __future__ import annotations

import ast
from typing import Dict, Iterable, List, Optional, Tuple


def dotted(node) -> Optional[str]:
    """``a.b.c`` for a Name/Attribute chain, else None."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def import_aliases(tree: ast.Module) -> Dict[str, str]:
    """Local name -> fully qualified module/object it is bound to.

    Covers ``import numpy as np`` (np -> numpy), ``import jax.numpy as
    jnp`` (jnp -> jax.numpy) and ``from x.y import z [as w]``
    (w -> x.y.z).
    """
    out: Dict[str, str] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                out[a.asname or a.name.split(".")[0]] = \
                    a.name if a.asname else a.name.split(".")[0]
        elif isinstance(node, ast.ImportFrom) and node.module \
                and node.level == 0:
            for a in node.names:
                if a.name == "*":
                    continue
                out[a.asname or a.name] = f"{node.module}.{a.name}"
    return out


def resolve(node, aliases: Dict[str, str]) -> Optional[str]:
    """Fully qualified dotted name of an expression, through the import
    aliases: with ``import jax.numpy as jnp``, ``jnp.sum`` ->
    ``jax.numpy.sum``."""
    d = dotted(node)
    if d is None:
        return None
    head, _, rest = d.partition(".")
    base = aliases.get(head, head)
    return f"{base}.{rest}" if rest else base


def call_name(call: ast.Call, aliases: Dict[str, str]) -> Optional[str]:
    return resolve(call.func, aliases)


def decorator_names(fn, aliases: Dict[str, str]) -> List[str]:
    """Resolved names of every decorator (for ``@partial(jax.jit, ...)``
    both ``functools.partial`` and ``jax.jit`` are reported)."""
    names: List[str] = []
    for dec in fn.decorator_list:
        if isinstance(dec, ast.Call):
            n = resolve(dec.func, aliases)
            if n:
                names.append(n)
            for a in list(dec.args) + [kw.value for kw in dec.keywords]:
                an = resolve(a, aliases)
                if an:
                    names.append(an)
        else:
            n = resolve(dec, aliases)
            if n:
                names.append(n)
    return names


def static_argnames(fn, aliases: Dict[str, str]) -> set:
    """Literal ``static_argnames=`` sets from jit/partial decorators."""
    out: set = set()
    for dec in fn.decorator_list:
        if not isinstance(dec, ast.Call):
            continue
        for kw in dec.keywords:
            if kw.arg in ("static_argnames", "static_argnums"):
                for c in ast.walk(kw.value):
                    if isinstance(c, ast.Constant) and \
                            isinstance(c.value, str):
                        out.add(c.value)
    return out


def param_names(fn) -> List[str]:
    a = fn.args
    names = [p.arg for p in a.posonlyargs + a.args + a.kwonlyargs]
    if a.vararg:
        names.append(a.vararg.arg)
    if a.kwarg:
        names.append(a.kwarg.arg)
    return names


def params_with_defaults(fn) -> set:
    """Parameter names that carry a default value (positional or kw-only)."""
    a = fn.args
    out = set()
    pos = a.posonlyargs + a.args
    for p, _ in zip(reversed(pos), reversed(a.defaults)):
        out.add(p.arg)
    for p, d in zip(a.kwonlyargs, a.kw_defaults):
        if d is not None:
            out.add(p.arg)
    return out


def functions(tree, *, nested: bool = True) -> List:
    """Every FunctionDef/AsyncFunctionDef, optionally including nested."""
    out = []

    def visit(node):
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                out.append(child)
                if nested:
                    visit(child)
            elif not isinstance(child, (ast.Lambda,)):
                visit(child)

    visit(tree)
    return out


def enclosing_function_map(tree) -> Dict[int, ast.AST]:
    """Map id(node) -> the innermost FunctionDef containing it."""
    owner: Dict[int, ast.AST] = {}

    def visit(node, fn):
        for child in ast.iter_child_nodes(node):
            here = child if isinstance(
                child, (ast.FunctionDef, ast.AsyncFunctionDef)) else fn
            if fn is not None:
                owner[id(child)] = fn
            visit(child, here)

    visit(tree, None)
    return owner


def walk_calls(node) -> Iterable[ast.Call]:
    for n in ast.walk(node):
        if isinstance(n, ast.Call):
            yield n


def const_str(node) -> Optional[str]:
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return node.value
    return None


def assigned_names(target) -> List[Tuple[str, ast.AST]]:
    """Flatten an assignment target into (name, node) pairs."""
    out: List[Tuple[str, ast.AST]] = []
    if isinstance(target, ast.Name):
        out.append((target.id, target))
    elif isinstance(target, (ast.Tuple, ast.List)):
        for el in target.elts:
            out.extend(assigned_names(el))
    elif isinstance(target, ast.Starred):
        out.extend(assigned_names(target.value))
    return out
