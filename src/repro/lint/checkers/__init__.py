"""Built-in checkers.  Importing this package registers all of them
(each module calls :func:`repro.lint.core.register_checker` at import
time); ``repro.lint.core`` imports it lazily before every run."""
from repro.lint.checkers import (batching, donation, dtypes, imports,
                                 pallas, protocol, resilience, serve,
                                 tracer)

__all__ = ["batching", "donation", "dtypes", "imports", "pallas",
           "protocol", "resilience", "serve", "tracer"]
