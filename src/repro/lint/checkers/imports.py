"""RPL601 noncanonical-import: kernel-family shared helpers must be
imported from ``repro.kernels.common``, their canonical home.

``auto_interpret`` / ``pad_leading`` are re-exported by every family's
``kernel.py`` for historical reasons; importing them *through* a family
module couples unrelated families (condat ops depending on condat
kernel internals for a backend-selection helper) and means an import
like ``from repro.kernels.X.kernel import auto_interpret`` silently
pins behavior to whichever module re-exported it.  One canonical home
keeps env-override behavior (``REPRO_FORCE_INTERPRET``) in one place.
"""
from __future__ import annotations

import ast
from typing import List

from repro.lint.core import Finding, ModuleSource, Rule, register_checker

RPL601 = Rule("RPL601", "noncanonical-import",
              "shared kernel helper imported from a non-canonical module")

_CANONICAL = "repro.kernels.common"
_SHARED_HELPERS = {"auto_interpret", "pad_leading"}


@register_checker("imports", [RPL601])
def check(mod: ModuleSource):
    findings: List[Finding] = []
    # common.py itself defines the helpers; kernel.py re-exports are
    # tolerated for backwards compatibility but must come from common
    for node in ast.walk(mod.tree):
        if not isinstance(node, ast.ImportFrom) or node.module is None:
            continue
        if node.module == _CANONICAL or node.level > 0:
            continue
        if not node.module.startswith("repro.kernels."):
            continue
        for alias in node.names:
            if alias.name in _SHARED_HELPERS:
                findings.append(mod.finding(
                    RPL601, node,
                    f"'{alias.name}' imported from '{node.module}' — "
                    f"import it from its canonical home "
                    f"'{_CANONICAL}' instead"))
    return findings
