"""Tracer hygiene inside jit/pallas-reachable functions.

RPL301 traced-branch   : Python control flow (``if``/``while``/``for``/
                         ternary/comprehension filter/``assert``) on a
                         traced value — concretization error at trace
                         time, or worse, silent trace-time constant.
RPL302 host-cast       : ``bool()``/``int()``/``float()`` or
                         ``.item()``/``.tolist()`` on a traced value —
                         forces a host sync / breaks tracing.
RPL303 numpy-on-traced : ``np.*`` call on a traced value — silently
                         drops out of the traced computation.

Reachability: a function is *jit-reachable* when it is decorated with
``jax.jit`` (directly or via ``functools.partial``), or passed by name
into ``jax.jit`` / ``pl.pallas_call`` / ``lax.scan`` / ``lax.cond`` /
``lax.while_loop`` / ``lax.fori_loop`` / ``shard_map`` / ``jax.vmap`` /
``jax.grad`` / ``jax.eval_shape``, or is a lambda given to one of those.

Taint: parameters of a reachable function are traced unless they carry
a default value, appear in the decorator's ``static_argnames``, or are
conventionally-static names (``axes``/``mesh``/``cfg``/``config``/
``opts``).  Taint flows through arithmetic, ``jnp.*``/``lax.*`` calls,
method chains, subscripts, and plain assignment.  It stops at
``.shape``/``.dtype``/``.ndim``-style metadata, shape-query helpers
(``jnp.ndim``, ``len``, ``isinstance`` …), ``is``/``is not`` compares,
and container literals (their truthiness is their static length).
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Set

from repro.lint.checkers._ast_util import (decorator_names, dotted,
                                           import_aliases,
                                           params_with_defaults, resolve,
                                           static_argnames)
from repro.lint.core import Finding, ModuleSource, Rule, register_checker

RPL301 = Rule("RPL301", "traced-branch",
              "Python control flow on a traced value")
RPL302 = Rule("RPL302", "host-cast",
              "host-side cast of a traced value")
RPL303 = Rule("RPL303", "numpy-on-traced",
              "numpy call on a traced value inside a jitted function")

# call targets whose function-valued arguments become jit-reachable
_TRACING_ENTRYPOINTS = {
    "jit", "pallas_call", "scan", "cond", "while_loop", "fori_loop",
    "shard_map", "vmap", "pmap", "grad", "value_and_grad", "eval_shape",
    "checkpoint", "remat", "switch", "custom_vjp", "custom_jvp",
}
_TRACING_PREFIXES = ("jax", "functools.partial")

# parameters that are static by convention in this codebase
_STATIC_PARAM_NAMES = {"axes", "mesh", "cfg", "config", "opts", "self"}

# metadata attributes that yield static values even on tracers
_STATIC_ATTRS = {"shape", "dtype", "ndim", "size", "sharding", "aval",
                 "weak_type", "itemsize"}

# calls that return static (host) values even on traced arguments
_STATIC_CALLS = {
    "len", "isinstance", "issubclass", "type", "range", "enumerate",
    "zip", "hasattr", "getattr", "callable", "sorted", "min", "max",
    "jax.numpy.ndim", "jax.numpy.shape", "jax.numpy.size",
    "jax.numpy.issubdtype", "jax.numpy.result_type", "jax.numpy.dtype",
    "jax.dtypes.issubdtype", "jax.dtypes.result_type",
    "jax.eval_shape", "jax.tree_util.tree_structure",
    "jax.tree.structure",
}

_HOST_CASTS = {"bool", "int", "float", "complex"}
_HOST_METHODS = {"item", "tolist", "__bool__", "__float__", "__index__"}


def _is_tracing_call(call: ast.Call, aliases) -> bool:
    name = resolve(call.func, aliases)
    if name is None:
        return False
    leaf = name.split(".")[-1]
    if leaf not in _TRACING_ENTRYPOINTS:
        return False
    # require a jax-ish qualification so a local helper named ``scan``
    # does not pull its arguments into tracing scope
    return name.startswith(_TRACING_PREFIXES) or "pallas" in name \
        or "lax" in name or name == leaf == "shard_map" or leaf == "jit"


def _jit_decorated(fn, aliases) -> bool:
    for name in decorator_names(fn, aliases):
        leaf = name.split(".")[-1]
        if leaf in ("jit", "pjit") and (name.startswith("jax")
                                        or leaf == name):
            return True
    return False


def _collect_roots(tree, aliases):
    """(reachable FunctionDefs, reachable Lambdas).

    A name passed into a tracing entrypoint marks the local def of that
    name; lambdas passed inline are collected directly.
    """
    defs: Dict[str, ast.AST] = {}
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            defs[node.name] = node

    roots: Set[int] = set()
    root_nodes = []

    def add(node):
        if id(node) not in roots:
            roots.add(id(node))
            root_nodes.append(node)

    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                and _jit_decorated(node, aliases):
            add(node)
        elif isinstance(node, ast.Call) and _is_tracing_call(node, aliases):
            for arg in list(node.args) + [kw.value for kw in node.keywords]:
                if isinstance(arg, ast.Name) and arg.id in defs:
                    add(defs[arg.id])
                elif isinstance(arg, ast.Lambda):
                    add(arg)
    return root_nodes


class _Taint:
    """Expression taint evaluation against a set of traced names."""

    def __init__(self, tainted: Set[str], aliases):
        self.names = tainted
        self.aliases = aliases

    def tainted(self, node) -> bool:
        if node is None or isinstance(node, ast.Constant):
            return False
        if isinstance(node, ast.Name):
            return node.id in self.names
        if isinstance(node, ast.Attribute):
            if node.attr in _STATIC_ATTRS:
                return False
            return self.tainted(node.value)
        if isinstance(node, ast.Subscript):
            return self.tainted(node.value)
        if isinstance(node, (ast.Dict, ast.List, ast.Tuple, ast.Set,
                             ast.ListComp, ast.DictComp, ast.SetComp,
                             ast.GeneratorExp)):
            return False                  # truthiness = static length
        if isinstance(node, ast.Call):
            return self._call_tainted(node)
        if isinstance(node, ast.Compare):
            if all(isinstance(op, (ast.Is, ast.IsNot)) for op in node.ops):
                return False              # identity checks are host bools
            return self.tainted(node.left) or \
                any(self.tainted(c) for c in node.comparators)
        if isinstance(node, ast.BoolOp):
            return any(self.tainted(v) for v in node.values)
        if isinstance(node, ast.BinOp):
            return self.tainted(node.left) or self.tainted(node.right)
        if isinstance(node, ast.UnaryOp):
            return self.tainted(node.operand)
        if isinstance(node, ast.IfExp):
            return self.tainted(node.body) or self.tainted(node.orelse)
        if isinstance(node, ast.Starred):
            return self.tainted(node.value)
        if isinstance(node, (ast.JoinedStr, ast.FormattedValue)):
            return False
        return False

    def _call_tainted(self, call: ast.Call) -> bool:
        name = resolve(call.func, self.aliases)
        if name is not None:
            if name in _STATIC_CALLS or \
                    name.split(".")[-1] in ("ndim", "shape") and \
                    name.startswith("jax"):
                return False
            if name.startswith(("jax.numpy", "jax.lax", "jax.nn",
                                "jax.random", "jax.scipy")):
                return True
        # method call on a traced value (x.sum(), x.astype(...))
        if isinstance(call.func, ast.Attribute) and \
                self.tainted(call.func.value):
            return True
        # unknown callee: conservatively propagate argument taint
        return any(self.tainted(a) for a in call.args) or \
            any(self.tainted(kw.value) for kw in call.keywords)


def _traced_params(fn, aliases) -> Set[str]:
    if isinstance(fn, ast.Lambda):
        return {a.arg for a in fn.args.args
                if a.arg not in _STATIC_PARAM_NAMES}
    defaulted = params_with_defaults(fn)
    static = static_argnames(fn, aliases) | _STATIC_PARAM_NAMES
    out = set()
    for a in fn.args.posonlyargs + fn.args.args + fn.args.kwonlyargs:
        if a.arg in defaulted or a.arg in static:
            continue
        out.add(a.arg)
    return out


def _infer_taint(fn, aliases) -> Set[str]:
    """Traced names in ``fn``'s body: params plus assignment fixpoint."""
    tainted = _traced_params(fn, aliases)
    if isinstance(fn, ast.Lambda):
        return tainted
    body_stmts = _own_statements(fn)
    for _ in range(4):                       # fixpoint (loops/reorders)
        t = _Taint(tainted, aliases)
        changed = False
        for st in body_stmts:
            targets = None
            if isinstance(st, ast.Assign):
                targets, value = st.targets, st.value
            elif isinstance(st, (ast.AugAssign, ast.AnnAssign)) and \
                    getattr(st, "value", None) is not None:
                targets, value = [st.target], st.value
            elif isinstance(st, (ast.For, ast.AsyncFor)):
                targets, value = [st.target], st.iter
            else:
                continue
            if not t.tainted(value):
                continue
            for tgt in targets:
                for n in ast.walk(tgt):
                    if isinstance(n, ast.Name) and n.id not in tainted:
                        tainted.add(n.id)
                        changed = True
        if not changed:
            break
    return tainted


def _own_statements(fn) -> List[ast.stmt]:
    """Statements of ``fn`` excluding nested function bodies (nested
    defs are analyzed as their own roots when reachable)."""
    out: List[ast.stmt] = []

    def visit(stmts):
        for st in stmts:
            if isinstance(st, (ast.FunctionDef, ast.AsyncFunctionDef,
                               ast.ClassDef)):
                continue
            out.append(st)
            for field in ("body", "orelse", "finalbody"):
                visit(getattr(st, field, []) or [])
            for h in getattr(st, "handlers", []) or []:
                visit(h.body)

    visit(fn.body)
    return out


def _check_root(mod, fn, aliases, findings) -> None:
    tainted = _infer_taint(fn, aliases)
    if not tainted:
        return
    t = _Taint(tainted, aliases)
    label = getattr(fn, "name", "<lambda>")

    if isinstance(fn, ast.Lambda):
        _check_expr_tree(mod, fn.body, t, label, findings)
        return

    for st in _own_statements(fn):
        if isinstance(st, (ast.If, ast.While)) and t.tainted(st.test):
            findings.append(mod.finding(
                RPL301, st.test,
                f"Python branch on traced value in jit-reachable "
                f"'{label}' — use jnp.where/lax.cond"))
        elif isinstance(st, (ast.For, ast.AsyncFor)) and t.tainted(st.iter):
            findings.append(mod.finding(
                RPL301, st.iter,
                f"Python loop over traced value in jit-reachable "
                f"'{label}' — use lax.scan/fori_loop"))
        elif isinstance(st, ast.Assert) and t.tainted(st.test):
            findings.append(mod.finding(
                RPL301, st.test,
                f"assert on traced value in jit-reachable '{label}' — "
                f"use checkify or a runtime sanitizer"))
        for expr in ast.walk(st):
            if isinstance(expr, (ast.stmt,)):
                continue
            _check_expr(mod, expr, t, label, findings)


def _check_expr_tree(mod, root, t, label, findings) -> None:
    for expr in ast.walk(root):
        _check_expr(mod, expr, t, label, findings)


def _check_expr(mod, expr, t, label, findings) -> None:
    if isinstance(expr, ast.IfExp) and t.tainted(expr.test):
        findings.append(mod.finding(
            RPL301, expr.test,
            f"ternary on traced value in jit-reachable '{label}' — "
            f"use jnp.where"))
    elif isinstance(expr, ast.comprehension):
        for cond in expr.ifs:
            if t.tainted(cond):
                findings.append(mod.finding(
                    RPL301, cond,
                    f"comprehension filter on traced value in "
                    f"jit-reachable '{label}'"))
    elif isinstance(expr, ast.Call):
        name = resolve(expr.func, t.aliases)
        if name in _HOST_CASTS and expr.args and t.tainted(expr.args[0]):
            findings.append(mod.finding(
                RPL302, expr,
                f"{name}() on traced value in jit-reachable '{label}' "
                f"forces a host sync"))
        elif isinstance(expr.func, ast.Attribute) and \
                expr.func.attr in _HOST_METHODS and \
                t.tainted(expr.func.value):
            findings.append(mod.finding(
                RPL302, expr,
                f".{expr.func.attr}() on traced value in jit-reachable "
                f"'{label}' forces a host sync"))
        elif name is not None and name.startswith("numpy.") and \
                (any(t.tainted(a) for a in expr.args) or
                 any(t.tainted(kw.value) for kw in expr.keywords)):
            findings.append(mod.finding(
                RPL303, expr,
                f"{name.replace('numpy', 'np', 1)}() on traced value in "
                f"jit-reachable '{label}' — use jnp instead"))


@register_checker("tracer", [RPL301, RPL302, RPL303])
def check(mod: ModuleSource):
    aliases = import_aliases(mod.tree)
    findings: List[Finding] = []
    for fn in _collect_roots(mod.tree, aliases):
        _check_root(mod, fn, aliases, findings)
    return findings
