"""Pallas kernel contracts.

RPL201 blockspec-grid      : BlockSpec block shape / index map is
                             inconsistent with the grid expression.
RPL202 missing-interpret   : a ``pl.pallas_call`` site without the
                             ``interpret=`` fallback plumbing.
RPL203 ref-parity          : a kernel family's ``ref.py`` oracle and
                             ``ops.py`` public wrapper disagree on
                             signatures (checked by import-and-inspect,
                             not string matching), or a sibling is
                             missing entirely.

The grid/BlockSpec check leans on this codebase's kernel idiom: 1-D (or
n-D) grids of the form ``grid=(padded // block, ...)`` with
``pl.BlockSpec((block, ...), lambda i, ...: (i, 0, 0))``.  For each grid
axis it finds the position where the lambda parameter appears in the
index map's return tuple and requires the block shape at that position
to be the same name as the grid divisor.  Specs built by helper calls
(e.g. SMEM scalar specs) are skipped — only literal ``pl.BlockSpec``
calls are validated.
"""
from __future__ import annotations

import ast
import importlib
import importlib.util
import inspect
from pathlib import Path
from typing import Dict, List, Optional

from repro.lint.checkers._ast_util import (dotted, functions,
                                           import_aliases, param_names,
                                           resolve)
from repro.lint.core import Finding, ModuleSource, Rule, register_checker

RPL201 = Rule("RPL201", "blockspec-grid",
              "BlockSpec block shape inconsistent with pallas_call grid")
RPL202 = Rule("RPL202", "missing-interpret",
              "pallas_call without an interpret fallback path")
RPL203 = Rule("RPL203", "ref-parity",
              "kernel ops.py / ref.py signature parity violation")

# kernel-control parameters the public wrapper may add on top of the
# oracle's mathematical signature
_CONTROL_PARAMS = {"use_kernel", "interpret"}


def _is_pallas_call(call: ast.Call, aliases) -> bool:
    name = resolve(call.func, aliases)
    return name is not None and name.split(".")[-1] == "pallas_call" \
        and ("pallas" in name or name.startswith("pl."))


def _kw(call: ast.Call, name: str):
    for kw in call.keywords:
        if kw.arg == name:
            return kw.value
    return None


def _grid_axes(grid_node) -> Optional[List]:
    """Grid expression -> list of per-axis AST nodes (None = opaque)."""
    if grid_node is None:
        return None
    if isinstance(grid_node, ast.Tuple):
        return list(grid_node.elts)
    return [grid_node]       # grid=8 / grid=n_full // block


def _block_divisor(axis_node) -> Optional[str]:
    """``padded // block`` -> "block" (the name the block shape must
    use); None when the axis expression has another shape."""
    if isinstance(axis_node, ast.BinOp) and \
            isinstance(axis_node.op, ast.FloorDiv):
        return dotted(axis_node.right)
    return None


def _blockspecs(call: ast.Call, aliases):
    """Literal ``pl.BlockSpec(...)`` calls in in_specs/out_specs, as
    ``(spec, is_output)`` pairs."""
    specs = []
    for kw_name in ("in_specs", "out_specs"):
        node = _kw(call, kw_name)
        if node is None:
            continue
        entries = node.elts if isinstance(node, (ast.List, ast.Tuple)) \
            else [node]
        for e in entries:
            if isinstance(e, ast.Call):
                name = resolve(e.func, aliases)
                if name and name.split(".")[-1] == "BlockSpec":
                    specs.append((e, kw_name == "out_specs"))
    return specs


def _index_map_positions(lam: ast.Lambda) -> Optional[Dict[str, int]]:
    """lambda i, j: (j, i, 0) -> {"i": 1, "j": 0}; None if opaque."""
    body = lam.body
    elts = body.elts if isinstance(body, ast.Tuple) else [body]
    out: Dict[str, int] = {}
    for pos, el in enumerate(elts):
        if isinstance(el, ast.Name):
            if el.id in out:
                return None
            out[el.id] = pos
    return out


def _check_grid_site(mod, call, aliases, findings) -> None:
    grid_axes = _grid_axes(_kw(call, "grid"))
    for spec, is_output in _blockspecs(call, aliases):
        if spec.keywords and not spec.args:
            continue                       # memory_space-only (SMEM) spec
        if not spec.args:
            continue
        shape_node = spec.args[0]
        lam = spec.args[1] if len(spec.args) > 1 else None
        if not isinstance(shape_node, ast.Tuple):
            continue
        block_dims = shape_node.elts
        if lam is None or not isinstance(lam, ast.Lambda):
            continue
        lam_params = [a.arg for a in lam.args.args]
        if grid_axes is not None and len(lam_params) != len(grid_axes):
            findings.append(mod.finding(
                RPL201, spec,
                f"index map takes {len(lam_params)} grid indices but the "
                f"grid has {len(grid_axes)} axes"))
            continue
        positions = _index_map_positions(lam)
        if positions is None:
            continue
        # every grid index must steer some block dimension of an
        # *input* spec; outputs may pin a block across grid steps (the
        # sequential-grid accumulator idiom, e.g. dict_outer)
        if not is_output:
            for p in lam_params:
                if p not in positions:
                    findings.append(mod.finding(
                        RPL201, spec,
                        f"grid index '{p}' never appears in the index "
                        f"map return — a whole grid axis reads the "
                        f"same input block"))
        if grid_axes is None:
            continue
        for axis_i, p in enumerate(lam_params):
            pos = positions.get(p)
            if pos is None:
                continue
            if pos >= len(block_dims):
                findings.append(mod.finding(
                    RPL201, spec,
                    f"index map position {pos} exceeds the "
                    f"{len(block_dims)}-d block shape"))
                continue
            divisor = _block_divisor(grid_axes[axis_i])
            block_name = dotted(block_dims[pos])
            if divisor is not None and block_name is not None \
                    and divisor != block_name:
                findings.append(mod.finding(
                    RPL201, spec,
                    f"grid axis {axis_i} steps in units of '{divisor}' "
                    f"but the block shape at position {pos} is "
                    f"'{block_name}' — block/grid math disagrees"))


def _check_interpret(mod, call, aliases, owner_fn, findings) -> None:
    if _kw(call, "interpret") is None:
        findings.append(mod.finding(
            RPL202, call,
            "pallas_call without interpret= — non-TPU backends have no "
            "fallback path (pass interpret=interpret resolved via "
            "repro.kernels.common.auto_interpret)"))
        return
    if owner_fn is not None and "interpret" not in param_names(owner_fn):
        findings.append(mod.finding(
            RPL202, call,
            f"'{owner_fn.name}' hardcodes the pallas_call interpret "
            f"mode — accept an interpret=None parameter and resolve it "
            f"via repro.kernels.common.auto_interpret"))


# --------------------------------------------------------------------
# ref.py <-> ops.py parity (import-and-inspect)
# --------------------------------------------------------------------

def _module_name_for(path: Path) -> Optional[str]:
    """Importable dotted module name for a file inside the repro
    package (resolved through its __init__.py chain), else None."""
    parts = list(path.with_suffix("").parts)
    if "repro" not in parts:
        return None
    idx = len(parts) - 1 - parts[::-1].index("repro")
    return ".".join(parts[idx:])


def _load_module(path: Path):
    name = _module_name_for(path)
    if name is not None:
        return importlib.import_module(name)
    # fixture files outside the package: load standalone by location
    spec = importlib.util.spec_from_file_location(
        f"_repro_lint_fixture_{abs(hash(str(path)))}", path)
    module = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(module)
    return module


def _math_params(fn) -> List[str]:
    """Signature minus the kernel-control knobs (use_kernel/interpret/
    block_*) — the part that must agree between oracle and wrapper."""
    out = []
    for name, p in inspect.signature(fn).parameters.items():
        if name in _CONTROL_PARAMS or name.startswith("block_"):
            continue
        if p.kind in (inspect.Parameter.VAR_POSITIONAL,
                      inspect.Parameter.VAR_KEYWORD):
            continue
        out.append(name)
    return out


def _check_parity(mod: ModuleSource) -> List[Finding]:
    findings: List[Finding] = []
    ops_path = mod.path
    ref_path = ops_path.with_name("ref.py")
    if not ref_path.exists():
        findings.append(Finding(
            str(ops_path), 1, 0, RPL203,
            "kernel family has no sibling ref.py oracle"))
        return findings
    try:
        ops_mod = _load_module(ops_path)
        ref_mod = _load_module(ref_path)
    except Exception as e:                  # pragma: no cover - env issue
        findings.append(Finding(
            str(ops_path), 1, 0, RPL203,
            f"could not import ops/ref pair for parity check: {e!r}"))
        return findings
    for ref_name in dir(ref_mod):
        if ref_name.startswith("_") or not ref_name.endswith("_ref"):
            continue
        ref_fn = getattr(ref_mod, ref_name)
        if not inspect.isfunction(ref_fn) or \
                ref_fn.__module__ != ref_mod.__name__:
            continue
        pub = ref_name[:-len("_ref")]
        ops_fn = getattr(ops_mod, pub, None)
        if ops_fn is None or not callable(ops_fn):
            findings.append(Finding(
                str(ops_path), 1, 0, RPL203,
                f"ref.py declares {ref_name} but ops.py has no public "
                f"'{pub}' wrapper"))
            continue
        want = _math_params(ref_fn)
        got = _math_params(ops_fn)
        if want != got:
            findings.append(Finding(
                str(ops_path), 1, 0, RPL203,
                f"'{pub}' signature drifted from its oracle: ops.py "
                f"takes {got}, ref.py takes {want} (kernel-control "
                f"params excluded)"))
    return findings


def _is_kernel_ops(path: Path) -> bool:
    return path.name == "ops.py" and path.parent.parent.name == "kernels"


def _is_kernel_module(path: Path) -> bool:
    return path.parent.parent.name == "kernels" and \
        path.name in ("kernel.py", "ops.py")


@register_checker("pallas", [RPL201, RPL202, RPL203])
def check(mod: ModuleSource):
    aliases = import_aliases(mod.tree)
    findings: List[Finding] = []

    # map pallas_call sites to their enclosing function
    for fn in [None] + functions(mod.tree):
        scope = fn if fn is not None else mod.tree
        for node in ast.walk(scope):
            if isinstance(node, ast.Call) and \
                    _is_pallas_call(node, aliases):
                # only attribute the call to its innermost function
                if fn is None and any(
                        node in ast.walk(f) for f in functions(mod.tree)):
                    continue
                if fn is not None and any(
                        node in ast.walk(g)
                        for g in functions(fn, nested=True)):
                    continue
                _check_grid_site(mod, node, aliases, findings)
                _check_interpret(mod, node, aliases, fn, findings)

    if _is_kernel_ops(mod.path):
        findings.extend(_check_parity(mod))
    elif mod.path.name == "kernel.py" and _is_kernel_module(mod.path):
        for sibling in ("ops.py", "ref.py"):
            if not mod.path.with_name(sibling).exists():
                findings.append(Finding(
                    str(mod.path), 1, 0, RPL203,
                    f"kernel family has no sibling {sibling}"))
    return findings
