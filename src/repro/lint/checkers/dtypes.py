"""Dtype promotion hygiene.

RPL401 f64-dtype : a literal ``float64`` / ``complex128`` dtype request.
                   This codebase runs under JAX's default f32 regime;
                   an explicit f64 either silently truncates (x64
                   disabled, the default) or doubles memory and
                   disables the Pallas kernels (x64 enabled).
RPL402 bf16-accum: a reduction (``jnp.sum``/``mean``/``dot``/``matmul``/
                   ``einsum``/``@``/``.sum()``…) whose operand is
                   explicitly cast to ``bfloat16``/``float16`` without a
                   wider accumulation dtype.  Low-precision inputs are
                   fine; *accumulating* in them silently loses the tail
                   of large sums (DESIGN.md §17).  Fix with
                   ``.astype(jnp.float32)`` before the reduction or a
                   ``preferred_element_type``/``dtype=`` on the
                   reduction itself.
"""
from __future__ import annotations

import ast
from typing import List, Optional

from repro.lint.checkers._ast_util import import_aliases, resolve
from repro.lint.core import Finding, ModuleSource, Rule, register_checker

RPL401 = Rule("RPL401", "f64-dtype",
              "explicit float64/complex128 dtype in an f32 codebase")
RPL402 = Rule("RPL402", "bf16-accum",
              "reduction accumulates in bf16/f16 without a wider dtype")

_WIDE_DTYPES = {"float64", "complex128", "f64", "double"}
_NARROW_DTYPES = {"bfloat16", "float16", "bf16", "f16", "half"}
_REDUCTIONS = {"sum", "mean", "prod", "cumsum", "cumprod", "dot",
               "matmul", "vdot", "tensordot", "einsum", "trace", "var",
               "std"}
# keywords that widen the accumulator and clear RPL402
_ACCUM_KWARGS = {"dtype", "preferred_element_type", "precision",
                 "accum_dtype"}


def _dtype_token(node, aliases) -> Optional[str]:
    """The dtype a node names, as a lowercase token, else None.

    Recognizes ``jnp.float64``, ``np.float64``, ``"float64"``, and
    ``jnp.dtype("float64")``-style spellings.
    """
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return node.value.lower()
    name = resolve(node, aliases)
    if name is not None:
        leaf = name.split(".")[-1].lower()
        if name.startswith(("jax", "numpy", "ml_dtypes")):
            return leaf
    if isinstance(node, ast.Call) and node.args:
        fn = resolve(node.func, aliases)
        if fn is not None and fn.split(".")[-1] == "dtype":
            return _dtype_token(node.args[0], aliases)
    return None


def _narrow_cast(node, aliases) -> bool:
    """True when ``node`` is explicitly cast/created as bf16/f16:
    ``x.astype(jnp.bfloat16)``, ``jnp.asarray(x, dtype=jnp.bfloat16)``,
    or any call with a narrow ``dtype=`` keyword."""
    if not isinstance(node, ast.Call):
        return False
    if isinstance(node.func, ast.Attribute) and \
            node.func.attr == "astype" and node.args:
        tok = _dtype_token(node.args[0], aliases)
        return tok in _NARROW_DTYPES
    for kw in node.keywords:
        if kw.arg == "dtype":
            tok = _dtype_token(kw.value, aliases)
            if tok in _NARROW_DTYPES:
                return True
    return False


def _has_wide_accumulator(call: ast.Call, aliases) -> bool:
    for kw in call.keywords:
        if kw.arg in _ACCUM_KWARGS:
            tok = _dtype_token(kw.value, aliases)
            if tok is None or tok not in _NARROW_DTYPES:
                return True
    return False


def _contains_narrow(node, aliases, depth: int = 0) -> Optional[ast.AST]:
    """A bf16/f16-cast subexpression feeding this operand, if any.

    Only looks through arithmetic/calls a few levels deep — a narrow
    cast buried behind another (widening) reduction is that reduction's
    problem, not this one's.
    """
    if depth > 4 or node is None:
        return None
    if _narrow_cast(node, aliases):
        return node
    if isinstance(node, ast.BinOp):
        return _contains_narrow(node.left, aliases, depth + 1) or \
            _contains_narrow(node.right, aliases, depth + 1)
    if isinstance(node, ast.UnaryOp):
        return _contains_narrow(node.operand, aliases, depth + 1)
    return None


@register_checker("dtypes", [RPL401, RPL402])
def check(mod: ModuleSource):
    aliases = import_aliases(mod.tree)
    findings: List[Finding] = []

    for node in ast.walk(mod.tree):
        # ---- RPL401: any reference to a wide dtype ------------------
        # host numpy is f64 by default, so only *jax*-side wide dtypes
        # are flagged (np.float64 reference computations in tests are
        # fine — they never enter the traced pipeline)
        tok = None
        if isinstance(node, (ast.Attribute, ast.Name)):
            name = resolve(node, aliases)
            if name is not None and \
                    name.split(".")[-1].lower() in _WIDE_DTYPES and \
                    name.startswith("jax"):
                tok = name.split(".")[-1].lower()
        elif isinstance(node, ast.Call):
            fn_name = resolve(node.func, aliases)
            if fn_name is not None and fn_name.startswith("jax"):
                for kw in node.keywords:
                    if kw.arg == "dtype" and \
                            isinstance(kw.value, ast.Constant):
                        t = _dtype_token(kw.value, aliases)
                        if t in _WIDE_DTYPES:
                            tok = t
        if tok is not None:
            findings.append(mod.finding(
                RPL401, node,
                f"explicit {tok} — silently truncated to f32 unless "
                f"jax_enable_x64 is set; keep the pipeline f32 or gate "
                f"behind a config"))
            continue

        # ---- RPL402: narrow accumulation in reductions --------------
        if isinstance(node, ast.Call):
            name = resolve(node.func, aliases)
            leaf = None
            if name is not None and name.startswith(("jax.numpy",
                                                     "jax.lax")):
                leaf = name.split(".")[-1]
            elif isinstance(node.func, ast.Attribute) and \
                    node.func.attr in _REDUCTIONS:
                leaf = node.func.attr       # x.sum() method form
            if leaf in _REDUCTIONS and not \
                    _has_wide_accumulator(node, aliases):
                operands = list(node.args)
                if isinstance(node.func, ast.Attribute) and \
                        node.func.attr in _REDUCTIONS:
                    operands.append(node.func.value)
                for op in operands:
                    narrow = _contains_narrow(op, aliases)
                    if narrow is not None:
                        findings.append(mod.finding(
                            RPL402, node,
                            f"'{leaf}' accumulates a bf16/f16-cast "
                            f"operand without a wider dtype — pass "
                            f"dtype=/preferred_element_type= or cast "
                            f"the operand to float32 first"))
                        break
        elif isinstance(node, ast.BinOp) and \
                isinstance(node.op, ast.MatMult):
            for op in (node.left, node.right):
                if _contains_narrow(op, aliases) is not None:
                    findings.append(mod.finding(
                        RPL402, node,
                        "'@' matmul on a bf16/f16-cast operand "
                        "accumulates in low precision — use jnp.matmul "
                        "with preferred_element_type=jnp.float32"))
                    break
    return findings
