"""Problem-protocol conformance for ``@register``-ed workloads.

RPL501 problem-hooks    : a registered Problem subclass is missing a
                          required hook or declares a hook with the
                          wrong arity.  The protocol is duck-typed —
                          without this check, a drifted signature only
                          fails deep inside ``derive_options``/trace.
RPL502 problem-metadata : class metadata and declared hooks disagree
                          (``replicated_in_carry`` without
                          ``refresh_replicated``/``light_step``,
                          ``refresh_replicated`` without
                          ``replicated_in_carry``, or
                          ``default_cost_every="chunk"`` without the
                          ``cost`` + ``light_step`` pair it wires up).

Expected hook arities (incl. ``self`` — DESIGN.md §14):
``init_bundle(self, inputs, mesh)``, ``full_step(self, d, rep, axes)``,
``light_step(self, d, rep, axes)``, ``cost(self, d, rep, axes)``,
``refresh_replicated(self, rep, out)``, ``finalize(self, bundle, log)``.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional

from repro.lint.checkers._ast_util import (import_aliases, param_names,
                                           resolve)
from repro.lint.core import Finding, ModuleSource, Rule, register_checker

RPL501 = Rule("RPL501", "problem-hooks",
              "registered Problem missing/mis-declared protocol hook")
RPL502 = Rule("RPL502", "problem-metadata",
              "Problem metadata inconsistent with its declared hooks")

_REQUIRED = {"init_bundle": 3, "full_step": 4}
_OPTIONAL = {"light_step": 4, "cost": 4, "refresh_replicated": 3,
             "finalize": 3}


def _registered(cls: ast.ClassDef, aliases) -> bool:
    for dec in cls.decorator_list:
        if isinstance(dec, ast.Call):
            name = resolve(dec.func, aliases)
            if name is not None and name.split(".")[-1] == "register":
                return True
    return False


def _methods(cls: ast.ClassDef) -> Dict[str, ast.AST]:
    return {st.name: st for st in cls.body
            if isinstance(st, (ast.FunctionDef, ast.AsyncFunctionDef))}


def _class_flag(cls: ast.ClassDef, name: str):
    """Literal value of a class-level ``name = <const>``, else None."""
    for st in cls.body:
        targets = []
        if isinstance(st, ast.Assign):
            targets = st.targets
        elif isinstance(st, ast.AnnAssign) and st.value is not None:
            targets = [st.target]
        for t in targets:
            if isinstance(t, ast.Name) and t.id == name and \
                    isinstance(st.value, ast.Constant):
                return st.value.value
    return None


def _arity_ok(fn, want: int) -> bool:
    """Exact positional arity, modulo trailing defaulted params."""
    names = param_names(fn)
    if fn.args.vararg or fn.args.kwarg:
        return True                        # forwarding wrapper — accept
    n_required = len(fn.args.posonlyargs + fn.args.args) - \
        len(fn.args.defaults)
    return n_required <= want <= len(names)


def _check_class(mod, cls, findings) -> None:
    methods = _methods(cls)

    for hook, arity in _REQUIRED.items():
        fn = methods.get(hook)
        if fn is None:
            findings.append(mod.finding(
                RPL501, cls,
                f"registered Problem '{cls.name}' does not declare "
                f"required hook '{hook}'"))
        elif not _arity_ok(fn, arity):
            findings.append(mod.finding(
                RPL501, fn,
                f"'{cls.name}.{hook}' takes {len(param_names(fn))} "
                f"params, protocol expects {arity} "
                f"(incl. self — DESIGN.md §14)"))

    for hook, arity in _OPTIONAL.items():
        fn = methods.get(hook)
        if fn is not None and not _arity_ok(fn, arity):
            findings.append(mod.finding(
                RPL501, fn,
                f"'{cls.name}.{hook}' takes {len(param_names(fn))} "
                f"params, protocol expects {arity} "
                f"(incl. self — DESIGN.md §14)"))

    # ---- metadata consistency (mirrors derive_options' runtime
    # validation, but at lint time and for *all* registered classes) ---
    replicated = _class_flag(cls, "replicated_in_carry")
    cost_every = _class_flag(cls, "default_cost_every")
    if replicated is True:
        for needed in ("refresh_replicated", "light_step"):
            if needed not in methods:
                findings.append(mod.finding(
                    RPL502, cls,
                    f"'{cls.name}' sets replicated_in_carry but does "
                    f"not declare {needed}() — the broadcast carry "
                    f"cannot advance (derive_options will reject it)"))
    if "refresh_replicated" in methods and replicated is not True:
        findings.append(mod.finding(
            RPL502, cls,
            f"'{cls.name}' declares refresh_replicated() without "
            f"replicated_in_carry=True — the hook is dead wiring"))
    if cost_every == "chunk":
        for needed in ("cost", "light_step"):
            if needed not in methods:
                findings.append(mod.finding(
                    RPL502, cls,
                    f"'{cls.name}' defaults cost_every='chunk' but "
                    f"does not declare {needed}() — the chunk-cost "
                    f"step cannot be assembled"))


@register_checker("protocol", [RPL501, RPL502])
def check(mod: ModuleSource):
    aliases = import_aliases(mod.tree)
    findings: List[Finding] = []
    for node in ast.walk(mod.tree):
        if isinstance(node, ast.ClassDef) and _registered(node, aliases):
            _check_class(mod, node, findings)
    return findings
