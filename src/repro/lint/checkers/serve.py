"""RPL901 untracked-task: serving-layer asyncio tasks must not drop
their exceptions.

In ``repro/serve/`` a task spawned with ``asyncio.create_task`` /
``asyncio.ensure_future`` (or ``loop.create_task``) whose handle is
discarded — a bare expression statement, or assigned to a name that is
never used again — loses its exception: asyncio only surfaces it as a
"Task exception was never retrieved" log line at garbage-collection
time, long after the serving loop silently stopped doing whatever the
task was for (the §21 watchdog dying this way would disable
hung-dispatch reaping with no visible failure).  A spawned task must be
awaited, gathered, stored on an object, returned, or given an
``add_done_callback`` that retrieves the exception.
"""
from __future__ import annotations

import ast
from typing import List

from repro.lint.core import Finding, ModuleSource, Rule, register_checker

RPL901 = Rule("RPL901", "untracked-task",
              "asyncio task spawned in repro/serve/ whose handle (and "
              "exception) is dropped")

#: path fragment that puts a module in scope (posix-normalised)
_SCOPED = "repro/serve/"

#: call attrs/names that spawn a task owning future exceptions
_SPAWNERS = frozenset({"create_task", "ensure_future"})


def _spawns_task(call: ast.Call) -> str:
    fn = call.func
    name = (fn.id if isinstance(fn, ast.Name)
            else fn.attr if isinstance(fn, ast.Attribute) else None)
    return name if name in _SPAWNERS else ""


def _flag(mod: ModuleSource, node, spawn: str, how: str) -> Finding:
    return mod.finding(
        RPL901, node,
        f"{spawn}(...) {how} — its exception is never retrieved and "
        f"the task dies silently; await/gather it, store the handle, "
        f"or attach an add_done_callback that calls .exception()")


@register_checker("serve", [RPL901])
def check(mod: ModuleSource):
    findings: List[Finding] = []
    if _SCOPED not in mod.path.as_posix():
        return findings
    # 1. bare-statement spawns anywhere in the module
    for node in ast.walk(mod.tree):
        if isinstance(node, ast.Expr) and \
                isinstance(node.value, ast.Call):
            spawn = _spawns_task(node.value)
            if spawn:
                findings.append(_flag(mod, node, spawn,
                                      "discards the task handle"))
    # 2. handle assigned to a local name that is never used again
    for fn in ast.walk(mod.tree):
        if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        assigns = []                     # (node, name, spawner)
        loads: dict = {}                 # name -> load count
        for node in ast.walk(fn):
            if isinstance(node, ast.Assign) and \
                    isinstance(node.value, ast.Call) and \
                    len(node.targets) == 1 and \
                    isinstance(node.targets[0], ast.Name):
                spawn = _spawns_task(node.value)
                if spawn:
                    assigns.append((node, node.targets[0].id, spawn))
            if isinstance(node, ast.Name) and \
                    isinstance(node.ctx, ast.Load):
                loads[node.id] = loads.get(node.id, 0) + 1
        for node, name, spawn in assigns:
            if loads.get(name, 0) == 0:
                findings.append(_flag(
                    mod, node, spawn,
                    f"handle {name!r} is assigned but never used"))
    return findings
