"""RPL101 donated-reuse: a buffer passed to a donated-argnums call site
must not be read again in the same scope.

The engine's compiled steps donate their carry buffers
(``engine.make_step`` donates arg 0; ``make_scan_step`` /
``make_chunk_cost_step`` donate args 0 and 3 — DESIGN.md §12): after

    data, rep, trace = step(data, rep, start)

the *old* ``data`` buffer is invalid, and XLA only errors if the stale
array is actually dispatched — silent until the worst moment.  This
checker tracks names bound to the known donated factories and flags any
read of a donated argument after the call, unless the name was rebound
first (the idiomatic ``data, ... = step(data, ...)`` rebinding clears
it).

The analysis is a linear source-order walk per function scope with a
branch fork/join (a name donated in *either* branch of an ``if`` counts
as donated after it) — no cross-function propagation, so passing a
donated name into another function is not tracked.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Set, Tuple

from repro.lint.checkers._ast_util import (assigned_names, dotted,
                                           import_aliases)
from repro.lint.core import Finding, ModuleSource, Rule, register_checker

RPL101 = Rule("RPL101", "donated-reuse",
              "buffer read after being donated to a compiled step")

# factory -> donated positional indices of the *returned* callable
_FACTORIES = {
    "make_step": (0,),
    "make_scan_step": (0, 3),
    "make_chunk_cost_step": (0, 3),
}


def _factory_of(node, aliases) -> Optional[Tuple[int, ...]]:
    """Donated indices when ``node`` is a call to a known step factory
    (``make_scan_step(...)`` / ``engine.make_scan_step(...)`` /
    ``self._scan_step(k)`` — the driver's compiled-step accessor)."""
    if not isinstance(node, ast.Call):
        return None
    d = dotted(node.func)
    if d is None:
        return None
    # an explicit donate=False at the factory call disables donation
    for kw in node.keywords:
        if kw.arg == "donate" and isinstance(kw.value, ast.Constant) \
                and not kw.value.value:
            return None
    leaf = d.split(".")[-1]
    if leaf in _FACTORIES:
        return _FACTORIES[leaf]
    if leaf == "_scan_step":            # IterativeDriver._scan_step(k)
        return (0, 3)
    return None


class _Scope:
    def __init__(self, mod: ModuleSource):
        self.mod = mod
        self.findings: List[Finding] = []
        # name -> line where it was donated
        self.donated: Dict[str, int] = {}
        # name -> donated indices (variables bound to factory results)
        self.step_vars: Dict[str, Tuple[int, ...]] = {}

    # -------------------------------------------------- expression pass
    def visit_expr(self, node) -> None:
        """Flag reads of donated names, then apply donations from calls
        inside this expression (the call's own arguments are read
        *before* the donation happens, so they are scanned first)."""
        if node is None:
            return
        for n in ast.walk(node):
            if isinstance(n, ast.Name) and isinstance(n.ctx, ast.Load) \
                    and n.id in self.donated:
                self.findings.append(self.mod.finding(
                    RPL101, n,
                    f"'{n.id}' was donated to a compiled step at line "
                    f"{self.donated[n.id]} and read again here; rebind "
                    f"it from the step's return instead"))
        for n in ast.walk(node):
            if isinstance(n, ast.Call):
                self._apply_donation(n)

    def _donated_indices(self, call: ast.Call) -> Optional[Tuple[int, ...]]:
        aliases = self._aliases
        # direct: make_step(...)(data, rep)
        idx = _factory_of(call.func, aliases) if \
            isinstance(call.func, ast.Call) else None
        if idx is not None:
            return idx
        # via a variable previously bound to a factory result
        if isinstance(call.func, ast.Name) and \
                call.func.id in self.step_vars:
            return self.step_vars[call.func.id]
        return None

    def _apply_donation(self, call: ast.Call) -> None:
        idx = self._donated_indices(call)
        if idx is None:
            return
        for i in idx:
            if i < len(call.args) and isinstance(call.args[i], ast.Name):
                self.donated[call.args[i].id] = call.lineno

    # -------------------------------------------------- statement pass
    def run(self, stmts, aliases) -> None:
        self._aliases = aliases
        self._stmts(stmts)

    def _store(self, name: str) -> None:
        self.donated.pop(name, None)
        self.step_vars.pop(name, None)

    def _assign(self, node) -> None:
        value = node.value
        self.visit_expr(value)
        targets = node.targets if isinstance(node, ast.Assign) \
            else [node.target]
        names = [n for t in targets for n, _ in assigned_names(t)]
        for n in names:
            self._store(n)
        # track variables bound to a factory result: step = make_...(...)
        idx = _factory_of(value, self._aliases)
        if idx is not None and len(names) == 1:
            self.step_vars[names[0]] = idx

    def _stmts(self, stmts) -> None:
        for st in stmts:
            if isinstance(st, (ast.Assign, ast.AugAssign, ast.AnnAssign)):
                if getattr(st, "value", None) is not None:
                    self._assign(st)
            elif isinstance(st, (ast.Expr, ast.Return)):
                self.visit_expr(st.value)
            elif isinstance(st, (ast.If,)):
                self.visit_expr(st.test)
                self._fork([st.body, st.orelse])
            elif isinstance(st, (ast.For, ast.AsyncFor)):
                self.visit_expr(st.iter)
                # two passes: donations late in the body poison reads at
                # the top of the next trip around the loop
                self._stmts(st.body)
                self._stmts(st.body)
                self._stmts(st.orelse)
            elif isinstance(st, ast.While):
                self.visit_expr(st.test)
                self._stmts(st.body)
                self._stmts(st.body)
                self._stmts(st.orelse)
            elif isinstance(st, (ast.With, ast.AsyncWith)):
                for item in st.items:
                    self.visit_expr(item.context_expr)
                self._stmts(st.body)
            elif isinstance(st, ast.Try):
                self._stmts(st.body)
                for h in st.handlers:
                    self._stmts(h.body)
                self._stmts(st.orelse)
                self._stmts(st.finalbody)
            elif isinstance(st, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.ClassDef)):
                continue            # separate scope, analyzed on its own
            elif isinstance(st, ast.Delete):
                for t in st.targets:
                    for n, _ in assigned_names(t):
                        self._store(n)
            else:
                for child in ast.iter_child_nodes(st):
                    if isinstance(child, ast.expr):
                        self.visit_expr(child)

    def _fork(self, branches) -> None:
        """Run each branch from the entry state; after the join a name
        is donated if any branch left it donated (conservative)."""
        entry_donated = dict(self.donated)
        entry_steps = dict(self.step_vars)
        merged: Dict[str, int] = {}
        merged_steps: Dict[str, Tuple[int, ...]] = {}
        for body in branches:
            self.donated = dict(entry_donated)
            self.step_vars = dict(entry_steps)
            self._stmts(body)
            merged.update(self.donated)
            merged_steps.update(self.step_vars)
        self.donated = merged
        self.step_vars = merged_steps


def _scopes(tree):
    """Module body + every function body (each a separate scope)."""
    yield tree.body
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            yield node.body


@register_checker("donation", [RPL101])
def check(mod: ModuleSource):
    aliases = import_aliases(mod.tree)
    findings: List[Finding] = []
    for body in _scopes(mod.tree):
        scope = _Scope(mod)
        scope.run(body, aliases)
        findings.extend(scope.findings)
    return findings
