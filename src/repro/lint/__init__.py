"""repro.lint — JAX/Pallas-aware static analysis for this codebase.

The hot path built in PRs 2–5 is dense with hazards JAX makes silent:
donated buffers that poison later reads, hand-derived BlockSpec/grid
math, tracer leaks (Python control flow / host casts on traced values),
dtype promotion surprises, and a duck-typed Problem protocol that only
fails at trace time.  This package is the machine-checked safety net:

    python -m repro.lint src tests benchmarks

Checkers register themselves into a rule registry (DESIGN.md §17); each
finding carries a stable rule ID and ``file:line:col`` location.  A
finding is suppressed by an end-of-line ``# repro-lint: disable=<rule>``
comment (rule ID or slug, comma-separated, ``all`` for everything) or a
file-wide ``# repro-lint: disable-file=<rule>``.

The static pass is paired with the runtime sanitizer mode
``solve(..., checks=True)`` / ``REPRO_CHECKS=1`` (``repro.core.checks``)
— the lint catches what never runs, the sanitizer what only fails on
real values.
"""
from repro.lint.core import (Finding, ModuleSource, Rule, all_rules,
                             lint_file, lint_paths, register_checker)

__all__ = ["Finding", "ModuleSource", "Rule", "all_rules", "lint_file",
           "lint_paths", "register_checker"]
