"""Checker registry, findings, suppressions, and the file walker.

A *checker* is a function ``check(mod: ModuleSource) -> Iterable[Finding]``
registered with :func:`register_checker` together with the rules it can
emit.  The runner parses each ``.py`` file once, hands the shared
:class:`ModuleSource` to every checker, then filters the collected
findings through the suppression comments before reporting.

Rule IDs are stable (``RPL101`` …) and each rule also has a slug
(``donated-reuse``) — suppressions accept either form.
"""
from __future__ import annotations

import ast
import re
from dataclasses import dataclass, field
from pathlib import Path
from typing import Callable, Dict, Iterable, List, Optional, Sequence, Tuple

# --------------------------------------------------------------------
# Rules and findings
# --------------------------------------------------------------------


@dataclass(frozen=True)
class Rule:
    """One diagnostic: stable ID, short slug, one-line description."""
    id: str                 # e.g. "RPL101" — never renumbered
    slug: str               # e.g. "donated-reuse" — suppression alias
    summary: str


@dataclass(frozen=True)
class Finding:
    path: str
    line: int
    col: int
    rule: Rule
    message: str

    def format(self) -> str:
        return (f"{self.path}:{self.line}:{self.col}: "
                f"{self.rule.id}[{self.rule.slug}] {self.message}")

    def to_json(self) -> dict:
        return {"path": self.path, "line": self.line, "col": self.col,
                "rule": self.rule.id, "slug": self.rule.slug,
                "message": self.message}


@dataclass
class ModuleSource:
    """One parsed source file, shared by every checker."""
    path: Path
    text: str
    tree: ast.Module
    lines: List[str] = field(default_factory=list)

    def __post_init__(self):
        if not self.lines:
            self.lines = self.text.splitlines()

    def finding(self, rule: Rule, node, message: str) -> Finding:
        line = getattr(node, "lineno", 1)
        col = getattr(node, "col_offset", 0)
        return Finding(path=str(self.path), line=line, col=col,
                       rule=rule, message=message)


# --------------------------------------------------------------------
# Registry
# --------------------------------------------------------------------

_CHECKERS: List[Tuple[str, Callable[[ModuleSource], Iterable[Finding]]]] = []
_RULES: Dict[str, Rule] = {}


def register_checker(name: str, rules: Sequence[Rule]):
    """Decorator: register ``check(mod) -> findings`` under ``name``,
    declaring the rules it may emit (IDs must be unique repo-wide)."""

    def deco(fn):
        for r in rules:
            prev = _RULES.get(r.id)
            if prev is not None and prev != r:
                raise ValueError(f"rule id {r.id} registered twice")
            _RULES[r.id] = r
        _CHECKERS.append((name, fn))
        return fn

    return deco


def all_rules() -> Tuple[Rule, ...]:
    _load_builtin_checkers()
    return tuple(sorted(_RULES.values(), key=lambda r: r.id))


def _load_builtin_checkers():
    # import for side effect: each module registers itself
    from repro.lint import checkers  # noqa: F401


# --------------------------------------------------------------------
# Suppressions
# --------------------------------------------------------------------

_SUPPRESS_RE = re.compile(
    r"#\s*repro-lint:\s*(disable(?:-file)?)\s*=\s*([A-Za-z0-9_,\s-]+)")


def _suppressions(mod: ModuleSource):
    """(per-line {lineno: set(tokens)}, file-wide set(tokens))."""
    per_line: Dict[int, set] = {}
    whole_file: set = set()
    for i, line in enumerate(mod.lines, start=1):
        if "repro-lint" not in line:
            continue
        for m in _SUPPRESS_RE.finditer(line):
            kind, rules = m.group(1), m.group(2)
            tokens = {t.strip().lower() for t in rules.split(",")
                      if t.strip()}
            if kind == "disable-file":
                whole_file |= tokens
            else:
                per_line.setdefault(i, set()).update(tokens)
    return per_line, whole_file


def _suppressed(f: Finding, per_line, whole_file) -> bool:
    keys = {"all", f.rule.id.lower(), f.rule.slug.lower()}
    if whole_file & keys:
        return True
    return bool(per_line.get(f.line, set()) & keys)


# --------------------------------------------------------------------
# Runner
# --------------------------------------------------------------------

def lint_file(path, *, checkers: Optional[Sequence[str]] = None
              ) -> List[Finding]:
    """Run every registered checker over one file (post-suppression)."""
    _load_builtin_checkers()
    path = Path(path)
    text = path.read_text()
    try:
        tree = ast.parse(text, filename=str(path))
    except SyntaxError as e:
        rule = Rule("RPL000", "syntax-error", "file does not parse")
        return [Finding(str(path), e.lineno or 1, e.offset or 0, rule,
                        f"syntax error: {e.msg}")]
    mod = ModuleSource(path=path, text=text, tree=tree)
    per_line, whole_file = _suppressions(mod)
    found: List[Finding] = []
    for name, fn in _CHECKERS:
        if checkers is not None and name not in checkers:
            continue
        found.extend(fn(mod))
    found = [f for f in found
             if not _suppressed(f, per_line, whole_file)]
    # dedupe: loop/branch re-walks may report one site twice (distinct
    # messages at one site are distinct findings, so the message is
    # part of the key)
    unique = {(f.path, f.line, f.col, f.rule.id, f.message): f
              for f in found}
    found = sorted(unique.values(),
                   key=lambda f: (f.path, f.line, f.col, f.rule.id,
                                  f.message))
    return found


def iter_py_files(paths: Sequence) -> List[Path]:
    files: List[Path] = []
    for p in paths:
        p = Path(p)
        if p.is_file() and p.suffix == ".py":
            files.append(p)
        elif p.is_dir():
            files.extend(sorted(
                q for q in p.rglob("*.py")
                if "__pycache__" not in q.parts))
    return files


def lint_paths(paths: Sequence, *,
               checkers: Optional[Sequence[str]] = None) -> List[Finding]:
    """Lint every ``.py`` file under ``paths`` (files or directories)."""
    findings: List[Finding] = []
    for f in iter_py_files(paths):
        findings.extend(lint_file(f, checkers=checkers))
    return findings
