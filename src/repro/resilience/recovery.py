"""Resilience configuration + the recovery report (DESIGN.md §18).

:class:`ResilienceConfig` is run control, passed as
``solve(..., resilience=ResilienceConfig(...))`` and carried on
``RunOptions``; :class:`RecoveryReport` is the run's resilience ledger,
returned on ``Solution.recovery`` — what failed, what it cost, and how
the run survived it.
"""
from __future__ import annotations

from dataclasses import asdict, dataclass, field
from typing import Any, Callable, List, Optional, Tuple


@dataclass(frozen=True)
class ResilienceConfig:
    """Supervised-execution policy for one run.

    - ``max_retries`` — transient dispatch failures retried per chunk,
      each after restoring the chunk-start snapshot (donated buffers
      do not survive a failed dispatch) and an exponential backoff of
      ``backoff_s * backoff_factor**attempt``, jittered by ``jitter``
      (seeded from ``seed``, or from the active chaos seed during a
      drill — chaos replays are deterministic run-to-run).
    - ``ring`` — chunk-boundary snapshots kept in host memory (the
      in-memory rollback source).  Divergence rollback consumes ring
      entries newest-first; when the ring runs dry it falls back to
      the newest *valid* on-disk checkpoint under ``checkpoint_dir``
      (``solve()`` fills this in from its own ``checkpoint_dir=``).
      Memory bound: each entry is one full host copy of the carried
      state — the data bundle plus replicated leaves (and the carried
      output slot when ``cost_every != 1``) — so resident overhead is
      ``ring × sizeof(carry)`` bytes; for a batched ``solve_many``
      bucket the carry is the *whole padded bucket*, so deep rings on
      large buckets are the first thing to trim under host-memory
      pressure (``ring=1`` still supports dispatch retry; rollback
      then leans on the on-disk checkpoint fallback).
    - ``max_rollbacks`` — total divergence rollbacks before giving up
      (:class:`~repro.resilience.errors.ResilienceExhausted`): a
      deterministically diverging iterate must not loop forever.
    - ``rollback_rescale(replicated, n_rollbacks) -> replicated`` —
      optional step-size backoff applied to the broadcast state after
      each rollback (e.g. shrink ``tau``/``sig``); ``None`` replays
      the chunk unchanged (chaos-injected divergence is one-shot, so
      the replay is clean).
    - ``transient_types`` — extra exception types classified transient
      on top of the built-in taxonomy (``resilience.errors``).
    """
    max_retries: int = 3
    backoff_s: float = 0.02
    backoff_factor: float = 2.0
    jitter: float = 0.1
    ring: int = 2
    max_rollbacks: int = 8
    rollback_rescale: Optional[Callable[[Any, int], Any]] = None
    checkpoint_dir: Optional[str] = None
    transient_types: Tuple[type, ...] = ()
    seed: int = 0

    def __post_init__(self):
        if self.ring < 1:
            raise ValueError(
                "ResilienceConfig.ring must be >= 1: retry after a "
                "failed (donating) dispatch needs at least the "
                "chunk-start snapshot to restore from")


@dataclass
class RecoveryReport:
    """What resilience did for one run: every fault seen, every retry
    and rollback taken, every kernel-family degradation recorded, and
    the wall time the failures cost (recovery machinery overhead —
    snapshots, validation — is *not* counted as lost; only failed work
    and its repair are)."""
    retries: int = 0
    rollbacks: int = 0
    checkpoint_restores: int = 0
    faults: List[dict] = field(default_factory=list)
    kernel_fallbacks: List[dict] = field(default_factory=list)
    wall_time_lost_s: float = 0.0

    def record_fault(self, point: str, step, exc: BaseException) -> None:
        self.faults.append({
            "point": point,
            "step": None if step is None else int(step),
            "error": f"{type(exc).__name__}: {exc}"})

    def to_json(self) -> dict:
        out = asdict(self)
        out["wall_time_lost_s"] = round(out["wall_time_lost_s"], 6)
        return out

    def for_range(self, last_step: Optional[int]) -> "RecoveryReport":
        """Slice this (bucket-level) ledger to the faults a single lane
        could have witnessed: those at ``step <= last_step`` (plus
        step-less ones).  Retry/rollback counts are recomputed from the
        sliced faults; kernel fallbacks and wall time lost are
        process-/bucket-level and carried over whole.  Used by the
        serving layer (§21) to attribute one shared per-bucket report
        per originating request; ``last_step=None`` means the lane ran
        to the end and sees everything."""
        if last_step is None:
            faults = list(self.faults)
        else:
            faults = [f for f in self.faults
                      if f.get("step") is None
                      or f["step"] <= int(last_step)]
        sliced = RecoveryReport(
            retries=sum(1 for f in faults if f["point"] == "dispatch"),
            rollbacks=sum(1 for f in faults
                          if f["point"] == "divergence"),
            checkpoint_restores=self.checkpoint_restores,
            faults=[dict(f) for f in faults],
            kernel_fallbacks=[dict(e) for e in self.kernel_fallbacks],
            wall_time_lost_s=self.wall_time_lost_s)
        # dispatch faults include the final (non-retried) raise; clamp
        # to the counters the supervisor actually banked
        sliced.retries = min(sliced.retries, self.retries)
        sliced.rollbacks = min(sliced.rollbacks, self.rollbacks)
        return sliced

    def __str__(self) -> str:
        return (f"RecoveryReport(retries={self.retries}, "
                f"rollbacks={self.rollbacks}, "
                f"checkpoint_restores={self.checkpoint_restores}, "
                f"faults={len(self.faults)}, "
                f"kernel_fallbacks={len(self.kernel_fallbacks)}, "
                f"wall_time_lost_s={self.wall_time_lost_s:.3f})")
