"""Resilient solve: chaos harness, supervised recovery, error taxonomy.

The "R" in the paper's Spark RDDs is resilience; this package is the
jax_pallas answer (DESIGN.md §18):

- :mod:`repro.resilience.chaos` — deterministic seeded fault injection
  at named fault points (``ChaosConfig`` / ``REPRO_CHAOS``);
- :mod:`repro.resilience.errors` — the transient/fatal/divergence
  taxonomy (:func:`classify`);
- :mod:`repro.resilience.recovery` — ``ResilienceConfig`` run control
  and the ``RecoveryReport`` returned on ``Solution.recovery``;
- :mod:`repro.resilience.supervisor` — the snapshot-ring / retry /
  rollback engine the driver engages for
  ``solve(..., resilience=ResilienceConfig(...))``.

``supervisor`` is imported lazily by the driver (only when resilience
is requested); everything re-exported here is dependency-light.
"""
from repro.resilience.chaos import ChaosConfig, active_chaos
from repro.resilience.errors import (DivergenceError, InjectedFault,
                                     ResilienceError, ResilienceExhausted,
                                     classify)
from repro.resilience.recovery import RecoveryReport, ResilienceConfig

__all__ = ["ChaosConfig", "DivergenceError", "InjectedFault",
           "RecoveryReport", "ResilienceConfig", "ResilienceError",
           "ResilienceExhausted", "active_chaos", "classify"]
