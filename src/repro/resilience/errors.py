"""Error taxonomy for the supervised solve loop (DESIGN.md §18).

Every exception that escapes a chunk dispatch is routed through
:func:`classify` before the supervisor decides what to do with it:

- ``"transient"`` — worth retrying from the last chunk-boundary
  snapshot: injected chaos faults, host I/O errors, and runtime errors
  whose message carries one of the retryable XLA/gRPC status markers
  (a preempted worker, a flaky interconnect).  ``ResilienceConfig.
  transient_types`` extends the set per run.
- ``"fatal"`` — a programming or configuration error (shape mismatch,
  unknown key, OOM): retrying replays the same failure, so the
  supervisor re-raises immediately.

Divergence (non-finite state/cost at a chunk-boundary host sync) is
deliberately *neither*: it is raised as :class:`DivergenceError` and
handled by rollback — re-running from a snapshot, optionally with a
rescaled step — not by blind retry.
"""
from __future__ import annotations

from typing import Optional, Tuple


class ResilienceError(RuntimeError):
    """Base class for everything the resilience subsystem raises."""


class InjectedFault(ResilienceError):
    """A chaos-harness fault (``repro.resilience.chaos``): deterministic,
    seeded, and always classified transient so the supervised loop's
    recovery path is what gets exercised."""

    def __init__(self, point: str, *, step: Optional[int] = None,
                 tag: Optional[str] = None):
        self.point = point
        self.step = step
        self.tag = tag
        where = f" at step {step}" if step is not None else ""
        what = f"{point}:{tag}" if tag else point
        super().__init__(f"injected chaos fault '{what}'{where}")


class DivergenceError(ResilienceError):
    """Non-finite state or objective observed at a chunk-boundary host
    sync — the iterate diverged (or a chaos injector poisoned it)."""

    def __init__(self, message: str, *, step: Optional[int] = None):
        self.step = step
        super().__init__(message)


class ResilienceExhausted(ResilienceError):
    """Recovery budget spent: retries exceeded ``max_retries``, or
    rollbacks exceeded ``max_rollbacks`` with no snapshot or valid
    on-disk checkpoint left to fall back to."""


#: exception types retried without further inspection
_TRANSIENT_TYPES: Tuple[type, ...] = (InjectedFault, OSError,
                                      TimeoutError, ConnectionError)

#: substrings marking a retryable runtime failure (XLA / gRPC status
#: codes surface in the exception message, not the exception type)
_TRANSIENT_MARKERS: Tuple[str, ...] = ("UNAVAILABLE", "DEADLINE_EXCEEDED",
                                       "DATA_LOSS", "ABORTED",
                                       "connection reset")


def classify(exc: BaseException, extra_transient: Tuple[type, ...] = ()
             ) -> str:
    """``"transient"`` (retry from snapshot) or ``"fatal"`` (re-raise).

    Divergence and exhausted-budget errors are the supervisor's own
    control flow and never retryable.
    """
    if isinstance(exc, (DivergenceError, ResilienceExhausted)):
        return "fatal"
    if isinstance(exc, _TRANSIENT_TYPES + tuple(extra_transient)):
        return "transient"
    msg = str(exc)
    if any(marker in msg for marker in _TRANSIENT_MARKERS):
        return "transient"
    return "fatal"
