"""The supervised chunk-execution loop's recovery engine.

``IterativeDriver`` stays in charge of *what* to run; this module owns
*what happens when it fails* (DESIGN.md §18):

- :meth:`Supervisor.begin_chunk` spills the chunk-start carry
  ``(data, replicated, last)`` to a host-memory ring — the rollback
  source that makes retry-after-donation and divergence replay exact;
- :meth:`Supervisor.dispatch` wraps one chunk dispatch in classify →
  bounded retry with exponential backoff + seeded jitter, restoring the
  chunk-start snapshot before every retry (a failed dispatch may have
  consumed the donated input buffers);
- :meth:`Supervisor.validate` turns a non-finite state/objective at the
  chunk-boundary host sync into a
  :class:`~repro.resilience.errors.DivergenceError`
  (reusing the ``repro.core.checks`` guards);
- :meth:`Supervisor.rollback` recovers from divergence: newest ring
  entry first (consumed, so repeated divergence walks back in time),
  then the newest *valid* on-disk checkpoint, with an optional
  step-size backoff hook on the broadcast state.

The driver only imports this module when ``RunOptions.resilience`` is
set, so the disabled path stays import- and dispatch-free.
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass
from typing import Any, Callable, Optional, Tuple

import jax
import numpy as np

from repro.core import checks as _checks
from repro.core import persistence
from repro.core.bundle import Bundle
from repro.resilience.errors import (DivergenceError, ResilienceExhausted,
                                     classify)
from repro.resilience.recovery import RecoveryReport, ResilienceConfig


@dataclass(frozen=True)
class _Snapshot:
    """Host copy of the full chunk-start carry plus the bookkeeping
    needed to rewind the run log to this boundary."""
    it: int                      # global iteration index of the boundary
    n_logged: int                # len(log.costs) at the boundary
    state: Any                   # {"data": ..., "replicated": ...} host
    last: Any                    # carried-output slot (host) or None


class Supervisor:
    """Per-run recovery engine; one instance per ``IterativeDriver.run``."""

    def __init__(self, cfg: ResilienceConfig, bundle: Bundle, *,
                 start_iter: int = 0,
                 last_init: Optional[Callable[[], Any]] = None):
        self.cfg = cfg
        self.bundle = bundle
        self.start_iter = start_iter
        self.last_init = last_init
        self.report = RecoveryReport()
        self.ring: deque = deque(maxlen=cfg.ring)
        # backoff jitter reuses the chaos seed when a drill is active so
        # recovery reports replay bit-for-bit run-to-run (§18 satellite)
        from repro.resilience import chaos as _chaos
        seed = _chaos.active_seed()
        self.rng = np.random.default_rng(cfg.seed if seed is None
                                         else seed)
        self._rollbacks_done = 0
        self._last_restored_it: Optional[int] = None
        from repro.kernels import common as _kcommon
        self._kernel_baseline = len(_kcommon.kernel_fallbacks())

    # ------------------------------------------------------- snapshots
    def begin_chunk(self, data, rep, last, it: int, n_logged: int) -> None:
        """Push the chunk-start carry onto the host-memory ring."""
        state = persistence.spill_bundle(
            self.bundle.with_data(data, replicated=rep))
        host_last = (None if last is None
                     else persistence.to_host(last))
        self.ring.append(_Snapshot(it=it, n_logged=n_logged, state=state,
                                   last=host_last))

    def _readmit(self, snap: _Snapshot):
        """Device-place a snapshot back under the bundle's shardings."""
        state = persistence.readmit_state(self.bundle, snap.state)
        last = (None if snap.last is None
                else persistence.readmit_replicated(self.bundle,
                                                    snap.last))
        return state["data"], state["replicated"], last

    # --------------------------------------------------------- dispatch
    def dispatch(self, fn: Callable, data, rep, last, i: int, k: int):
        """Run ``fn(data, rep, last, i, k)`` with classify → bounded
        retry; every retry restores the chunk-start snapshot first."""
        attempt = 0
        while True:
            t0 = time.perf_counter()
            try:
                return fn(data, rep, last, i, k)
            except Exception as e:
                kind = classify(e, self.cfg.transient_types)
                self.report.record_fault("dispatch", i, e)
                self.report.wall_time_lost_s += time.perf_counter() - t0
                if kind != "transient":
                    raise
                if attempt >= self.cfg.max_retries:
                    raise self._exhausted(
                        f"chunk dispatch at iteration {i} still failing "
                        f"after {attempt} retries: {e}") from e
                t1 = time.perf_counter()
                self.report.retries += 1
                time.sleep(self._backoff(attempt))
                data, rep, last = self._readmit(self.ring[-1])
                self.report.wall_time_lost_s += time.perf_counter() - t1
                attempt += 1

    def _backoff(self, attempt: int) -> float:
        base = self.cfg.backoff_s * self.cfg.backoff_factor ** attempt
        return base * (1.0 + self.cfg.jitter
                       * float(self.rng.uniform(-1.0, 1.0)))

    # ------------------------------------------------------- divergence
    def validate(self, data, rep, costs, it: int) -> None:
        """Chunk-boundary divergence detection (host sync already paid):
        non-finite objective or state raises ``DivergenceError``."""
        try:
            _checks.assert_costs_finite(
                costs, f"resilience: chunk ending at iteration {it}")
            _checks.assert_all_finite(
                {"data": data, "replicated": rep},
                f"resilience: state after iteration {it}")
        except _checks.CheckError as e:
            raise DivergenceError(str(e), step=it) from e

    def rollback(self, err: DivergenceError, log) -> Tuple[Any, Any, Any,
                                                           int]:
        """Recover from divergence: restore the newest ring entry
        (consumed) or, ring dry, the newest valid on-disk checkpoint;
        rewind ``log`` to the restored boundary.  Returns the restored
        ``(data, replicated, last, iteration)``."""
        self.report.record_fault("divergence", err.step, err)
        if self._rollbacks_done >= self.cfg.max_rollbacks:
            raise self._exhausted(
                f"rollback budget ({self.cfg.max_rollbacks}) exhausted; "
                f"latest divergence: {err}") from err
        self._rollbacks_done += 1
        self.report.rollbacks += 1
        t0 = time.perf_counter()
        # the replayed chunk re-pushed its start snapshot via
        # begin_chunk; when that exact boundary already failed once (and
        # no rescale hook changes the replay), restoring it again would
        # loop on the same divergence — walk back to an older snapshot
        if (self.ring and self.cfg.rollback_rescale is None
                and self.ring[-1].it == self._last_restored_it):
            self.ring.pop()
        if self.ring:
            snap = self.ring.pop()
            data, rep, last = self._readmit(snap)
            it, n_logged = snap.it, snap.n_logged
        else:
            data, rep, last, it, n_logged = self._restore_from_disk(err)
        self._last_restored_it = it
        del log.costs[n_logged:]
        del log.times[n_logged:]
        if self.cfg.rollback_rescale is not None:
            rep = self.cfg.rollback_rescale(rep, self._rollbacks_done)
        self.report.wall_time_lost_s += time.perf_counter() - t0
        return data, rep, last, it

    def _restore_from_disk(self, err: DivergenceError):
        """Ring exhausted: restore the newest checkpoint that passes
        integrity validation (``checkpoint.checkpointer``)."""
        if self.cfg.checkpoint_dir is None:
            raise self._exhausted(
                "snapshot ring exhausted and no checkpoint_dir to fall "
                "back to; latest divergence: " + str(err)) from err
        from repro.checkpoint import checkpointer as ckpt
        step, _skipped = ckpt.latest_valid_step(self.cfg.checkpoint_dir)
        if step is None:
            raise self._exhausted(
                f"snapshot ring exhausted and no valid checkpoint under "
                f"{self.cfg.checkpoint_dir!r}; latest divergence: {err}"
            ) from err
        like = {"data": self.bundle.data,
                "replicated": self.bundle.replicated}
        state, _ = ckpt.restore(
            self.cfg.checkpoint_dir, step, like,
            shardings=persistence.bundle_shardings(self.bundle))
        self.report.checkpoint_restores += 1
        last = self.last_init() if self.last_init is not None else None
        n_logged = max(step - self.start_iter, 0)
        return state["data"], state["replicated"], last, step, n_logged

    def _exhausted(self, msg: str) -> ResilienceExhausted:
        """Build a budget-exhaustion error carrying the (finalized)
        recovery ledger so upstream layers — notably the serving
        quarantine path (§21) — can attribute the failure per request."""
        err = ResilienceExhausted(msg)
        err.report = self.finalize()
        return err

    # --------------------------------------------------------- wrap-up
    def finalize(self) -> RecoveryReport:
        """Fold the kernel-degradation events recorded during this run
        into the report and return it."""
        from repro.kernels import common as _kcommon
        events = _kcommon.kernel_fallbacks()[self._kernel_baseline:]
        self.report.kernel_fallbacks = [dict(e) for e in events]
        return self.report
