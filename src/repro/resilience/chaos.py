"""Deterministic fault-injection harness (DESIGN.md §18).

Spark earns its resilience story by killing executors in integration
tests; this module is the jax_pallas analogue — every failure mode the
supervised solve loop recovers from can be injected *deterministically*
so the recovery path is a unit test, not a war story.

Fault points (each injector is a no-op unless chaos is active, so the
probes cost one module-global ``is None`` check on the hot path):

==================  ==================================================
``dispatch``        raise inside the driver's chunk dispatch (before
                    the compiled step runs) — a lost worker / failed
                    launch, classified transient
``carry_nan``      poison one float leaf of the data carry with NaN
                    after a chunk lands — divergence of the iterate
``ckpt_write``      raise at the top of a checkpoint ``save()`` — a
                    failed write (exercises async error surfacing)
``ckpt_corrupt``    truncate a leaf file of a checkpoint *after* the
                    manifest checksums are computed — a torn write
                    that survives the atomic rename
``kernel``          raise on a kernel family's compiled attempt inside
                    ``kernels.common.degraded_call`` — a Pallas
                    lowering failure (also addressable per family as
                    ``kernel:<family>``)
==================  ==================================================

Each fault point keeps an invocation counter; a :class:`ChaosConfig`
maps points to the 0-based invocation indices at which they fire (each
index fires once — a retried dispatch advances the counter, so the
retry sees a healthy call).  Leaf selection for poisoning and any
jittered choices are drawn from one seeded generator, so a failing
chaos run replays bit-for-bit from its spec string.

Activation: ``with chaos.active_chaos(cfg): ...`` in tests, or the
``REPRO_CHAOS`` environment variable (parsed once per ``solve()``), e.g.
``REPRO_CHAOS="dispatch@1;carry_nan@0,2;seed=7"``.

Run ``python -m repro.resilience.chaos --workload deconvolve`` for a
self-contained chaos smoke: a seeded faulty solve with resilience on,
dumping the recovery report as JSON (the CI chaos job's artifact).
"""
from __future__ import annotations

import contextlib
import os
from dataclasses import dataclass, field
from pathlib import Path
from typing import Dict, Iterator, Optional, Tuple

import numpy as np

from repro.resilience.errors import InjectedFault

ENV_VAR = "REPRO_CHAOS"

#: the canonical fault-point names (``kernel:<family>`` also accepted).
#: The ``serve_*`` points are serving-layer faults consumed by
#: ``repro.serve`` (DESIGN.md §21) rather than the solve loop:
#: ``serve_admit_drop`` loses an admitted request after it was
#: journaled (a crash between journal append and scheduling),
#: ``serve_bucket_poison`` NaN-poisons one lane of a coalesced bucket
#: (addressable per lane as ``serve_bucket_poison@<lane>``), and
#: ``serve_crash`` hard-stops the service at the k-th progress event —
#: the restart-and-replay drill.
FAULT_POINTS = ("dispatch", "carry_nan", "ckpt_write", "ckpt_corrupt",
                "kernel", "serve_admit_drop", "serve_bucket_poison",
                "serve_crash")


@dataclass(frozen=True)
class ChaosConfig:
    """Seeded, declarative fault plan: ``faults`` maps a fault-point
    name (optionally ``point:tag``) to the invocation indices at which
    it fires."""
    seed: int = 0
    faults: Dict[str, Tuple[int, ...]] = field(default_factory=dict)

    @classmethod
    def parse(cls, spec: str) -> "ChaosConfig":
        """Parse a ``REPRO_CHAOS`` spec: ``;``-separated tokens, each
        ``point@i[,j...]``, a bare ``point`` (index 0), or ``seed=N``."""
        seed = 0
        faults: Dict[str, Tuple[int, ...]] = {}
        for token in spec.split(";"):
            token = token.strip()
            if not token:
                continue
            if token.startswith("seed="):
                seed = int(token[len("seed="):])
                continue
            point, _, idx = token.partition("@")
            point = point.strip()
            base = point.split(":", 1)[0]
            if base not in FAULT_POINTS:
                raise ValueError(
                    f"unknown chaos fault point {point!r}; known points: "
                    f"{FAULT_POINTS} (plus 'kernel:<family>')")
            indices = (tuple(int(t) for t in idx.split(",") if t.strip())
                       if idx else (0,))
            faults[point] = tuple(sorted(set(
                faults.get(point, ()) + indices)))
        return cls(seed=seed, faults=faults)

    @classmethod
    def from_env(cls) -> Optional["ChaosConfig"]:
        spec = os.environ.get(ENV_VAR, "").strip()
        return cls.parse(spec) if spec else None


class _ChaosState:
    """One activation: per-point invocation counters + the seeded rng."""

    def __init__(self, cfg: ChaosConfig):
        self.cfg = cfg
        self.counts: Dict[str, int] = {}
        self.rng = np.random.default_rng(cfg.seed)
        self.fired: list = []           # [(key, invocation index), ...]

    def _tick(self, key: str) -> bool:
        n = self.counts.get(key, 0)
        self.counts[key] = n + 1
        want = self.cfg.faults.get(key)
        if want is not None and n in want:
            self.fired.append((key, n))
            return True
        return False

    def should_fire(self, point: str, tag: Optional[str] = None) -> bool:
        hit = self._tick(point)
        if tag is not None:
            hit = self._tick(f"{point}:{tag}") or hit
        return hit


_STATE: Optional[_ChaosState] = None


def is_active() -> bool:
    return _STATE is not None


def active_seed() -> Optional[int]:
    """The seed of the active chaos plan, or ``None`` when chaos is
    inactive.  Recovery-path consumers (supervisor backoff jitter) reuse
    it so a chaos drill's recovery report replays bit-for-bit."""
    return _STATE.cfg.seed if _STATE is not None else None


@contextlib.contextmanager
def active_chaos(cfg: Optional[ChaosConfig]) -> Iterator:
    """Install ``cfg`` as the process-wide chaos plan for the block
    (``None`` is a no-op context, so callers can pass through an absent
    env config unconditionally)."""
    global _STATE
    if cfg is None:
        yield None
        return
    prev = _STATE
    _STATE = _ChaosState(cfg)
    try:
        yield _STATE
    finally:
        _STATE = prev


def maybe_from_env() -> contextlib.AbstractContextManager:
    """Activation context for the ``REPRO_CHAOS`` env var; inert when
    the variable is unset or chaos is already active (an explicit
    ``active_chaos`` wins over the environment)."""
    if is_active():
        return contextlib.nullcontext()
    return active_chaos(ChaosConfig.from_env())


# --------------------------------------------------------------------
# Injectors (each a cheap no-op when chaos is inactive)
# --------------------------------------------------------------------

def maybe_raise(point: str, *, step: Optional[int] = None,
                tag: Optional[str] = None) -> None:
    """Raise :class:`InjectedFault` when ``point`` (or ``point:tag``)
    is scheduled to fire at this invocation."""
    st = _STATE
    if st is None:
        return
    if st.should_fire(point, tag):
        raise InjectedFault(point, step=step, tag=tag)


def poison_tree(point: str, tree, *, step: Optional[int] = None):
    """Overwrite one seeded element of one seeded float leaf of
    ``tree`` with NaN when ``point`` fires — the injected analogue of a
    numerically diverged iterate.  Returns ``tree`` (possibly poisoned);
    identity when chaos is inactive or the point does not fire."""
    st = _STATE
    if st is None:
        return tree
    if not st.should_fire(point):
        return tree
    import jax
    import jax.numpy as jnp
    leaves, treedef = jax.tree.flatten(tree)
    float_idx = [i for i, leaf in enumerate(leaves)
                 if jnp.issubdtype(jnp.result_type(leaf), jnp.floating)]
    if not float_idx:
        return tree
    pick = int(st.rng.choice(float_idx))
    leaf = jnp.asarray(leaves[pick])
    if leaf.ndim == 0:
        leaves[pick] = jnp.full_like(leaf, jnp.nan)
    else:
        flat = leaf.reshape(-1)
        pos = int(st.rng.integers(flat.shape[0]))
        leaves[pick] = flat.at[pos].set(jnp.nan).reshape(leaf.shape)
    return jax.tree.unflatten(treedef, leaves)


def corrupt_checkpoint_files(point: str, directory, *,
                             step: Optional[int] = None) -> bool:
    """Truncate the first leaf file (or, leafless, the manifest) of a
    just-written checkpoint directory to half its size when ``point``
    fires — a torn write the restore-side validation must catch.
    Returns whether a file was corrupted."""
    st = _STATE
    if st is None:
        return False
    if not st.should_fire(point):
        return False
    directory = Path(directory)
    leaves = sorted(directory.glob("leaf_*.npy"))
    target = leaves[0] if leaves else directory / "manifest.json"
    if not target.exists():
        return False
    data = target.read_bytes()
    target.write_bytes(data[: max(len(data) // 2, 1)])
    return True


# --------------------------------------------------------------------
# Chaos smoke entry point (the CI chaos job)
# --------------------------------------------------------------------

def _main(argv=None) -> int:
    """Seeded faulty solve with resilience on; dumps the recovery
    report.  Chaos comes from ``REPRO_CHAOS`` (or ``--spec``)."""
    import argparse
    import json

    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--workload", default="deconvolve",
                    choices=("deconvolve", "scdl"))
    ap.add_argument("--n", type=int, default=16)
    ap.add_argument("--iters", type=int, default=16)
    ap.add_argument("--chunk", type=int, default=4)
    ap.add_argument("--spec", default=None,
                    help=f"chaos spec (default: ${ENV_VAR})")
    ap.add_argument("--report", default=None,
                    help="write the recovery report JSON here")
    args = ap.parse_args(argv)

    import jax

    from repro.core.problem import solve
    # under ``python -m`` this file executes as ``__main__`` — activate
    # chaos on the canonical module instance, the one the solve stack's
    # injectors read, not on this alias
    from repro.resilience import chaos as _canon
    from repro.resilience.recovery import ResilienceConfig

    cfg = (_canon.ChaosConfig.parse(args.spec) if args.spec is not None
           else _canon.ChaosConfig.from_env())
    if cfg is None:
        cfg = _canon.ChaosConfig.parse("dispatch@1;carry_nan@2;seed=7")
    with _canon.active_chaos(cfg) as state:
        if args.workload == "deconvolve":
            from repro.imaging import psf as psf_op
            from repro.imaging.condat import SolverConfig
            data = psf_op.simulate(args.n, jax.random.PRNGKey(0))
            sol = solve("deconvolve", data.Y, data.psfs,
                        cfg=SolverConfig(mode="sparse", n_scales=3),
                        max_iter=args.iters, tol=0, chunk=args.chunk,
                        resilience=ResilienceConfig())
        else:
            from repro.data.synthetic import coupled_patches
            from repro.imaging.scdl import SCDLConfig
            S_h, S_l = coupled_patches(256, 25, 9, 16, seed=0)
            sol = solve("scdl", S_h, S_l,
                        cfg=SCDLConfig(n_atoms=16, max_iter=args.iters),
                        tol=0, chunk=args.chunk,
                        resilience=ResilienceConfig())
        fired = list(state.fired) if state is not None else []
    report = sol.recovery.to_json() if sol.recovery is not None else {}
    report["chaos"] = {"seed": cfg.seed,
                       "faults": {k: list(v)
                                  for k, v in cfg.faults.items()},
                       "fired": [{"point": k, "invocation": n}
                                 for k, n in fired]}
    report["final_cost"] = float(sol.log.costs[-1])
    print(json.dumps(report, indent=2))
    if args.report:
        Path(args.report).write_text(json.dumps(report, indent=2))
    return 0


if __name__ == "__main__":                        # pragma: no cover
    raise SystemExit(_main())
