"""Mixture-of-Experts FFN: top-k routing, capacity buffers, expert parallel.

TPU adaptation (DESIGN.md §7): we do NOT use the GShard one-hot-einsum
dispatch — its dispatch tensor costs O(T·E·C·d) fake FLOPs that would
swamp the roofline's useful-compute ratio.  Instead we use *sort-based
capacity routing*, local to each data shard:

  - routing (router matmul, top-k) is computed where the tokens live;
  - token->expert assignment is an argsort of (T·k) keys (data movement,
    not FLOPs) into per-expert capacity buffers;
  - expert FFNs are dense (E_local, cap, d) batched matmuls — honest FLOPs
    ~ active_FLOPs * capacity_factor;
  - experts are sharded over the `model` axis (expert parallelism): each
    model-rank owns E/tp experts, computes contributions for its experts
    only, and a single psum over `model` combines (activations are already
    replicated over `model` at this point, so EP costs one all-reduce that
    coincides with the tensor-parallel FFN reduction it replaces).

Experts whose count is not divisible by the model-axis size are padded with
inert experts (router logits masked to -inf); the padding overhead is
reported by ``padding_ratio`` and accounted in §Roofline.
"""
from __future__ import annotations

import math
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import MoEConfig


class MoEParams(NamedTuple):
    router: jax.Array        # (d, E_pad)
    we1: jax.Array           # (E_pad, d, f)
    we3: jax.Array           # (E_pad, d, f)
    we2: jax.Array           # (E_pad, f, d)
    ws1: Optional[jax.Array]  # (d, n_shared*f) or None
    ws3: Optional[jax.Array]
    ws2: Optional[jax.Array]  # (n_shared*f, d)


def padded_experts(n_experts: int, tp: int) -> int:
    """Experts padded up to a multiple of the model-axis size."""
    return ((n_experts + tp - 1) // tp) * tp


def padding_ratio(n_experts: int, tp: int) -> float:
    return padded_experts(n_experts, tp) / n_experts - 1.0


def capacity(n_tokens: int, moe: MoEConfig, n_experts_pad: int) -> int:
    """Static per-expert buffer length (GShard capacity discipline).

    Serving-scale token counts (decode steps) get a drop-free buffer
    (worst case: every token picks the same expert) — a dropped token in
    decode corrupts that sequence's output, whereas in training it is a
    standard regularising approximation."""
    cap = math.ceil(n_tokens * moe.top_k * moe.capacity_factor
                    / n_experts_pad)
    if n_tokens <= 256:
        cap = max(cap, n_tokens)
    return max(cap, 1)


def route(x, router_w, moe: MoEConfig, n_real_experts: int):
    """Router: softmax -> top-k -> renormalise.  x: (T, d).

    Returns (weights (T, k), expert_ids (T, k), probs (T, E_pad)) — probs
    are returned for the load-balancing auxiliary loss.
    Padded experts are masked to -inf before the softmax.
    """
    logits = (x.astype(jnp.float32) @ router_w.astype(jnp.float32))
    E_pad = router_w.shape[1]
    if E_pad > n_real_experts:
        mask = jnp.arange(E_pad) < n_real_experts
        logits = jnp.where(mask, logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    weights, ids = jax.lax.top_k(probs, moe.top_k)
    weights = weights / (jnp.sum(weights, axis=-1, keepdims=True) + 1e-9)
    return weights.astype(x.dtype), ids, probs


def load_balance_parts(probs, ids):
    """Ingredients of the Switch aux loss: per-expert routed fraction and
    mean router prob.  Both are token-means, so pmean over equal-sized
    data shards reproduces the global statistics exactly."""
    E = probs.shape[-1]
    onehot = jax.nn.one_hot(ids[..., 0], E, dtype=jnp.float32)
    return jnp.mean(onehot, axis=0), jnp.mean(probs, axis=0)


def load_balance_loss(frac, mean_p, n_real_experts: int) -> jax.Array:
    return n_real_experts * jnp.sum(frac * mean_p)


def moe_ffn_local(p: MoEParams, x, moe: MoEConfig, *, expert_offset,
                  n_experts_pad: int, n_real_experts: int):
    """Expert FFN for this rank's expert slice.

    ``p.we*`` hold the LOCAL expert slice (already sharded by shard_map);
    ``expert_offset`` maps global routed ids onto it.  x: (T, d) local
    tokens (replicated across the model axis).  Returns the *partial*
    output (T, d) — caller psums over the model axis — plus the aux loss.
    """
    T, d = x.shape
    n_local_experts = p.we1.shape[0]
    k = moe.top_k
    cap = capacity(T, moe, n_experts_pad)

    weights, ids, probs = route(x, p.router, moe, n_real_experts)

    flat_e = ids.reshape(-1)                       # (T*k,)
    flat_w = weights.reshape(-1)
    flat_t = jnp.repeat(jnp.arange(T, dtype=jnp.int32), k)

    local_e = flat_e - expert_offset
    mine = (local_e >= 0) & (local_e < n_local_experts)
    key = jnp.where(mine, local_e, n_local_experts)       # drop-bucket last
    order = jnp.argsort(key, stable=True)                 # (T*k,)
    skey = key[order]
    # rank of each entry within its expert group
    starts = jnp.searchsorted(skey, jnp.arange(n_local_experts + 1),
                              side="left")
    pos = jnp.arange(T * k, dtype=jnp.int32) - starts[skey].astype(jnp.int32)
    overflow = n_local_experts * cap
    slot = jnp.where((skey < n_local_experts) & (pos < cap),
                     skey.astype(jnp.int32) * cap + pos, overflow)

    gathered = x[flat_t[order]]                            # (T*k, d)
    buf = jnp.zeros((n_local_experts * cap + 1, d), x.dtype)
    buf = buf.at[slot].set(gathered)
    buf = buf[:-1].reshape(n_local_experts, cap, d)

    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, p.we1)) * \
        jnp.einsum("ecd,edf->ecf", buf, p.we3)
    y = jnp.einsum("ecf,efd->ecd", h, p.we2)               # (E_loc, cap, d)

    yflat = jnp.concatenate([y.reshape(-1, d),
                             jnp.zeros((1, d), y.dtype)], axis=0)
    contrib = yflat[slot] * flat_w[order][:, None]
    out = jnp.zeros((T, d), x.dtype).at[flat_t[order]].add(contrib)

    return out, load_balance_parts(probs, ids)


def shared_expert_ffn(p: MoEParams, x):
    """Always-on (deepseek 'shared') experts: a plain SwiGLU."""
    if p.ws1 is None:
        return jnp.zeros_like(x)
    h = jax.nn.silu(x @ p.ws1) * (x @ p.ws3)
    return h @ p.ws2


def moe_ffn(p: MoEParams, x, moe: MoEConfig, *, tp_size: int, axis_name,
            n_real_experts: int, dp_axes=()):
    """MoE FFN over (T, d) tokens.

    Inside a shard_map over the model axis, ``axis_name`` is set and each
    rank computes its expert slice + a psum.  ``dp_axes``: data axes to
    pmean the aux-loss ingredients over (token-means combine exactly
    across equal shards).  Outside (single-device smoke tests),
    tp_size == 1 computes everything locally.
    """
    n_local = p.we1.shape[0]               # already the per-rank slice
    E_pad = n_local * tp_size
    if axis_name is None:
        out, (frac, mean_p) = moe_ffn_local(
            p, x, moe, expert_offset=0,
            n_experts_pad=E_pad, n_real_experts=n_real_experts)
        out = out + shared_expert_ffn(p, x)
    else:
        rank = jax.lax.axis_index(axis_name)
        offset = rank * n_local
        out, (frac, mean_p) = moe_ffn_local(
            p, x, moe, expert_offset=offset,
            n_experts_pad=E_pad, n_real_experts=n_real_experts)
        # shared experts are column-sharded over the model axis by the
        # caller, so their partial output joins the same psum.
        out = out + shared_expert_ffn(p, x)
        out = jax.lax.psum(out, axis_name)
        frac = jax.lax.pmean(frac, axis_name)
        mean_p = jax.lax.pmean(mean_p, axis_name)
    if dp_axes:
        frac = jax.lax.pmean(frac, dp_axes)
        mean_p = jax.lax.pmean(mean_p, dp_axes)
    return out, load_balance_loss(frac, mean_p, n_real_experts)
