"""Shared model layers: norms, RoPE, SwiGLU, embeddings, init helpers.

Pure-functional: parameters are plain pytrees (dicts of jnp arrays); every
layer is a function ``f(params, x, ...) -> y``.  Mixed precision convention:
parameters are stored in ``param_dtype`` (bf16 for the large configs), all
reductions (norms, softmax, loss) run in fp32.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp


def rms_norm(x: jax.Array, weight: Optional[jax.Array], eps: float = 1e-6,
             zero_centered: bool = False) -> jax.Array:
    """RMSNorm in fp32, cast back to the input dtype.

    ``weight=None`` gives the weightless norm used for falcon-mamba's
    dt/B/C stabilisation. ``zero_centered`` uses the (1+w) gemma convention.
    """
    dtype = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    xf = xf * jax.lax.rsqrt(var + eps)
    if weight is not None:
        w = weight.astype(jnp.float32)
        xf = xf * (1.0 + w) if zero_centered else xf * w
    return xf.astype(dtype)


def rope_angles(positions: jax.Array, head_dim: int, theta) -> tuple:
    """sin/cos tables for rotary embeddings.

    positions: integer array (...,); returns sin, cos of shape (..., hd/2).
    ``theta`` may be a traced scalar (per-layer theta inside scan).
    """
    half = head_dim // 2
    freq_exp = jnp.arange(half, dtype=jnp.float32) / half
    inv_freq = jnp.asarray(theta, jnp.float32) ** (-freq_exp)
    ang = positions.astype(jnp.float32)[..., None] * inv_freq
    return jnp.sin(ang), jnp.cos(ang)


def apply_rope(x: jax.Array, sin: jax.Array, cos: jax.Array) -> jax.Array:
    """Rotate-half RoPE. x: (..., n_heads, head_dim); sin/cos: (..., hd/2)
    broadcastable against x's leading dims (a heads axis is inserted)."""
    dtype = x.dtype
    x = x.astype(jnp.float32)
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    s, c = sin[..., None, :], cos[..., None, :]
    out = jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1)
    return out.astype(dtype)


def swiglu(x: jax.Array, w1: jax.Array, w3: jax.Array, w2: jax.Array) -> jax.Array:
    """SwiGLU MLP: (silu(x@w1) * (x@w3)) @ w2."""
    h = jax.nn.silu(x @ w1) * (x @ w3)
    return h @ w2


def embed_lookup(table: jax.Array, tokens: jax.Array) -> jax.Array:
    """Embedding lookup; vocab padding rows are reachable only if the data
    pipeline emits padded ids (it does not)."""
    return jnp.take(table, tokens, axis=0)


def pad_to(n: int, multiple: int) -> int:
    return ((n + multiple - 1) // multiple) * multiple


# ----------------------------------------------------------------------
# Initializers (explicitly keyed; counter-based so init is reproducible
# regardless of device count — the "deterministic lineage" requirement)
# ----------------------------------------------------------------------

def dense_init(key, shape, dtype, scale: float = 1.0):
    fan_in = shape[0] if len(shape) >= 2 else 1
    std = scale / math.sqrt(fan_in)
    return (jax.random.truncated_normal(key, -2, 2, shape, jnp.float32)
            * std).astype(dtype)


def embed_init(key, shape, dtype, std: float = 0.02):
    return (jax.random.truncated_normal(key, -2, 2, shape, jnp.float32)
            * std).astype(dtype)
