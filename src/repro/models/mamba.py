"""Mamba-1 mixer: gated selective state-space block, TPU-adapted.

TPU adaptation (see DESIGN.md §8): the CUDA mamba kernel is a fused
sequential scan over time held in SRAM.  On TPU we *chunk* the sequence:
an outer ``lax.scan`` carries the (B, d_inner, d_state) state across chunks
while an inner ``associative_scan`` parallelises within the chunk — this
keeps the MXU/VPU busy on (chunk, d_inner) tiles instead of serialising
4096 tiny steps, and bounds live memory to one chunk of (B, c, dI, dS).
The Pallas kernel in ``repro.kernels.selective_scan`` implements the same
chunking with explicit VMEM residency of the state.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import rms_norm


class MambaParams(NamedTuple):
    in_proj: jax.Array      # (d, 2*dI)  -> x, z
    conv_w: jax.Array       # (dc, dI)   depthwise causal conv
    conv_b: jax.Array       # (dI,)
    x_proj: jax.Array       # (dI, dtr + 2*dS)
    dt_proj: jax.Array      # (dtr, dI)
    dt_bias: jax.Array      # (dI,)
    A_log: jax.Array        # (dI, dS)
    D: jax.Array            # (dI,)
    out_proj: jax.Array     # (dI, d)


class MambaState(NamedTuple):
    conv: jax.Array         # (B, dc-1, dI) last inputs for the causal conv
    ssm: jax.Array          # (B, dI, dS)


def _ssm_coeffs(p: MambaParams, xc, dt_rank, d_state, dt_bc_norm, eps):
    """xc: (B, L, dI) post-conv activations -> dt (B,L,dI), B/C (B,L,dS)."""
    proj = xc @ p.x_proj
    dt, Bmat, Cmat = jnp.split(proj, [dt_rank, dt_rank + d_state], axis=-1)
    if dt_bc_norm:  # falcon-mamba stabilisation: weightless RMSNorm
        dt = rms_norm(dt, None, eps)
        Bmat = rms_norm(Bmat, None, eps)
        Cmat = rms_norm(Cmat, None, eps)
    dt = jax.nn.softplus(dt @ p.dt_proj + p.dt_bias)     # (B, L, dI)
    return dt, Bmat, Cmat


def _discretize(p: MambaParams, dt, Bmat, x):
    """a = exp(dt*A): (B,L,dI,dS); b = dt*B*x: (B,L,dI,dS)."""
    A = -jnp.exp(p.A_log.astype(jnp.float32))            # (dI, dS)
    a = jnp.exp(dt.astype(jnp.float32)[..., None] * A)   # (B, L, dI, dS)
    b = (dt * x).astype(jnp.float32)[..., None] * \
        Bmat.astype(jnp.float32)[:, :, None, :]          # (B, L, dI, dS)
    return a, b


def _chunk_scan(a, b, C, h0, chunk):
    """Selective scan h_t = a_t*h_{t-1} + b_t, emitting y_t = <h_t, C_t>.

    a, b: (B, L, dI, dS) fp32; C: (B, L, dS) fp32; h0: (B, dI, dS).
    Returns (y (B, L, dI) fp32, h_last).  The (B, L, dI, dS) state history
    is never materialised beyond one chunk: the outer ``lax.scan`` carries
    the state across chunks, the inner ``associative_scan`` parallelises
    within a chunk, and the C-projection is fused into the chunk body.
    """
    B, L, dI, dS = a.shape
    n = max(L // chunk, 1)
    chunk = L // n
    a_c = a.reshape(B, n, chunk, dI, dS).swapaxes(0, 1)
    b_c = b.reshape(B, n, chunk, dI, dS).swapaxes(0, 1)
    c_c = C.reshape(B, n, chunk, dS).swapaxes(0, 1)

    def combine(x, y):
        ax, bx = x
        ay, by = y
        return ax * ay, bx * ay + by

    def outer(h, abc):
        ac, bc, cc = abc                                # chunk slabs
        a_run, b_run = jax.lax.associative_scan(combine, (ac, bc), axis=1)
        h_all = a_run * h[:, None] + b_run              # (B, chunk, dI, dS)
        yc = jnp.einsum("blds,bls->bld", h_all, cc)
        return h_all[:, -1], yc

    h_last, y_chunks = jax.lax.scan(outer, h0, (a_c, b_c, c_c))
    y = y_chunks.swapaxes(0, 1).reshape(B, L, dI)
    return y, h_last


def _chunk_scan_fused(p: MambaParams, dt, Bmat, C, xc, h0, chunk):
    """Chunked scan with in-body discretisation (a/b never hit HBM).

    dt, xc: (B, L, dI); Bmat: (B, L, dS); C: (B, L, dS) f32.
    Returns (y (B, L, dI) f32, h_last).
    """
    B, L, dI = dt.shape
    dS = Bmat.shape[-1]
    n = max(L // chunk, 1)
    chunk = L // n
    A = -jnp.exp(p.A_log.astype(jnp.float32))            # (dI, dS)

    slab = lambda t: t.reshape((B, n, chunk) + t.shape[2:]).swapaxes(0, 1)
    dt_c, x_c, b_c, c_c = slab(dt), slab(xc), slab(Bmat), slab(C)

    def combine(u, v):
        au, bu = u
        av, bv = v
        return au * av, bu * av + bv

    def outer(h, xs):
        dtc, xcc, bc, cc = xs
        dtf = dtc.astype(jnp.float32)[..., None]          # (B, c, dI, 1)
        a = jnp.exp(dtf * A)                              # (B, c, dI, dS)
        b = (dtf * xcc.astype(jnp.float32)[..., None]) * \
            bc.astype(jnp.float32)[:, :, None, :]
        a_run, b_run = jax.lax.associative_scan(combine, (a, b), axis=1)
        h_all = a_run * h[:, None] + b_run
        yc = jnp.einsum("blds,bls->bld", h_all, cc)
        return h_all[:, -1], yc

    h_last, y_chunks = jax.lax.scan(outer, h0, (dt_c, x_c, b_c, c_c))
    return y_chunks.swapaxes(0, 1).reshape(B, L, dI), h_last


def mamba_mixer(p: MambaParams, x, *, d_inner, d_state, dt_rank, d_conv,
                chunk, dt_bc_norm: bool = False, eps: float = 1e-6,
                return_state: bool = False,
                init_state: Optional[MambaState] = None,
                fused: bool = False):
    """Full-sequence mamba mixer. x: (B, L, d) -> (B, L, d)."""
    B, L, _ = x.shape
    xz = x @ p.in_proj
    xs, z = jnp.split(xz, 2, axis=-1)                    # (B, L, dI)

    # causal depthwise conv (kernel dc) along L
    if init_state is not None:
        pad = init_state.conv
    else:
        pad = jnp.zeros((B, d_conv - 1, d_inner), xs.dtype)
    xpad = jnp.concatenate([pad, xs], axis=1)
    xc = sum(xpad[:, i:i + L] * p.conv_w[i][None, None, :]
             for i in range(d_conv))
    xc = jax.nn.silu(xc + p.conv_b)

    dt, Bmat, Cmat = _ssm_coeffs(p, xc, dt_rank, d_state, dt_bc_norm, eps)
    h0 = (init_state.ssm.astype(jnp.float32) if init_state is not None
          else jnp.zeros((B, d_inner, d_state), jnp.float32))
    if fused:
        # beyond-baseline (§Perf): discretisation happens inside the chunk
        # body, so the (B, L, dI, dS) a/b tensors never materialise in
        # HBM — only the dS-times-smaller dt/B/C/x slabs stream in.  The
        # Pallas kernel realises the same fusion on TPU.
        y, h_last = _chunk_scan_fused(p, dt, Bmat,
                                      Cmat.astype(jnp.float32), xc, h0,
                                      chunk)
    else:
        a, b = _discretize(p, dt, Bmat, xc)
        y, h_last = _chunk_scan(a, b, Cmat.astype(jnp.float32), h0, chunk)
    y = y.astype(x.dtype) + xc * p.D
    y = y * jax.nn.silu(z)
    out = y @ p.out_proj
    if return_state:
        new_conv = xpad[:, L:L + d_conv - 1] if L >= d_conv - 1 else \
            jnp.concatenate([pad, xs], axis=1)[:, -(d_conv - 1):]
        return out, MambaState(conv=new_conv, ssm=h_last.astype(jnp.float32))
    return out, None


def mamba_decode(p: MambaParams, x, state: MambaState, *, d_inner, d_state,
                 dt_rank, d_conv, dt_bc_norm: bool = False,
                 eps: float = 1e-6) -> Tuple[jax.Array, MambaState]:
    """Single-token decode. x: (B, 1, d); O(1) state update."""
    B = x.shape[0]
    xz = x[:, 0] @ p.in_proj
    xs, z = jnp.split(xz, 2, axis=-1)                    # (B, dI)

    window = jnp.concatenate([state.conv, xs[:, None]], axis=1)  # (B, dc, dI)
    xc = jnp.einsum("bcd,cd->bd", window, p.conv_w)
    xc = jax.nn.silu(xc + p.conv_b)

    dt, Bmat, Cmat = _ssm_coeffs(p, xc[:, None], dt_rank, d_state,
                                 dt_bc_norm, eps)
    dt, Bmat, Cmat = dt[:, 0], Bmat[:, 0], Cmat[:, 0]
    A = -jnp.exp(p.A_log.astype(jnp.float32))
    a = jnp.exp(dt.astype(jnp.float32)[..., None] * A)          # (B, dI, dS)
    b = (dt * xc).astype(jnp.float32)[..., None] * \
        Bmat.astype(jnp.float32)[:, None, :]
    h = a * state.ssm + b
    y = jnp.einsum("bds,bs->bd", h, Cmat.astype(jnp.float32)).astype(x.dtype)
    y = y + xc * p.D
    y = y * jax.nn.silu(z)
    out = (y @ p.out_proj)[:, None]
    return out, MambaState(conv=window[:, 1:], ssm=h)
