"""The LM: parameter init, train forward, prefill, and cached decode.

Structure: every layer of a given arch is structurally homogeneous (the
local/global attention heterogeneity of gemma3/hymba is a *traced* mask
switch, not a structural one), so the layer stack is a single
``lax.scan`` over stacked (L, ...) parameters — this keeps the HLO (and
compile time) O(1) in depth, which is what makes 88-layer dry-runs at 512
devices tractable.  Remat ("MEMORY_ONLY" persistence in the paper's terms)
wraps the scan body.

All functions are pure; parameters are nested dicts of arrays so the
sharding rules in ``parallel/sharding.py`` can address leaves by path.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.core.compat import shard_map
from repro.configs.base import (LAYER_GLOBAL, LAYER_HYBRID, LAYER_LOCAL,
                                LAYER_MAMBA, ModelConfig)
from repro.models import moe as moe_lib
from repro.models.attention import (AttnParams, attention,
                                    decode_attention,
                                    decode_attention_quant)
from repro.models.layers import embed_init, embed_lookup, pad_to, rms_norm, swiglu
from repro.models.mamba import MambaParams, MambaState, mamba_decode, mamba_mixer
from repro.parallel.sharding import MeshRules

Params = Dict[str, Any]

VOCAB_PAD_MULTIPLE = 256
DEFAULT_Q_CHUNK = 1024       # lazy-flash threshold: chunk queries if S > this


def vocab_padded(cfg: ModelConfig) -> int:
    return pad_to(cfg.vocab_size, VOCAB_PAD_MULTIPLE)


# ----------------------------------------------------------------------
# Init
# ----------------------------------------------------------------------

def _init(key, shape, fan_in, dtype, scale=1.0):
    std = scale / math.sqrt(max(fan_in, 1))
    return (jax.random.truncated_normal(key, -2, 2, shape, jnp.float32)
            * std).astype(dtype)


def init_params(cfg: ModelConfig, key: jax.Array,
                dtype=jnp.bfloat16) -> Params:
    """Build the full parameter pytree (stacked layers).

    Deterministic in ``key`` alone (counter-based fold_in per leaf), so a
    restored-elsewhere replica re-derives identical params — the lineage
    property DESIGN.md §2 relies on.
    """
    d = cfg.d_model
    hd = cfg.resolved_head_dim
    L = cfg.n_layers
    V = vocab_padded(cfg)
    kinds = cfg.layer_kinds
    out_scale = 1.0 / math.sqrt(2 * L)

    def k(*names):
        kk = key
        for n in names:
            kk = jax.random.fold_in(kk, hash(n) % (2 ** 31))
        return kk

    params: Params = {}
    params["embed"] = embed_init(k("embed"), (V, d), dtype)
    if not cfg.tie_embeddings:
        params["head"] = _init(k("head"), (d, V), d, dtype)
    params["final_norm"] = jnp.ones((d,), dtype)

    layers: Params = {"ln1": jnp.ones((L, d), dtype)}
    has_attn = any(kd in (LAYER_GLOBAL, LAYER_LOCAL, LAYER_HYBRID)
                   for kd in kinds)
    has_mamba = any(kd in (LAYER_MAMBA, LAYER_HYBRID) for kd in kinds)
    has_ffn = kinds[0] != LAYER_MAMBA

    if has_attn:
        H, K = cfg.n_heads, cfg.n_kv_heads
        layers["attn"] = AttnParams(
            wq=_init(k("wq"), (L, d, H * hd), d, dtype),
            wk=_init(k("wk"), (L, d, K * hd), d, dtype),
            wv=_init(k("wv"), (L, d, K * hd), d, dtype),
            wo=_init(k("wo"), (L, H * hd, d), H * hd, dtype, out_scale),
            q_norm=jnp.ones((L, hd), dtype) if cfg.qk_norm else None,
            k_norm=jnp.ones((L, hd), dtype) if cfg.qk_norm else None,
        )
    if has_mamba:
        s = cfg.ssm
        dI = s.expand * d
        dtr = s.resolved_dt_rank(d)
        dt_init = jnp.log(jnp.expm1(jnp.exp(
            jax.random.uniform(k("dt"), (L, dI), jnp.float32)
            * (math.log(0.1) - math.log(0.001)) + math.log(0.001))))
        layers["mamba"] = MambaParams(
            in_proj=_init(k("m_in"), (L, d, 2 * dI), d, dtype),
            conv_w=_init(k("m_conv"), (L, s.d_conv, dI), s.d_conv, dtype),
            conv_b=jnp.zeros((L, dI), dtype),
            x_proj=_init(k("m_x"), (L, dI, dtr + 2 * s.d_state), dI, dtype),
            dt_proj=_init(k("m_dt"), (L, dtr, dI), dtr, dtype),
            dt_bias=dt_init.astype(dtype),
            A_log=jnp.log(jnp.broadcast_to(
                jnp.arange(1, s.d_state + 1, dtype=jnp.float32),
                (L, dI, s.d_state))).astype(jnp.float32),
            D=jnp.ones((L, dI), dtype),
            out_proj=_init(k("m_out"), (L, dI, d), dI, dtype, out_scale),
        )
    if kinds[0] == LAYER_HYBRID:
        layers["attn_out_norm"] = jnp.ones((L, d), dtype)
        layers["mamba_out_norm"] = jnp.ones((L, d), dtype)
    if has_ffn:
        layers["ln2"] = jnp.ones((L, d), dtype)
        if cfg.moe.enabled:
            tp_pad = 16  # pad for the production model-axis size
            E = moe_lib.padded_experts(cfg.moe.n_experts, tp_pad)
            f = cfg.d_ff
            nsh = cfg.moe.n_shared_experts
            layers["ffn"] = moe_lib.MoEParams(
                router=_init(k("router"), (L, d, E), d, jnp.float32),
                we1=_init(k("we1"), (L, E, d, f), d, dtype),
                we3=_init(k("we3"), (L, E, d, f), d, dtype),
                we2=_init(k("we2"), (L, E, f, d), f, dtype, out_scale),
                ws1=_init(k("ws1"), (L, d, nsh * f), d, dtype) if nsh else None,
                ws3=_init(k("ws3"), (L, d, nsh * f), d, dtype) if nsh else None,
                ws2=_init(k("ws2"), (L, nsh * f, d), nsh * f, dtype,
                          out_scale) if nsh else None,
            )
        else:
            layers["ffn"] = {
                "w1": _init(k("w1"), (L, d, cfg.d_ff), d, dtype),
                "w3": _init(k("w3"), (L, d, cfg.d_ff), d, dtype),
                "w2": _init(k("w2"), (L, cfg.d_ff, d), cfg.d_ff, dtype,
                            out_scale),
            }
    params["layers"] = layers
    return params


def layer_meta(cfg: ModelConfig) -> Dict[str, jax.Array]:
    """Per-layer traced metadata consumed by the scan body."""
    kinds = cfg.layer_kinds
    flags = [kd in (LAYER_GLOBAL, LAYER_MAMBA) or
             (kd == LAYER_HYBRID and i in cfg.global_layers)
             for i, kd in enumerate(kinds)]
    theta_g = cfg.rope_theta
    theta_l = cfg.rope_theta_local or cfg.rope_theta
    theta = [theta_g if g else theta_l for g in flags]
    return {"is_global": jnp.array(flags, jnp.bool_),
            "theta": jnp.array(theta, jnp.float32)}


# ----------------------------------------------------------------------
# Blocks
# ----------------------------------------------------------------------

def _ffn_apply(lp, x, cfg: ModelConfig, rules: MeshRules):
    """FFN sub-block on (B, S, d); returns (out, aux_loss)."""
    B, S, d = x.shape
    if not cfg.moe.enabled:
        f = lp["ffn"]
        h = jax.nn.silu(x @ f["w1"]) * (x @ f["w3"])
        h = rules.cs(h, jax.sharding.PartitionSpec(
            rules.dp if rules.dp else None, None, rules.t_ax))
        return h @ f["w2"], jnp.float32(0)
    p: moe_lib.MoEParams = lp["ffn"]
    tokens = x.reshape(B * S, d)
    if rules.mesh is None:
        out, aux = moe_lib.moe_ffn(p, tokens, cfg.moe, tp_size=1,
                                   axis_name=None,
                                   n_real_experts=cfg.moe.n_experts)
        return out.reshape(B, S, d), aux
    if rules.dp_only:
        # DP-only remap (§Perf/D): experts replicated, tokens sharded
        # over every mesh axis — routing and expert FFNs are local
        from jax.sharding import PartitionSpec as P
        dp = rules.dp

        def local_fn(tok, router, we1, we3, we2, ws1, ws3, ws2):
            pp = moe_lib.MoEParams(router, we1, we3, we2, ws1, ws3, ws2)
            return moe_lib.moe_ffn(pp, tok, cfg.moe, tp_size=1,
                                   axis_name=None, dp_axes=dp,
                                   n_real_experts=cfg.moe.n_experts)

        rep = lambda a: None if a is None else P(*([None] * a.ndim))
        in_specs = (P(dp, None), rep(p.router), rep(p.we1), rep(p.we3),
                    rep(p.we2), rep(p.ws1), rep(p.ws3), rep(p.ws2))
        out, aux = shard_map(
            local_fn, mesh=rules.mesh, in_specs=in_specs,
            out_specs=(P(dp, None), P()), check_vma=False)(
            tokens, p.router, p.we1, p.we3, p.we2, p.ws1, p.ws3, p.ws2)
        return out.reshape(B, S, d), aux

    from jax.sharding import PartitionSpec as P
    mesh = rules.mesh
    dp = rules.dp
    t = rules.tp_axis
    tp = rules.tp

    def local_fn(tok, router, we1, we3, we2, ws1, ws3, ws2):
        pp = moe_lib.MoEParams(router, we1, we3, we2, ws1, ws3, ws2)
        return moe_lib.moe_ffn(pp, tok, cfg.moe, tp_size=tp, axis_name=t,
                               dp_axes=dp,
                               n_real_experts=cfg.moe.n_experts)

    in_specs = (P(dp, None),                 # tokens: rows over dp
                P(None, None),               # router replicated
                P(t, None, None), P(t, None, None), P(t, None, None),
                P(None, t) if p.ws1 is not None else None,
                P(None, t) if p.ws3 is not None else None,
                P(t, None) if p.ws2 is not None else None)
    out_specs = (P(dp, None), P())
    out, aux = shard_map(
        local_fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
        check_vma=False)(tokens, p.router, p.we1, p.we3, p.we2,
                         p.ws1, p.ws3, p.ws2)
    return out.reshape(B, S, d), aux


def block_forward(x, lp, meta, cfg: ModelConfig, rules: MeshRules, *,
                  positions, q_chunk, return_kv=False, return_state=False,
                  init_state: Optional[MambaState] = None):
    """One layer, full-sequence. Returns (x, (kv, mamba_state), aux)."""
    kind = cfg.layer_kinds[0]   # structural kind (homogeneous per arch)
    hd = cfg.resolved_head_dim
    heads = (cfg.n_heads, cfg.n_kv_heads, hd)
    eps = cfg.norm_eps
    aspec = rules.act_spec(cfg)
    kv = state = None
    aux = jnp.float32(0)

    h = rms_norm(x, lp["ln1"], eps)
    if kind in (LAYER_GLOBAL, LAYER_LOCAL):
        out, kv = attention(
            lp["attn"], h, cfg_heads=heads, positions=positions,
            theta=meta["theta"], window=cfg.sliding_window,
            is_global=meta["is_global"], eps=eps, q_chunk=q_chunk,
            return_kv=return_kv)
        x = x + rules.cs(out, aspec)
    elif kind == LAYER_MAMBA:
        s = cfg.ssm
        out, state = mamba_mixer(
            lp["mamba"], h, d_inner=s.expand * cfg.d_model,
            d_state=s.d_state, dt_rank=s.resolved_dt_rank(cfg.d_model),
            d_conv=s.d_conv, chunk=s.chunk, dt_bc_norm=True, eps=eps,
            return_state=return_state, init_state=init_state,
            fused=s.fused)
        x = x + rules.cs(out, aspec)
    elif kind == LAYER_HYBRID:
        a_out, kv = attention(
            lp["attn"], h, cfg_heads=heads, positions=positions,
            theta=meta["theta"], window=cfg.sliding_window,
            is_global=meta["is_global"], eps=eps, q_chunk=q_chunk,
            return_kv=return_kv)
        s = cfg.ssm
        m_out, state = mamba_mixer(
            lp["mamba"], h, d_inner=s.expand * cfg.d_model,
            d_state=s.d_state, dt_rank=s.resolved_dt_rank(cfg.d_model),
            d_conv=s.d_conv, chunk=s.chunk, eps=eps,
            return_state=return_state, init_state=init_state,
            fused=s.fused)
        fused = 0.5 * (rms_norm(a_out, lp["attn_out_norm"], eps) +
                       rms_norm(m_out, lp["mamba_out_norm"], eps))
        x = x + rules.cs(fused, aspec)

    if kind != LAYER_MAMBA:
        h2 = rms_norm(x, lp["ln2"], eps)
        f_out, aux = _ffn_apply(lp, h2, cfg, rules)
        x = x + rules.cs(f_out, aspec)
    return x, (kv, state), aux


# ----------------------------------------------------------------------
# Full-model passes
# ----------------------------------------------------------------------

def _inputs_to_x(params, cfg, batch):
    if cfg.frontend == "embed":
        return batch["embeds"]
    return embed_lookup(params["embed"], batch["tokens"])


def forward(params: Params, batch, cfg: ModelConfig, rules: MeshRules, *,
            remat: bool = True, q_chunk: int = DEFAULT_Q_CHUNK,
            collect_cache: bool = False):
    """Full forward pass over (B, S). Returns (hidden, cache|None, aux)."""
    x = _inputs_to_x(params, cfg, batch)
    B, S, _ = x.shape
    x = rules.cs(x, rules.act_spec(cfg))
    positions = jnp.arange(S, dtype=jnp.int32)
    meta = layer_meta(cfg)

    def body(carry, xs):
        lp, m = xs
        y, (kv, state), aux = block_forward(
            carry, lp, m, cfg, rules, positions=positions, q_chunk=q_chunk,
            return_kv=collect_cache and cfg.uses_attention,
            return_state=collect_cache and cfg.uses_ssm)
        y = rules.cs(y, rules.act_spec(cfg))
        return y, (kv, state, aux)

    body_fn = jax.checkpoint(body) if remat else body
    x, (kvs, states, auxs) = jax.lax.scan(
        body_fn, x, (params["layers"], meta))
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)

    cache = None
    if collect_cache:
        cache = {}
        if kvs is not None:
            cache["k"], cache["v"] = kvs
        if states is not None:
            cache["conv"], cache["ssm"] = states.conv, states.ssm
    return x, cache, jnp.sum(auxs)


def lm_loss(params: Params, hidden, labels, cfg: ModelConfig,
            rules: MeshRules, *, chunk: int = 512):
    """Chunked cross-entropy: logits materialise one (B, chunk, V) slab at
    a time (a 262k vocab over 1M tokens would otherwise need ~1 PB)."""
    from jax.sharding import PartitionSpec as P
    B, S, d = hidden.shape
    V = vocab_padded(cfg)
    head = (params["embed"] if cfg.tie_embeddings else params["head"])
    vmask = (jnp.arange(V) < cfg.vocab_size)
    n = max(S // chunk, 1)
    csize = S // n

    def chunk_nll(carry, xs):
        h_c, y_c = xs                        # (B, c, d), (B, c)
        logits = h_c.astype(jnp.float32) @ (
            head.T if cfg.tie_embeddings else head).astype(jnp.float32)
        logits = rules.cs(logits, P(rules.dp if rules.dp else None, None,
                                    rules.t_ax))
        logits = jnp.where(vmask, logits, -1e30)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, y_c[..., None], axis=-1)[..., 0]
        return carry + jnp.sum(logz - gold), None

    h_chunks = hidden.reshape(B, n, csize, d).swapaxes(0, 1)
    y_chunks = labels.reshape(B, n, csize).swapaxes(0, 1)
    total, _ = jax.lax.scan(jax.checkpoint(chunk_nll), jnp.float32(0),
                            (h_chunks, y_chunks))
    return total / (B * S)


def loss_fn(params, batch, cfg, rules, *, remat=True,
            q_chunk=DEFAULT_Q_CHUNK, aux_weight=0.01):
    hidden, _, aux = forward(params, batch, cfg, rules, remat=remat,
                             q_chunk=q_chunk)
    nll = lm_loss(params, hidden, batch["labels"], cfg, rules)
    return nll + aux_weight * aux, {"nll": nll, "aux": aux}


# ----------------------------------------------------------------------
# Serving: prefill + decode
# ----------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16, kv_quant: bool = False):
    """Abstract-or-concrete decode cache for one model.

    ``kv_quant``: int8 cache + bf16 per-(token, head) scales — halves the
    decode state and the bandwidth-bound cache read (§Perf/F)."""
    L = cfg.n_layers
    hd = cfg.resolved_head_dim
    cache = {}
    if cfg.uses_attention:
        shape = (L, batch, max_len, cfg.n_kv_heads, hd)
        kv_dtype = jnp.int8 if kv_quant else dtype
        cache["k"] = jnp.zeros(shape, kv_dtype)
        cache["v"] = jnp.zeros(shape, kv_dtype)
        if kv_quant:
            cache["k_scale"] = jnp.zeros(shape[:-1], jnp.bfloat16)
            cache["v_scale"] = jnp.zeros(shape[:-1], jnp.bfloat16)
    if cfg.uses_ssm:
        s = cfg.ssm
        dI = s.expand * cfg.d_model
        cache["conv"] = jnp.zeros((L, batch, s.d_conv - 1, dI), dtype)
        cache["ssm"] = jnp.zeros((L, batch, dI, s.d_state), jnp.float32)
    return cache


def quantize_cache(cache: Dict[str, Any]) -> Dict[str, Any]:
    """Convert a bf16 prefill cache to the int8 decode layout."""
    from repro.models.attention import quantize_kv
    if "k" not in cache or cache["k"].dtype == jnp.int8:
        return cache
    out = dict(cache)
    out["k"], out["k_scale"] = quantize_kv(cache["k"])
    out["v"], out["v_scale"] = quantize_kv(cache["v"])
    return out


def prefill(params, batch, cfg: ModelConfig, rules: MeshRules, *,
            q_chunk: int = DEFAULT_Q_CHUNK):
    """Prefill: returns (last-token logits, cache at positions [0, S))."""
    hidden, cache, _ = forward(params, batch, cfg, rules, remat=False,
                               q_chunk=q_chunk, collect_cache=True)
    last = hidden[:, -1:]
    logits = _head_logits(params, last, cfg, rules)
    return logits, cache


def _head_logits(params, h, cfg, rules):
    from jax.sharding import PartitionSpec as P
    V = vocab_padded(cfg)
    head = (params["embed"].T if cfg.tie_embeddings else params["head"])
    logits = h.astype(jnp.float32) @ head.astype(jnp.float32)
    logits = rules.cs(logits, P(None, None, rules.t_ax))
    return jnp.where(jnp.arange(V) < cfg.vocab_size, logits, -1e30)


def decode_step(params, cache, batch, cfg: ModelConfig, rules: MeshRules):
    """One decode step.

    batch: {"tokens": (B,1) | "embeds": (B,1,d), "pos": (B,) int32} with
    ``pos`` the cache slot of the new token.  Returns (logits (B,1,V),
    new cache).
    """
    x = _inputs_to_x(params, cfg, batch)
    pos = batch["pos"]
    meta = layer_meta(cfg)
    hd = cfg.resolved_head_dim
    heads = (cfg.n_heads, cfg.n_kv_heads, hd)
    eps = cfg.norm_eps
    kind = cfg.layer_kinds[0]
    s = cfg.ssm

    kv_quant = "k_scale" in cache

    def attend(lp, m, h, cache_l, new_cache_l):
        if kv_quant:
            out, (k2, v2, ks2, vs2) = decode_attention_quant(
                lp["attn"], h, cache_l["k"], cache_l["v"],
                cache_l["k_scale"], cache_l["v_scale"], cfg_heads=heads,
                pos=pos, theta=m["theta"], window=cfg.sliding_window,
                is_global=m["is_global"], eps=eps)
            new_cache_l.update(k=k2, v=v2, k_scale=ks2, v_scale=vs2)
        else:
            out, k2, v2 = decode_attention(
                lp["attn"], h, cache_l["k"], cache_l["v"], cfg_heads=heads,
                pos=pos, theta=m["theta"], window=cfg.sliding_window,
                is_global=m["is_global"], eps=eps)
            new_cache_l.update(k=k2, v=v2)
        return out

    def body(carry, xs):
        lp, m, cache_l = xs
        x = carry
        new_cache_l = dict(cache_l)
        h = rms_norm(x, lp["ln1"], eps)
        if kind in (LAYER_GLOBAL, LAYER_LOCAL):
            out = attend(lp, m, h, cache_l, new_cache_l)
            x = x + out
        elif kind == LAYER_MAMBA:
            out, st = mamba_decode(
                lp["mamba"], h, MambaState(cache_l["conv"], cache_l["ssm"]),
                d_inner=s.expand * cfg.d_model, d_state=s.d_state,
                dt_rank=s.resolved_dt_rank(cfg.d_model), d_conv=s.d_conv,
                dt_bc_norm=True, eps=eps)
            new_cache_l["conv"], new_cache_l["ssm"] = st.conv, st.ssm
            x = x + out
        else:  # hybrid
            a_out = attend(lp, m, h, cache_l, new_cache_l)
            m_out, st = mamba_decode(
                lp["mamba"], h, MambaState(cache_l["conv"], cache_l["ssm"]),
                d_inner=s.expand * cfg.d_model, d_state=s.d_state,
                dt_rank=s.resolved_dt_rank(cfg.d_model), d_conv=s.d_conv,
                eps=eps)
            new_cache_l["conv"], new_cache_l["ssm"] = st.conv, st.ssm
            fused = 0.5 * (rms_norm(a_out, lp["attn_out_norm"], eps) +
                           rms_norm(m_out, lp["mamba_out_norm"], eps))
            x = x + fused
        if kind != LAYER_MAMBA:
            h2 = rms_norm(x, lp["ln2"], eps)
            f_out, _ = _ffn_apply(lp, h2, cfg, rules)
            x = x + f_out
        return x, new_cache_l

    x, new_cache = jax.lax.scan(body, x, (params["layers"], meta, cache))
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = _head_logits(params, x, cfg, rules)
    return logits, new_cache
