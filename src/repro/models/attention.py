"""GQA attention: full/sliding-window, train/prefill and cached decode.

Design notes (TPU adaptation):
- The reference path is pure jnp with optional *query chunking* (a lazy
  flash-attention: ``lax.scan`` over query blocks so the (S, T) score matrix
  never materialises beyond one block — this is what makes the 32k-prefill
  dry-run fit in HBM).  The Pallas kernel in ``repro.kernels.flash_attention``
  implements the same math with explicit VMEM tiling and is validated against
  this path; dry-runs lower the jnp path (Pallas cannot lower on the CPU
  backend except in interpret mode).
- KV is stored un-repeated (n_kv heads); query-head replication is a gather
  that XLA shards along the head axis when divisible (see
  ``parallel/sharding.py`` for the head-sharding rules).
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models.layers import apply_rope, rms_norm, rope_angles

NEG_INF = -2.0e38


class AttnParams(NamedTuple):
    wq: jax.Array            # (d, H*hd)
    wk: jax.Array            # (d, K*hd)
    wv: jax.Array            # (d, K*hd)
    wo: jax.Array            # (H*hd, d)
    q_norm: Optional[jax.Array]   # (hd,) or None
    k_norm: Optional[jax.Array]


def _project_qkv(p: AttnParams, x, n_heads, n_kv, head_dim, sin, cos,
                 eps: float):
    B, S, _ = x.shape
    q = (x @ p.wq).reshape(B, S, n_heads, head_dim)
    k = (x @ p.wk).reshape(B, S, n_kv, head_dim)
    v = (x @ p.wv).reshape(B, S, n_kv, head_dim)
    if p.q_norm is not None:
        q = rms_norm(q, p.q_norm, eps)
        k = rms_norm(k, p.k_norm, eps)
    q = apply_rope(q, sin, cos)
    k = apply_rope(k, sin, cos)
    return q, k, v


def _group_q(q: jax.Array, n_kv: int) -> jax.Array:
    """(B, S, H, hd) -> (B, S, K, G, hd): group query heads per kv head.

    Grouped einsums read the UN-repeated kv tensors directly — a
    ``jnp.repeat`` of a 32k-token cache would materialise a cache-sized
    temp per layer."""
    B, S, H, hd = q.shape
    return q.reshape(B, S, n_kv, H // n_kv, hd)


def _scores_mask(q_pos, k_pos, window, is_global):
    """Causal (+ optional sliding window) additive mask.

    q_pos: (S,) or (B, 1); k_pos: (T,). ``is_global`` may be a traced bool —
    local/global layer heterogeneity inside scan-over-layers is a cheap
    ``where`` on the mask rather than a ``lax.cond``.
    """
    causal = k_pos[None, :] <= q_pos[..., None]
    if window:
        in_win = k_pos[None, :] > (q_pos[..., None] - window)
        keep = causal & (is_global | in_win)
    else:
        keep = causal
    return jnp.where(keep, 0.0, NEG_INF)


def _sdpa(q, k, v, mask, scale):
    """Grouped SDPA. q: (B, Sq, K, G, hd); k/v: (B, T, K, hd);
    mask: (Sq, T) additive. Returns (B, Sq, K, G, hd)."""
    logits = jnp.einsum("bskgd,btkd->bkgst", q, k,
                        preferred_element_type=jnp.float32) * scale
    logits = logits + mask                      # broadcast over (B, K, G)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    return jnp.einsum("bkgst,btkd->bskgd", probs, v)


def attention(p: AttnParams, x, *, cfg_heads, positions, theta,
              window: int, is_global, eps: float,
              q_chunk: int = 0, return_kv: bool = False):
    """Full-sequence attention (train / prefill).

    cfg_heads: (n_heads, n_kv, head_dim).  ``q_chunk`` > 0 scans over query
    blocks (lazy-flash) to bound the score-matrix footprint.
    Returns (out, (k, v) if return_kv else None).
    """
    H, K, hd = cfg_heads
    B, S, _ = x.shape
    sin, cos = rope_angles(positions, hd, theta)
    q, k, v = _project_qkv(p, x, H, K, hd, sin, cos, eps)
    scale = hd ** -0.5
    qg = _group_q(q, K)                         # (B, S, K, G, hd)
    k_pos = positions

    if q_chunk and S > q_chunk and S % q_chunk == 0:
        n_blk = S // q_chunk

        def blk(carry, inp):
            qb, qpos = inp                      # (B, C, K, G, hd), (C,)
            mask = _scores_mask(qpos, k_pos, window, is_global)
            ob = _sdpa(qb, k, v, mask, scale)
            return carry, ob

        q_blocks = qg.reshape(B, n_blk, q_chunk, K, H // K, hd
                              ).swapaxes(0, 1)
        pos_blocks = positions.reshape(n_blk, q_chunk)
        _, out_blocks = jax.lax.scan(blk, None, (q_blocks, pos_blocks))
        out = out_blocks.swapaxes(0, 1).reshape(B, S, H * hd)
    else:
        mask = _scores_mask(positions, k_pos, window, is_global)
        out = _sdpa(qg, k, v, mask, scale).reshape(B, S, H * hd)

    out = out @ p.wo
    return out, ((k, v) if return_kv else None)


def quantize_kv(x: jax.Array):
    """Symmetric int8 per-(token, head) KV quantization.

    x: (..., hd) -> (int8 (..., hd), scale (...,) bf16).  Halving the
    cache dtype halves both the decode state and the bandwidth-bound
    cache read (EXPERIMENTS.md §Perf/F)."""
    scale = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1) / 127.0 + 1e-8
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]),
                 -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.bfloat16)


def decode_attention_quant(p: AttnParams, x, k_cache, v_cache, k_scale,
                           v_scale, *, cfg_heads, pos, theta, window: int,
                           is_global, eps: float):
    """Cached decode over an int8-quantized KV cache.

    k_cache/v_cache: int8 (B, T, K, hd); k_scale/v_scale: bf16 (B, T, K).
    Dequantization happens inside the attention math (per-tile on TPU),
    so the HBM stream stays int8.
    """
    H, K, hd = cfg_heads
    B = x.shape[0]
    T = k_cache.shape[1]
    sin, cos = rope_angles(pos[:, None], hd, theta)
    q, k_new, v_new = _project_qkv(p, x, H, K, hd, sin, cos, eps)

    kq, ks = quantize_kv(k_new[:, 0])                    # (B,K,hd),(B,K)
    vq, vs = quantize_kv(v_new[:, 0])
    bidx = jnp.arange(B, dtype=jnp.int32)
    k_cache = k_cache.at[bidx, pos].set(kq)
    v_cache = v_cache.at[bidx, pos].set(vq)
    k_scale = k_scale.at[bidx, pos].set(ks)
    v_scale = v_scale.at[bidx, pos].set(vs)

    k_pos = jnp.arange(T, dtype=jnp.int32)
    valid = k_pos[None, :] <= pos[:, None]
    if window:
        in_win = k_pos[None, :] > (pos[:, None] - window)
        valid = valid & (is_global | in_win)
    mask = jnp.where(valid, 0.0, NEG_INF)                # (B, T)

    qg = _group_q(q, K)
    logits = jnp.einsum("bskgd,btkd->bkgst", qg,
                        k_cache.astype(qg.dtype),
                        preferred_element_type=jnp.float32) * (hd ** -0.5)
    logits = logits * k_scale.astype(jnp.float32).transpose(0, 2, 1)[
        :, :, None, None, :]
    logits = logits + mask[:, None, None, None, :]
    probs = jax.nn.softmax(logits, axis=-1)
    pv = (probs * v_scale.astype(jnp.float32).transpose(0, 2, 1)[
        :, :, None, None, :]).astype(qg.dtype)
    out = jnp.einsum("bkgst,btkd->bskgd", pv,
                     v_cache.astype(qg.dtype)).reshape(B, 1, H * hd)
    return out @ p.wo, (k_cache, v_cache, k_scale, v_scale)


def decode_attention(p: AttnParams, x, k_cache, v_cache, *, cfg_heads,
                     pos, theta, window: int, is_global, eps: float):
    """One-token cached decode.

    x: (B, 1, d); k_cache/v_cache: (B, T, K, hd) with the new slot at
    ``pos`` (B,) int32.  Returns (out (B,1,d), k_cache', v_cache').
    """
    H, K, hd = cfg_heads
    B, _, _ = x.shape
    T = k_cache.shape[1]
    sin, cos = rope_angles(pos[:, None], hd, theta)      # (B,1,hd/2)
    q, k_new, v_new = _project_qkv(p, x, H, K, hd, sin, cos, eps)

    # scatter the new kv into slot `pos` (per-sequence index) — an
    # in-place donated update, not a one-hot blend (which would build two
    # cache-sized temporaries per layer)
    bidx = jnp.arange(B, dtype=jnp.int32)
    k_cache = k_cache.at[bidx, pos].set(k_new[:, 0])
    v_cache = v_cache.at[bidx, pos].set(v_new[:, 0])

    k_pos = jnp.arange(T, dtype=jnp.int32)
    valid = k_pos[None, :] <= pos[:, None]                         # (B, T)
    if window:
        in_win = k_pos[None, :] > (pos[:, None] - window)
        valid = valid & (is_global | in_win)
    mask = jnp.where(valid, 0.0, NEG_INF)                          # (B, T)

    qg = _group_q(q, K)                                  # (B, 1, K, G, hd)
    logits = jnp.einsum("bskgd,btkd->bkgst", qg, k_cache,
                        preferred_element_type=jnp.float32) * (hd ** -0.5)
    logits = logits + mask[:, None, None, None, :]
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgst,btkd->bskgd", probs, v_cache
                     ).reshape(B, 1, H * hd)
    return out @ p.wo, k_cache, v_cache
