"""Sharded AdamW with optional ZeRO-1 optimizer-state partitioning.

Mixed-precision discipline: model params live in bf16 (compute dtype);
the optimizer holds an fp32 master copy + fp32 moments.  With ZeRO-1 the
three fp32 tensors are additionally sharded over the data axis — each
data-parallel rank owns a 1/dp slice of the optimizer state, which is what
makes 27B-param training fit per-chip HBM at 512 chips (see DESIGN.md §7).

Implementation note: ZeRO-1 here is expressed through *sharding specs*, not
manual collectives — the update math is written once, and the in/out
shardings on the optimizer-state leaves tell XLA to keep them partitioned;
XLA inserts the reduce-scatter (grads into the owned slice) and all-gather
(updated master back to bf16 replicas) that the hand-written version would
have.  This keeps the optimizer a pure function usable on any mesh.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0


def adamw_init(params) -> Dict[str, Any]:
    """fp32 master + moments, matching the param tree."""
    # copy=True: with fp32 params, astype would alias the same buffer and
    # break (params, opt) double-donation in the train step
    f32 = lambda p: jnp.array(p, jnp.float32, copy=True)
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "master": jax.tree.map(f32, params),
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adamw_update(grads, opt_state, params, cfg: AdamWConfig,
                 lr_scale=1.0) -> Tuple[Any, Dict[str, Any], Dict[str, Any]]:
    """One AdamW step. Returns (new bf16 params, new opt state, metrics)."""
    step = opt_state["step"] + 1
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)
    lr = cfg.lr * lr_scale

    def upd(g, m, v, master):
        g = g.astype(jnp.float32) * clip
        m2 = b1 * m + (1 - b1) * g
        v2 = b2 * v + (1 - b2) * jnp.square(g)
        update = (m2 / bc1) / (jnp.sqrt(v2 / bc2) + cfg.eps)
        master2 = master - lr * (update + cfg.weight_decay * master)
        return m2, v2, master2

    flat_g, treedef = jax.tree.flatten(grads)
    flat_m = treedef.flatten_up_to(opt_state["m"])
    flat_v = treedef.flatten_up_to(opt_state["v"])
    flat_ma = treedef.flatten_up_to(opt_state["master"])
    out = [upd(g, m, v, ma)
           for g, m, v, ma in zip(flat_g, flat_m, flat_v, flat_ma)]
    new_m = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_master = jax.tree.unflatten(treedef, [o[2] for o in out])

    old_dtypes = jax.tree.map(lambda p: p.dtype, params)
    new_params = jax.tree.map(lambda ma, dt: ma.astype(dt), new_master,
                              old_dtypes)
    new_state = {"master": new_master, "m": new_m, "v": new_v, "step": step}
    return new_params, new_state, {"grad_norm": gnorm,
                                   "lr": jnp.float32(lr)}


def zero_assign(parts, dims, dp_axes: Tuple[str, ...], mesh_shape=None):
    """Shard the largest free dim over the largest dividing dp-axis
    subset (full tuple first, then single axes — odd dims like hymba's
    1600 can't divide 256 but do divide 16).  Mutates and returns parts;
    no-op when nothing divides."""
    sizes = dict(mesh_shape or {})
    candidates = [dp_axes] + [(a,) for a in dp_axes if len(dp_axes) > 1]
    for axes in candidates:
        k = 1
        for a in axes:
            k *= sizes.get(a, 16)
        best, best_sz = None, 0
        for i, (ax, n) in enumerate(zip(parts, dims)):
            if ax is None and n % max(k, 1) == 0 and n > best_sz:
                best, best_sz = i, n
        if best is not None:
            parts[best] = axes if len(axes) > 1 else axes[0]
            return parts
    return parts


def opt_pspecs(param_specs, param_shapes, dp_axes: Tuple[str, ...] = (),
               dp_size: int = 1, mesh_shape=None):
    """Optimizer-state specs: param spec + optional ZeRO-1 data-sharding.

    With ``dp_axes`` set, each fp32 state leaf additionally shards its
    largest still-unsharded, dp-divisible dimension over the data axes
    (small norm vectors that don't divide stay replicated — they are
    irrelevant to the footprint).
    """
    from jax.sharding import PartitionSpec as P

    def leafspec(spec, shape):
        if shape is None:
            return None
        dims = shape.shape if hasattr(shape, "shape") else shape
        parts = list(spec) if spec is not None else []
        parts += [None] * (len(dims) - len(parts))
        used = {a for p in parts if p is not None
                for a in (p if isinstance(p, tuple) else (p,))}
        free_axes = tuple(a for a in dp_axes if a not in used)
        if free_axes and dp_size > 1:
            zero_assign(parts, dims, free_axes, mesh_shape)
        return P(*parts)

    is_spec = lambda s: isinstance(s, P) or s is None
    state_spec = jax.tree.map(leafspec, param_specs, param_shapes,
                              is_leaf=is_spec)
    return {"master": state_spec, "m": state_spec, "v": state_spec,
            "step": P()}
