"""Roofline analysis from dry-run artifacts (EXPERIMENTS.md §Roofline).

Per (arch x shape) on the single-pod mesh (256 chips), from the per-device
HLO stats (trip-count corrected; see hlo_analysis.py):

  compute    = dot_FLOPs_per_device / 197 TFLOP/s      [s]
  memory     = traffic_bytes_per_device / 819 GB/s     [s]
  collective = collective_bytes_per_device / 50 GB/s   [s]

(The per-device form is identical to the spec's totals/(chips x rate).)

MODEL_FLOPS = 6 N D for train steps (N = active params for MoE),
2 N D for forward-only steps (prefill/decode; stated deviation so the
useful-compute ratio stays interpretable).  The roofline fraction is
useful_time / dominant_term — the score the perf loop drives up.

    python -m repro.launch.roofline [--dir artifacts/dryrun] [--mesh single]
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path

V5E = {"peak_flops": 197e12, "hbm_bw": 819e9, "ici_bw": 50e9}


def analyze_cell(d: dict) -> dict:
    h = d["hlo"]
    chips = d["chips"]
    compute = h["flops"] / V5E["peak_flops"]
    memory_hi = h["traffic_bytes"] / V5E["hbm_bw"]
    memory_lo = h.get("traffic_fused_bytes", h["traffic_bytes"]) \
        / V5E["hbm_bw"]
    # bracketed memory term: hi = CPU-fusion granularity (every op
    # materialises), lo = TPU-grade fusion (only dots/collectives/stash
    # slices/gathers touch HBM).  The table scores against `lo`; both are
    # reported so the bracket is visible.
    memory = memory_lo
    collective = h["collective_bytes"] / V5E["ici_bw"]
    terms = {"compute": compute, "memory": memory,
             "collective": collective}
    dominant = max(terms, key=terms.get)

    n = (d["params_active"] if "moe" in d["arch"] or
         d["params_active"] != d["params_total"] else d["params_total"])
    tokens = d["tokens_per_step"]
    if d["kind"] == "train":
        model_flops = 6.0 * n * tokens
    else:
        model_flops = 2.0 * n * tokens
    model_flops_per_dev = model_flops / chips
    useful_time = model_flops_per_dev / V5E["peak_flops"]
    ratio_flops = (model_flops_per_dev / h["flops"]) if h["flops"] else 0.0
    frac = useful_time / max(terms[dominant], 1e-12)

    return {
        "cell": d["cell"],
        "arch": d["arch"],
        "shape": d["shape"],
        "kind": d["kind"],
        "chips": chips,
        "compute_s": compute,
        "memory_s": memory,
        "memory_hi_s": memory_hi,
        "collective_s": collective,
        "dominant": dominant,
        "model_flops": model_flops,
        "model_flops_convention": ("6ND" if d["kind"] == "train"
                                   else "2ND"),
        "useful_flops_ratio": ratio_flops,
        "roofline_fraction": frac,
        "state_gib": d.get("state_bytes_per_device", 0) / 1024 ** 3,
        "raw_mem_gib": d["memory_analysis"]["per_device_total"] / 1024 ** 3,
        "collective_by_type": h.get("collective_by_type", {}),
        "options": d.get("options", {}),
    }


_MOVE_HINTS = {
    "compute": ("recompute (remat) dominates: relax the remat policy / "
                "larger microbatch, or cut attention-flop overhead"),
    "memory": ("HBM traffic dominates: fuse/cast transients to bf16, "
               "shrink the remat stash, or raise arithmetic intensity "
               "with bigger per-device tiles"),
    "collective": ("ICI dominates: shrink/reschedule TP reductions "
                   "(bf16 collectives, hierarchical reduce, overlap "
                   "with compute)"),
}


def to_markdown(rows, mesh: str) -> str:
    lines = [
        f"### Roofline — {mesh} pod mesh "
        f"(256 chips; v5e: 197 TF/s bf16, 819 GB/s HBM, 50 GB/s ICI)",
        "",
        "| cell | compute s | memory s (lo..hi) | collective s | bound | "
        "MODEL/HLO flops | roofline frac | state GiB |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        lines.append(
            f"| {r['arch']} {r['shape']} | {r['compute_s']:.3e} | "
            f"{r['memory_s']:.3e}..{r['memory_hi_s']:.3e} | "
            f"{r['collective_s']:.3e} | "
            f"**{r['dominant']}** | {r['useful_flops_ratio']:.2f} "
            f"({r['model_flops_convention']}) | "
            f"{r['roofline_fraction']:.3f} | {r['state_gib']:.2f} |")
    lines.append("")
    lines.append("Bottleneck keys: " + "; ".join(
        f"**{k}** — {v}" for k, v in _MOVE_HINTS.items()))
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="artifacts/dryrun")
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--out", default="artifacts/roofline.json")
    args = ap.parse_args()

    rows = []
    for f in sorted(Path(args.dir).glob(f"*__{args.mesh}.json")):
        d = json.loads(f.read_text())
        if not d.get("ok"):
            print(f"skipping failed cell {d.get('cell')}")
            continue
        rows.append(analyze_cell(d))
    rows.sort(key=lambda r: (r["shape"], r["arch"]))

    Path(args.out).write_text(json.dumps(rows, indent=1))
    md = to_markdown(rows, args.mesh)
    Path(args.out).with_suffix(".md").write_text(md)
    print(md)


if __name__ == "__main__":
    main()
