"""End-to-end training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-1.7b \
        --reduced --steps 200 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt \
        --ckpt-every 50 [--resume] [--simulate-failure 75]

Production behaviours exercised here (single host, any device count):
  - sharded params/opt (MeshRules over whatever mesh exists),
  - deterministic prefetching data pipeline (counter-based; resume-exact),
  - async atomic checkpoints + restore (elastic across device counts),
  - straggler watchdog (EMA step-time; logs + early checkpoint),
  - --simulate-failure N: hard-kills the in-process trainer at step N and
    restarts from the last checkpoint, asserting bit-identical loss
    trajectory vs an uninterrupted run (lineage-replay equivalence).
"""
from __future__ import annotations

import argparse
import time
from pathlib import Path

import jax
import numpy as np

from repro.checkpoint import Checkpointer, latest_step, restore
from repro.configs import get_config, reduced
from repro.data.pipeline import lm_loader
from repro.launch.mesh import smallest_mesh
from repro.models import model as model_lib
from repro.optim import adamw as adamw_lib
from repro.parallel.sharding import MeshRules
from repro.training import steps as steps_lib


class SimulatedFailure(RuntimeError):
    pass


def train(arch: str, *, steps: int, batch: int, seq: int,
          use_reduced: bool = True, ckpt_dir=None, ckpt_every: int = 0,
          resume: bool = False, fail_at: int = -1, seed: int = 0,
          lr: float = 3e-3, log_every: int = 10, mesh=None,
          straggler_factor: float = 5.0, verbose: bool = True):
    cfg = get_config(arch)
    if use_reduced:
        cfg = reduced(cfg)
    rules = MeshRules(mesh=mesh)

    key = jax.random.PRNGKey(seed)
    params = model_lib.init_params(cfg, key, dtype=jax.numpy.float32)
    opt = adamw_lib.adamw_init(params)
    opt_cfg = adamw_lib.AdamWConfig(lr=lr)
    step_fn = jax.jit(steps_lib.build_train_step(
        cfg, rules, opt_cfg=opt_cfg, remat=True, q_chunk=0),
        donate_argnums=(0, 1))

    start = 0
    ck = None
    if ckpt_dir:
        ck = Checkpointer(ckpt_dir, meta={"arch": arch, "seq": seq,
                                          "batch": batch})
        if resume:
            last = latest_step(ckpt_dir)
            if last is not None:
                (params, opt), _ = restore(ckpt_dir, last, (params, opt))
                start = last
                if verbose:
                    print(f"[train] resumed from step {start}")

    loader = lm_loader(cfg, rules, batch=batch, seq=seq, seed=seed,
                       start_step=start)
    losses = []
    ema = None
    try:
        for i, (step_idx, data) in zip(range(start, steps), loader):
            t0 = time.perf_counter()
            if fail_at == i:
                raise SimulatedFailure(f"injected failure at step {i}")
            params, opt, metrics = step_fn(params, opt, data)
            loss = float(np.asarray(metrics["loss"]))
            dt = time.perf_counter() - t0
            losses.append(loss)
            if ema is not None and dt > straggler_factor * ema and ck:
                if verbose:
                    print(f"[watchdog] straggler step {i} "
                          f"({dt:.3f}s vs ema {ema:.3f}s) — checkpointing")
                ck.save_async(i + 1, (params, opt))
            ema = dt if ema is None else 0.9 * ema + 0.1 * dt
            if ck and ckpt_every and (i + 1) % ckpt_every == 0:
                ck.save_async(i + 1, (params, opt))
            if verbose and (i % log_every == 0 or i == steps - 1):
                print(f"[train] step {i:5d} loss {loss:8.4f} "
                      f"({dt*1e3:6.1f} ms)")
    finally:
        loader.close()
        if ck:
            ck.wait()
    return params, opt, losses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--full", action="store_true",
                    help="full config (needs real accelerators)")
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--simulate-failure", type=int, default=-1,
                    help="inject a crash at step N, then auto-restart "
                         "from the last checkpoint")
    args = ap.parse_args()

    mesh = smallest_mesh()
    kw = dict(steps=args.steps, batch=args.batch, seq=args.seq,
              use_reduced=not args.full, ckpt_dir=args.ckpt_dir,
              ckpt_every=args.ckpt_every, seed=args.seed, lr=args.lr,
              mesh=mesh)
    if args.simulate_failure >= 0:
        assert args.ckpt_dir and args.ckpt_every, \
            "--simulate-failure needs --ckpt-dir/--ckpt-every"
        try:
            train(args.arch, fail_at=args.simulate_failure, **kw)
        except SimulatedFailure as e:
            print(f"[train] {e}; restarting from checkpoint")
        _, _, losses = train(args.arch, resume=True, **kw)
    else:
        _, _, losses = train(args.arch, resume=args.resume, **kw)
    print(f"[train] done; first loss {losses[0]:.4f} "
          f"final loss {losses[-1]:.4f}")


if __name__ == "__main__":
    main()
