"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (not a module constant) so that
importing this module never touches jax device state — smoke tests see one
CPU device; only the dry-run (which sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import) sees the full placeholder topology.

Topology (TPU v5e target): single pod = (data=16, model=16) — 256 chips;
multi-pod = (pod=2, data=16, model=16) — 512 chips.  The `model` axis is
mapped innermost so tensor-parallel collectives stay on intra-board ICI
links; the `pod` axis is outermost (DCI), carrying only data-parallel
gradient reductions (see parallel/collectives.py for the hierarchical
schedule).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax

from repro.core.compat import make_mesh as _make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]):
    """Arbitrary mesh for tests/examples (e.g. (2,2) on 4 host devices)."""
    return _make_mesh(shape, axes)


def smallest_mesh() -> Optional[object]:
    """A (data=N, model=1) mesh over whatever devices exist; None if 1."""
    n = len(jax.devices())
    if n == 1:
        return None
    return make_mesh((n, 1), ("data", "model"))
