"""Tuned per-(arch x shape) launch policies — the §Perf conclusions as
code (EXPERIMENTS.md §Perf scoreboard).

    from repro.launch.policies import tuned_options
    opts = tuned_options("granite-34b", "train_4k")
    lower_cell(arch, shape, multi_pod, **opts)

Policy rules (derived, not hand-waved — every rule cites its §Perf
iteration):
  - small models (total params <= ~3.5B) on a 256-chip pod: DP-only
    remap + FSDP (D-series: 1.9-12x roofline fraction);
  - deep/huge dense (params bf16 x 2 > HBM budget after TP): FSDP (C1);
  - gemma3-class dense: accum 4 (B4) — accum 8 default otherwise
    (HBM-safety first);
  - SP activation sharding always on for TP cells (B1 refuted dropping
    it); irrelevant under dp_only;
  - mamba archs keep the unfused jnp scan until the Pallas kernel path
    is active on real TPUs (A-series: jnp-level fusion is neutral).
"""
from __future__ import annotations

from typing import Dict

from repro.configs import get_config

DP_ONLY_MAX_PARAMS = 3.5e9


def tuned_options(arch: str, shape_name: str) -> Dict:
    cfg = get_config(arch)
    n = cfg.param_count()
    opts: Dict = {"q_chunk": 1024, "zero1": True, "remat": True,
                  "seq_shard": True, "accum_steps": 8,
                  "fsdp": False, "dp_only": False, "accum_bf16": False}
    if shape_name != "train_4k":
        opts["accum_steps"] = 1
        if shape_name.startswith("decode") or shape_name.startswith("long"):
            # §Perf/F: int8 KV cache — 2.6-3.5x off the decode memory
            # term, greedy tokens unchanged (test_int8_kv_decode...)
            opts["kv_quant"] = True
        return opts
    if n <= DP_ONLY_MAX_PARAMS:
        opts.update(dp_only=True, fsdp=True, accum_steps=1,
                    seq_shard=False)
        return opts
    if arch == "granite-34b":
        opts.update(fsdp=True)                      # C1/C3
    if arch in ("gemma3-27b", "internvl2-26b"):
        opts.update(accum_steps=4)                  # B4 / E2 (+5-6%)
    # glm4-9b probed flat on accum 4 (E1: +0.5%) — stays at the default
    return opts
