"""Batched decode server loop.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b \
        --reduced --batch 8 --prompt-len 32 --gen 64

Continuous-batching-shaped loop: prefill builds the cache, then the
serve_step (greedy) advances every sequence one token per call with
per-sequence positions — the same step the decode dry-run cells lower at
(batch=128, 32k cache) scale.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced
from repro.data.synthetic import lm_batch
from repro.launch.mesh import smallest_mesh
from repro.models import model as model_lib
from repro.parallel.sharding import MeshRules
from repro.training import steps as steps_lib


def serve(arch: str, *, batch: int, prompt_len: int, gen: int,
          use_reduced: bool = True, seed: int = 0, mesh=None,
          verbose: bool = True):
    cfg = get_config(arch)
    if use_reduced:
        cfg = reduced(cfg)
    rules = MeshRules(mesh=mesh)
    max_len = prompt_len + gen

    params = model_lib.init_params(cfg, jax.random.PRNGKey(seed),
                                   dtype=jnp.float32)
    prompt = lm_batch(cfg, batch, prompt_len, seed, 0)
    prompt.pop("labels")

    prefill_fn = jax.jit(steps_lib.build_prefill_step(cfg, rules,
                                                      q_chunk=0))
    serve_fn = jax.jit(steps_lib.build_serve_step(cfg, rules),
                       donate_argnums=(1,))

    t0 = time.perf_counter()
    next_tok, cache = prefill_fn(params, prompt)
    # grow the cache to max_len slots
    def grow(c):
        out = dict(c)
        for k in ("k", "v"):
            if k in c:
                pad_shape = (c[k].shape[0], c[k].shape[1],
                             max_len - c[k].shape[2]) + c[k].shape[3:]
                out[k] = jnp.concatenate(
                    [c[k], jnp.zeros(pad_shape, c[k].dtype)], axis=2)
        return out
    cache = grow(cache)
    t_prefill = time.perf_counter() - t0

    tokens = [np.asarray(next_tok[:, 0])]
    pos = jnp.full((batch,), prompt_len, jnp.int32)
    t0 = time.perf_counter()
    tok = next_tok.astype(jnp.int32)
    for i in range(gen - 1):
        step_in = {"pos": pos}
        if cfg.frontend == "embed":
            step_in["embeds"] = 0.02 * jax.random.normal(
                jax.random.fold_in(jax.random.PRNGKey(seed), i),
                (batch, 1, cfg.d_model), jnp.float32)
        else:
            step_in["tokens"] = tok
        tok, cache = serve_fn(params, cache, step_in)
        tokens.append(np.asarray(tok[:, 0]))
        pos = pos + 1
    jax.block_until_ready(tok)
    t_decode = time.perf_counter() - t0

    toks_per_s = batch * (gen - 1) / max(t_decode, 1e-9)
    if verbose:
        print(f"[serve] prefill {prompt_len} tokens x {batch}: "
              f"{t_prefill*1e3:.1f} ms")
        print(f"[serve] decode {gen-1} steps x {batch}: "
              f"{t_decode*1e3:.1f} ms ({toks_per_s:.0f} tok/s)")
    return np.stack(tokens, axis=1), {"prefill_s": t_prefill,
                                      "decode_s": t_decode,
                                      "tok_per_s": toks_per_s}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=64)
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    out, stats = serve(args.arch, batch=args.batch,
                       prompt_len=args.prompt_len, gen=args.gen,
                       use_reduced=not args.full, mesh=smallest_mesh())
    print(f"[serve] generated shape {out.shape}")


if __name__ == "__main__":
    main()
