import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above MUST stay the first statements in this module — jax
locks the device count on first init, and the dry-run needs 512 placeholder
CPU devices to build the production meshes.  (Smoke tests and benchmarks
import other modules and see 1 device.)

Per cell this produces artifacts/dryrun/<arch>__<shape>__<mesh>.json with:
  - compiled.memory_analysis() (per-device bytes: the fits-in-HBM proof),
  - compiled.cost_analysis() (XLA's own flops/bytes — body-once semantics),
  - the trip-count-corrected HLO stats (dot FLOPs, HBM-traffic model,
    collective bytes by type) from repro.launch.hlo_analysis,
  - analytic MODEL_FLOPS and the config fingerprint.

Usage:
  python -m repro.launch.dryrun --all [--mesh both] [--force]
  python -m repro.launch.dryrun --arch gemma3-27b --shape train_4k --mesh single
"""
import argparse
import dataclasses
import json
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.configs import (ARCH_IDS, SHAPES, get_config, shape_applicable)
from repro.launch.hlo_analysis import analyze
from repro.launch.mesh import make_production_mesh
from repro.parallel.sharding import MeshRules
from repro.training import steps as steps_lib

V5E = {"peak_flops": 197e12, "hbm_bw": 819e9, "ici_bw": 50e9,
       "hbm_bytes": 16 * 1024**3}


def cell_name(arch: str, shape: str, mesh: str) -> str:
    return f"{arch}__{shape}__{mesh}"


def list_cells():
    cells = []
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for shape in SHAPES.values():
            if shape_applicable(cfg, shape):
                cells.append((arch, shape.name))
    return cells


def build_rules(cfg, shape, mesh, seq_shard: bool = False) -> MeshRules:
    dp_size = 1
    for a in ("pod", "data"):
        if a in mesh.shape:
            dp_size *= mesh.shape[a]
    shard_seq = (shape.kind == "decode" and shape.global_batch % dp_size != 0)
    return MeshRules(mesh=mesh, shard_cache_seq=shard_seq,
                     seq_shard_activations=seq_shard and shape.kind == "train")


def lower_cell(arch: str, shape_name: str, multi_pod: bool, *,
               accum_steps: int = 1, q_chunk: int = 1024,
               zero1: bool = True, remat: bool = True,
               seq_shard: bool = False, fsdp: bool = False,
               accum_bf16: bool = False, mamba_fused: bool = False,
               mamba_chunk: int = 0, dp_only: bool = False,
               kv_quant: bool = False):
    """Returns (lowered, compiled, meta) for one cell."""
    cfg = get_config(arch)
    if cfg.ssm is not None and (mamba_fused or mamba_chunk):
        cfg = dataclasses.replace(
            cfg, ssm=dataclasses.replace(
                cfg.ssm, fused=mamba_fused,
                chunk=mamba_chunk or cfg.ssm.chunk))
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    if dp_only and shape.global_batch % mesh.size != 0:
        dp_only = False          # e.g. batch 256 on the 512-chip mesh
    rules = build_rules(cfg, shape, mesh, seq_shard=seq_shard)
    if fsdp or dp_only:
        rules = dataclasses.replace(rules, fsdp=fsdp, dp_only=dp_only)

    pshapes, pspecs, p_sds = steps_lib.abstract_params(cfg, rules)
    batch_sds = steps_lib.input_specs(cfg, shape, rules)

    def shard_bytes(tree) -> int:
        """Exact per-device bytes of a sharded ShapeDtypeStruct tree."""
        total = 0
        for leaf in jax.tree.leaves(tree):
            if leaf is None:
                continue
            shp = (leaf.sharding.shard_shape(leaf.shape)
                   if leaf.sharding is not None else leaf.shape)
            n = 1
            for d in shp:
                n *= d
            total += n * leaf.dtype.itemsize
        return total
    meta = {
        "arch": arch, "shape": shape_name,
        "mesh": "multi" if multi_pod else "single",
        "mesh_shape": dict(mesh.shape),
        "chips": mesh.size,
        "kind": shape.kind,
        "tokens_per_step": (shape.tokens if shape.kind != "decode"
                            else shape.global_batch),
        "params_total": cfg.param_count(),
        "params_active": cfg.active_param_count(),
        "options": {"accum_steps": accum_steps, "q_chunk": q_chunk,
                    "zero1": zero1, "remat": remat,
                    "seq_shard": rules.seq_shard_activations,
                    "fsdp": fsdp, "accum_bf16": accum_bf16,
                    "mamba_fused": mamba_fused, "dp_only": dp_only,
                    "kv_quant": kv_quant,
                    "shard_cache_seq": rules.shard_cache_seq},
    }

    with mesh:
        if shape.kind == "train":
            _, ospecs, o_sds = steps_lib.abstract_opt_state(
                cfg, rules, pshapes, pspecs, zero1=zero1)
            step = steps_lib.build_train_step(
                cfg, rules, accum_steps=accum_steps, q_chunk=q_chunk,
                remat=remat,
                grad_specs=ospecs["m"] if accum_steps > 1 else None,
                accum_dtype=jnp.bfloat16 if accum_bf16 else jnp.float32)
            fn = jax.jit(step, donate_argnums=(0, 1))
            lowered = fn.lower(p_sds, o_sds, batch_sds)
            # state: params + opt (donated/aliased, counted once) + batch
            meta["state_bytes_per_device"] = (
                shard_bytes(p_sds) + shard_bytes(o_sds)
                + shard_bytes(batch_sds))
        elif shape.kind == "prefill":
            step = steps_lib.build_prefill_step(cfg, rules, q_chunk=q_chunk)
            fn = jax.jit(step)
            lowered = fn.lower(p_sds, batch_sds)
            cache_out = steps_lib.cache_specs(cfg, shape, rules)
            meta["state_bytes_per_device"] = (
                shard_bytes(p_sds) + shard_bytes(batch_sds)
                + shard_bytes(cache_out))
        else:  # decode
            cache_sds = steps_lib.cache_specs(cfg, shape, rules,
                                              kv_quant=kv_quant)
            step = steps_lib.build_serve_step(cfg, rules)
            fn = jax.jit(step, donate_argnums=(1,))
            lowered = fn.lower(p_sds, cache_sds, batch_sds)
            meta["state_bytes_per_device"] = (
                shard_bytes(p_sds) + shard_bytes(cache_sds)
                + shard_bytes(batch_sds))
        compiled = lowered.compile()
    return lowered, compiled, meta


def run_cell(arch: str, shape_name: str, multi_pod: bool, out_dir: Path,
             force: bool = False, **opts) -> dict:
    mesh_tag = "multi" if multi_pod else "single"
    out = out_dir / (cell_name(arch, shape_name, mesh_tag) + ".json")
    if out.exists() and not force:
        return json.loads(out.read_text())
    t0 = time.time()
    status: dict = {"cell": cell_name(arch, shape_name, mesh_tag)}
    try:
        lowered, compiled, meta = lower_cell(arch, shape_name, multi_pod,
                                             **opts)
        t_compile = time.time() - t0
        ma = compiled.memory_analysis()
        mem = {k: int(getattr(ma, k)) for k in (
            "argument_size_in_bytes", "output_size_in_bytes",
            "temp_size_in_bytes", "alias_size_in_bytes",
            "generated_code_size_in_bytes")}
        mem["per_device_total"] = (mem["argument_size_in_bytes"]
                                   + mem["output_size_in_bytes"]
                                   + mem["temp_size_in_bytes"]
                                   - mem["alias_size_in_bytes"])
        ca = compiled.cost_analysis() or {}
        cost = {k: float(ca[k]) for k in ("flops", "bytes accessed")
                if k in ca}
        hlo = analyze(compiled.as_text()).to_dict()
        status.update(meta)
        state_b = meta.get("state_bytes_per_device", 0)
        status.update({
            "ok": True,
            "compile_seconds": round(t_compile, 1),
            "memory_analysis": mem,
            "cost_analysis": cost,
            "hlo": hlo,
            # raw CPU-backend total (includes the f32 shadow copies the
            # CPU emitter makes of bf16 dot/dus operands — absent on TPU;
            # see EXPERIMENTS.md §Dry-run) vs exact sharded state bytes
            "fits_hbm_raw": mem["per_device_total"] <= V5E["hbm_bytes"],
            "fits_hbm_state": state_b <= V5E["hbm_bytes"],
        })
    except Exception as e:  # a failing cell is a bug — record it loudly
        status.update({
            "ok": False,
            "error": f"{type(e).__name__}: {e}",
            "traceback": traceback.format_exc()[-4000:],
        })
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps(status, indent=1))
    flag = "OK " if status.get("ok") else "FAIL"
    mem_gb = (status.get("memory_analysis", {}).get("per_device_total", 0)
              / 1024**3)
    print(f"[{flag}] {status['cell']:54s} "
          f"compile={status.get('compile_seconds', 0):7.1f}s "
          f"mem/dev={mem_gb:6.2f}GiB", flush=True)
    return status


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--accum-steps", type=int, default=1)
    ap.add_argument("--q-chunk", type=int, default=1024)
    ap.add_argument("--seq-shard", action="store_true")
    ap.add_argument("--fsdp", action="store_true")
    ap.add_argument("--accum-bf16", action="store_true")
    ap.add_argument("--mamba-fused", action="store_true")
    ap.add_argument("--mamba-chunk", type=int, default=0)
    ap.add_argument("--dp-only", action="store_true")
    ap.add_argument("--kv-quant", action="store_true")
    ap.add_argument("--tuned", action="store_true",
                    help="per-cell tuned policies (EXPERIMENTS.md §Perf)")
    ap.add_argument("--no-zero1", action="store_true")
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--out", default="artifacts/dryrun")
    args = ap.parse_args()

    out_dir = Path(args.out)
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    if args.all:
        cells = list_cells()
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]

    n_fail = 0
    for arch, shape in cells:
        for multi in meshes:
            if args.tuned:
                from repro.launch.policies import tuned_options
                opts = tuned_options(arch, shape)
            else:
                opts = dict(accum_steps=args.accum_steps,
                            q_chunk=args.q_chunk,
                            seq_shard=args.seq_shard,
                            fsdp=args.fsdp,
                            accum_bf16=args.accum_bf16,
                            mamba_fused=args.mamba_fused,
                            mamba_chunk=args.mamba_chunk,
                            dp_only=args.dp_only,
                            kv_quant=args.kv_quant,
                            zero1=not args.no_zero1,
                            remat=not args.no_remat)
            st = run_cell(arch, shape, multi, out_dir, force=args.force,
                          **opts)
            n_fail += 0 if st.get("ok") else 1
    print(f"done; {n_fail} failing cells")
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
