"""Static analysis of optimized (post-SPMD) HLO text.

``compiled.cost_analysis()`` counts a ``while`` body ONCE regardless of
trip count (verified empirically — a 10-iteration scan reports 1x the body
FLOPs), which would under-count every scanned-layer model by ~n_layers.
This module re-derives roofline inputs from ``compiled.as_text()``:

  - per-device dot FLOPs, with while-loop bodies multiplied by their trip
    counts (parsed from the loop-condition constant), nested loops
    multiplying through;
  - per-device HBM traffic estimate: operand+result bytes of every
    top-level op (fusions count as one read+write unit, which models
    post-fusion HBM traffic more faithfully than per-primitive sums);
  - collective bytes by op type (all-reduce / all-gather / reduce-scatter /
    all-to-all / collective-permute), operand-sized, trip-multiplied.

All numbers are PER DEVICE (the SPMD module is the per-device program);
multiply by chip count for cluster totals.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1,
    "f8e4m3": 1, "f8e3m4": 1, "bf16": 2, "f16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16, "token": 0, "opaque": 0,
}

COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute", "collective-broadcast", "ragged-all-to-all",
)

# ops that move data but do no math; parameters/tuples/bitcasts are free
_NO_TRAFFIC = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "iota", "partition-id", "replica-id", "while",
    "conditional", "call",
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_KIND_RE = re.compile(r"([\w\-]+)\(")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_DIMS_RE = {
    "lb": re.compile(r"lhs_batch_dims=\{([0-9,]*)\}"),
    "lc": re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}"),
    "rb": re.compile(r"rhs_batch_dims=\{([0-9,]*)\}"),
    "rc": re.compile(r"rhs_contracting_dims=\{([0-9,]*)\}"),
}
_CONST_INT_RE = re.compile(r"constant\((\d+)\)")


def type_bytes(type_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * DTYPE_BYTES[dtype]
    return total


def _shape_dims(type_str: str) -> List[int]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


@dataclass
class Op:
    name: str
    kind: str
    type_str: str
    operands: List[str]
    called: List[str]
    line: str

    @property
    def result_bytes(self) -> int:
        return type_bytes(self.type_str)


@dataclass
class Computation:
    name: str
    ops: List[Op] = field(default_factory=list)
    by_name: Dict[str, Op] = field(default_factory=dict)


def _balanced(s: str, open_ch: str = "(", close_ch: str = ")") -> int:
    """Index one past the paren that closes s[0] (which must be open_ch)."""
    depth = 0
    for j, ch in enumerate(s):
        if ch == open_ch:
            depth += 1
        elif ch == close_ch:
            depth -= 1
            if depth == 0:
                return j + 1
    return len(s)


def _parse_op_line(s: str) -> Optional[Op]:
    """Parse `[ROOT ]%name = TYPE kind(operands), attrs...`.

    TYPE may be a tuple `(f32[..], /*index=5*/s32[..], ...)` — the comment
    markers contain `=`, so this uses balanced-paren scanning, not regex.
    """
    if s.startswith("ROOT "):
        s = s[5:]
    if not s.startswith("%"):
        return None
    eq = s.find(" = ")
    if eq < 0:
        return None
    name = s[1:eq].strip()
    rest = s[eq + 3:]
    if rest.startswith("("):                      # tuple type
        end = _balanced(rest)
        type_str = rest[:end]
        rest = rest[end:].lstrip()
    else:
        sp = rest.find(" ")
        if sp < 0:
            return None
        type_str = rest[:sp]
        rest = rest[sp + 1:].lstrip()
    m = _KIND_RE.match(rest)
    if not m:
        return None
    kind = m.group(1)
    call = rest[len(kind):]
    end = _balanced(call)
    operand_sec = call[1:end - 1]
    attr_sec = call[end:]
    operands = _OPERAND_RE.findall(operand_sec)
    called = _OPERAND_RE.findall(attr_sec)        # computation refs in attrs
    return Op(name, kind, type_str, operands, called, s)


def parse_hlo(text: str) -> Tuple[Dict[str, Computation], str]:
    """Parse optimized HLO text -> ({name: Computation}, entry_name)."""
    comps: Dict[str, Computation] = {}
    entry = None
    cur: Optional[Computation] = None
    for line in text.splitlines():
        stripped = line.strip()
        # computation header: `%name (...` or `ENTRY %name (...` at top level
        if (line.startswith("%") or line.startswith("ENTRY")) \
                and "{" in line:
            is_entry = line.startswith("ENTRY")
            m = re.match(r"(?:ENTRY\s+)?%?([\w.\-]+)\s*\(", line)
            if m:
                cur = Computation(m.group(1))
                comps[cur.name] = cur
                if is_entry:
                    entry = cur.name
            continue
        if stripped == "}":
            cur = None
            continue
        if cur is None:
            continue
        op = _parse_op_line(stripped)
        if op is not None:
            cur.ops.append(op)
            cur.by_name[op.name] = op
    if entry is None:
        raise ValueError("no ENTRY computation found")
    return comps, entry


def _trip_count(comps: Dict[str, Computation], cond_name: str) -> int:
    """Heuristic: the loop bound is the max integer constant in the cond."""
    cond = comps.get(cond_name)
    if cond is None:
        return 1
    best = 1
    for op in cond.ops:
        for m in _CONST_INT_RE.finditer(op.line):
            best = max(best, int(m.group(1)))
    return best


def _dot_flops(comp: Computation, op: Op) -> float:
    """2*B*M*N*K from operand shapes + dimension-number attrs."""
    if len(op.operands) < 2:
        return 0.0
    lhs = comp.by_name.get(op.operands[0])
    rhs = comp.by_name.get(op.operands[1])
    if lhs is None or rhs is None:
        return 0.0
    ldims = _shape_dims(lhs.type_str)
    rdims = _shape_dims(rhs.type_str)
    rb = _DIMS_RE["rb"].search(op.line)
    rc = _DIMS_RE["rc"].search(op.line)
    rb_idx = [int(i) for i in rb.group(1).split(",") if i] if rb else []
    rc_idx = [int(i) for i in rc.group(1).split(",") if i] if rc else []
    n = 1
    for i, d in enumerate(rdims):
        if i not in rb_idx and i not in rc_idx:
            n *= d
    lprod = 1
    for d in ldims:
        lprod *= d
    return 2.0 * lprod * n


@dataclass
class HloStats:
    flops: float = 0.0                       # per-device dot flops
    traffic_bytes: float = 0.0               # upper bound: every op
    traffic_fused_bytes: float = 0.0         # lower bound: see analyze()
    collective_bytes: float = 0.0            # per-device, operand-sized
    collective_by_type: Dict[str, float] = field(default_factory=dict)
    collective_count: Dict[str, int] = field(default_factory=dict)
    collective_by_site: Dict[str, float] = field(default_factory=dict)
    traffic_by_sig: Dict[str, float] = field(default_factory=dict)
    n_while: int = 0
    trip_counts: List[int] = field(default_factory=list)

    def top_traffic(self, n: int = 10):
        return sorted(self.traffic_by_sig.items(), key=lambda kv: -kv[1])[:n]

    def to_dict(self) -> dict:
        return {
            "flops": self.flops,
            "traffic_bytes": self.traffic_bytes,
            "traffic_fused_bytes": self.traffic_fused_bytes,
            "collective_bytes": self.collective_bytes,
            "collective_by_type": dict(self.collective_by_type),
            "collective_count": dict(self.collective_count),
            "collective_top": sorted(self.collective_by_site.items(),
                                     key=lambda kv: -kv[1])[:12],
            "traffic_top": self.top_traffic(),
            "n_while": self.n_while,
            "trip_counts": sorted(self.trip_counts, reverse=True)[:16],
        }


def analyze(text: str) -> HloStats:
    comps, entry = parse_hlo(text)
    stats = HloStats()
    visiting: set = set()

    _CONVERTISH = {"parameter", "constant", "convert", "bitcast"}

    def _is_dus_fusion(op: Op) -> bool:
        """Fusion whose root is a dynamic-update-slice (stash writes)."""
        if op.kind != "fusion" or not op.called:
            return False
        body = comps.get(op.called[0])
        if not body or not body.ops:
            return False
        return any(o.kind == "dynamic-update-slice" for o in body.ops[-2:])

    def _is_ds_fusion(op: Op) -> bool:
        """Fusion that slices a stacked operand (scan reading layer i of
        stacked params/stash).  Charging the whole stack per iteration
        would overcount by ~n_layers; the real read is slice-sized."""
        if op.kind != "fusion" or not op.called:
            return False
        body = comps.get(op.called[0])
        if not body:
            return False
        return any(o.kind == "dynamic-slice" for o in body.ops)

    def _is_convert_only(op: Op) -> bool:
        """convert/bitcast-only ops or fusions: dtype shadow copies the
        CPU emitter makes of bf16 dot/dus operands.  The TPU backend
        consumes bf16 natively — charge no HBM traffic."""
        if op.kind in ("convert", "bitcast"):
            return True
        if op.kind != "fusion" or not op.called:
            return False
        body = comps.get(op.called[0])
        if not body:
            return False
        return all(o.kind in _CONVERTISH for o in body.ops)

    def _dtype_of(type_str: str) -> str:
        m = _SHAPE_RE.search(type_str)
        return m.group(1) if m else "f32"

    def _logical_dtype(comp: Computation, op: Optional[Op]) -> str:
        if op is None:
            return "f32"
        if _is_convert_only(op) and op.operands:
            inner = comp.by_name.get(op.operands[0])
            if inner is not None:
                return _dtype_of(inner.type_str)
        return _dtype_of(op.type_str)

    def _operand_logical_bytes(comp: Computation, name: str) -> float:
        """Bytes of an operand at its pre-convert (logical) dtype.

        Also resolves through dots: XLA:CPU's float normalization turns
        bf16 x bf16 -> bf16 dots into f32 BEFORE SPMD partitioning, so
        the TP all-reduce lands on an f32 value that is bf16 in the jax
        program (and on TPU).  A dot whose operands are logically bf16 is
        charged at bf16 width.  (Attention einsums with an explicit
        preferred_element_type=f32 don't feed collectives directly, so
        this resolution is safe for our module structure.)
        """
        src = comp.by_name.get(name)
        if src is None:
            return 0.0
        # resolve through pass-through wrapper fusions (copy/bitcast/convert)
        hops = 0
        while (src.kind == "fusion" and src.called and hops < 3
               and (body := comps.get(src.called[0])) is not None
               and all(o.kind in _PASSTHRU for o in body.ops)
               and src.operands):
            big = max(src.operands,
                      key=lambda o: type_bytes(comp.by_name[o].type_str)
                      if o in comp.by_name else 0)
            nxt = comp.by_name.get(big)
            if nxt is None:
                break
            src = nxt
            hops += 1
        b = float(type_bytes(src.type_str))
        if _is_convert_only(src) and src.operands:
            inner = comp.by_name.get(src.operands[0])
            if inner is not None:
                b = min(b, float(type_bytes(inner.type_str)))
        elif src.kind == "dot" and _dtype_of(src.type_str) == "f32":
            if src.operands and all(_src_width(comp, o) <= 2
                                    for o in src.operands):
                b /= 2.0
        return b

    _PASSTHRU = {"parameter", "constant", "convert", "bitcast", "copy",
                 "transpose", "reshape", "broadcast",
                 "get-tuple-element"}

    def _src_width(comp: Computation, name: str, depth: int = 4) -> int:
        """Smallest element width (bytes) along the producer chain of
        pass-through ops — the logical dtype before CPU float
        normalization widened it."""
        op = comp.by_name.get(name)
        if op is None:
            return 4
        here = DTYPE_BYTES.get(_dtype_of(op.type_str), 4)
        if depth <= 0:
            return here
        if op.kind in ("convert", "bitcast", "copy", "transpose",
                       "reshape") and op.operands:
            return min(here, _src_width(comp, op.operands[0], depth - 1))
        if op.kind == "fusion" and op.called:
            body = comps.get(op.called[0])
            if body and all(o.kind in _PASSTHRU for o in body.ops):
                ws = [_src_width(comp, o, depth - 1)
                      for o in op.operands]
                if ws:
                    return min(here, min(ws))
        return here

    def op_bytes(comp: Computation, op: Op) -> float:
        """HBM-traffic model for one top-level op.

        - in-place update patterns (dus / dus-rooted fusions) charge the
          slice, not the whole aliased buffer (XLA:TPU updates in place;
          charging the full stash per layer overcounts ~n_layers x);
        - dtype-shadow converts charge nothing, and operands are charged
          at their logical (pre-convert) width.
        """
        if _is_convert_only(op):
            return 0.0
        operand_bytes = [_operand_logical_bytes(comp, o)
                         for o in op.operands]
        if op.kind == "dynamic-update-slice" or _is_dus_fusion(op):
            big = float(op.result_bytes)
            small = [b for b in operand_bytes if b < big]
            return 2.0 * max(small) if small else big
        if op.kind == "dynamic-slice" or (
                _is_ds_fusion(op)
                and operand_bytes
                and max(operand_bytes) > 2 * op.result_bytes):
            return 2.0 * float(op.result_bytes)
        total = float(op.result_bytes)
        skipped_alias = False
        for b in operand_bytes:
            if not skipped_alias and b == op.result_bytes:
                skipped_alias = True      # likely aliased/in-place operand
                continue
            total += b
        return total

    def _fusion_contains(op: Op, kinds) -> bool:
        if op.kind != "fusion" or not op.called:
            return False
        body = comps.get(op.called[0])
        return bool(body) and any(o.kind in kinds for o in body.ops)

    _MATERIALIZE = {"dot", "custom-call", "gather", "scatter", "sort",
                    "dynamic-update-slice", "reduce", "concatenate",
                    "dynamic-slice"}

    def _is_materialization(op: Op) -> bool:
        """Ops that must touch HBM even under TPU-grade fusion: matmul
        operands/results, stash slices, gathers/scatters/sorts, big
        reductions.  Pure elementwise chains (CPU kLoop fusions) are
        assumed fused into neighbours — the optimistic bound."""
        if op.kind in _MATERIALIZE:
            return True
        base = op.kind.removesuffix("-start")
        if base in COLLECTIVES:
            return True
        return _fusion_contains(op, _MATERIALIZE)

    _users_cache: Dict[str, Dict[str, list]] = {}

    def _users_of(comp: Computation) -> Dict[str, list]:
        if comp.name not in _users_cache:
            users: Dict[str, list] = {}
            for o in comp.ops:
                for nm in o.operands:
                    users.setdefault(nm, []).append(o)
            _users_cache[comp.name] = users
        return _users_cache[comp.name]

    def _reduce_scatterable(comp: Computation, op: Op) -> float:
        """If every (transitive) consumer of an all-reduce slices the
        result down by >=4x, return the largest sliced size (else 0).

        Same-size elementwise consumers (the dx add chains in layer
        backward) are followed through: on TPU, AllReduceReassociate
        sinks the reduce below the adds and ReduceScatterCreator folds
        the following slice — the CPU pipeline runs neither pass.
        """
        if not op.kind.startswith("all-reduce"):
            return 0.0
        full = float(op.result_bytes)
        users = _users_of(comp)
        # `full` per element: combined (tuple) all-reduces divide first
        n_parts = max(op.type_str.count("]"), 1) if \
            op.type_str.startswith("(") else 1
        elem = full / n_parts
        seen, frontier = set(), [op.name]
        biggest, depth = 0.0, 0
        while frontier and depth < 6:
            nxt = []
            for name in frontier:
                for c in users.get(name, []):
                    if c.name in seen:
                        continue
                    seen.add(c.name)
                    rb = float(c.result_bytes)
                    if rb * 4 <= elem:
                        biggest = max(biggest, rb)     # slicing consumer
                    elif rb <= full * 1.01 and c.kind in (
                            "add", "subtract", "fusion", "convert",
                            "copy", "bitcast", "multiply",
                            "get-tuple-element"):
                        nxt.append(c.name)             # follow the chain
                    else:
                        return 0.0                     # escapes full-size
            frontier = nxt
            depth += 1
        if frontier:                                   # unresolved chain
            return 0.0
        return biggest

    def walk(comp_name: str, mult: float, traffic: bool):
        if comp_name not in comps or comp_name in visiting:
            return
        comp = comps[comp_name]
        visiting.add(comp_name)
        for op in comp.ops:
            base = op.kind.removesuffix("-start").removesuffix("-done")
            if op.kind == "while":
                trip = _trip_count(comps, op.called[0] if op.called else "")
                stats.n_while += 1
                stats.trip_counts.append(trip)
                for c in op.called:          # [condition, body]
                    walk(c, mult * trip, traffic)
                continue
            if base in COLLECTIVES:
                if op.kind.endswith("-done"):
                    continue                 # counted at -start
                b = sum(_operand_logical_bytes(comp, o)
                        for o in op.operands) * mult
                rs = _reduce_scatterable(comp, op)
                if rs:
                    # every consumer immediately slices the result to a
                    # shard (SP residual): XLA:TPU's ReduceScatterCreator
                    # turns this all-reduce into a reduce-scatter whose
                    # per-device bytes ~ 2 x shard
                    b = min(b, 2.0 * rs * mult)
                stats.collective_bytes += b
                stats.collective_by_type[base] = \
                    stats.collective_by_type.get(base, 0.0) + b
                stats.collective_count[base] = \
                    stats.collective_count.get(base, 0) + int(mult)
                mname = re.search(r'op_name="([^"]+)"', op.line)
                m = _SHAPE_RE.search(op.type_str)
                site = (f"{base}:{m.group(0) if m else '?'}:"
                        + (mname.group(1)[-70:] if mname else "?"))
                stats.collective_by_site[site] = \
                    stats.collective_by_site.get(site, 0.0) + b
            if op.kind == "dot":
                stats.flops += _dot_flops(comp, op) * mult
            if traffic and op.kind not in _NO_TRAFFIC:
                b = op_bytes(comp, op) * mult
                stats.traffic_bytes += b
                if _is_materialization(op):
                    stats.traffic_fused_bytes += b
                    m = _SHAPE_RE.search(op.type_str)
                    sig = (f"{op.kind}:{m.group(0) if m else '?'}")
                    stats.traffic_by_sig[sig] = \
                        stats.traffic_by_sig.get(sig, 0.0) + b
            if op.kind in ("fusion", "reduce", "map", "scatter", "sort",
                           "reduce-window", "select-and-scatter"):
                # descend for dot flops only (no traffic double-count)
                for c in op.called:
                    walk(c, mult, traffic=False)
            elif op.kind in ("call", "conditional", "custom-call"):
                for c in op.called:
                    walk(c, mult, traffic=traffic)
        visiting.discard(comp_name)

    walk(entry, 1.0, traffic=True)
    return stats
