"""Step builders: train_step / prefill_step / serve_step, plus input_specs.

These are the functions the launcher jits and the dry-run lowers.  Each
builder closes over (cfg, rules) and returns a pure function plus the
in/out sharding trees, so ``jax.jit(step, in_shardings=..., ...)`` is
assembled in one place for trainer, server, and dry-run alike.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import model as model_lib
from repro.optim import adamw as adamw_lib
from repro.optim.adamw import AdamWConfig
from repro.optim.schedule import warmup_cosine
from repro.parallel.sharding import MeshRules, cache_pspecs, param_pspecs


# ----------------------------------------------------------------------
# input_specs — ShapeDtypeStruct stand-ins for every model input
# ----------------------------------------------------------------------

def input_specs(cfg: ModelConfig, shape: ShapeConfig,
                rules: MeshRules) -> Dict[str, jax.ShapeDtypeStruct]:
    """Abstract inputs for a (arch x shape) cell; no device allocation.

    train/prefill: token ids (or stub frontend embeddings) + labels.
    decode: one new token (or embedding) + per-sequence cache position.
    """
    B, S = shape.global_batch, shape.seq_len
    bspec = rules.batch_spec

    def sds(shp, dtype, spec):
        return jax.ShapeDtypeStruct(shp, dtype, sharding=rules.sharding(spec))

    dp = rules.dp if rules.dp else None
    batch_shardable = rules.dp_size <= 1 or B % rules.dp_size == 0
    b_ax = dp if (dp and batch_shardable) else None

    if shape.kind in ("train", "prefill"):
        specs = {}
        if cfg.frontend == "embed":
            specs["embeds"] = sds((B, S, cfg.d_model), jnp.bfloat16,
                                  P(b_ax, None, None))
        else:
            specs["tokens"] = sds((B, S), jnp.int32, P(b_ax, None))
        specs["labels"] = sds((B, S), jnp.int32, P(b_ax, None))
        return specs

    # decode: single new token against a pre-filled cache
    specs = {}
    if cfg.frontend == "embed":
        specs["embeds"] = sds((B, 1, cfg.d_model), jnp.bfloat16,
                              P(b_ax, None, None))
    else:
        specs["tokens"] = sds((B, 1), jnp.int32, P(b_ax, None))
    specs["pos"] = sds((B,), jnp.int32, P(b_ax))
    return specs


def _with_shardings(shapes, specs, rules: MeshRules):
    """Attach NamedShardings to a ShapeDtypeStruct tree (None-leaf safe)."""
    def leaf(s, sp):
        if s is None:
            return None
        return jax.ShapeDtypeStruct(s.shape, s.dtype,
                                    sharding=rules.sharding(sp))
    return jax.tree.map(
        leaf, shapes, specs,
        is_leaf=lambda x: x is None or hasattr(x, "shape") or
        isinstance(x, P))


def cache_specs(cfg: ModelConfig, shape: ShapeConfig, rules: MeshRules,
                dtype=jnp.bfloat16, kv_quant: bool = False
                ) -> Dict[str, jax.ShapeDtypeStruct]:
    """Abstract decode cache for a cell (KV len = shape.seq_len)."""
    cache_shapes = jax.eval_shape(
        lambda: model_lib.init_cache(cfg, shape.global_batch, shape.seq_len,
                                     dtype, kv_quant=kv_quant))
    specs = cache_pspecs(cfg, rules, cache_shapes, shape.global_batch)
    return _with_shardings(cache_shapes, specs, rules)


def abstract_params(cfg: ModelConfig, rules: MeshRules,
                    dtype=jnp.bfloat16):
    """(shapes, pspecs, ShapeDtypeStructs-with-sharding) for the params."""
    shapes = jax.eval_shape(
        partial(model_lib.init_params, cfg, dtype=dtype),
        jax.random.PRNGKey(0))
    pspecs = param_pspecs(cfg, rules, shapes)
    return shapes, pspecs, _with_shardings(shapes, pspecs, rules)


def abstract_opt_state(cfg: ModelConfig, rules: MeshRules, param_shapes,
                       pspecs, zero1: bool = True):
    shapes = jax.eval_shape(adamw_lib.adamw_init, param_shapes)
    dp = rules.dp
    ospecs = adamw_lib.opt_pspecs(
        pspecs, param_shapes, dp_axes=dp if zero1 else (),
        dp_size=rules.dp_size if zero1 else 1,
        mesh_shape=dict(rules.mesh.shape) if rules.mesh else None)
    return shapes, ospecs, _with_shardings(shapes, ospecs, rules)


# ----------------------------------------------------------------------
# Steps
# ----------------------------------------------------------------------

def build_train_step(cfg: ModelConfig, rules: MeshRules, *,
                     opt_cfg: AdamWConfig = AdamWConfig(),
                     remat: bool = True, accum_steps: int = 1,
                     q_chunk: int = model_lib.DEFAULT_Q_CHUNK,
                     lr_schedule=warmup_cosine, grad_specs=None,
                     accum_dtype=jnp.float32):
    """Returns train_step(params, opt_state, batch) -> (params', opt', metrics).

    ``accum_steps`` > 1 splits the global batch into microbatches scanned
    sequentially with gradient accumulation — the activation-footprint
    knob (the paper's N-partitions analogue; DESIGN.md §2).  When
    ``grad_specs`` (a spec tree, typically the ZeRO-1 optimizer-state
    specs) is given, the fp32 accumulator is constrained to it, so XLA
    reduce-scatters each microbatch's gradients into a dp-sharded
    accumulator instead of keeping a replicated fp32 copy of the model
    (ZeRO-2-style gradient sharding).
    """

    def loss(params, batch):
        return model_lib.loss_fn(params, batch, cfg, rules, remat=remat,
                                 q_chunk=q_chunk)

    def constrain_grads(g):
        if grad_specs is None or rules.mesh is None:
            return g
        return jax.tree.map(
            lambda x, sp: rules.cs(x, sp) if sp is not None else x,
            g, grad_specs)

    def train_step(params, opt_state, batch):
        if accum_steps == 1:
            (l, metrics), grads = jax.value_and_grad(
                loss, has_aux=True)(params, batch)
        else:
            def micro(carry, mb):
                acc, _ = carry
                (l, m), g = jax.value_and_grad(loss, has_aux=True)(params, mb)
                g = jax.tree.map(lambda x: x.astype(accum_dtype), g)
                acc = constrain_grads(jax.tree.map(jnp.add, acc, g))
                return (acc, l), None

            micro_batches = jax.tree.map(
                lambda x: x.reshape((accum_steps,
                                     x.shape[0] // accum_steps) + x.shape[1:]),
                batch)
            zeros = constrain_grads(jax.tree.map(
                lambda p: jnp.zeros(p.shape, accum_dtype), params))
            (grads, l), _ = jax.lax.scan(micro, (zeros, jnp.float32(0)),
                                         micro_batches)
            grads = jax.tree.map(lambda g: g / accum_steps, grads)
            metrics = {"nll": l, "aux": jnp.float32(0)}

        lr_scale = lr_schedule(opt_state["step"])
        new_params, new_opt, opt_metrics = adamw_lib.adamw_update(
            grads, opt_state, params, opt_cfg, lr_scale=lr_scale)
        metrics = dict(metrics, **opt_metrics, loss=l)
        return new_params, new_opt, metrics

    return train_step


def build_prefill_step(cfg: ModelConfig, rules: MeshRules, *,
                       q_chunk: int = model_lib.DEFAULT_Q_CHUNK):
    def prefill_step(params, batch):
        logits, cache = model_lib.prefill(params, batch, cfg, rules,
                                          q_chunk=q_chunk)
        return jnp.argmax(logits, axis=-1), cache

    return prefill_step


def build_serve_step(cfg: ModelConfig, rules: MeshRules):
    """One-token decode step: greedy next token + updated cache."""

    def serve_step(params, cache, batch):
        logits, new_cache = model_lib.decode_step(params, cache, batch, cfg,
                                                  rules)
        return jnp.argmax(logits, axis=-1).astype(jnp.int32), new_cache

    return serve_step
