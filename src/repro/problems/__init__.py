"""The workload registry, as a package: ``repro.problems``.

    from repro import problems
    problems.list()                    # ('deconvolve', 'lowrank', 'scdl')
    cls = problems.get("scdl")         # -> SCDLProblem
    sol = problems.solve("scdl", S_h, S_l, cfg=SCDLConfig(...))

Thin façade over :mod:`repro.core.problem` (where the registry and the
``solve()`` entry point live so imaging modules can register themselves
without an import cycle).  Importing this package eagerly loads the
built-in workloads, so ``list()`` reflects everything registered.
"""
from repro.core.problem import (Problem, RunOptions, Solution, available,
                                derive_options, get, register, solve)

# eager-register the built-in workloads (core.problem also lazily
# imports these on get(); doing it here keeps list() complete even for
# keys added by future modules that register at import time)
from repro.imaging import deconvolve as _deconvolve  # noqa: F401
from repro.imaging import lowrank as _lowrank        # noqa: F401
from repro.imaging import scdl as _scdl              # noqa: F401


def list() -> tuple:
    """All registered workload keys (shadows the builtin deliberately —
    this namespace is the registry)."""
    return available()


__all__ = ["Problem", "RunOptions", "Solution", "available",
           "derive_options", "get", "list", "register", "solve"]
