"""falcon-mamba-7b — pure Mamba-1 architecture [arXiv:2410.05355].

Assigned spec: 64L d_model=4096 (attn-free) d_ff=0 vocab=65024, ssm_state=16.
Every layer is a mamba-1 mixer (no attention, no FFN: the mixer's gated
in/out projections play the FFN role, d_inner = 2*d_model).
"""
from repro.configs.base import ModelConfig, SSMConfig, register

CONFIG = register(ModelConfig(
    name="falcon-mamba-7b",
    family="ssm",
    n_layers=64,
    d_model=4096,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab_size=65024,
    ssm=SSMConfig(d_state=16, expand=2, d_conv=4),
    attn_free=True,
    tie_embeddings=True,
    source="arXiv:2410.05355; unverified",
    notes="mamba1 arch; RMSNorm on dt/B/C as in falcon-mamba",
))
