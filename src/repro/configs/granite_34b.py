"""granite-34b — llama-arch code model, MQA [arXiv:2405.04324].

Assigned spec: 88L d_model=6144 48H (GQA kv=1 == MQA) d_ff=24576 vocab=49152.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="granite-34b",
    family="dense",
    n_layers=88,
    d_model=6144,
    n_heads=48,
    n_kv_heads=1,
    d_ff=24576,
    vocab_size=49152,
    head_dim=128,
    source="arXiv:2405.04324; hf",
    notes="MQA (single KV head); deepest assigned arch (88L) — PP candidate",
))
