from repro.configs.base import (ARCH_IDS, SHAPES, ModelConfig, MoEConfig,  # noqa
                                SSMConfig, ShapeConfig, all_configs,
                                get_config, reduced, shape_applicable)
