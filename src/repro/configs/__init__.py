from repro.configs.base import (ModelConfig, MoEConfig, SSMConfig,  # noqa
                                ShapeConfig)
