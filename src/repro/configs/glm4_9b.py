"""glm4-9b — dense GQA decoder [hf:THUDM/glm-4-9b].

Assigned spec: 40L d_model=4096 32H (GQA kv=2) d_ff=13696 vocab=151552.
RoPE, GQA with 2 KV heads.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="glm4-9b",
    family="dense",
    n_layers=40,
    d_model=4096,
    n_heads=32,
    n_kv_heads=2,
    d_ff=13696,
    vocab_size=151_552,
    head_dim=128,
    rope_theta=10_000.0,
    source="hf:THUDM/glm-4-9b; hf",
))
