"""musicgen-large — decoder-only over EnCodec tokens [arXiv:2306.05284].

Assigned spec (backbone only): 48L d_model=2048 32H (GQA kv=32 == MHA)
d_ff=8192 vocab=2048.  The EnCodec modality frontend is a STUB per the
assignment: ``input_specs()`` supplies precomputed frame embeddings (the sum
of the 4 codebook embeddings) of shape (batch, seq, d_model); the single
2048-way head predicts the next codebook token.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="musicgen-large",
    family="audio",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab_size=2048,
    head_dim=64,
    frontend="embed",              # precomputed frame embeddings (stub)
    source="arXiv:2306.05284; hf",
))
