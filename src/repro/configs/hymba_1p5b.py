"""hymba-1.5b — hybrid parallel attention + mamba heads [arXiv:2411.13676].

Assigned spec: 32L d_model=1600 25H (GQA kv=5) d_ff=5504 vocab=32001,
ssm_state=16.  Every layer runs attention and a mamba mixer in *parallel*
on the same input and mean-fuses the branch outputs (the paper's "parallel
heads").  Hymba uses full attention on three layers (first/middle/last) and
sliding-window attention elsewhere.
"""
from repro.configs.base import ModelConfig, SSMConfig, register

CONFIG = register(ModelConfig(
    name="hymba-1.5b",
    family="hybrid",
    n_layers=32,
    d_model=1600,
    n_heads=25,
    n_kv_heads=5,
    d_ff=5504,
    vocab_size=32001,
    head_dim=64,                  # 1600 / 25
    sliding_window=1024,
    global_layers=(0, 15, 31),    # full-attention layers per the hymba paper
    ssm=SSMConfig(d_state=16, expand=2, d_conv=4),
    hybrid=True,
    source="arXiv:2411.13676; hf",
    notes="parallel attn+mamba heads, mean-fused; SWA except 3 global layers",
))
