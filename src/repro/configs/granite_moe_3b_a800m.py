"""granite-moe-3b-a800m — fine-grained MoE [hf:ibm-granite/granite-3.0].

Assigned spec: 32L d_model=1536 24H (GQA kv=8) d_ff=512 vocab=49155,
MoE 40 experts top-8.  (The source comment mentions 32 experts; the inline
assigned spec "40e top-8" is taken as authoritative — see DESIGN.md §6.)
"""
from repro.configs.base import ModelConfig, MoEConfig, register

CONFIG = register(ModelConfig(
    name="granite-moe-3b-a800m",
    family="moe",
    n_layers=32,
    d_model=1536,
    n_heads=24,
    n_kv_heads=8,
    d_ff=512,                      # per-expert FFN width (fine-grained)
    vocab_size=49155,
    head_dim=64,
    moe=MoEConfig(n_experts=40, top_k=8),
    tie_embeddings=True,
    source="hf:ibm-granite/granite-3.0-1b-a400m-base scaled; hf",
))
