"""gemma3-27b — dense, 5:1 local:global attention, 128k ctx [hf:google/gemma-3].

Assigned spec: 62L d_model=5376 32H (GQA kv=16) d_ff=21504 vocab=262144.
head_dim=128 per the public gemma-3 configs (not d_model/n_heads).
Local layers use a 1024-token sliding window with theta=10k; global layers
use theta=1M.  qk-norm per gemma3.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="gemma3-27b",
    family="dense",
    n_layers=62,
    d_model=5376,
    n_heads=32,
    n_kv_heads=16,
    d_ff=21504,
    vocab_size=262_144,
    head_dim=128,
    qk_norm=True,
    rope_theta=1_000_000.0,
    rope_theta_local=10_000.0,
    sliding_window=1024,
    local_global_ratio=5,          # 5 local : 1 global
    tie_embeddings=True,
    source="hf:google/gemma-3-1b-pt scaled; unverified",
    notes="5:1 local:global; global-layer KV sequence-sharded for long_500k",
))
