"""internvl2-26b — VLM: InternViT frontend + InternLM2 backbone [arXiv:2404.16821].

Assigned spec (backbone only): 48L d_model=6144 48H (GQA kv=8) d_ff=16384
vocab=92553.  The InternViT modality frontend is a STUB per the assignment:
``input_specs()`` supplies precomputed patch embeddings of shape
(batch, seq, d_model); the backbone consumes them directly.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="internvl2-26b",
    family="vlm",
    n_layers=48,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=16384,
    vocab_size=92553,
    head_dim=128,
    frontend="embed",              # precomputed patch embeddings (stub)
    source="arXiv:2404.16821; hf",
))
