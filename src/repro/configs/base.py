"""Model-shape dataclasses kept for the sharding/data substrate.

The LM architecture registry (10 arch modules, ``get_config``/``reduced``)
was pruned with the rest of the LM surface (DESIGN.md §15);
``parallel/sharding`` and ``data/`` still type against ``ModelConfig``.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Optional, Tuple

# --------------------------------------------------------------------------
# Model configuration
# --------------------------------------------------------------------------

LAYER_GLOBAL = "global"      # full causal attention
LAYER_LOCAL = "local"        # sliding-window causal attention
LAYER_MAMBA = "mamba"        # attention-free mamba-1 mixer
LAYER_HYBRID = "hybrid"      # parallel attention + mamba heads (hymba)


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int = 0               # routed experts
    top_k: int = 0
    n_shared_experts: int = 0        # always-on experts (deepseek)
    capacity_factor: float = 1.25    # per-expert buffer = T*k*cf/E
    router_jitter: float = 0.0

    @property
    def enabled(self) -> bool:
        return self.n_experts > 0


@dataclass(frozen=True)
class SSMConfig:
    d_state: int = 16
    expand: int = 2
    d_conv: int = 4
    dt_rank: int = 0                 # 0 -> ceil(d_model / 16)
    chunk: int = 256                 # selective-scan chunk length
    fused: bool = False              # in-body discretisation (see §Perf)

    def resolved_dt_rank(self, d_model: int) -> int:
        return self.dt_rank or max(1, math.ceil(d_model / 16))


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int                     # query heads (0 for attention-free archs)
    n_kv_heads: int
    d_ff: int                        # dense FFN dim, or per-expert dim for MoE
    vocab_size: int
    head_dim: int = 0                # 0 -> d_model // n_heads
    # attention
    qk_norm: bool = False
    rope_theta: float = 10_000.0
    rope_theta_local: float = 0.0    # separate theta for local layers (gemma3); 0 -> rope_theta
    sliding_window: int = 0          # window for LAYER_LOCAL layers
    local_global_ratio: int = 0      # k -> pattern of k local layers then 1 global; 0 -> all global
    global_layers: Tuple[int, ...] = ()   # explicit global-attn layer ids (hymba style)
    logit_softcap: float = 0.0
    # ssm / hybrid
    ssm: Optional[SSMConfig] = None
    attn_free: bool = False          # falcon-mamba: every layer LAYER_MAMBA
    hybrid: bool = False             # hymba: every layer LAYER_HYBRID
    # moe
    moe: MoEConfig = field(default_factory=MoEConfig)
    # modality frontend:  token | embed (precomputed patch/frame embeddings stub)
    frontend: str = "token"
    tie_embeddings: bool = False
    norm_eps: float = 1e-6
    source: str = ""                 # provenance note
    notes: str = ""

    # ---------------- derived -------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // max(self.n_heads, 1)

    def layer_kind(self, i: int) -> str:
        """Mixer kind of layer ``i`` (static python)."""
        if self.attn_free:
            return LAYER_MAMBA
        if self.hybrid:
            return LAYER_HYBRID
        if self.global_layers:
            return LAYER_GLOBAL if i in self.global_layers else LAYER_LOCAL
        if self.local_global_ratio > 0:
            # pattern: r local layers then 1 global, repeating (gemma3: 5:1)
            return (
                LAYER_GLOBAL
                if (i % (self.local_global_ratio + 1)) == self.local_global_ratio
                else LAYER_LOCAL
            )
        return LAYER_GLOBAL

    @property
    def layer_kinds(self) -> Tuple[str, ...]:
        return tuple(self.layer_kind(i) for i in range(self.n_layers))

    @property
    def uses_attention(self) -> bool:
        return not self.attn_free

    @property
    def uses_ssm(self) -> bool:
        return self.attn_free or self.hybrid

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for the 500k-context decode shape.

        True when no layer keeps an unbounded full-attention KV cache
        (SSM/hybrid archs) or when full-attention layers are a bounded
        minority mixed with windowed layers (gemma3's 5:1 local:global —
        the global-layer KV is sequence-sharded; see DESIGN.md).
        """
        if self.attn_free or self.hybrid:
            return True
        return self.local_global_ratio > 0 and self.sliding_window > 0

    def param_count(self) -> int:
        """Analytic parameter count (total, including embeddings)."""
        return _param_count(self, active_only=False)

    def active_param_count(self) -> int:
        """Params touched per token (MoE: shared + top_k experts)."""
        return _param_count(self, active_only=True)


def _param_count(cfg: ModelConfig, active_only: bool) -> int:
    d = cfg.d_model
    hd = cfg.resolved_head_dim
    total = cfg.vocab_size * d                       # embedding
    if not cfg.tie_embeddings:
        total += cfg.vocab_size * d                  # lm head
    total += d                                       # final norm
    for i in range(cfg.n_layers):
        kind = cfg.layer_kind(i)
        total += 2 * d                               # two pre-norms
        if kind in (LAYER_GLOBAL, LAYER_LOCAL, LAYER_HYBRID):
            q = d * cfg.n_heads * hd
            kv = 2 * d * cfg.n_kv_heads * hd
            o = cfg.n_heads * hd * d
            total += q + kv + o
            if cfg.qk_norm:
                total += 2 * hd
        if kind in (LAYER_MAMBA, LAYER_HYBRID) and cfg.ssm is not None:
            di = cfg.ssm.expand * d
            dtr = cfg.ssm.resolved_dt_rank(d)
            total += d * 2 * di                      # in_proj (x, z)
            total += di * cfg.ssm.d_conv             # depthwise conv
            total += di * (dtr + 2 * cfg.ssm.d_state)  # x_proj
            total += dtr * di + di                   # dt_proj (+bias)
            total += di * cfg.ssm.d_state + di       # A_log, D
            total += di * d                          # out_proj
        if kind != LAYER_MAMBA:                      # FFN present
            if cfg.moe.enabled:
                n_routed = cfg.moe.top_k if active_only else cfg.moe.n_experts
                total += n_routed * 3 * d * cfg.d_ff
                total += cfg.moe.n_shared_experts * 3 * d * cfg.d_ff
                total += d * cfg.moe.n_experts       # router
            else:
                total += 3 * d * cfg.d_ff            # SwiGLU w1,w3,w2
    return total


# --------------------------------------------------------------------------
# Input shapes (assigned to the LM family; every arch pairs with all four,
# modulo the documented skips)
# --------------------------------------------------------------------------

@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                        # train | prefill | decode

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch
