"""deepseek-moe-16b — fine-grained MoE, shared experts [arXiv:2401.06066].

Assigned spec: 28L d_model=2048 16H (GQA kv=16 == MHA) d_ff=1408
vocab=102400, MoE 64 routed top-6 + 2 shared experts.
"""
from repro.configs.base import ModelConfig, MoEConfig, register

CONFIG = register(ModelConfig(
    name="deepseek-moe-16b",
    family="moe",
    n_layers=28,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,                     # per-expert FFN width (fine-grained)
    vocab_size=102_400,
    head_dim=128,
    moe=MoEConfig(n_experts=64, top_k=6, n_shared_experts=2),
    source="arXiv:2401.06066; hf",
))
