"""Minimal stdlib client for the repro.serve HTTP frontend.

``urllib`` only — the client mirrors the transport's endpoint set
(submit / status / result / cancel / events / metrics) and adds the two
conveniences every caller wants: blocking ``result()`` polling and a
line-iterator over the progress stream.  Arrays go over the wire as
nested lists (``numpy`` ``.tolist()``).
"""
from __future__ import annotations

import json
import time
import urllib.error
import urllib.request
from typing import Any, Dict, Iterator, Optional, Sequence

import numpy as np


class ServeError(RuntimeError):
    """Non-2xx response; ``status`` is the HTTP code, ``payload`` the
    decoded JSON body (``retriable`` inside it marks admission-control
    refusals safe to resubmit)."""

    def __init__(self, status: int, payload: dict):
        super().__init__(f"HTTP {status}: {payload.get('error')}")
        self.status = status
        self.payload = payload

    @property
    def retriable(self) -> bool:
        return bool(self.payload.get("retriable"))


class ServeClient:
    def __init__(self, base_url: str, timeout: float = 60.0):
        self.base_url = base_url.rstrip("/")
        self.timeout = timeout

    # ------------------------------------------------------ transport
    def _call(self, method: str, path: str,
              body: Optional[dict] = None) -> dict:
        data = json.dumps(body).encode() if body is not None else None
        req = urllib.request.Request(
            self.base_url + path, data=data, method=method,
            headers={"Content-Type": "application/json"})
        try:
            with urllib.request.urlopen(req, timeout=self.timeout) as r:
                return json.loads(r.read().decode())
        except urllib.error.HTTPError as e:
            payload = {}
            try:
                payload = json.loads(e.read().decode())
            except (ValueError, UnicodeDecodeError):
                payload = {"error": str(e)}
            raise ServeError(e.code, payload) from None

    # ------------------------------------------------------ endpoints
    def submit(self, problem: str, inputs: Sequence[Any], *,
               cfg: Optional[dict] = None,
               options: Optional[dict] = None,
               chaos: Optional[str] = None,
               deadline_s: Optional[float] = None) -> str:
        """Submit one request; returns its id.  ``cfg``/``options`` are
        plain dicts (see ``serve.codec``); ``deadline_s`` is a
        wall-clock budget from admission — past it, a running request
        is frozen at the next chunk boundary and fails with a deadline
        error.  Raises :class:`ServeError` with ``retriable=True`` on
        admission refusal (queue full, drain, or open breaker)."""
        body = {"problem": problem,
                "inputs": [np.asarray(x).tolist() for x in inputs]}
        if cfg is not None:
            body["cfg"] = cfg
        if options is not None:
            body["options"] = options
        if chaos is not None:
            body["chaos"] = chaos
        if deadline_s is not None:
            body["deadline_s"] = float(deadline_s)
        return self._call("POST", "/v1/requests", body)["id"]

    def status(self, request_id: str) -> dict:
        return self._call("GET", f"/v1/requests/{request_id}")

    def result(self, request_id: str, *, include_x: bool = False,
               poll_s: float = 0.05,
               timeout: Optional[float] = None) -> dict:
        """Poll until the request is terminal, then fetch the result."""
        deadline = None if timeout is None else time.time() + timeout
        suffix = "/result" + ("?include_x=1" if include_x else "")
        while True:
            try:
                return self._call("GET",
                                  f"/v1/requests/{request_id}{suffix}")
            except ServeError as e:
                if e.status != 409:          # 409 = still running
                    raise
            if deadline is not None and time.time() > deadline:
                raise TimeoutError(
                    f"request {request_id} not finished after "
                    f"{timeout}s")
            time.sleep(poll_s)

    def cancel(self, request_id: str) -> bool:
        try:
            return bool(self._call(
                "POST", f"/v1/requests/{request_id}/cancel")["cancelled"])
        except ServeError as e:
            if e.status == 409:
                return False
            raise

    def events(self, request_id: str) -> Iterator[Dict]:
        """Iterate live progress events (newline-delimited JSON); the
        final item is the ``{"kind": "end", ...}`` terminal marker."""
        req = urllib.request.Request(
            self.base_url + f"/v1/requests/{request_id}/events")
        with urllib.request.urlopen(req, timeout=self.timeout) as r:
            for line in r:
                line = line.strip()
                if line:
                    yield json.loads(line.decode())

    def metrics(self) -> dict:
        return self._call("GET", "/v1/metrics")

    def health(self) -> dict:
        return self._call("GET", "/v1/healthz")

    def ready(self) -> dict:
        """Readiness probe; the 503-while-not-ready response body is
        returned (not raised) so callers can inspect the detail."""
        try:
            return self._call("GET", "/v1/readyz")
        except ServeError as e:
            if e.status == 503:
                return e.payload
            raise

    def drain(self) -> dict:
        return self._call("POST", "/v1/admin/drain")
