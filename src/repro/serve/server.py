"""Thin stdlib JSON-over-HTTP transport for :class:`AsyncSolveService`.

No new dependencies: ``http.server.ThreadingHTTPServer`` handles
connections on worker threads and bridges every call onto the service's
asyncio loop via ``asyncio.run_coroutine_threadsafe`` (the
:class:`ServiceRunner` owns that loop on a dedicated thread, so the same
runner also serves in-process callers — benchmarks, tests, notebooks —
without HTTP in the way).

Endpoints (all JSON):

- ``POST /v1/requests``                  — submit ``{problem, inputs,
  cfg?, options?, chaos?}``; 202 with ``{id, status}``, 503 with
  ``retriable: true`` when admission control refuses, 400 when the
  request is malformed.
- ``GET  /v1/requests/<id>``             — status record.
- ``GET  /v1/requests/<id>/result``      — terminal result (costs,
  convergence, timing percentiles, optional ``?include_x=1`` payload);
  409 while the request is still queued/running.
- ``POST /v1/requests/<id>/cancel``      — cancel a queued request.
- ``GET  /v1/requests/<id>/events``      — progress stream: newline-
  delimited JSON chunk events relayed live from the driver's
  ``progress_fn``, terminated by a ``{"kind": "end", ...}`` line.
- ``GET  /v1/metrics`` / ``GET /v1/healthz`` — metrics snapshot (incl.
  per-workload breaker states) / liveness (+ drain/crash state).
- ``GET  /v1/readyz``                    — readiness: 200 when the
  service can usefully take traffic, 503 (with detail) while draining,
  crashed, queue-full, or a workload breaker is open (§21).
- ``POST /v1/admin/drain``               — graceful drain (in-flight
  finishes, queued rejected retriable).

Input arrays arrive as nested JSON lists and are decoded as float32
(override per input with ``{"data": ..., "dtype": "..."}``); workload
configs arrive as plain dicts and are decoded through the per-workload
config dataclass.  The codecs live in ``serve.codec`` (shared with the
request journal) and are re-exported here for compatibility.
"""
from __future__ import annotations

import asyncio
import json
import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Optional, Tuple

import numpy as np

# re-exported: the journal shares these codecs (see serve.codec)
from repro.serve.codec import (decode_config, decode_inputs,  # noqa: F401
                               decode_options)
from repro.serve.service import (AsyncSolveService, RequestRejected,
                                 RequestRecord, ServeConfig,
                                 SolveRequest)


def decode_request(payload: dict) -> SolveRequest:
    if "problem" not in payload or "inputs" not in payload:
        raise ValueError('request body needs "problem" and "inputs"')
    problem = payload["problem"]
    deadline = payload.get("deadline_s")
    return SolveRequest(
        problem=problem,
        inputs=decode_inputs(payload["inputs"]),
        cfg=decode_config(problem, payload.get("cfg")),
        options=decode_options(payload.get("options")),
        chaos_spec=payload.get("chaos"),
        deadline_s=float(deadline) if deadline is not None else None)


def _tree_to_lists(x):
    import jax
    return jax.tree.map(lambda a: np.asarray(a).tolist(), x)


def encode_result(rec: RequestRecord, include_x: bool = False) -> dict:
    out = rec.public()
    sol = rec.solution
    if sol is not None:
        out["costs"] = [float(c) for c in sol.log.costs]
        out["converged_at"] = sol.log.converged_at
        out["iters_run"] = sol.log.iters_run
        out["time_percentiles_s"] = sol.percentiles()
        if include_x:
            out["x"] = _tree_to_lists(sol.x)
    # prefer the per-request ledger (§21: sliced from the bucket's
    # shared report, or attached by the quarantine solo re-run) over
    # the raw Solution report
    rep = rec.recovery if rec.recovery is not None else \
        (sol.recovery if sol is not None else None)
    if rep is not None:
        out["recovery"] = rep.to_json()
    return out


class ServiceRunner:
    """Owns an event loop on a daemon thread and runs one
    :class:`AsyncSolveService` on it; every method is thread-safe, so
    HTTP handler threads (and plain synchronous callers) can drive the
    asyncio core directly."""

    def __init__(self, config: Optional[ServeConfig] = None, *,
                 service: Optional[AsyncSolveService] = None, mesh=None):
        self.service = service or AsyncSolveService(config, mesh=mesh)
        self._loop = asyncio.new_event_loop()
        self._thread = threading.Thread(
            target=self._loop.run_forever, daemon=True,
            name="repro-serve-loop")
        self._thread.start()
        self.call(self.service.start())

    def call(self, coro, timeout: Optional[float] = None):
        return asyncio.run_coroutine_threadsafe(
            coro, self._loop).result(timeout)

    # thin sync facade over the service coroutines
    def submit(self, request: SolveRequest) -> RequestRecord:
        return self.call(self.service.submit(request))

    def record(self, request_id: str) -> RequestRecord:
        return self.service.record(request_id)

    def result(self, request_id: str,
               timeout: Optional[float] = None) -> RequestRecord:
        return self.call(self.service.result(request_id, timeout))

    def wait_events(self, request_id: str, cursor: int,
                    timeout: float = 0.5):
        return self.call(
            self.service.wait_events(request_id, cursor, timeout))

    def cancel(self, request_id: str) -> bool:
        return self.call(self.service.cancel(request_id))

    def drain(self) -> dict:
        return self.call(self.service.drain())

    def shutdown(self) -> None:
        """Drain the service, stop the loop thread."""
        self.call(self.service.close())
        self._loop.call_soon_threadsafe(self._loop.stop)
        self._thread.join(timeout=10)


class _Handler(BaseHTTPRequestHandler):
    # HTTP/1.0: the events endpoint streams until EOF with no chunked
    # framing, which every stdlib/urllib client reads correctly
    protocol_version = "HTTP/1.0"
    server_version = "repro-serve/1.0"

    # ------------------------------------------------------- plumbing
    @property
    def runner(self) -> ServiceRunner:
        return self.server.runner            # type: ignore[attr-defined]

    def log_message(self, fmt, *args):       # quiet by default
        if getattr(self.server, "verbose", False):
            super().log_message(fmt, *args)

    def _json(self, code: int, payload: dict) -> None:
        body = json.dumps(payload).encode()
        self.send_response(code)
        self.send_header("Content-Type", "application/json")
        self.send_header("Content-Length", str(len(body)))
        self.end_headers()
        self.wfile.write(body)

    def _read_body(self) -> dict:
        n = int(self.headers.get("Content-Length") or 0)
        raw = self.rfile.read(n) if n else b"{}"
        return json.loads(raw.decode() or "{}")

    def _split(self):
        path, _, query = self.path.partition("?")
        q = dict(p.partition("=")[::2] for p in query.split("&") if p)
        return [p for p in path.split("/") if p], q

    # --------------------------------------------------------- routes
    def do_GET(self) -> None:  # noqa: N802  (stdlib handler contract)
        parts, q = self._split()
        try:
            if parts == ["v1", "metrics"]:
                svc = self.runner.service
                snap = svc.metrics.snapshot()
                snap["breakers"] = svc.breaker_states()
                return self._json(200, snap)
            if parts == ["v1", "healthz"]:
                svc = self.runner.service
                return self._json(200, {
                    "ok": not svc.crashed, "draining": svc.draining,
                    "crashed": svc.crashed,
                    "queue_depth": svc.metrics.queue_depth})
            if parts == ["v1", "readyz"]:
                ok, detail = self.runner.service.ready()
                return self._json(200 if ok else 503,
                                  {"ready": ok, **detail})
            if len(parts) == 3 and parts[:2] == ["v1", "requests"]:
                rec = self.runner.record(parts[2])
                return self._json(200, rec.public())
            if len(parts) == 4 and parts[:2] == ["v1", "requests"] \
                    and parts[3] == "result":
                return self._result(parts[2], q)
            if len(parts) == 4 and parts[:2] == ["v1", "requests"] \
                    and parts[3] == "events":
                return self._stream_events(parts[2])
        except KeyError as e:
            return self._json(404, {"error": str(e)})
        self._json(404, {"error": f"no route for GET {self.path}"})

    def do_POST(self) -> None:  # noqa: N802
        parts, _ = self._split()
        try:
            if parts == ["v1", "requests"]:
                return self._submit()
            if len(parts) == 4 and parts[:2] == ["v1", "requests"] \
                    and parts[3] == "cancel":
                ok = self.runner.cancel(parts[2])
                return self._json(200 if ok else 409,
                                  {"id": parts[2], "cancelled": ok})
            if parts == ["v1", "admin", "drain"]:
                return self._json(200, self.runner.drain())
        except KeyError as e:
            return self._json(404, {"error": str(e)})
        self._json(404, {"error": f"no route for POST {self.path}"})

    # -------------------------------------------------- route bodies
    def _submit(self) -> None:
        try:
            request = decode_request(self._read_body())
        except (ValueError, TypeError, KeyError,
                json.JSONDecodeError) as e:
            return self._json(400, {"error": f"{e}", "retriable": False})
        try:
            rec = self.runner.submit(request)
        except RequestRejected as e:
            # admission refusal: 503 + retriable when load/drain-shaped
            code = 503 if e.retriable else 400
            return self._json(code, {
                "id": e.record.id, "status": e.record.status,
                "error": e.record.error, "retriable": e.retriable})
        self._json(202, {"id": rec.id, "status": rec.status})

    def _result(self, rid: str, q: dict) -> None:
        rec = self.runner.record(rid)
        if not rec.done.is_set():
            return self._json(409, {
                "id": rid, "status": rec.status,
                "error": "request not finished; poll status or stream "
                         "events"})
        include_x = q.get("include_x", "0") not in ("0", "", "false")
        code = {"done": 200, "cancelled": 410,
                "rejected": 503 if rec.retriable else 400}.get(
                    rec.status, 500)
        self._json(code, encode_result(rec, include_x=include_x))

    def _stream_events(self, rid: str) -> None:
        rec = self.runner.record(rid)       # 404 before headers go out
        self.send_response(200)
        self.send_header("Content-Type", "application/x-ndjson")
        self.end_headers()
        cursor = 0
        while True:
            events, done, cursor = self.runner.wait_events(
                rid, cursor, timeout=0.5)
            for e in events:
                self.wfile.write((json.dumps(e) + "\n").encode())
            self.wfile.flush()
            if done and cursor >= len(rec.events):
                end = {"kind": "end", "status": rec.status,
                       "error": rec.error}
                self.wfile.write((json.dumps(end) + "\n").encode())
                self.wfile.flush()
                return


class ServerHandle:
    """A running HTTP frontend; ``close()`` is the graceful-shutdown
    path (stop accepting connections, drain the service)."""

    def __init__(self, httpd: ThreadingHTTPServer, runner: ServiceRunner,
                 thread: threading.Thread, owns_runner: bool):
        self.httpd = httpd
        self.runner = runner
        self._thread = thread
        self._owns_runner = owns_runner

    @property
    def address(self) -> Tuple[str, int]:
        return self.httpd.server_address[:2]

    @property
    def url(self) -> str:
        host, port = self.address
        return f"http://{host}:{port}"

    def close(self) -> None:
        self.httpd.shutdown()
        self.httpd.server_close()
        self._thread.join(timeout=10)
        if self._owns_runner:
            self.runner.shutdown()

    def __enter__(self) -> "ServerHandle":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


def serve_http(config: Optional[ServeConfig] = None, *,
               runner: Optional[ServiceRunner] = None,
               host: str = "127.0.0.1", port: int = 0,
               verbose: bool = False, mesh=None) -> ServerHandle:
    """Start the HTTP frontend on a daemon thread (``port=0`` binds an
    ephemeral port — read it back from ``handle.address``).  Pass an
    existing ``runner`` to share a service between transports; otherwise
    one is created and owned (and drained) by the returned handle."""
    owns = runner is None
    runner = runner or ServiceRunner(config, mesh=mesh)
    httpd = ThreadingHTTPServer((host, port), _Handler)
    httpd.daemon_threads = True
    httpd.runner = runner                    # type: ignore[attr-defined]
    httpd.verbose = verbose                  # type: ignore[attr-defined]
    thread = threading.Thread(target=httpd.serve_forever, daemon=True,
                              name="repro-serve-http")
    thread.start()
    return ServerHandle(httpd, runner, thread, owns_runner=owns)
