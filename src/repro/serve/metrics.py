"""Serving metrics registry (DESIGN.md §20).

One thread-safe accumulator per service: request counters, a queue-depth
gauge, dispatch batch occupancy, and end-to-end request latencies
summarized by the same :func:`repro.core.driver.percentiles` helper a
``RunLog``/``Solution`` uses for per-iteration wall times — a server and
a single run report p50/p99 the same way.

Everything is plain counters and bounded deques: ``record_*`` calls are
cheap enough for the request hot path (they run on the service loop and
on executor worker threads, hence the lock), and ``snapshot()`` returns
a JSON-ready dict for the ``/v1/metrics`` endpoint and
``BENCH_serve.json``.
"""
from __future__ import annotations

import threading
import time
from collections import deque
from typing import Dict, Optional

from repro.core.driver import percentiles

#: counters every service exposes; ``rejected`` counts admission-control
#: refusals (queue full / draining) — those are retriable by contract.
#: §21 resilience counters: ``shed`` (circuit-breaker refusals, a
#: subset of ``rejected``), ``expired`` (deadline exceeded in flight),
#: ``quarantined`` (poison buckets re-dispatched solo), ``replayed``
#: (requests re-admitted from the journal on restart), and ``hung``
#: (dispatches reaped by the watchdog timeout)
COUNTERS = ("submitted", "accepted", "rejected", "cancelled",
            "dispatched", "completed", "failed",
            "shed", "expired", "quarantined", "replayed", "hung")


class Metrics:
    """Thread-safe serving metrics for one :class:`AsyncSolveService`."""

    def __init__(self, window: int = 2048):
        self._lock = threading.Lock()
        self._t0 = time.perf_counter()
        self._counters: Dict[str, int] = {k: 0 for k in COUNTERS}
        self._queue_depth = 0
        # bounded sample windows: latency in seconds (submit -> done),
        # occupancy in requests per dispatched batch
        self._latencies = deque(maxlen=window)
        self._batch_sizes = deque(maxlen=window)

    # ------------------------------------------------------- recording
    def incr(self, name: str, n: int = 1) -> None:
        with self._lock:
            self._counters[name] += n

    def queue_delta(self, delta: int) -> None:
        """Adjust the queued+running depth gauge."""
        with self._lock:
            self._queue_depth += delta

    def record_batch(self, size: int) -> None:
        with self._lock:
            self._counters["dispatched"] += 1
            self._batch_sizes.append(int(size))

    def record_latency(self, seconds: float) -> None:
        with self._lock:
            self._latencies.append(float(seconds))

    # ------------------------------------------------------- reporting
    @property
    def queue_depth(self) -> int:
        with self._lock:
            return self._queue_depth

    def counter(self, name: str) -> int:
        with self._lock:
            return self._counters[name]

    def snapshot(self, now: Optional[float] = None) -> dict:
        """JSON-ready view: counters, depth gauge, throughput over the
        service's lifetime, batch-occupancy stats, latency percentiles."""
        with self._lock:
            uptime = max((now or time.perf_counter()) - self._t0, 1e-9)
            sizes = list(self._batch_sizes)
            lats = list(self._latencies)
            counters = dict(self._counters)
            depth = self._queue_depth
        return {
            "uptime_s": round(uptime, 3),
            "counters": counters,
            "queue_depth": depth,
            "requests_per_s": round(counters["completed"] / uptime, 3),
            "batch_occupancy": {
                "mean": (round(sum(sizes) / len(sizes), 3)
                         if sizes else None),
                "max": max(sizes) if sizes else None,
                "batches": len(sizes),
            },
            "latency_s": {k: round(v, 6) for k, v in
                          percentiles(lats, (50, 90, 99)).items()},
        }
