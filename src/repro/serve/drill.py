"""Serve-level chaos drills (DESIGN.md §21) — the CI gate for
end-to-end serving resilience.

Three scenarios, each asserting the §21 isolation contract against the
real solver stack on tiny deconvolution instances:

- ``poison-bucket`` — a ``serve_bucket_poison`` fault NaN-poisons one
  lane of a coalesced dispatch; the bucket fails as a unit, quarantine
  re-dispatches every lane solo.  Assert: the poisoned request fails
  with a per-request recovery report attached; every sibling completes
  with rtol 1e-4 trajectory parity against its unfaulted direct run.
- ``deadline-storm`` — a burst of requests with deadlines too tight for
  their iteration budget, coalesced with undeadlined traffic.  Assert:
  the tight-deadline requests fail with the deadline error (frozen at a
  chunk boundary, i.e. before their full iteration count); the
  undeadlined siblings complete with trajectory parity.
- ``kill-and-restart`` — a journaled, checkpointed service takes a
  coalesced bucket plus an admitted-but-never-scheduled request
  (``serve_admit_drop``), then ``serve_crash`` kills it mid-bucket.
  A second service started over the same journal replays everything.
  Assert: every request completes (``replayed=True``), the resumed
  bucket's cost trajectory matches the reference suffix at rtol 1e-4,
  and final iterates match.

Run as a module::

    PYTHONPATH=src python -m repro.serve.drill --scenario all \
        --report serve_drill.json

Exit status is non-zero when any assertion fails; ``--report`` writes a
JSON artifact with per-scenario outcomes and the recovery reports the
drills produced (the CI job uploads it).
"""
from __future__ import annotations

import argparse
import asyncio
import json
import sys
import tempfile
import time
from pathlib import Path
from typing import List, Optional, Tuple

import numpy as np

ITERS, CHUNK = 6, 2
RTOL = 1e-4


# ------------------------------------------------------------ fixtures
def _instances(specs=None):
    import jax
    from repro.imaging import psf as psf_op
    out = []
    for seed, (n, stamp) in enumerate(specs or [(3, 16), (5, 16),
                                                (3, 20)]):
        d = psf_op.simulate(n, jax.random.PRNGKey(seed), stamp=stamp)
        out.append((d.Y, d.psfs))
    return out


def _cfg(max_iter: int = ITERS):
    from repro.imaging.condat import SolverConfig
    return SolverConfig(mode="sparse", max_iter=max_iter, tol=0.0,
                        n_scales=2)


def _options():
    return dict(chunk=CHUNK, cost_every=1)


def _direct(inputs, max_iter: int = ITERS):
    from repro.core.problem import solve
    return solve("deconvolve", *inputs, cfg=_cfg(max_iter),
                 **_options())


def _req(inputs, *, options=None, deadline_s=None, max_iter=ITERS):
    from repro.serve import SolveRequest
    return SolveRequest("deconvolve", inputs, cfg=_cfg(max_iter),
                        options=options or _options(),
                        deadline_s=deadline_s)


def _assert_parity(rec, ref, *, what: str) -> None:
    """Full-trajectory parity: costs and final iterate."""
    assert rec.status == "done", \
        f"{what}: expected done, got {rec.status} ({rec.error})"
    got = np.asarray(rec.solution.log.costs)
    want = np.asarray(ref.log.costs)
    assert got.shape == want.shape, \
        f"{what}: trajectory length {got.shape} vs {want.shape}"
    np.testing.assert_allclose(got, want, rtol=RTOL, err_msg=what)
    _assert_x_parity(rec.solution, ref, what=what)


def _assert_suffix_parity(rec, ref, *, what: str) -> None:
    """Resumed-run parity: the replayed bucket restores from a mid-run
    checkpoint, so its log covers only the post-resume iterations —
    they must match the reference trajectory's suffix."""
    assert rec.status == "done", \
        f"{what}: expected done, got {rec.status} ({rec.error})"
    got = np.asarray(rec.solution.log.costs)
    want = np.asarray(ref.log.costs)
    assert 0 < got.size <= want.size, \
        f"{what}: resumed trajectory length {got.size} vs {want.size}"
    np.testing.assert_allclose(got, want[-got.size:], rtol=RTOL,
                               err_msg=what)
    _assert_x_parity(rec.solution, ref, what=what)


def _assert_x_parity(sol, ref, *, what: str) -> None:
    import jax
    for a, b in zip(jax.tree.leaves(sol.x), jax.tree.leaves(ref.x)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=RTOL, atol=1e-6, err_msg=what)


def _recovery_json(rec) -> Optional[dict]:
    return rec.recovery.to_json() if rec.recovery is not None else None


# ------------------------------------------------------------ scenarios
def drill_poison_bucket() -> dict:
    from repro.resilience.recovery import ResilienceConfig
    from repro.serve import AsyncSolveService, ServeConfig

    # same stamp everywhere so all three lanes coalesce into ONE bucket
    insts = _instances([(3, 16), (5, 16), (4, 16)])
    refs = [_direct(i) for i in insts]
    # one lane of the coalesced bucket is poisoned; ring stays small so
    # the rollback loop exhausts fast (NaN is in the input, rollback
    # cannot cure it)
    res = ResilienceConfig(max_rollbacks=2, backoff_s=0.001, ring=2)

    async def run():
        cfg = ServeConfig(batch_window_s=0.5, max_batch=8,
                          chaos_spec="serve_bucket_poison@0;seed=7")
        svc = AsyncSolveService(cfg)
        await svc.start()
        opts = _options()
        opts["resilience"] = res
        recs = [await svc.submit(_req(i, options=dict(opts)))
                for i in insts]
        out = [await svc.result(r.id, timeout=600) for r in recs]
        metrics = svc.metrics.snapshot()
        await svc.close()
        return out, metrics

    out, metrics = asyncio.run(run())
    keys = {r.bucket_key for r in out}
    assert len(keys) == 1 and out[0].batch_size == len(out), \
        f"drill lanes did not coalesce into one bucket: {keys}"
    failed = [r for r in out if r.status == "failed"]
    assert len(failed) == 1, \
        f"exactly one lane should fail, got {len(failed)}"
    poisoned = failed[0]
    assert poisoned.quarantined, "poisoned lane not quarantined"
    assert poisoned.recovery is not None, \
        "poisoned lane has no per-request recovery report"
    assert poisoned.recovery.rollbacks >= 1, \
        "recovery report records no rollback attempts"
    siblings = [(r, ref) for r, ref in zip(out, refs)
                if r.id != poisoned.id]
    for rec, ref in siblings:
        assert rec.quarantined, "sibling missed the quarantine re-run"
        _assert_parity(rec, ref, what=f"quarantined sibling {rec.id}")
    assert metrics["counters"]["quarantined"] == 1
    return {
        "poisoned": {"id": poisoned.id, "status": poisoned.status,
                     "error": poisoned.error,
                     "recovery": _recovery_json(poisoned)},
        "siblings_done": [r.id for r, _ in siblings],
        "counters": metrics["counters"],
    }


def drill_deadline_storm() -> dict:
    from repro.serve import AsyncSolveService, ServeConfig

    insts = _instances([(3, 16), (5, 16), (3, 20), (4, 20)])
    refs = [_direct(i) for i in insts[:2]]
    long_iters = 600

    async def run():
        cfg = ServeConfig(batch_window_s=0.5, max_batch=8)
        svc = AsyncSolveService(cfg)
        await svc.start()
        # two undeadlined controls coalesce with two doomed requests
        # whose deadline cannot cover their iteration budget
        recs = [await svc.submit(_req(insts[0])),
                await svc.submit(_req(insts[1]))]
        doomed = [await svc.submit(_req(i, max_iter=long_iters,
                                        deadline_s=0.5))
                  for i in insts[2:]]
        out = [await svc.result(r.id, timeout=600)
               for r in recs + doomed]
        metrics = svc.metrics.snapshot()
        await svc.close()
        return out, metrics

    out, metrics = asyncio.run(run())
    controls, doomed = out[:2], out[2:]
    for rec, ref in zip(controls, refs):
        _assert_parity(rec, ref, what=f"deadline-storm control {rec.id}")
    for rec in doomed:
        assert rec.status == "failed" and "deadline" in rec.error, \
            f"doomed request: {rec.status} / {rec.error}"
        chunks = [e for e in rec.events if e.get("kind") == "chunk"]
        iters_seen = max((e["done"] for e in chunks), default=0)
        assert iters_seen < long_iters, \
            "expired lane ran to completion instead of freezing"
    assert metrics["counters"]["expired"] == len(doomed)
    return {
        "controls_done": [r.id for r in controls],
        "expired": [{"id": r.id, "error": r.error} for r in doomed],
        "counters": metrics["counters"],
    }


def drill_kill_and_restart(workdir: Optional[str] = None) -> dict:
    from repro.serve import AsyncSolveService, ServeConfig

    base = Path(workdir or tempfile.mkdtemp(prefix="repro-drill-"))
    journal_dir = str(base / "journal")
    ckpt_dir = str(base / "ckpt")
    insts = _instances()
    refs = [_direct(i) for i in insts]

    def mk_cfg(chaos: Optional[str]) -> "ServeConfig":
        return ServeConfig(batch_window_s=0.5, max_batch=8,
                           journal_dir=journal_dir,
                           checkpoint_dir=ckpt_dir, checkpoint_every=2,
                           chaos_spec=chaos)

    async def phase1():
        # admit 2 coalescing requests; the 3rd is journaled but never
        # scheduled (serve_admit_drop); the crash lands mid-bucket
        svc = AsyncSolveService(
            mk_cfg("serve_admit_drop@2;serve_crash@1;seed=5"))
        await svc.start()
        ids = []
        for i in insts:
            rec = await svc.submit(_req(i))
            ids.append(rec.id)
        t0 = time.monotonic()
        while not svc.crashed and time.monotonic() - t0 < 120:
            await asyncio.sleep(0.05)
        crashed = svc.crashed
        await svc.abandon()
        return ids, crashed

    ids, crashed = asyncio.run(phase1())
    assert crashed, "serve_crash never fired — drill misconfigured"

    async def phase2():
        svc = AsyncSolveService(mk_cfg(None))
        await svc.start()
        out = [await svc.result(i, timeout=600) for i in ids]
        metrics = svc.metrics.snapshot()
        await svc.close()
        return out, metrics

    out, metrics = asyncio.run(phase2())
    resumed = 0
    for rec, ref in zip(out, refs):
        assert rec.replayed, f"request {rec.id} not replayed"
        if rec.solution is not None and \
                len(rec.solution.log.costs) < len(ref.log.costs):
            _assert_suffix_parity(rec, ref,
                                  what=f"resumed request {rec.id}")
            resumed += 1
        else:
            _assert_parity(rec, ref, what=f"replayed request {rec.id}")
    assert metrics["counters"]["replayed"] == len(ids)
    return {
        "replayed": [r.id for r in out],
        "resumed_from_checkpoint": resumed,
        "counters": metrics["counters"],
    }


SCENARIOS = {
    "poison-bucket": drill_poison_bucket,
    "deadline-storm": drill_deadline_storm,
    "kill-and-restart": drill_kill_and_restart,
}


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        description="repro.serve chaos drills (DESIGN.md §21)")
    ap.add_argument("--scenario", default="all",
                    choices=["all"] + sorted(SCENARIOS))
    ap.add_argument("--report", default=None,
                    help="write a JSON artifact of drill outcomes here")
    args = ap.parse_args(argv)

    names = sorted(SCENARIOS) if args.scenario == "all" \
        else [args.scenario]
    report, failed = {}, []
    for name in names:
        t0 = time.perf_counter()
        try:
            detail = SCENARIOS[name]()
            report[name] = {"ok": True, "detail": detail}
            verdict = "ok"
        except AssertionError as e:
            report[name] = {"ok": False, "error": str(e)}
            failed.append(name)
            verdict = f"FAILED: {e}"
        report[name]["elapsed_s"] = round(time.perf_counter() - t0, 3)
        print(f"[drill] {name}: {verdict} "
              f"({report[name]['elapsed_s']}s)")
    if args.report:
        Path(args.report).write_text(json.dumps(report, indent=2))
        print(f"[drill] report -> {args.report}")
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
