"""Per-workload circuit breaker for load shedding (DESIGN.md §21).

A sliding window of recent dispatch outcomes drives the classic
closed → open → half-open state machine:

- **closed** — normal service; every outcome lands in the window.  When
  the window holds at least ``min_samples`` outcomes and the error rate
  reaches ``error_threshold``, the breaker trips open.
- **open** — submits for this workload are shed with the *retriable*
  rejection (clients back off; siblings on other workloads are
  unaffected).  After ``cooldown_s`` the next ``allow()`` admits one
  probe request and moves to half-open.
- **half-open** — exactly one probe in flight; its success closes the
  breaker (window cleared — stale failures must not re-trip it), its
  failure re-opens and restarts the cooldown.

All methods are called from the service's event loop (or its executor
callbacks holding the GIL); the breaker itself is lock-free.
"""
from __future__ import annotations

import time
from collections import deque
from typing import Callable, Optional

CLOSED, OPEN, HALF_OPEN = "closed", "open", "half_open"


class CircuitBreaker:
    def __init__(self, *, window: int = 32, min_samples: int = 8,
                 error_threshold: float = 0.5, cooldown_s: float = 5.0,
                 clock: Callable[[], float] = time.monotonic):
        self.window = int(window)
        self.min_samples = int(min_samples)
        self.error_threshold = float(error_threshold)
        self.cooldown_s = float(cooldown_s)
        self._clock = clock
        self._outcomes: deque = deque(maxlen=self.window)   # bools: ok?
        self._latencies: deque = deque(maxlen=self.window)
        self._state = CLOSED
        self._opened_at: Optional[float] = None
        self._probe_inflight = False

    @property
    def state(self) -> str:
        return self._state

    def allow(self) -> bool:
        """May a new request for this workload be admitted now?"""
        if self._state == CLOSED:
            return True
        if self._state == OPEN:
            if (self._clock() - self._opened_at) < self.cooldown_s:
                return False
            self._state = HALF_OPEN
            self._probe_inflight = True
            return True
        # half-open: one probe at a time
        if self._probe_inflight:
            return False
        self._probe_inflight = True
        return True

    def record(self, ok: bool,
               latency_s: Optional[float] = None) -> None:
        """Feed one dispatch outcome.  ``ok`` means the request is not
        evidence of service trouble — completions, cancels and deadline
        expiries count as ok; solver/infrastructure failures do not."""
        if latency_s is not None:
            self._latencies.append(float(latency_s))
        if self._state == HALF_OPEN:
            self._probe_inflight = False
            if ok:
                self._state = CLOSED
                self._outcomes.clear()
            else:
                self._state = OPEN
                self._opened_at = self._clock()
            return
        self._outcomes.append(bool(ok))
        if self._state == CLOSED and self._tripped():
            self._state = OPEN
            self._opened_at = self._clock()

    def _tripped(self) -> bool:
        n = len(self._outcomes)
        if n < self.min_samples:
            return False
        errs = sum(1 for ok in self._outcomes if not ok)
        return (errs / n) >= self.error_threshold

    def snapshot(self) -> dict:
        n = len(self._outcomes)
        errs = sum(1 for ok in self._outcomes if not ok)
        return {"state": self._state, "samples": n, "errors": errs,
                "error_rate": (errs / n) if n else 0.0,
                "cooldown_remaining_s": (
                    max(0.0, self.cooldown_s
                        - (self._clock() - self._opened_at))
                    if self._state == OPEN else 0.0)}
