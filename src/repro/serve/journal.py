"""Crash-safe request journal for the serving layer (DESIGN.md §21).

An append-only WAL (``checkpoint.wal``) of three record kinds:

- ``admit`` — a request cleared admission control: id plus a lossless
  encoding of the whole :class:`~repro.serve.service.SolveRequest`
  (inputs as base64 array records, config/options via ``serve.codec``).
- ``bucket`` — a coalesced bucket dispatched: its lane bucket key and
  the member request ids *in dispatch order* (the order fixes
  ``solve_many``'s internal re-plan, hence the per-bucket checkpoint
  directory a restart resumes from).
- ``done`` — a request reached a terminal state; replay skips it.

:func:`RequestJournal.replay` folds the log into the work a restarted
service owes: still-pending requests and the bucket grouping of any
that were already dispatched together.  Torn/corrupt tail lines are
skipped, not fatal — the WAL reader's contract.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from pathlib import Path
from typing import Dict, List, Optional, Tuple

from repro.checkpoint.wal import WriteAheadLog
from repro.serve import codec

JOURNAL_FILE = "requests.wal"


@dataclass
class ReplayPlan:
    """What a restarted service owes: ``pending`` maps request id to
    its reconstructed request (admission order preserved by dict
    insertion); ``buckets`` lists ``(bucket_key, [ids...])`` groups
    whose members are ALL still pending — they must re-dispatch
    together, in order, to land on the same per-bucket checkpoints."""
    pending: Dict[str, "object"] = field(default_factory=dict)
    buckets: List[Tuple[str, List[str]]] = field(default_factory=list)
    skipped_lines: int = 0
    done: int = 0


class RequestJournal:
    def __init__(self, directory, *, fsync: bool = False):
        self.directory = Path(directory)
        self._wal = WriteAheadLog(self.directory / JOURNAL_FILE,
                                  fsync=fsync)

    # -------------------------------------------------------- appends
    def admit(self, request_id: str, request) -> None:
        self._wal.append({
            "kind": "admit", "id": request_id,
            "problem": request.problem,
            "inputs": [codec.encode_array(x) for x in request.inputs],
            "cfg": codec.encode_config(request.cfg),
            "options": codec.encode_options(request.options),
            "chaos": request.chaos_spec,
            "deadline_s": request.deadline_s})

    def bucket(self, bucket_key: str, request_ids: List[str]) -> None:
        self._wal.append({"kind": "bucket", "key": bucket_key,
                          "ids": list(request_ids)})

    def done(self, request_id: str, status: str) -> None:
        self._wal.append({"kind": "done", "id": request_id,
                          "status": status})

    def close(self) -> None:
        self._wal.close()

    # --------------------------------------------------------- replay
    @staticmethod
    def replay(directory) -> ReplayPlan:
        from repro.serve.service import SolveRequest
        records, skipped = WriteAheadLog.read(
            Path(directory) / JOURNAL_FILE)
        plan = ReplayPlan(skipped_lines=skipped)
        admits: Dict[str, dict] = {}
        buckets: Dict[str, Tuple[str, List[str]]] = {}
        finished: set = set()
        for r in records:
            kind = r.get("kind")
            if kind == "admit":
                admits[r["id"]] = r
            elif kind == "bucket":
                for rid in r["ids"]:
                    buckets[rid] = (r["key"], list(r["ids"]))
            elif kind == "done":
                finished.add(r["id"])
        plan.done = len(finished)
        for rid, r in admits.items():
            if rid in finished:
                continue
            plan.pending[rid] = SolveRequest(
                problem=r["problem"],
                inputs=codec.decode_inputs(r["inputs"]),
                cfg=codec.decode_config(r["problem"], r.get("cfg")),
                options=codec.decode_options(r.get("options")),
                chaos_spec=r.get("chaos"),
                deadline_s=r.get("deadline_s"))
        # a dispatched bucket only re-dispatches as a group when every
        # member is still owed — a partially-finished bucket's survivors
        # re-enter coalescing like fresh traffic
        seen: set = set()
        for rid in plan.pending:
            grp = buckets.get(rid)
            if grp is None or grp[0] in seen:
                continue
            key, ids = grp
            if len(ids) >= 2 and all(i in plan.pending for i in ids):
                plan.buckets.append((key, ids))
                seen.add(key)
        return plan


def journal_pending(directory) -> Optional[ReplayPlan]:
    """Replay helper tolerant of a missing journal (cold start)."""
    path = Path(directory) / JOURNAL_FILE
    if not path.exists():
        return None
    return RequestJournal.replay(directory)
