"""repro.serve — async batched solve-as-a-service frontend (DESIGN.md §20).

The serving layer the paper's architecture implies: an asyncio core
(:class:`AsyncSolveService`) that admits, coalesces and batches solve
requests onto :func:`repro.core.problem.solve_many`, plus a stdlib-only
JSON-over-HTTP transport (``serve.server``) and client (``serve.client``).

    from repro.serve import AsyncSolveService, ServeConfig, SolveRequest
    from repro.serve.server import serve_http, ServiceRunner
    from repro.serve.client import ServeClient
"""
from repro.serve.metrics import Metrics
from repro.serve.service import (AsyncSolveService, RequestRecord,
                                 RequestRejected, ServeConfig,
                                 SolveRequest)

__all__ = [
    "AsyncSolveService", "Metrics", "RequestRecord", "RequestRejected",
    "ServeConfig", "SolveRequest",
]
