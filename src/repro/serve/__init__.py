"""repro.serve — async batched solve-as-a-service frontend (DESIGN.md
§20, resilience §21).

The serving layer the paper's architecture implies: an asyncio core
(:class:`AsyncSolveService`) that admits, coalesces and batches solve
requests onto :func:`repro.core.problem.solve_many` — with poison-bucket
quarantine, per-request deadlines, breaker-based load shedding and a
crash-safe request journal — plus a stdlib-only JSON-over-HTTP
transport (``serve.server``) and client (``serve.client``).

    from repro.serve import AsyncSolveService, ServeConfig, SolveRequest
    from repro.serve.server import serve_http, ServiceRunner
    from repro.serve.client import ServeClient
"""
from repro.serve.breaker import CircuitBreaker
from repro.serve.journal import RequestJournal, ReplayPlan, \
    journal_pending
from repro.serve.metrics import Metrics
from repro.serve.service import (AsyncSolveService, RequestRecord,
                                 RequestRejected, ServeConfig,
                                 SolveRequest)

__all__ = [
    "AsyncSolveService", "CircuitBreaker", "Metrics", "ReplayPlan",
    "RequestJournal", "RequestRecord", "RequestRejected", "ServeConfig",
    "SolveRequest", "journal_pending",
]
