"""AsyncSolveService: the framework-agnostic serving core (DESIGN.md §20).

The paper's architecture *serves* imaging workloads; this module is the
traffic side of that claim.  One asyncio event loop owns all scheduling
state (no locks on the hot path); actual solves run on a small worker
executor so the loop stays responsive:

- **submit** — admission control first: a draining service or a full
  queue rejects with a *retriable* status (the client's signal to back
  off or go elsewhere), everything else is enqueued for coalescing.
- **micro-batch scheduler** — requests are grouped by a compatibility
  key (workload + config fingerprint + run-option fingerprint) and then
  offered to an incremental :class:`~repro.core.batching.OpenBucketPlanner`
  (same static-signature grouping and waste-budget rule as the offline
  ``solve_many`` planner).  The first request into an open bucket arms a
  deadline timer (``batch_window_s``); the bucket dispatches when the
  window expires, when it reaches ``max_batch`` occupancy, or when a
  drain flushes it — whichever comes first.
- **dispatch** — a closed bucket runs as ONE ``solve_many`` call (a
  single-member bucket takes the plain ``solve`` path) on the executor,
  with per-request ``RunOptions`` — including ``resilience=`` — passed
  straight through.  The driver's ``progress_fn`` chunk events are
  relayed onto the loop and fanned out per request, so clients can
  stream per-chunk progress while the batch runs.
- **drain** — stop admitting, *reject* still-queued requests with the
  retriable status, let in-flight batches finish.  ``close()`` drains
  and tears down the executor.

A request carrying ``chaos_spec`` (the §18 fault-injection drill)
always dispatches as its own singleton batch: chaos activation is
process-global, so an injected fault must never share a dispatch with
paying traffic.
"""
from __future__ import annotations

import asyncio
import itertools
import time
import uuid
from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

from repro.core import batching
from repro.core.problem import Solution, _as_problem, \
    _config_fingerprint, solve, solve_many
from repro.serve.metrics import Metrics

#: terminal request states — once here, a record never changes again
TERMINAL = ("done", "failed", "cancelled", "rejected")
#: every state a record can be in
STATES = ("queued", "running") + TERMINAL


@dataclass(frozen=True)
class ServeConfig:
    """Service-level knobs (per-request solver knobs ride each
    :class:`SolveRequest` instead).

    - ``max_queue`` — admission-control cap on queued+running requests;
      beyond it, submits are rejected retriable (closed-loop clients
      back off, the paper's Spark analogue would spill to another
      executor).
    - ``batch_window_s`` — coalescing deadline: how long the first
      request in an open bucket waits for compatible companions before
      the bucket dispatches anyway.  0 disables coalescing (every
      request dispatches solo — the serialized baseline of
      ``benchmarks/bench_serve``).
    - ``max_batch`` — occupancy that dispatches an open bucket early.
    - ``workers`` — executor threads running solves.  The default of 1
      serializes device work (one process-wide accelerator); >1 only
      helps when solves block on I/O or separate devices.
    - ``waste_budget`` — open-bucket padding budget (see
      ``core.batching``); serving defaults looser than ``solve_many``'s
      0.25 because coalescing wins usually beat padding waste.
    """
    max_queue: int = 256
    batch_window_s: float = 0.05
    max_batch: int = 32
    workers: int = 1
    waste_budget: float = 0.5
    history_window: int = 2048


@dataclass(frozen=True)
class SolveRequest:
    """One client request: exactly the arguments of a ``solve()`` call.

    ``options`` holds run-control overrides (``max_iter``, ``tol``,
    ``chunk``, ``cost_every``, ``resilience=ResilienceConfig(...)``,
    ...); step wiring is always derived from the Problem declaration.
    ``chaos_spec`` arms the §18 fault-injection harness for this request
    only (dispatched solo, see module docstring).
    """
    problem: str
    inputs: Tuple[Any, ...]
    cfg: Any = None
    options: Dict[str, Any] = field(default_factory=dict)
    chaos_spec: Optional[str] = None


@dataclass
class RequestRecord:
    """Mutable server-side state of one request.

    Written by the service loop and (status/timestamps/result fields)
    by the executor worker running its batch; read by transports.
    ``retriable`` is only meaningful with status ``"rejected"``: the
    request never ran and can be resubmitted verbatim.
    """
    id: str
    request: SolveRequest
    status: str = "queued"
    retriable: bool = False
    error: Optional[str] = None
    solution: Optional[Solution] = None
    submitted_at: float = 0.0
    started_at: Optional[float] = None
    finished_at: Optional[float] = None
    batch_size: int = 0
    bucket_key: Optional[str] = None
    events: List[dict] = field(default_factory=list)
    # loop-side plumbing (not part of the public record)
    done: asyncio.Event = field(default_factory=asyncio.Event, repr=False)
    _waiters: List[asyncio.Future] = field(default_factory=list,
                                           repr=False)
    _token: Optional[int] = field(default=None, repr=False)
    _open: Optional[batching.OpenBucket] = field(default=None, repr=False)
    _lane: Optional["_Lane"] = field(default=None, repr=False)

    @property
    def latency_s(self) -> Optional[float]:
        if self.finished_at is None:
            return None
        return self.finished_at - self.submitted_at

    def public(self) -> dict:
        """JSON-ready status view (no arrays, no Solution)."""
        return {
            "id": self.id, "status": self.status,
            "retriable": self.retriable, "error": self.error,
            "problem": self.request.problem,
            "batch_size": self.batch_size,
            "bucket_key": self.bucket_key,
            "submitted_at": self.submitted_at,
            "started_at": self.started_at,
            "finished_at": self.finished_at,
            "latency_s": self.latency_s,
            "n_events": len(self.events),
        }


class _Lane:
    """All open buckets of one compatibility key (workload + config +
    run options): requests only coalesce within a lane."""

    def __init__(self, key: str, problem, axes: batching.BatchAxes,
                 planner: batching.OpenBucketPlanner):
        self.key = key
        self.problem = problem          # prototype Problem instance
        self.axes = axes
        self.planner = planner
        # open bucket -> (records in admission order, deadline timer)
        self.pending: Dict[int, Tuple[batching.OpenBucket,
                                      List[RequestRecord], Any]] = {}


class RequestRejected(RuntimeError):
    """Raised by :meth:`AsyncSolveService.submit` at admission time.
    ``retriable`` mirrors the record's flag: the request never ran."""

    def __init__(self, msg: str, record: RequestRecord):
        super().__init__(msg)
        self.record = record
        self.retriable = record.retriable


class AsyncSolveService:
    """The asyncio serving core.  All public coroutines must run on the
    loop that called :meth:`start`; transports on other threads bridge
    via ``asyncio.run_coroutine_threadsafe`` (see ``serve.server``)."""

    def __init__(self, config: Optional[ServeConfig] = None, *,
                 mesh=None):
        self.cfg = config or ServeConfig()
        self.mesh = mesh
        self.metrics = Metrics(window=self.cfg.history_window)
        self.records: Dict[str, RequestRecord] = {}
        self._lanes: Dict[str, _Lane] = {}
        self._inflight: Dict[int, asyncio.Future] = {}
        self._draining = False
        self._closed = False
        self._loop: Optional[asyncio.AbstractEventLoop] = None
        self._executor = ThreadPoolExecutor(
            max_workers=max(int(self.cfg.workers), 1),
            thread_name_prefix="repro-serve")
        self._tokens = itertools.count()

    # ----------------------------------------------------------- setup
    async def start(self) -> "AsyncSolveService":
        self._loop = asyncio.get_running_loop()
        return self

    async def __aenter__(self) -> "AsyncSolveService":
        return await self.start()

    async def __aexit__(self, *exc) -> None:
        await self.close()

    @property
    def draining(self) -> bool:
        return self._draining

    # ------------------------------------------------------- admission
    async def submit(self, request: SolveRequest) -> RequestRecord:
        """Admit one request: returns its (live) record, or raises
        :class:`RequestRejected` — with ``retriable=True`` when the
        refusal is load/drain-shaped rather than malformed input."""
        assert self._loop is not None, \
            "AsyncSolveService.submit before start()"
        self.metrics.incr("submitted")
        rec = RequestRecord(id=uuid.uuid4().hex[:12], request=request,
                            submitted_at=time.time())
        if self._draining or self._closed:
            return self._reject(rec, "service is draining",
                                retriable=True)
        depth = self.metrics.queue_depth
        if depth >= self.cfg.max_queue:
            return self._reject(
                rec, f"queue full ({depth} >= max_queue="
                     f"{self.cfg.max_queue})", retriable=True)
        # malformed requests fail loudly at admission, not in the batch:
        # building the prototype Problem validates workload key + config
        try:
            problem = _as_problem(request.problem, request.cfg)
            lane_key = self._lane_key(problem, request)
        except Exception as e:
            rec.error = f"{type(e).__name__}: {e}"
            return self._reject(rec, rec.error, retriable=False)
        self.records[rec.id] = rec
        self.metrics.incr("accepted")
        self.metrics.queue_delta(+1)
        if request.chaos_spec or self.cfg.batch_window_s <= 0 \
                or self.cfg.max_batch <= 1:
            self._dispatch([rec], problem, bucket_key=None)
            return rec
        self._enqueue(rec, problem, lane_key)
        return rec

    def _reject(self, rec: RequestRecord, why: str,
                *, retriable: bool) -> RequestRecord:
        rec.status = "rejected"
        rec.retriable = retriable
        rec.error = rec.error or why
        rec.finished_at = time.time()
        rec.done.set()
        self.metrics.incr("rejected")
        self.records[rec.id] = rec
        raise RequestRejected(why, rec)

    def _lane_key(self, problem, request: SolveRequest) -> str:
        """Compatibility key: requests coalesce only when the same
        Problem (by config fingerprint) runs under the same run options
        — one ``RunOptions`` drives a whole ``solve_many`` call."""
        opts = ";".join(f"{k}={request.options[k]!r}"
                        for k in sorted(request.options))
        return (f"{request.problem}|{_config_fingerprint(problem)}|"
                f"{opts}")

    # ------------------------------------------------------ scheduling
    def _enqueue(self, rec: RequestRecord, problem, lane_key: str) -> None:
        lane = self._lanes.get(lane_key)
        if lane is None:
            axes = problem.batch_axes()
            salt = f"{lane_key}"
            lane = _Lane(lane_key, problem, axes,
                         batching.OpenBucketPlanner(
                             axes, waste_budget=self.cfg.waste_budget,
                             salt=salt, max_members=self.cfg.max_batch))
            self._lanes[lane_key] = lane
        token = next(self._tokens)
        bucket = lane.planner.offer(token, rec.request.inputs)
        rec._token, rec._open, rec._lane = token, bucket, lane
        entry = lane.pending.get(id(bucket))
        if entry is None:
            # first member arms the coalescing deadline
            timer = self._loop.call_later(
                self.cfg.batch_window_s, self._flush_bucket, lane,
                id(bucket))
            lane.pending[id(bucket)] = (bucket, [rec], timer)
        else:
            entry[1].append(rec)
        if len(bucket) >= self.cfg.max_batch:
            self._flush_bucket(lane, id(bucket))

    def _flush_bucket(self, lane: _Lane, bucket_id: int) -> None:
        entry = lane.pending.pop(bucket_id, None)
        if entry is None:
            return                       # already flushed or cancelled
        bucket, recs, timer = entry
        timer.cancel()
        closed = lane.planner.close(bucket)
        # solve_many receives instances in bucket order; map each back
        token_to_rec = {r._token: r for r in recs}
        ordered = [token_to_rec[t] for t in closed.indices]
        for r in ordered:
            r._open = r._lane = None
            r.bucket_key = closed.key
        self._dispatch(ordered, lane.problem, bucket_key=closed.key)

    def _dispatch(self, recs: List[RequestRecord], problem,
                  *, bucket_key: Optional[str]) -> None:
        for r in recs:
            r.batch_size = len(recs)
        self.metrics.record_batch(len(recs))
        fut = self._loop.run_in_executor(
            self._executor, self._run_batch, recs, problem)
        key = id(fut)
        self._inflight[key] = fut
        fut.add_done_callback(
            lambda f, _recs=recs: self._on_batch_done(key, _recs, f))

    # -------------------------------------------------- executor side
    def _run_batch(self, recs: List[RequestRecord], problem) -> None:
        """Runs on a worker thread: one solve()/solve_many() for the
        whole batch, progress relayed to the loop per request."""
        loop = self._loop
        now = time.time()
        for r in recs:
            r.status = "running"
            r.started_at = now

        if len(recs) == 1:
            rec = recs[0]

            def relay_single(event, _rec=rec):
                loop.call_soon_threadsafe(self._push_event, _rec, event)

            sols = [self._solve_one(rec, problem, relay_single)]
        else:
            def relay_batch(event):
                base = {k: v for k, v in event.items()
                        if k != "instances"}
                for j, st in event.get("instances", {}).items():
                    loop.call_soon_threadsafe(
                        self._push_event, recs[j], {**base, **st})

            opts = dict(recs[0].request.options)
            sols = solve_many(
                problem, [r.request.inputs for r in recs],
                mesh=self.mesh, waste_budget=self.cfg.waste_budget,
                progress_fn=relay_batch, **opts)
        for r, s in zip(recs, sols):
            r.solution = s

    def _solve_one(self, rec: RequestRecord, problem, relay) -> Solution:
        from repro.resilience import chaos
        opts = dict(rec.request.options)
        spec = rec.request.chaos_spec
        ctx = chaos.active_chaos(chaos.ChaosConfig.parse(spec)) \
            if spec else None
        if ctx is None:
            return solve(problem, *rec.request.inputs, mesh=self.mesh,
                         progress_fn=relay, **opts)
        with ctx:
            return solve(problem, *rec.request.inputs, mesh=self.mesh,
                         progress_fn=relay, **opts)

    # ------------------------------------------------------- loop side
    def _push_event(self, rec: RequestRecord, event: dict) -> None:
        if rec.status in TERMINAL:
            return
        rec.events.append(event)
        self._wake_waiters(rec)

    def _wake_waiters(self, rec: RequestRecord) -> None:
        for w in rec._waiters:
            if not w.done():
                w.set_result(None)
        rec._waiters.clear()

    def _on_batch_done(self, key: int, recs: List[RequestRecord],
                       fut) -> None:
        self._inflight.pop(key, None)
        err = fut.exception()
        now = time.time()
        for r in recs:
            if r.status in TERMINAL:
                continue
            r.finished_at = now
            if err is not None:
                r.status = "failed"
                r.error = f"{type(err).__name__}: {err}"
                self.metrics.incr("failed")
            else:
                r.status = "done"
                self.metrics.incr("completed")
                self.metrics.record_latency(r.latency_s)
            self.metrics.queue_delta(-1)
            r.done.set()
            self._wake_waiters(r)

    # --------------------------------------------------------- queries
    def record(self, request_id: str) -> RequestRecord:
        try:
            return self.records[request_id]
        except KeyError:
            raise KeyError(f"unknown request id {request_id!r}") from None

    async def result(self, request_id: str,
                     timeout: Optional[float] = None) -> RequestRecord:
        """Wait for a terminal state and return the record."""
        rec = self.record(request_id)
        await asyncio.wait_for(rec.done.wait(), timeout)
        return rec

    async def wait_events(self, request_id: str, cursor: int = 0,
                          timeout: float = 1.0
                          ) -> Tuple[List[dict], bool, int]:
        """Long-poll progress: events past ``cursor`` (possibly empty on
        timeout), whether the request is terminal, and the new cursor.
        This is the transport-friendly streaming primitive — the HTTP
        endpoint loops it and writes JSON lines."""
        rec = self.record(request_id)
        if cursor >= len(rec.events) and not rec.done.is_set():
            waiter = self._loop.create_future()
            rec._waiters.append(waiter)
            try:
                await asyncio.wait_for(waiter, timeout)
            except asyncio.TimeoutError:
                pass
            finally:
                if waiter in rec._waiters:
                    rec._waiters.remove(waiter)
        events = rec.events[cursor:]
        return events, rec.done.is_set(), cursor + len(events)

    async def cancel(self, request_id: str) -> bool:
        """Cancel a *queued* request (still coalescing).  A running or
        terminal request is not cancellable — dispatched work is shared
        with the rest of its batch."""
        rec = self.record(request_id)
        if rec.status != "queued" or rec._open is None:
            return False
        lane = rec._lane
        lane.planner.discard(rec._open, rec._token)
        entry = lane.pending.get(id(rec._open))
        if entry is not None:
            _, recs, timer = entry
            recs.remove(rec)
            if not recs:
                timer.cancel()
                lane.pending.pop(id(rec._open), None)
        rec._open = rec._lane = None
        rec.status = "cancelled"
        rec.finished_at = time.time()
        rec.done.set()
        self.metrics.incr("cancelled")
        self.metrics.queue_delta(-1)
        self._wake_waiters(rec)
        return True

    # ----------------------------------------------------------- drain
    async def drain(self) -> dict:
        """Graceful shutdown of traffic: stop admitting, reject every
        still-queued request with the retriable status, and wait for
        in-flight batches to finish.  Returns a summary dict."""
        self._draining = True
        rejected = 0
        for lane in self._lanes.values():
            for bucket, recs, timer in list(lane.pending.values()):
                timer.cancel()
                for rec in recs:
                    lane.planner.discard(bucket, rec._token)
                    rec._open = rec._lane = None
                    rec.status = "rejected"
                    rec.retriable = True
                    rec.error = "service drained before dispatch"
                    rec.finished_at = time.time()
                    rec.done.set()
                    self.metrics.incr("rejected")
                    self.metrics.queue_delta(-1)
                    self._wake_waiters(rec)
                    rejected += 1
            lane.pending.clear()
        inflight = list(self._inflight.values())
        if inflight:
            await asyncio.gather(*inflight, return_exceptions=True)
        return {"rejected_queued": rejected,
                "finished_inflight": len(inflight)}

    async def close(self) -> None:
        """Drain, then tear down the worker executor."""
        if not self._closed:
            await self.drain()
            self._closed = True
            self._executor.shutdown(wait=True)
